"""Shared topology layer — ring/subgroup/hierarchical chain geometry.

Single source of truth for successor maps, ppermute schedules, initiator
election and alive-bitmap compaction, consumed by BOTH planes:

  * device data plane — ``core/chain.py`` builds its ppermute pairs,
    neighbour keys and initiator election from these objects inside
    shard_map;
  * discrete-event control plane — ``core/protocol.py`` derives learner
    successor/initiator decisions from the same objects.

See ARCHITECTURE.md for the two-plane picture.
"""
from repro.topology.base import (
    MIN_PRIVACY_GROUP,
    RingTopology,
    elect_initiator_local,
    make_topology,
)
from repro.topology.hierarchy import HierarchicalTopology
from repro.topology.failover import AliveTracker

__all__ = [
    "MIN_PRIVACY_GROUP",
    "RingTopology",
    "HierarchicalTopology",
    "AliveTracker",
    "elect_initiator_local",
    "make_topology",
]
