"""Host control-plane liveness: the alive bitmap the data plane consumes.

This is the module ``core/chain.py``'s original docstring promised as
``core/failover.py``: between aggregation rounds the host decides which
learners participate, and hands the device plane a replicated f32[n]
bitmap — dead ranks forward-and-repad without contributing, and the
published mean divides by popcount(alive) (§5.3). Within a round the
*protocol* handles failures (progress monitor reposts, §5.4 initiator
re-election); across rounds this tracker persists those verdicts so the
next round's chain is compacted up front instead of re-discovering every
death by timeout.

``report_failure`` / ``report_recovery`` are the integration points: the
serve engine calls them from its host loop, the sim from monitor events.
A learner is also declared dead after ``max_strikes`` consecutive missed
heartbeats (``tick`` advances the clock).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.topology.base import MIN_PRIVACY_GROUP, RingTopology


@dataclasses.dataclass
class AliveTracker:
    """Per-learner liveness with strike-based failure declaration.

    Attributes:
      topology: ring geometry (for compaction and privacy checks).
      max_strikes: consecutive missed heartbeats before a rank is
        declared dead (1 = declare on first report).
    """

    topology: RingTopology
    max_strikes: int = 1

    def __post_init__(self) -> None:
        n = self.topology.num_learners
        self._strikes = np.zeros((n,), np.int32)
        self._dead = np.zeros((n,), bool)

    # ---- verdict inputs --------------------------------------------------
    def report_failure(self, rank: int) -> None:
        """One missed heartbeat / failed posting for ``rank``."""
        self._strikes[rank] += 1
        if self._strikes[rank] >= self.max_strikes:
            self._dead[rank] = True

    def report_recovery(self, rank: int) -> None:
        """Rank rejoined (the paper's nodes re-register between rounds)."""
        self._strikes[rank] = 0
        self._dead[rank] = False

    def tick(self, heartbeats: Optional[np.ndarray] = None) -> None:
        """Advance one monitoring interval. ``heartbeats`` is bool[n]
        (True = seen this interval); absent ranks accrue a strike."""
        if heartbeats is None:
            return
        hb = np.asarray(heartbeats, bool)
        self._strikes[hb] = 0
        self._dead[hb] = False
        for r in np.nonzero(~hb)[0]:
            self.report_failure(int(r))

    # ---- data-plane outputs ---------------------------------------------
    def alive(self) -> np.ndarray:
        """f32[n] bitmap for the device plane (replicated across ranks)."""
        return (~self._dead).astype(np.float32)

    def survivors(self) -> int:
        return int((~self._dead).sum())

    def compact_chains(self, node_base: int = 0) -> Dict[int, List[int]]:
        """Per-group chain order with dead ranks removed (§5.3)."""
        return self.topology.compact(self.alive(), node_base)

    def elect_initiators(self, rotate: int = 0) -> List[int]:
        """Initiator rank per group for the next round (§5.4 + §8)."""
        return self.topology.elect_initiators(self.alive(), rotate)

    def degraded_groups(self) -> List[int]:
        """Groups that dropped below the >= 3 alive-member privacy bound —
        the host should merge or pause them rather than run the round."""
        out = []
        alive = self.alive()
        for g in range(self.topology.subgroups):
            if self.topology.group_alive(alive, g).sum() < MIN_PRIVACY_GROUP:
                out.append(g)
        return out
