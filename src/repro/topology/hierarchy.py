"""Hierarchical federation topology (paper §5.10).

``HierarchicalTopology`` = P identical pods, each running its own
RingTopology (intra-pod chains + subgroup rings), with the pod level a
plain average of pod results: child controllers post their anonymized
group averages to the parent, which never needs encryption because every
posted value is already a mean over >= 3 learners.

Device plane: the pod level is a second mesh axis (``cfg.pod_axis``) and
the cross-pod average a ``pmean`` over it — the per-pod ring geometry is
exactly ``self.pod``'s. Sim plane: one Controller per pod (the existing
``HierarchicalController`` collects them).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.topology.base import RingTopology


@dataclasses.dataclass(frozen=True)
class HierarchicalTopology:
    """P pods × one RingTopology per pod.

    Global rank layout is pod-major: global rank = pod * n + local rank,
    matching a ("pod", "data") mesh flattened in C order.
    """

    pods: int
    pod: RingTopology

    def __post_init__(self) -> None:
        if self.pods < 1:
            raise ValueError("pods must be >= 1")

    @property
    def num_learners(self) -> int:
        """Total learners across all pods."""
        return self.pods * self.pod.num_learners

    @property
    def subgroups(self) -> int:
        return self.pod.subgroups

    @property
    def group_size(self) -> int:
        return self.pod.group_size

    def validate_privacy(self) -> None:
        self.pod.validate_privacy()

    # ---- global-rank geometry (delegates to the pod ring) ----------------
    def pod_of(self, rank):
        return rank // self.pod.num_learners

    def pod_local(self, rank):
        return rank % self.pod.num_learners

    def successor(self, rank):
        base = self.pod_of(rank) * self.pod.num_learners
        return base + self.pod.successor(self.pod_local(rank))

    def predecessor(self, rank):
        base = self.pod_of(rank) * self.pod.num_learners
        return base + self.pod.predecessor(self.pod_local(rank))

    def successor_map(self) -> np.ndarray:
        return np.array([self.successor(r) for r in range(self.num_learners)],
                        np.int32)

    def group_chains(self, node_base: int = 0) -> Dict[int, Dict[int, List[int]]]:
        """{pod: {group: [node ids]}} — per-pod chain orders. Node ids are
        global (pod-major) plus ``node_base``."""
        n = self.pod.num_learners
        return {
            p: {
                g: [p * n + node for node in chain]
                for g, chain in self.pod.group_chains(node_base).items()
            }
            for p in range(self.pods)
        }

    def elect_initiators(self, alive: Optional[Sequence] = None,
                         rotate: int = 0) -> Dict[int, List[int]]:
        """{pod: [initiator global rank per group]}."""
        n = self.pod.num_learners
        if alive is None:
            alive = np.ones((self.num_learners,), np.float32)
        alive = np.asarray(alive, np.float32)
        return {
            p: [p * n + r
                for r in self.pod.elect_initiators(alive[p * n:(p + 1) * n],
                                                   rotate)]
            for p in range(self.pods)
        }
