"""Ring topologies — the single source of truth for SAFE chain shape.

One ``RingTopology`` object answers every structural question both planes
ask: successor/predecessor on the (sub)group ring (paper §5.5), the
``ppermute`` pair list for the device plane, per-group chain orders for
the discrete-event sim, and initiator election over an alive bitmap
(§5.4 re-election + §8 per-round rotation). The arithmetic is written
with plain operators so the *same* methods work on python ints (the sim,
host control plane) and on traced jax values (inside shard_map) — sim
and device cannot diverge on topology semantics because they execute the
same code.

Ranks are 0-based and contiguous: group g owns ranks
[g·m, (g+1)·m) where m = group_size. The sim's 1-based paper numbering
is a ``node_base`` offset applied at the edge (``group_chains``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

#: minimum learners per ring for the paper's privacy argument (§5.3/§5.5):
#: with 2, each member recovers the other's value by subtracting its own.
MIN_PRIVACY_GROUP = 3


def elect_initiator_local(group_alive, rotate, xp=np):
    """Local index of the elected initiator on one subgroup ring.

    The initiator is the first *alive* local index scanning cyclically
    from the per-round rotation offset (§5.4 re-election semantics + §8
    round-order randomization). ``xp`` is the array namespace — numpy for
    the host/sim plane, jax.numpy for the device plane — so both planes
    run the identical formula.

    Args:
      group_alive: float/bool[m] liveness of this ring's members, local
        order.
      rotate: int — per-round rotation offset (taken mod m).
      xp: numpy or jax.numpy.

    Returns:
      int (or traced scalar) local index in [0, m).
    """
    m = group_alive.shape[-1]
    rot = xp.asarray(rotate, xp.int32) % m
    rolled = xp.roll(group_alive, -rot)
    return (xp.argmax(rolled > 0).astype(xp.int32) + rot) % m


@dataclasses.dataclass(frozen=True)
class RingTopology:
    """g disjoint rings over one learner axis (g = 1 is the flat chain).

    Attributes:
      num_learners: chain length n (== mesh axis size on device).
      subgroups: number of parallel rings g (paper §5.5). Must divide
        num_learners.
    """

    num_learners: int
    subgroups: int = 1

    def __post_init__(self) -> None:
        if self.subgroups < 1 or self.num_learners % self.subgroups != 0:
            raise ValueError(
                f"subgroups ({self.subgroups}) must divide num_learners "
                f"({self.num_learners})")

    # ---- structure -------------------------------------------------------
    @property
    def group_size(self) -> int:
        return self.num_learners // self.subgroups

    def validate_privacy(self) -> None:
        """Raise unless every ring meets the >= 3-member privacy bound."""
        if self.group_size < MIN_PRIVACY_GROUP:
            raise ValueError(
                f"each ring needs >= {MIN_PRIVACY_GROUP} members for the "
                f"privacy guarantee (got group_size={self.group_size}; "
                "paper §5.3/§5.5)")

    # ---- per-rank ring geometry (int or traced) --------------------------
    def group_of(self, rank):
        return rank // self.group_size

    def group_start(self, rank):
        m = self.group_size
        return (rank // m) * m

    def local_index(self, rank):
        return rank % self.group_size

    def successor(self, rank):
        """Next rank on this rank's ring (the node it posts aggregates to)."""
        m = self.group_size
        g0 = self.group_start(rank)
        return g0 + (rank - g0 + 1) % m

    def predecessor(self, rank):
        m = self.group_size
        g0 = self.group_start(rank)
        return g0 + (rank - g0 + m - 1) % m

    def neighbors(self, rank):
        """(predecessor, successor) on this rank's ring."""
        return self.predecessor(rank), self.successor(rank)

    # ---- whole-topology views -------------------------------------------
    def ring_permutation(self) -> List[Tuple[int, int]]:
        """(src, dst) pairs for a +1 ring shift — the device plane's
        ``jax.lax.ppermute`` schedule."""
        return [(r, self.successor(r)) for r in range(self.num_learners)]

    def successor_map(self) -> np.ndarray:
        """int32[n] — successor_map[r] is r's ring successor."""
        return np.array([self.successor(r) for r in range(self.num_learners)],
                        np.int32)

    def group_chains(self, node_base: int = 0) -> Dict[int, List[int]]:
        """Chain (ring) order per group, as node ids offset by
        ``node_base`` (the sim uses the paper's 1-based numbering)."""
        m = self.group_size
        return {
            g: [g * m + i + node_base for i in range(m)]
            for g in range(self.subgroups)
        }

    # ---- liveness / election --------------------------------------------
    def group_alive(self, alive, group: int):
        """Slice of the full alive bitmap covering ``group`` (host path;
        the device plane uses a dynamic_slice at the traced rank — see
        core/chain.py)."""
        m = self.group_size
        return alive[group * m:(group + 1) * m]

    def elect_initiators(self, alive: Optional[Sequence] = None,
                         rotate: int = 0) -> List[int]:
        """Elected initiator *rank* of every group (host plane).

        With all members alive and rotate=0 this is each group's first
        rank — the sim's round-start initiator. After failures it is the
        §5.4 re-elected initiator the device plane also converges on.
        """
        if alive is None:
            alive = np.ones((self.num_learners,), np.float32)
        alive = np.asarray(alive, np.float32)
        out = []
        for g in range(self.subgroups):
            ga = self.group_alive(alive, g)
            loc = int(elect_initiator_local(ga, rotate, xp=np))
            out.append(g * self.group_size + loc)
        return out

    def compact(self, alive: Optional[Sequence] = None,
                node_base: int = 0) -> Dict[int, List[int]]:
        """Alive-bitmap compaction: per-group chain order with dead
        members removed (dead ranks forward-and-repad on device; in the
        control plane the monitor's repost orders skip them — §5.3)."""
        if alive is None:
            alive = np.ones((self.num_learners,), np.float32)
        alive = np.asarray(alive, np.float32)
        chains = {}
        for g, chain in self.group_chains(node_base).items():
            chains[g] = [node for node in chain
                         if alive[node - node_base] > 0]
        return chains


def make_topology(num_learners: int, subgroups: int = 1,
                  pods: int = 1) -> "RingTopology":
    """Factory: flat chain, subgroup rings, or hierarchical pods.

    Returns a RingTopology for pods == 1, else a HierarchicalTopology
    (imported lazily to avoid a module cycle).
    """
    if pods <= 1:
        return RingTopology(num_learners, subgroups)
    from repro.topology.hierarchy import HierarchicalTopology
    return HierarchicalTopology(pods, RingTopology(num_learners, subgroups))
