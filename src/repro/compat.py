"""Version compatibility shims for the jax API surface this repo targets.

The codebase is written against the modern jax API (``jax.shard_map``,
``jax.set_mesh``, ``jax.make_mesh``). Older jax releases (< 0.6) ship the
same functionality under ``jax.experimental.shard_map`` and the ``Mesh``
context manager; this module installs thin aliases onto the ``jax``
module so every call site — src, tests, benchmarks, examples — runs
unmodified on both. Imported for its side effects from ``repro/__init__``
(and therefore by every entry point that touches repro).

The shims are strictly additive: on a modern jax none of the branches
fire and jax is untouched.
"""
from __future__ import annotations

import contextlib

import jax

if not hasattr(jax, "shard_map"):
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, mesh=None, in_specs=None, out_specs=None,
                  axis_names=None, check_vma=None, **_ignored):
        """Modern-signature wrapper over jax.experimental.shard_map.

        ``axis_names`` maps onto the old ``auto`` parameter (auto = mesh
        axes not named manual); ``check_vma`` has no pre-0.6 equivalent,
        so replication checking is disabled (the repo's out_specs already
        encode replication intent).
        """
        auto = frozenset()
        if axis_names is not None and hasattr(mesh, "axis_names"):
            auto = frozenset(mesh.axis_names) - frozenset(axis_names)
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False, auto=auto)

    jax.shard_map = shard_map

if not hasattr(jax, "set_mesh"):

    @contextlib.contextmanager
    def set_mesh(mesh):
        with mesh:
            yield mesh

    jax.set_mesh = set_mesh

if not hasattr(jax, "make_mesh"):
    import numpy as _np
    from jax.sharding import Mesh as _Mesh

    def make_mesh(axis_shapes, axis_names):
        devs = _np.asarray(jax.devices()[: int(_np.prod(axis_shapes))])
        return _Mesh(devs.reshape(axis_shapes), axis_names)

    jax.make_mesh = make_mesh
