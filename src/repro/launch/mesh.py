"""Production meshes (TPU v5e).

Single pod: 16×16 = 256 chips, axes (data, model) — 'data' is the
learner/chain axis (one SAFE learner per data rank), 'model' the
tensor-parallel axis.

Multi-pod: 2×16×16 = 512 chips, axes (pod, data, model) — 'pod' is the
hierarchical-federation axis (paper §5.10): intra-pod SAFE chains, then a
plain mean of the already-anonymized pod averages across pods.

Defined as functions so importing this module never touches device state
(dryrun.py must set XLA_FLAGS before the first jax call).
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{max(n, 512)} (dryrun.py sets this automatically)")
    # more devices than needed (e.g. 512 placeholders, single-pod mesh):
    # use the first n
    from jax.sharding import Mesh
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_test_mesh(data: int = 4, model: int = 2, pod: int = 1):
    """Small host-device mesh for tests/examples."""
    if pod > 1:
        from jax.sharding import Mesh
        devs = np.asarray(jax.devices()[: pod * data * model])
        return Mesh(devs.reshape(pod, data, model), ("pod", "data", "model"))
    from jax.sharding import Mesh
    devs = np.asarray(jax.devices()[: data * model])
    return Mesh(devs.reshape(data, model), ("data", "model"))
