"""Serving launcher: batched decode over a smoke-size model.

  python -m repro.launch.serve --arch qwen3-14b --requests 8 --max-new 32
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    import numpy as np
    from repro.configs import get_smoke_config
    from repro.models import Model
    from repro.serve import ServeEngine
    from repro.serve.engine import Request

    cfg = get_smoke_config(args.arch)
    model = Model(cfg)
    params = model.init(jax.random.key(args.seed))
    eng = ServeEngine(model, params, batch_slots=args.slots,
                      max_seq=args.max_seq, temperature=args.temperature,
                      seed=args.seed)
    rng = np.random.RandomState(args.seed)
    t0 = time.time()
    for i in range(args.requests):
        plen = int(rng.randint(4, 32))
        eng.submit(Request(rid=i, prompt=rng.randint(0, cfg.vocab, plen)
                           .astype(np.int32), max_new=args.max_new))
    eng.run_until_done()
    dt = time.time() - t0
    total_tokens = args.requests * args.max_new
    print(f"served {args.requests} requests / {total_tokens} tokens in "
          f"{dt:.2f}s ({total_tokens/dt:.1f} tok/s, {eng.steps} decode steps, "
          f"batch efficiency {total_tokens/max(eng.steps*args.slots,1):.2f})")


if __name__ == "__main__":
    main()
