"""ShapeDtypeStruct stand-ins for every (arch × input-shape) entry point.

No device allocation: these are the abstract arguments ``dryrun.py``
lowers against. Shardings are attached so GSPMD lowers the *production*
layout (params Megatron-TP over 'model', experts over 'data', batch over
'data' (+'pod'), long-context KV sequence-sharded).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.sharding import param_pspecs
from repro.models.transformer import Model
from repro.serve.engine import cache_pspecs

INPUT_SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}


def _sharded(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def _with_sharding(tree_abs, specs, mesh):
    return jax.tree.map(
        lambda x, s: _sharded(x.shape, x.dtype, mesh, s), tree_abs, specs)


def use_expert_parallel(cfg: ModelConfig) -> bool:
    """Giant MoEs shard experts over the learner axis (DESIGN.md §3)."""
    return cfg.uses_moe and cfg.moe is not None and cfg.moe.num_experts >= 64


def params_abstract(model: Model, mesh: Mesh):
    """Abstract params with production shardings attached."""
    abs_ = jax.eval_shape(model.init, jax.random.key(0))
    axes_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    specs = param_pspecs(model.cfg, abs_, axes_sizes)
    return _with_sharding(abs_, specs, mesh), specs


def token_shape(cfg: ModelConfig, batch: int, seq: int) -> tuple:
    if cfg.num_codebooks > 1:
        return (batch, seq, cfg.num_codebooks)
    return (batch, seq)


@dataclasses.dataclass
class DryrunSpec:
    """A lowerable entry point: fn + abstract args."""
    fn: Any
    args: tuple
    description: str


def train_spec(arch_cfg: ModelConfig, mesh: Mesh, shape: dict,
               aggregator_mode: str = "safe", pipelined: bool = False,
               subgroups: int = 1, chain_model_sharded: bool = False) -> DryrunSpec:
    """train_4k: the full SAFE train step (shard_map)."""
    from repro.core import make_aggregator
    from repro.train.train_step import make_train_step

    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = axes["data"]
    pods = axes.get("pod", 1)
    pod_axis = "pod" if "pod" in axes else None

    cfg = arch_cfg
    if use_expert_parallel(cfg):
        cfg = dataclasses.replace(cfg, ep_axis="data", ep_ranks=n)
    model = Model(cfg)

    if not chain_model_sharded:
        # The paper-faithful single full-vector chain needs ~4 bytes ×
        # sec_params transient per device; above ~12 GB it cannot exist on
        # a v5e (16 GB HBM) at all, so the giant archs take the
        # model-sharded chain as their *baseline* (16 parallel slice
        # chains — same schedule, same per-link bytes; noted in
        # EXPERIMENTS.md §Dry-run).
        from repro.train.flatten import partition_tree, is_expert_path, tree_size
        p_abs = jax.eval_shape(Model(cfg).init, jax.random.key(0))
        sec_abs, _ = partition_tree(p_abs, lambda p: not is_expert_path(p))
        if tree_size(sec_abs) * 4 > 12e9:
            chain_model_sharded = True

    agg = make_aggregator(aggregator_mode, n, axis="data",
                          pod_axis=pod_axis, pipelined=pipelined,
                          subgroups=subgroups)
    bundle = make_train_step(model, agg, mesh, pod_axis=pod_axis,
                             donate=True, chain_model_sharded=chain_model_sharded)

    params_abs_global = bundle.params_abs
    specs = param_pspecs(cfg, params_abs_global,
                         dict(zip(mesh.axis_names, mesh.devices.shape)))
    params_in = _with_sharding(params_abs_global, specs, mesh)

    B_l = shape["global_batch"] // (n * pods)
    assert B_l >= 1, "global batch too small for the mesh"
    flat_len = n if bundle.leafwise else bundle.padded_size
    flat = _sharded((flat_len,), jnp.float32, mesh, P("data"))
    fstep = jax.ShapeDtypeStruct((), jnp.int32)
    if bundle.leafwise:
        # tree AdamW state for the secure partition, Megatron-sharded
        from repro.train.flatten import partition_tree, is_expert_path
        sec_abs_t, _ = partition_tree(bundle.params_abs,
                                      lambda p: not is_expert_path(p))
        mv_specs = param_pspecs(cfg, sec_abs_t,
                                dict(zip(mesh.axis_names, mesh.devices.shape)))
        sec_state = type(bundle.sec_opt_abs)(
            step=jax.ShapeDtypeStruct((), jnp.int32),
            m=_with_sharding(bundle.sec_opt_abs.m, mv_specs, mesh),
            v=_with_sharding(bundle.sec_opt_abs.v, mv_specs, mesh),
        )
    else:
        sec_state = jax.ShapeDtypeStruct((), jnp.float32)
    if use_expert_parallel(cfg):
        from repro.optim.adamw import AdamW
        from repro.train.flatten import partition_tree, is_expert_path
        _, ep_abs = partition_tree(params_abs_global,
                                   lambda p: not is_expert_path(p))
        ep_opt = AdamW()
        ep_state_abs = jax.eval_shape(ep_opt.init, ep_abs)  # no allocation
        # m/v mirror the expert weight sharding (experts over 'data',
        # expert-ff over 'model') — replicating them over 'model' would
        # cost ~190 GB/device for llama4
        ep_specs_m = param_pspecs(cfg, ep_state_abs.m,
                                  dict(zip(mesh.axis_names,
                                           mesh.devices.shape)))
        ep_state = type(ep_state_abs)(
            step=jax.ShapeDtypeStruct((), jnp.int32),
            m=_with_sharding(ep_state_abs.m, ep_specs_m, mesh),
            v=_with_sharding(ep_state_abs.v, ep_specs_m, mesh),
        )
    else:
        ep_state = jax.ShapeDtypeStruct((), jnp.float32)

    batch_axes = ("pod", "data") if pod_axis else ("data",)
    toks = _sharded(token_shape(cfg, n * pods, shape["seq_len"])[:1] +
                    (B_l,) + token_shape(cfg, 1, shape["seq_len"])[1:],
                    jnp.int32, mesh, P(batch_axes))
    # token_shape(cfg, n*pods, seq)[:1] == (n*pods,)
    if cfg.prefix_embeds:
        prefix = _sharded((n * pods, B_l, cfg.prefix_embeds, cfg.d_model),
                          jnp.bfloat16, mesh, P(batch_axes))
    else:
        prefix = jax.ShapeDtypeStruct((1,), jnp.float32)
    weights = jax.ShapeDtypeStruct((n,), jnp.float32)
    counter = jax.ShapeDtypeStruct((), jnp.uint32)
    alive = jax.ShapeDtypeStruct((n,), jnp.float32)

    args = (params_in, flat, flat, flat, fstep, ep_state, sec_state, toks,
            prefix, weights, counter, alive)
    return DryrunSpec(fn=bundle.jit_fn, args=args,
                      description=f"train_step n={n} pods={pods} B_l={B_l} "
                                  f"agg={aggregator_mode}"
                                  f"{'+pipelined' if pipelined else ''}"
                                  f"{'+msharded' if chain_model_sharded else ''}"
                                  f"{f'+g{subgroups}' if subgroups > 1 else ''}")


def prefill_spec(arch_cfg: ModelConfig, mesh: Mesh, shape: dict) -> DryrunSpec:
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    pod_axis = "pod" if "pod" in axes else None
    batch_axes = ("pod", "data") if pod_axis else ("data",)
    n_batch_ranks = axes["data"] * axes.get("pod", 1)
    model = Model(arch_cfg)
    params_in, _ = params_abstract(model, mesh)
    B = shape["global_batch"]
    toks = _sharded(token_shape(arch_cfg, B, shape["seq_len"]), jnp.int32,
                    mesh, P(batch_axes))

    if use_expert_parallel(arch_cfg) and B % n_batch_ranks == 0:
        # giant MoEs: manual expert parallelism for prefill too — global
        # routing through a GSPMD gather would all-gather the token matrix
        # per layer (hundreds of GB/device); the manual a2a keeps tokens
        # rank-local (DESIGN.md §3)
        cfg_ep = dataclasses.replace(arch_cfg, ep_axis="data",
                                     ep_ranks=axes["data"])
        model_ep = Model(cfg_ep)
        from repro.train.flatten import is_expert_path, _path_str

        def per_rank(prm, t):
            # t: this rank's [B_local, S] slice of the request batch
            logits, cache = model_ep.prefill(prm, t)
            return logits, cache

        params_abs_plain = jax.eval_shape(model.init, jax.random.key(0))
        p_specs = jax.tree_util.tree_map_with_path(
            lambda p, x: P(None, "data") if is_expert_path(_path_str(p))
            else P(), params_abs_plain)

        def cache_out_spec(leaf):
            nd = len(leaf.shape)
            # batch dim (index 1) is rank-local
            return P(*([None, batch_axes] + [None] * (nd - 2)))

        cache_abs = jax.eval_shape(
            lambda: Model(cfg_ep).init_cache(B // n_batch_ranks,
                                             shape["seq_len"],
                                             prefilled=False))
        cache_specs = jax.tree.map(cache_out_spec, cache_abs)
        logits_spec = P(batch_axes)
        manual = {"data"} | ({"pod"} if pod_axis else set())
        fn = jax.jit(jax.shard_map(
            per_rank, mesh=mesh,
            in_specs=(p_specs, P(batch_axes)),
            out_specs=(logits_spec, cache_specs),
            axis_names=frozenset(manual), check_vma=False))
        toks_lead = _sharded((B,) + token_shape(arch_cfg, 1, shape["seq_len"])[1:],
                             jnp.int32, mesh, P(batch_axes))
        return DryrunSpec(fn=fn, args=(params_in, toks_lead),
                          description=f"prefill B={B} S={shape['seq_len']} "
                                      f"manual-EP")

    args = [params_in, toks]
    if arch_cfg.prefix_embeds:
        prefix = _sharded((B, arch_cfg.prefix_embeds, arch_cfg.d_model),
                          jnp.bfloat16, mesh, P(batch_axes))
        args.append(prefix)
        fn = jax.jit(lambda p, t, pe: model.prefill(p, t, pe))
    else:
        fn = jax.jit(lambda p, t: model.prefill(p, t))
    return DryrunSpec(fn=fn, args=tuple(args),
                      description=f"prefill B={B} S={shape['seq_len']}")


def decode_spec(arch_cfg: ModelConfig, mesh: Mesh, shape: dict) -> DryrunSpec:
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    pod_axis = "pod" if "pod" in axes else None
    model = Model(arch_cfg)
    params_in, _ = params_abstract(model, mesh)
    B = shape["global_batch"]
    S = shape["seq_len"]
    cache_abs = jax.eval_shape(
        lambda: model.init_cache(B, S, prefilled=True))
    batch_sharded = B > 1
    seq_axis = None if batch_sharded else "data"
    axes_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    specs = cache_pspecs(cache_abs, batch_sharded, seq_axis,
                         model_size=axes_sizes.get("model", 1))
    if pod_axis and batch_sharded:
        # decode batch over pod×data
        def up(s):
            parts = list(s)
            parts = [("pod", "data") if p == "data" else p for p in parts]
            return P(*parts)
        specs = jax.tree.map(lambda s: up(s), specs,
                             is_leaf=lambda x: isinstance(x, P))
    cache_in = _with_sharding(cache_abs, specs, mesh)
    tok_shape = (B, arch_cfg.num_codebooks) if arch_cfg.num_codebooks > 1 else (B,)
    tok_spec = P(("pod", "data") if pod_axis else "data") if batch_sharded else P()
    toks = _sharded(tok_shape, jnp.int32, mesh, tok_spec)
    # donate the cache: the new cache aliases it (no double-buffering)
    fn = jax.jit(model.decode_step, donate_argnums=(2,))
    return DryrunSpec(fn=fn, args=(params_in, toks, cache_in),
                      description=f"decode B={B} cache={S}"
                                  f"{' seq-sharded' if seq_axis else ''}")


def build_spec(arch_cfg: ModelConfig, mesh: Mesh, shape_name: str,
               **train_kw) -> Optional[DryrunSpec]:
    shape = INPUT_SHAPES[shape_name]
    if shape_name == "long_500k" and not arch_cfg.subquadratic:
        return None  # documented skip (DESIGN.md §5)
    if shape["kind"] == "train":
        return train_spec(arch_cfg, mesh, shape, **train_kw)
    if shape["kind"] == "prefill":
        return prefill_spec(arch_cfg, mesh, shape)
    return decode_spec(arch_cfg, mesh, shape)
