import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax-touching import: jax locks the device count on
# first init. 512 placeholder host devices back both the 16×16 single-pod
# mesh and the 2×16×16 multi-pod mesh. Never set this globally — smoke
# tests and benches run on 1 device.

"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh).

Per combination this script:
  1. builds the production mesh (launch/mesh.py),
  2. builds abstract inputs with production shardings (launch/input_specs),
  3. ``jit(...).lower(...).compile()`` — any sharding mismatch, OOM at
     compile, or unsupported collective is a bug in the framework,
  4. records memory_analysis() (proves the per-device footprint),
     cost_analysis() (FLOPs/bytes for §Roofline), and the collective
     schedule parsed from the partitioned HLO,
  5. writes results/dryrun/<arch>__<shape>__<mesh>[__<agg>].json.

Usage:
  python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
  python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k --multi-pod
  python -m repro.launch.dryrun --all          # everything missing, serially
"""

import argparse
import json
import re
import sys
import time
import traceback

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    """bytes of an HLO shape literal like 'bf16[16,1024]{1,0}' (tuples
    summed)."""
    total = 0
    for m in re.finditer(r"(\w+)\[([\d,]*)\]", shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str, loop_multiplier: int = 1) -> dict:
    """Sum per-device collective bytes from partitioned HLO.

    Collectives inside while-loop bodies (the unit scan) are multiplied by
    ``loop_multiplier`` (= n_units): XLA's text shows the body once but it
    executes once per unit. Heuristic documented in EXPERIMENTS.md §Dry-run.
    """
    per_op = {c: 0 for c in _COLLECTIVES}
    counts = {c: 0 for c in _COLLECTIVES}
    current_comp = ""
    for line in hlo_text.splitlines():
        stripped = line.strip()
        comp_m = re.match(r"%?([\w.\-]+)\s*\([^)]*\)\s*->", stripped)
        if comp_m and stripped.endswith("{"):
            current_comp = comp_m.group(1)
            continue
        for coll in _COLLECTIVES:
            # e.g.  %ag = bf16[8,128]{1,0} all-gather(...)
            m = re.search(r"=\s*([^=]*?)\s*" + coll + r"(?:-start|-done)?\(",
                          stripped)
            if m:
                nbytes = _shape_bytes(m.group(1))
                mult = loop_multiplier if ("while" in current_comp
                                           or "body" in current_comp) else 1
                per_op[coll] += nbytes * mult
                counts[coll] += mult
                break
    return {"bytes": per_op, "counts": counts,
            "total_bytes": sum(per_op.values())}


def run_one(arch: str, shape_name: str, multi_pod: bool,
            aggregator_mode: str = "safe", pipelined: bool = False,
            subgroups: int = 1, tag: str = "",
            chain_model_sharded: bool = False,
            capacity: float = 0.0) -> dict:
    import dataclasses
    import jax
    from repro.configs import get_config
    from repro.launch.mesh import make_production_mesh
    from repro.launch.input_specs import build_spec

    mesh_name = "pod512" if multi_pod else "pod256"
    cfg = get_config(arch)
    if capacity and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=capacity))
    mesh = make_production_mesh(multi_pod=multi_pod)

    record = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "aggregator": aggregator_mode, "pipelined": pipelined,
        "subgroups": subgroups,
        "params": cfg.param_count(), "active_params": cfg.active_param_count(),
        "status": "pending",
    }
    spec = build_spec(cfg, mesh, shape_name, aggregator_mode=aggregator_mode,
                      pipelined=pipelined, subgroups=subgroups,
                      chain_model_sharded=chain_model_sharded) \
        if shape_name == "train_4k" else build_spec(cfg, mesh, shape_name)
    if spec is None:
        record["status"] = "skipped"
        record["reason"] = ("long_500k requires sub-quadratic attention; "
                            f"{arch} is pure global attention (DESIGN.md §5)")
        return record

    record["description"] = spec.description
    t0 = time.time()
    with jax.set_mesh(mesh):
        lowered = spec.fn.lower(*spec.args)
        record["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        record["compile_s"] = round(time.time() - t1, 1)

    ma = compiled.memory_analysis()
    record["memory"] = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "total_per_device_bytes": int(ma.argument_size_in_bytes
                                      + ma.output_size_in_bytes
                                      + ma.temp_size_in_bytes
                                      - ma.alias_size_in_bytes),
    }
    ca = compiled.cost_analysis() or {}
    record["cost"] = {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "transcendentals": float(ca.get("transcendentals", 0.0)),
    }
    txt = compiled.as_text()
    record["hlo_bytes"] = len(txt)
    record["collectives"] = parse_collectives(txt, loop_multiplier=cfg.n_units)
    record["status"] = "ok"
    print(f"[dryrun] {arch} {shape_name} {mesh_name}: "
          f"mem/device={record['memory']['total_per_device_bytes']/2**30:.2f}GiB "
          f"flops/device={record['cost']['flops']:.3e} "
          f"coll={record['collectives']['total_bytes']/2**20:.1f}MiB "
          f"(lower {record['lower_s']}s compile {record['compile_s']}s)",
          flush=True)
    print(ma)
    return record


def result_path(arch, shape, multi_pod, tag=""):
    mesh_name = "pod512" if multi_pod else "pod256"
    suffix = f"__{tag}" if tag else ""
    os.makedirs(RESULTS_DIR, exist_ok=True)
    return os.path.join(RESULTS_DIR, f"{arch}__{shape}__{mesh_name}{suffix}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=["train_4k", "prefill_32k",
                                        "decode_32k", "long_500k"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--aggregator", default="safe",
                    choices=["safe", "saf", "insec", "bon"])
    ap.add_argument("--pipelined", action="store_true",
                    help="beyond-paper segmented chain schedule")
    ap.add_argument("--chain-model-sharded", action="store_true",
                    help="beyond-paper: 16 parallel chains over 'model'")
    ap.add_argument("--subgroups", type=int, default=1)
    ap.add_argument("--capacity", type=float, default=0.0,
                    help="override MoE capacity factor")
    ap.add_argument("--tag", default="", help="suffix for the result file")
    ap.add_argument("--all", action="store_true",
                    help="run every missing (arch × shape) on this mesh")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    from repro.configs import all_arch_ids

    combos = []
    if args.all:
        for arch in all_arch_ids():
            for shape in ["train_4k", "prefill_32k", "decode_32k", "long_500k"]:
                combos.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        combos.append((args.arch, args.shape))

    failures = 0
    for arch, shape in combos:
        path = result_path(arch, shape, args.multi_pod, args.tag)
        if os.path.exists(path) and not args.force:
            print(f"[dryrun] cached: {path}")
            continue
        try:
            rec = run_one(arch, shape, args.multi_pod, args.aggregator,
                          args.pipelined, args.subgroups, args.tag,
                          args.chain_model_sharded, args.capacity)
        except Exception as e:  # noqa: BLE001 — record the failure
            rec = {"arch": arch, "shape": shape,
                   "mesh": "pod512" if args.multi_pod else "pod256",
                   "status": "error", "error": repr(e),
                   "traceback": traceback.format_exc()[-4000:]}
            failures += 1
            print(f"[dryrun] FAILED {arch} {shape}: {e}", flush=True)
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
