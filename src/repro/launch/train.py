"""Training launcher.

Examples:
  # small real run on host devices (the quickstart path)
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
  python -m repro.launch.train --arch internlm2-1.8b --smoke \\
      --steps 50 --learners 4 --model-shards 2 --aggregator safe

  # federated (FedAvg, weighted SAFE delta aggregation)
  ... --federated --local-steps 4
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch-per-learner", type=int, default=2)
    ap.add_argument("--learners", type=int, default=4)
    ap.add_argument("--model-shards", type=int, default=2)
    ap.add_argument("--aggregator", default="safe",
                    choices=["safe", "saf", "insec", "bon"])
    ap.add_argument("--pipelined", action="store_true")
    ap.add_argument("--subgroups", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--federated", action="store_true")
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--fail-learners", default="",
                    help="comma-separated learner ranks to mark dead (failover demo)")
    ap.add_argument("--fail-at-step", type=int, default=-1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--metrics", default="")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    needed = args.learners * args.model_shards
    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={needed}")

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import get_config, get_smoke_config
    from repro.core import make_aggregator
    from repro.data import make_federated_batches
    from repro.models import Model
    from repro.train import (MetricsLogger, make_federated_round,
                             make_train_step)
    from repro.ckpt import save_checkpoint, restore_checkpoint, latest_step
    from jax.sharding import Mesh

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = Model(cfg)
    devs = np.asarray(jax.devices()[:needed]).reshape(
        args.learners, args.model_shards)
    mesh = Mesh(devs, ("data", "model"))

    agg = make_aggregator(args.aggregator, args.learners, axis="data",
                          pipelined=args.pipelined, subgroups=args.subgroups,
                          weighted=args.federated)
    stream = make_federated_batches(cfg, args.learners,
                                    args.batch_per_learner, args.seq_len,
                                    seed=args.seed)
    log = MetricsLogger(args.metrics or None)
    params = model.init(jax.random.key(args.seed))
    dead = {int(x) for x in args.fail_learners.split(",") if x}

    t0 = time.time()
    if args.federated:
        bundle = make_federated_round(model, agg, mesh,
                                      local_steps=args.local_steps,
                                      local_lr=args.lr)
        for r in range(args.steps):
            toks = np.stack([
                np.stack([stream.learner_batch(l, r * args.local_steps + k)
                          ["tokens"] for k in range(args.local_steps)])
                for l in range(args.learners)])
            gb = stream.global_batch(r)
            alive = np.ones(args.learners, np.float32)
            if dead and (args.fail_at_step < 0 or r >= args.fail_at_step):
                alive[list(dead)] = 0.0
            params, m = bundle.round_fn(
                params, jnp.asarray(toks), weights=jnp.asarray(gb["weights"]),
                counter=r * 2**20, alive=jnp.asarray(alive))
            log.log(r, **{k: float(v) for k, v in m.items()})
    else:
        bundle = make_train_step(model, agg, mesh, lr=args.lr)
        state = bundle.init_state_fn(params)
        start = 0
        if args.ckpt_dir and (s := latest_step(args.ckpt_dir)) is not None:
            state, extra = restore_checkpoint(args.ckpt_dir, s, state)
            start = int(extra.get("step", s))
            print(f"resumed from step {start}")
        for step in range(start, args.steps):
            gb = stream.global_batch(step)
            alive = np.ones(args.learners, np.float32)
            if dead and (args.fail_at_step < 0 or step >= args.fail_at_step):
                alive[list(dead)] = 0.0
            state, m = bundle.step_fn(
                state, jnp.asarray(gb["tokens"]),
                counter=(step % 2000) * (bundle.padded_size + 2),
                alive=jnp.asarray(alive))
            log.log(step, loss=float(m["loss"]),
                    grad_scale=float(m["grad_scale"]))
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                save_checkpoint(args.ckpt_dir, step + 1, state,
                                extra={"step": step + 1})
    print(f"done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
