"""Threefry-2x32 counter-mode PRF in pure JAX.

This is the cipher underlying ``jax.random`` (Salmon et al., "Parallel
random numbers: as easy as 1, 2, 3", SC'11), re-implemented here so that

  * the secure-aggregation core has a self-contained, auditable keystream
    generator (we do not depend on jax.random internals or versioning),
  * the Pallas kernels in ``repro.kernels`` have a bit-exact pure-jnp
    oracle to validate against.

SAFE usage (DESIGN.md §2): hop "encryption" between chain neighbours is a
one-time pad ``cipher = plain + PRF(k_pair, counter)  (mod 2**32)``, the
TPU-native form of the paper's pre-negotiated symmetric-key mode (§5.8).
The initiator mask R (§5.2) is a keystream from the initiator's private
seed. All arithmetic is uint32 so the masking is exact.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

# Threefry-2x32 rotation schedule (8 distinct rotations, reused over 20 rounds).
_ROTATIONS = ((13, 15, 26, 6), (17, 29, 16, 24))
# Threefish key-schedule parity constant for 32-bit words.
_PARITY = np.uint32(0x1BD11BDA)


def _rotl32(x: jax.Array, d: int) -> jax.Array:
    """Rotate-left for uint32 lanes."""
    return (x << d) | (x >> (32 - d))


def threefry2x32(key: jax.Array, x0: jax.Array, x1: jax.Array):
    """Threefry-2x32, 20 rounds.

    Args:
      key: uint32[2] cipher key (k0, k1).
      x0, x1: uint32 counter words, broadcastable to a common shape.

    Returns:
      (y0, y1): uint32 keystream words, same shape as the broadcast inputs.
    """
    key = jnp.asarray(key, jnp.uint32)
    x0 = jnp.asarray(x0, jnp.uint32)
    x1 = jnp.asarray(x1, jnp.uint32)
    ks0, ks1 = key[0], key[1]
    ks2 = ks0 ^ ks1 ^ _PARITY

    x0 = x0 + ks0
    x1 = x1 + ks1
    ks = (ks0, ks1, ks2)
    for i in range(5):
        for r in _ROTATIONS[i % 2]:
            x0 = x0 + x1
            x1 = _rotl32(x1, r)
            x1 = x1 ^ x0
        x0 = x0 + ks[(i + 1) % 3]
        x1 = x1 + ks[(i + 2) % 3] + np.uint32(i + 1)
    return x0, x1


@partial(jax.jit, static_argnums=(1,))
def keystream(key: jax.Array, n: int, counter_base: jax.Array | int = 0) -> jax.Array:
    """Generate ``n`` uint32 keystream words.

    Word ``i`` is derived from counter ``counter_base + i`` so streams for
    successive aggregation rounds never overlap when the caller advances
    ``counter_base`` by at least ``n`` (see ``RoundCounter``).

    Args:
      key: uint32[2] PRF key.
      n: number of words (static).
      counter_base: uint32 starting counter (traced ok).

    Returns:
      uint32[n] keystream.
    """
    if isinstance(counter_base, (int, np.integer)):
        counter_base = np.uint32(int(counter_base) & 0xFFFFFFFF)
    base = jnp.asarray(counter_base, jnp.uint32)
    idx = jnp.arange(n, dtype=jnp.uint32)
    # Counter words: (block index, lane). Two output words per block would
    # halve PRF work; we deliberately keep 1 word/counter here for clarity —
    # the fused Pallas kernel uses both lanes (see kernels/threefry_mask_add).
    y0, _ = threefry2x32(key, base + idx, jnp.zeros_like(idx))
    return y0


def keystream_pair_lanes(key: jax.Array, n: int, counter_base: jax.Array | int = 0) -> jax.Array:
    """Keystream using both Threefry output lanes (half the PRF invocations).

    This is the schedule the Pallas kernel implements: block ``b`` yields
    words ``(2b, 2b+1)``. Bit-exact oracle for ``kernels.threefry_mask_add``.
    """
    if isinstance(counter_base, (int, np.integer)):
        counter_base = np.uint32(int(counter_base) & 0xFFFFFFFF)
    base = jnp.asarray(counter_base, jnp.uint32)
    nblk = (n + 1) // 2
    idx = jnp.arange(nblk, dtype=jnp.uint32)
    y0, y1 = threefry2x32(key, base + idx, jnp.zeros_like(idx))
    out = jnp.stack([y0, y1], axis=-1).reshape(-1)
    return out[:n]


def derive_key(master: jax.Array, *tags: int) -> jax.Array:
    """Derive a subkey from a uint32[2] master key and integer tags.

    A small KDF built from the PRF itself: fold each tag in with one
    Threefry application. Used for per-round / per-chunk / per-purpose
    domain separation.
    """
    k = jnp.asarray(master, jnp.uint32)
    for tag in tags:
        t = jnp.asarray(tag, jnp.uint32)
        y0, y1 = threefry2x32(k, t, jnp.uint32(0x9E3779B9))
        k = jnp.stack([y0, y1])
    return k


def derive_pair_key(seed_i: jax.Array, i: int | jax.Array, j: int | jax.Array) -> jax.Array:
    """Pairwise key for chain neighbours (i -> j).

    In the deployed system the pair key comes from an out-of-band exchange
    (paper §5.8: symmetric-key pre-negotiation; in practice X25519 +
    HKDF during Round 0). For the device data plane we model it as a KDF
    of a common provisioning seed and the ordered pair (i, j) — both ends
    can derive it, nobody else learns it without the provisioning seed.
    """
    i = jnp.asarray(i, jnp.uint32)
    j = jnp.asarray(j, jnp.uint32)
    y0, y1 = threefry2x32(jnp.asarray(seed_i, jnp.uint32), i, j)
    return jnp.stack([y0, y1])


class RoundCounter:
    """Host-side monotone counter allocator.

    Guarantees keystream non-reuse across aggregation rounds: each round
    reserves ``nwords`` of counter space per purpose. Plain Python (host
    control-plane state, never traced).

    The Threefry counter words are uint32, so the usable space per key is
    exactly ``2**32`` words. ``reserve`` refuses — *before* mutating any
    state — any reservation whose range ``[base, base + nwords)`` would
    cross that boundary: a silent wrap would hand out counters already
    consumed in an earlier round, i.e. reuse one-time pads. After a
    refusal the allocator is still valid for smaller reservations, and
    the remedy is a Round-0 key rotation (fresh pair keys ⇒ fresh
    counter space).
    """

    #: usable counter words per (key, purpose): the full uint32 range.
    LIMIT = 2**32

    def __init__(self) -> None:
        self._next = 0

    @property
    def remaining(self) -> int:
        """Counter words still available before a key rotation is due."""
        return self.LIMIT - self._next

    def reserve(self, nwords: int) -> int:
        nwords = int(nwords)
        if nwords < 0:
            raise ValueError(f"nwords must be >= 0, got {nwords}")
        if nwords > self.remaining:
            raise OverflowError(
                f"counter space exhausted: {self._next} of 2**32 words used, "
                f"{nwords} requested; rotate pair keys (Round 0) before reuse"
            )
        base = self._next
        self._next += nwords
        return base
