"""Vectorized numpy implementations of the crypto substrate.

The host control-plane simulation (``core/protocol.py``) does *real*
masking arithmetic on numpy arrays — these mirror ``crypto/prf.py`` /
``crypto/fixedpoint.py`` bit-for-bit (property-tested in
``tests/test_crypto.py``) but avoid JAX dispatch overhead for the
many small host-side operations the protocol sim performs.
"""
from __future__ import annotations

import numpy as np

_ROTATIONS = ((13, 15, 26, 6), (17, 29, 16, 24))
_PARITY = np.uint32(0x1BD11BDA)


def _rotl32(x: np.ndarray, d: int) -> np.ndarray:
    return (x << np.uint32(d)) | (x >> np.uint32(32 - d))


def threefry2x32_np(key: np.ndarray, x0: np.ndarray, x1: np.ndarray):
    """Threefry-2x32, 20 rounds — numpy mirror of crypto.prf.threefry2x32."""
    old = np.seterr(over="ignore")
    try:
        key = np.asarray(key, np.uint32)
        x0 = np.asarray(x0, np.uint32).copy()
        x1 = np.asarray(x1, np.uint32).copy()
        ks0, ks1 = key[0], key[1]
        ks2 = ks0 ^ ks1 ^ _PARITY
        x0 = x0 + ks0
        x1 = x1 + ks1
        ks = (ks0, ks1, ks2)
        for i in range(5):
            for r in _ROTATIONS[i % 2]:
                x0 = x0 + x1
                x1 = _rotl32(x1, r)
                x1 = x1 ^ x0
            x0 = x0 + ks[(i + 1) % 3]
            x1 = x1 + ks[(i + 2) % 3] + np.uint32(i + 1)
        return x0, x1
    finally:
        np.seterr(**old)


def keystream_np(key: np.ndarray, n: int, counter_base: int = 0) -> np.ndarray:
    """uint32[n] keystream, single-lane schedule (mirror of prf.keystream)."""
    old = np.seterr(over="ignore")
    try:
        idx = np.arange(n, dtype=np.uint32) + np.uint32(counter_base)
        y0, _ = threefry2x32_np(key, idx, np.zeros_like(idx))
        return y0
    finally:
        np.seterr(**old)


def keystream_pair_lanes_np(key: np.ndarray, n: int, counter_base: int = 0) -> np.ndarray:
    """uint32[n] keystream, two-lane schedule (mirror of
    prf.keystream_pair_lanes and of the Pallas kernel)."""
    old = np.seterr(over="ignore")
    try:
        nblk = (n + 1) // 2
        idx = np.arange(nblk, dtype=np.uint32) + np.uint32(counter_base)
        y0, y1 = threefry2x32_np(key, idx, np.zeros_like(idx))
        out = np.stack([y0, y1], axis=-1).reshape(-1)
        return out[:n]
    finally:
        np.seterr(**old)


def keystream_slice_np(key: np.ndarray, n: int, start: int,
                       counter_base: int = 0) -> np.ndarray:
    """Words ``[start, start + n)`` of the two-lane keystream based at
    ``counter_base`` — the seekable slab the streaming chunk-combine path
    runs on.

    Bit-identical to ``keystream_pair_lanes_np(key, total, counter_base)
    [start:start + n]`` for any ``total >= start + n``, computed without
    generating the prefix. ``counter_base`` is in two-word *blocks* (the
    Threefry counter schedule), so word ``start`` of the stream lives at
    global word ``2 * counter_base + start``; an odd ``start`` lands
    mid-block and costs one extra generated word. Property-tested in
    ``tests/test_crypto.py`` (arbitrary split points, chunk edges, empty
    slices).
    """
    if n < 0:
        raise ValueError(f"slice length must be >= 0, got {n}")
    if start < 0:
        raise ValueError(f"slice start must be >= 0, got {start}")
    if n == 0:
        return np.empty(0, np.uint32)
    word0 = 2 * int(counter_base) + int(start)
    block0, off = divmod(word0, 2)
    return keystream_pair_lanes_np(key, n + off, block0 % (1 << 32))[off:]


def derive_key_np(master: np.ndarray, *tags: int) -> np.ndarray:
    k = np.asarray(master, np.uint32)
    for tag in tags:
        y0, y1 = threefry2x32_np(k, np.uint32(tag), np.uint32(0x9E3779B9))
        k = np.stack([y0, y1])
    return k


def derive_pair_key_np(seed: np.ndarray, i: int, j: int) -> np.ndarray:
    y0, y1 = threefry2x32_np(np.asarray(seed, np.uint32), np.uint32(i), np.uint32(j))
    return np.stack([y0, y1])


class NpFixedPoint:
    """numpy mirror of crypto.fixedpoint.FixedPointCodec."""

    def __init__(self, scale_bits: int = 16):
        self.scale_bits = scale_bits
        self.scale = float(2**scale_bits)

    def encode(self, x: np.ndarray) -> np.ndarray:
        scaled = np.round(np.asarray(x, np.float32) * self.scale)
        return scaled.astype(np.int64).astype(np.int32).view(np.uint32)

    def decode(self, u: np.ndarray) -> np.ndarray:
        return u.view(np.int32).astype(np.float32) / self.scale

    @staticmethod
    def add(a: np.ndarray, b: np.ndarray) -> np.ndarray:
        old = np.seterr(over="ignore")
        try:
            return a + b
        finally:
            np.seterr(**old)

    @staticmethod
    def sub(a: np.ndarray, b: np.ndarray) -> np.ndarray:
        old = np.seterr(over="ignore")
        try:
            return a - b
        finally:
            np.seterr(**old)
