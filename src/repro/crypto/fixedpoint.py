"""Fixed-point codec: f32 <-> uint32 ring elements.

Secure aggregation with additive one-time pads requires *exact* arithmetic
in a finite ring — floating point addition is neither associative nor
mask-cancelling. We therefore encode features as two's-complement
fixed-point integers living in Z/2^32Z:

    encode(x) = round(x * 2**scale_bits)  as int32, bit-cast to uint32
    decode(u) = int32(u) / 2**scale_bits

Sums of up to ``headroom`` encoded values stay exact provided
``|x_i| < 2**(31 - scale_bits) / headroom``; the codec exposes the bound so
callers (and property tests) can check it. The SAFE average divides by the
contributor count *after* decoding, so the ring only ever holds sums.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

# 16 fractional bits: ~1.5e-5 resolution, |sum| < 32768 — comfortable for
# gradients/deltas of normalized models aggregated over <= 1024 learners.
DEFAULT_SCALE_BITS = 16


@dataclasses.dataclass(frozen=True)
class FixedPointCodec:
    """f32 <-> uint32 fixed-point codec over Z/2^32Z."""

    scale_bits: int = DEFAULT_SCALE_BITS

    @property
    def scale(self) -> float:
        return float(2**self.scale_bits)

    def max_abs_value(self, n_addends: int = 1) -> float:
        """Largest |x| for which a sum of ``n_addends`` values cannot wrap."""
        return float(2 ** (31 - self.scale_bits)) / float(n_addends)

    def encode(self, x: jax.Array) -> jax.Array:
        """f32 -> uint32 ring element (round-to-nearest-even)."""
        scaled = jnp.round(jnp.asarray(x, jnp.float32) * self.scale)
        return jnp.asarray(scaled, jnp.int32).view(jnp.uint32)

    def decode(self, u: jax.Array) -> jax.Array:
        """uint32 ring element -> f32."""
        return jnp.asarray(u.view(jnp.int32), jnp.float32) / self.scale

    def decode_mean(self, u: jax.Array, count: jax.Array | int) -> jax.Array:
        """Decode a ring sum and divide by the contributor count."""
        return self.decode(u) / jnp.asarray(count, jnp.float32)

    def add(self, a: jax.Array, b: jax.Array) -> jax.Array:
        """Ring addition (wrapping uint32 add)."""
        return a + b

    def sub(self, a: jax.Array, b: jax.Array) -> jax.Array:
        """Ring subtraction (wrapping uint32 sub)."""
        return a - b
