"""Cryptographic substrate for SAFE secure aggregation.

Pure-JAX reference implementations of:
  - Threefry-2x32 counter-mode PRF (the keystream generator used for
    hop "encryption" — one-time-pad masking over Z/2^32Z).
  - Fixed-point codec (f32 <-> i32) so masking is exact modular arithmetic.
  - Key schedule / pairwise key derivation for chain neighbours.

These are the oracles for the Pallas kernels in ``repro.kernels``.
"""
from repro.crypto.prf import (
    threefry2x32,
    keystream,
    derive_pair_key,
    derive_key,
)
from repro.crypto.fixedpoint import (
    FixedPointCodec,
    DEFAULT_SCALE_BITS,
)

__all__ = [
    "threefry2x32",
    "keystream",
    "derive_pair_key",
    "derive_key",
    "FixedPointCodec",
    "DEFAULT_SCALE_BITS",
]
