"""InternLM2 1.8B — dense GQA decoder.

[dense] 24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92544
[arXiv:2403.17297]. Pure global attention -> long_500k skipped.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="internlm2-1.8b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab=92544,
    head_dim=128,
    pattern=("global",),
    rope_theta=1000000.0,
)
