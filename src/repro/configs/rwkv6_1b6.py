"""RWKV6 "Finch" 1.6B — attention-free, data-dependent decay.

[ssm] 24L d_model=2048 d_ff=7168 vocab=65536  [arXiv:2404.05892]
Sub-quadratic by construction (O(1) recurrent state) -> runs long_500k.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,          # 2048 / head_size 64
    n_kv_heads=32,       # unused by rwkv blocks; kept for uniform tooling
    d_ff=7168,
    vocab=65536,
    pattern=("rwkv6",),
    rwkv_head_size=64,
    subquadratic=True,
    tie_embeddings=False,
)
