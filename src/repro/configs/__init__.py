"""Architecture registry: one module per assigned architecture.

``get_config(arch_id)`` returns the full ModelConfig;
``get_smoke_config(arch_id)`` the reduced same-family variant used by the
CPU smoke tests (2 layers, d_model <= 512, <= 4 experts).
"""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig, reduced

ARCHS = (
    "rwkv6_1b6",
    "zamba2_2b7",
    "qwen3_moe_235b",
    "musicgen_large",
    "gemma2_27b",
    "internvl2_1b",
    "internlm2_1b8",
    "llama4_maverick",
    "qwen3_14b",
    "gemma3_12b",
)

# CLI ids (match the assignment list) -> module names
ALIASES = {
    "rwkv6-1.6b": "rwkv6_1b6",
    "zamba2-2.7b": "zamba2_2b7",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b",
    "musicgen-large": "musicgen_large",
    "gemma2-27b": "gemma2_27b",
    "internvl2-1b": "internvl2_1b",
    "internlm2-1.8b": "internlm2_1b8",
    "llama4-maverick-400b-a17b": "llama4_maverick",
    "qwen3-14b": "qwen3_14b",
    "gemma3-12b": "gemma3_12b",
}


def get_config(arch: str) -> ModelConfig:
    mod_name = ALIASES.get(arch, arch.replace("-", "_").replace(".", "_"))
    if mod_name not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ALIASES)}")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return reduced(get_config(arch))


def all_arch_ids() -> list[str]:
    return sorted(ALIASES.keys())
