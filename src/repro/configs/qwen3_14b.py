"""Qwen3 14B — dense GQA with qk-norm.

[dense] 40L d_model=5120 40H (GQA kv=8) d_ff=17408 vocab=151936
[hf:Qwen/Qwen3-14B]. Pure global attention -> long_500k skipped.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen3-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=17408,
    vocab=151936,
    head_dim=128,
    pattern=("global",),
    qk_norm=True,
    rope_theta=1000000.0,
    tie_embeddings=False,
    fsdp=True,
)
