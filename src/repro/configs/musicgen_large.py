"""MusicGen-Large — decoder-only over EnCodec tokens.

[audio] 48L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=2048
[arXiv:2306.05284]. 4 parallel codebooks (delay pattern): embeddings are
summed, one lm head per codebook. The EnCodec conv codec itself is the
modality-frontend stub (input_specs provides frame token ids).
Pure global attention -> long_500k skipped.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=2048,
    head_dim=64,
    pattern=("global",),
    num_codebooks=4,
    tie_embeddings=False,
)
