"""Gemma3 12B — 5:1 local:global attention, 128k context.

[dense] 48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144
[hf:google/gemma-3-12b]. window=1024 locals; long_500k runs via the
windowed locals (globals decode O(L) with seq-sharded KV).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    d_ff=15360,
    vocab=262144,
    head_dim=256,
    pattern=("local", "local", "local", "local", "local", "global"),
    window=1024,
    rope_theta=1000000.0,
    subquadratic=True,
    fsdp=True,
)
