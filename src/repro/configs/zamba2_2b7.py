"""Zamba2 2.7B — Mamba2 backbone + shared-weight attention blocks.

[hybrid] 54L d_model=2560 32H (GQA kv=32) d_ff=10240 vocab=32000,
ssm_state=64  [arXiv:2411.15242]

Pattern: 5 Mamba2 blocks then one shared attention block (the paper
interleaves 2 alternating shared blocks with per-site LoRA; we share a
single block and note the simplification in DESIGN.md). Sub-quadratic:
Mamba2 state is O(1); the shared attention uses a sliding window for
long_500k.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab=32000,
    head_dim=80,
    pattern=("mamba2", "mamba2", "mamba2", "mamba2", "mamba2", "shared_attn"),
    ssm_state=64,
    ssm_heads=80,        # expand factor 2: inner = 5120
    window=4096,         # shared_attn treated as local for long-context
    subquadratic=True,
    recurrent_mlp=False,
)
