"""InternVL2-1B — InternViT frontend + Qwen2-0.5B-class LLM backbone.

[vlm] 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655
[arXiv:2404.16821]. The ViT+projector is the stub frontend: input_specs
provides 256 precomputed patch embeddings prefixed to the text tokens.
Pure global attention -> long_500k skipped.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab=151655,
    head_dim=64,
    pattern=("global",),
    prefix_embeds=256,
    rope_theta=1000000.0,
)
