"""Llama-4 Maverick 400B-A17B — MoE (128 experts top-1 + shared expert),
iRoPE chunked-local attention, early-fusion multimodal.

[moe] 48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128e
top-1  [hf:meta-llama/Llama-4 family]. Pattern: MoE every other layer
(dense interleave), 3 chunked-local + 1 global per unit (iRoPE-style); chunked attention gives the
sub-quadratic path for long_500k. d_ff is the per-expert width; a shared
expert is always active (A17B active params).
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab=202048,
    head_dim=128,
    pattern=("chunked_moe", "chunked", "chunked", "moe"),
    chunk=8192,
    moe=MoEConfig(num_experts=128, top_k=1, expert_d_ff=8192,
                  num_shared_experts=1),
    rope_theta=500000.0,
    tie_embeddings=False,
    subquadratic=True,
    fsdp=True,
)
