"""Gemma2 27B — alternating local:global attention, logit softcaps.

[dense] 46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000
[arXiv:2408.00118]. window=4096, attn softcap 50, final softcap 30.
Locals are windowed -> long_500k runs (global layers decode O(L)).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    d_ff=36864,
    vocab=256000,
    head_dim=128,
    pattern=("local", "global"),
    window=4096,
    attn_softcap=50.0,
    logit_softcap=30.0,
    subquadratic=True,
    fsdp=True,
)
