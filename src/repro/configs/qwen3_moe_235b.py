"""Qwen3-MoE 235B-A22B — 128 experts, top-8.

[moe] 94L d_model=4096 64H (GQA kv=4) d_ff=1536 vocab=151936
[hf:Qwen/Qwen3-30B-A3B scaled]. d_ff is the per-expert ffn width.
Pure global attention -> long_500k skipped (DESIGN.md §5).
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=1536,
    vocab=151936,
    head_dim=128,
    pattern=("moe",),
    moe=MoEConfig(num_experts=128, top_k=8, expert_d_ff=1536),
    qk_norm=True,
    rope_theta=1000000.0,
    tie_embeddings=False,
    fsdp=True,
)
