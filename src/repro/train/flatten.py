"""Deterministic tree <-> flat-vector codec and path-based partitioning.

The SAFE chain aggregates a single flat f32 vector (the paper's "feature
vector" is our gradient); these helpers define the canonical layout.
Unlike jax.flatten_util.ravel_pytree they also work on abstract
(ShapeDtypeStruct) templates, which the train-step builder uses to size
the ZeRO-1 shards before any real array exists.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


def tree_size(tree: Any) -> int:
    return int(sum(np.prod(np.shape(l)) for l in jax.tree.leaves(tree)))


def tree_to_flat(tree: Any) -> jax.Array:
    """Concatenate all leaves (tree order) as f32[P]."""
    leaves = jax.tree.leaves(tree)
    if not leaves:
        return jnp.zeros((0,), jnp.float32)
    return jnp.concatenate([jnp.ravel(l).astype(jnp.float32) for l in leaves])


def flat_to_tree(flat: jax.Array, template: Any) -> Any:
    """Inverse of tree_to_flat; casts each leaf to the template's dtype."""
    leaves, treedef = jax.tree.flatten(template)
    out, off = [], 0
    for l in leaves:
        n = int(np.prod(np.shape(l)))
        out.append(flat[off:off + n].reshape(np.shape(l)).astype(l.dtype))
        off += n
    return jax.tree.unflatten(treedef, out)


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def partition_tree(tree: Any, pred: Callable[[str], bool]):
    """Split into (selected, rest) trees; non-matching leaves become None
    (empty pytree nodes, invisible to tree.map/leaves)."""
    sel = jax.tree_util.tree_map_with_path(
        lambda p, x: x if pred(_path_str(p)) else None, tree)
    rest = jax.tree_util.tree_map_with_path(
        lambda p, x: None if pred(_path_str(p)) else x, tree)
    return sel, rest


def combine_trees(a: Any, b: Any) -> Any:
    """Merge two complementary partitions back into one tree."""
    isn = lambda x: x is None
    return jax.tree.map(lambda x, y: y if x is None else x, a, b, is_leaf=isn)


def is_expert_path(path: str) -> bool:
    """Expert-parallel leaves: the per-expert matrices inside moe blocks
    (router and shared experts stay in the secure-aggregated partition)."""
    return "moe/" in path and path.rsplit("/", 1)[-1] in ("wi", "wg", "wo")
