"""Training: SAFE-integrated distributed step, FedAvg rounds, metrics."""
from repro.train.train_step import make_train_step, TrainStepBundle
from repro.train.federated import (
    FederatedBundle,
    WireFederated,
    apply_delta,
    make_federated_round,
    make_local_update,
    make_wire_federated,
)
from repro.train.loss import next_token_loss
from repro.train.metrics import MetricsLogger

__all__ = [
    "make_train_step", "TrainStepBundle",
    "make_federated_round", "FederatedBundle",
    "make_local_update", "make_wire_federated", "WireFederated",
    "apply_delta",
    "next_token_loss", "MetricsLogger",
]
