"""Training: SAFE-integrated distributed step, FedAvg rounds, metrics."""
from repro.train.train_step import make_train_step, TrainStepBundle
from repro.train.federated import make_federated_round, FederatedBundle
from repro.train.loss import next_token_loss
from repro.train.metrics import MetricsLogger

__all__ = [
    "make_train_step", "TrainStepBundle",
    "make_federated_round", "FederatedBundle",
    "next_token_loss", "MetricsLogger",
]
