"""Next-token cross-entropy over the zoo's output conventions."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def next_token_loss(logits: jax.Array, tokens: jax.Array,
                    prefix_len: int = 0) -> jax.Array:
    """Mean next-token CE.

    logits: [B, S(+P), V] or [B, S(+P), nc, V] (multi-codebook);
    tokens:  [B, S] or [B, S, nc]. ``prefix_len`` positions at the front
    of the logits (modality-frontend embeddings) carry no loss.
    """
    if prefix_len:
        logits = logits[:, prefix_len:]
    # predict token t+1 from position t
    logits = logits[:, :-1]
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)
