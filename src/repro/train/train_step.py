"""The SAFE-integrated distributed train step.

One jitted SPMD program per (arch × mesh), structured as (DESIGN.md §3):

  shard_map — manual over the learner axis 'data' (+ 'pod'), auto 'model'
  ├─ per-learner forward/backward (GSPMD tensor-parallel over 'model';
  │    giant MoEs use manual expert parallelism over 'data')
  ├─ SAFE chain secure aggregation of the flat gradient (the paper's
  │    Round 1 — ppermute ring, masked in Z/2^32Z)
  ├─ ZeRO-1 optimizer: each learner updates its 1/n slice of the f32
  │    master vector (safe: the aggregated gradient is public by
  │    protocol), then all-gathers the updated parameters
  └─ hierarchical federation over 'pod' (paper §5.10) via the
       aggregator's pod_axis

The same builder serves all four aggregator modes, so INSEC (plain
psum) vs SAFE is a one-flag ablation — that delta is the §Perf story.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.aggregators import SecureAggregator
from repro.models.transformer import Model
from repro.optim.adamw import AdamW, FlatAdamW
from repro.train.flatten import (
    combine_trees,
    flat_to_tree,
    is_expert_path,
    partition_tree,
    tree_size,
    tree_to_flat,
)
from repro.train.loss import next_token_loss


@dataclasses.dataclass
class TrainStepBundle:
    """Everything the launcher needs: the jitted step + state builders."""
    step_fn: Any          # (state, batch, counter, alive) -> (state, metrics)
    init_state_fn: Any    # params -> state
    state_shardings: Any  # pytree of NamedSharding (for jit donation / ckpt)
    batch_spec: Any       # PartitionSpec for the token batch
    sec_size: int
    padded_size: int
    jit_fn: Any = None    # raw jitted shard_map step (dry-run lowering)
    params_abs: Any = None  # abstract params (local-expert view if EP)
    leafwise: bool = False
    sec_opt_abs: Any = None  # abstract sec AdamState when leafwise


def make_train_step(
    model: Model,
    aggregator: SecureAggregator,
    mesh: Mesh,
    *,
    lr=3e-4,
    learner_axis: str = "data",
    pod_axis: Optional[str] = None,
    grad_clip: float = 1.0,
    weight_decay: float = 0.1,
    donate: bool = True,
    chain_model_sharded: bool = False,
    leafwise: Optional[bool] = None,
) -> TrainStepBundle:
    """chain_model_sharded: beyond-paper optimization — run 16 parallel
    chains, one per model-axis shard of the flat gradient (each model rank
    chains its slice; privacy per-slice identical, per-device chain memory
    and PRF work /16). False = paper-faithful single full-vector chain.

    leafwise: aggregate per parameter tensor instead of one flat vector
    (counters domain-separated per leaf). Each leaf keeps its Megatron
    sharding through the chain — no giant replicated flat temp — at the
    cost of the flat ZeRO-1 master (a tree AdamW with model-sharded state
    is used instead). Auto-enabled when the flat vector would exceed 8 GB
    f32 per device (the giant archs)."""
    cfg = model.cfg
    n = aggregator.cfg.num_learners
    use_ep = cfg.ep_axis is not None
    flat_opt = FlatAdamW(lr=lr, weight_decay=weight_decay)
    ep_opt = AdamW(lr=lr, weight_decay=weight_decay, grad_clip=None)
    sec_opt = AdamW(lr=lr, weight_decay=weight_decay, grad_clip=grad_clip)

    # ---- size the secure-aggregated partition from an abstract template ----
    params_abs = jax.eval_shape(model.init, jax.random.key(0))
    if use_ep:
        # the template sees the LOCAL expert shard (what each rank holds)
        def _localize(path, x):
            if is_expert_path(path):
                # experts stacked as [n_units, E, d, f] -> shard E over ranks
                shape = (x.shape[0], x.shape[1] // n) + x.shape[2:]
                return jax.ShapeDtypeStruct(shape, x.dtype)
            return x
        from repro.train.flatten import _path_str
        params_abs_local = jax.tree_util.tree_map_with_path(
            lambda p, x: _localize(_path_str(p), x), params_abs)
    else:
        params_abs_local = params_abs
    sec_abs, _ = partition_tree(params_abs_local, lambda p: not is_expert_path(p))
    sec_size = tree_size(sec_abs)
    shard_len = -(-sec_size // n)
    padded_size = shard_len * n
    if leafwise is None:
        leafwise = sec_size * 4 > 8e9
    # per-leaf counter offsets (static): disjoint keystream ranges
    leaf_sizes = [int(np.prod(np.shape(l))) for l in jax.tree.leaves(sec_abs)]
    leaf_offsets = list(np.cumsum([0] + leaf_sizes[:-1]).astype(np.int64))

    # Megatron-TP output anchors ('data' stripped: it is manual here)
    from repro.models.sharding import param_pspecs, sanitize_spec
    axes_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def _strip_manual(spec, leaf):
        parts = []
        for p in spec:
            if p == learner_axis or p == pod_axis or (
                    isinstance(p, tuple) and
                    (learner_axis in p or (pod_axis or "") in p)):
                parts.append(None)
            else:
                parts.append(p)
        return sanitize_spec(P(*parts), np.shape(leaf), axes_sizes)

    _all_specs = param_pspecs(cfg, params_abs)
    _all_specs = jax.tree.map(_strip_manual, _all_specs, params_abs)
    sec_model_specs, _ = partition_tree(_all_specs,
                                        lambda p: not is_expert_path(p))

    # ---- per-rank step (inside shard_map) -----------------------------------
    def per_rank_step(params, master_shard, fopt_m, fopt_v, fopt_step,
                      ep_opt_state, sec_opt_state, tokens, prefix, weights,
                      counter, alive):
        tokens = tokens.reshape(tokens.shape[1:])  # drop learner dim
        if prefix is not None:
            prefix = prefix.reshape(prefix.shape[1:])
        my_w = weights[jax.lax.axis_index(learner_axis)]

        def loss_fn(p):
            logits, aux = model.forward(p, tokens, prefix)
            return next_token_loss(logits, tokens, cfg.prefix_embeds) + aux

        loss, grads = jax.value_and_grad(loss_fn)(params)

        sec_g, ep_g = partition_tree(grads, lambda p: not is_expert_path(p))
        sec_params_tpl, _ = partition_tree(params,
                                           lambda p: not is_expert_path(p))
        # §8 collusion mitigation: rotate the initiator role every round
        rotate = (counter % jnp.uint32(2 * n + 1)).astype(jnp.int32)

        from repro.optim.adamw import AdamState
        fstate = AdamState(fopt_step, fopt_m, fopt_v)
        if leafwise:
            # per-leaf chains: each tensor keeps its Megatron sharding;
            # keystream domains separated by leaf index
            leaves, treedef = jax.tree.flatten(sec_g)
            avg_leaves = []
            for idx, leaf in enumerate(leaves):
                v = leaf.reshape(-1).astype(jnp.float32)
                if chain_model_sharded:
                    v = jax.lax.with_sharding_constraint(v, P("model"))
                a = aggregator.aggregate(v, counter, alive=alive,
                                         domain=idx + 1, rotate=rotate)
                avg_leaves.append(a.reshape(leaf.shape))
            avg_tree = jax.tree.unflatten(treedef, avg_leaves)
            new_sec, sec_opt_state = sec_opt.update(avg_tree, sec_opt_state,
                                                    sec_params_tpl)
            new_master = master_shard  # unused placeholder
            grad_norm = jnp.sqrt(sum(jnp.sum(jnp.square(a))
                                     for a in avg_leaves))
        else:
            flat_g = tree_to_flat(sec_g)
            flat_g = jnp.pad(flat_g, (0, padded_size - sec_size))
            if chain_model_sharded:
                # 16 parallel chains over the auto 'model' axis
                flat_g = jax.lax.with_sharding_constraint(flat_g, P("model"))

            # ---- the paper's technique: secure gradient aggregation ----
            avg = aggregator.aggregate(flat_g, counter, alive=alive,
                                       rotate=rotate)

            # ---- ZeRO-1 slice update (public post-aggregation) ----
            rank = jax.lax.axis_index(learner_axis)
            gshard = jax.lax.dynamic_slice(avg, (rank * shard_len,),
                                           (shard_len,))
            new_master, fstate = flat_opt.update(gshard, fstate, master_shard)
            new_flat = jax.lax.all_gather(new_master, learner_axis, tiled=True)
            if pod_axis is not None:
                new_flat = jax.lax.pmean(new_flat, pod_axis)  # identical anyway
            new_sec = flat_to_tree(new_flat[:sec_size], sec_params_tpl)
            grad_norm = jnp.sqrt(jnp.sum(jnp.square(avg[:sec_size])))
        # anchor the rebuilt params to the Megatron-TP layout — without
        # this the all-gathered tree comes out replicated per device
        new_sec = jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(x, s)
            if s is not None else x,
            new_sec, sec_model_specs,
            is_leaf=lambda x: x is None)

        if use_ep:
            # expert grads were already globally summed by the a2a
            # transpose; update locally (state is per-rank = sharded).
            _, ep_params = partition_tree(params,
                                          lambda p: not is_expert_path(p))
            new_ep, ep_opt_state = ep_opt.update(ep_g, ep_opt_state, ep_params)
            new_params = combine_trees(new_sec, new_ep)
        else:
            new_params = new_sec

        metrics = {
            "loss": jax.lax.pmean(loss, learner_axis),
            "grad_scale": grad_norm,
            "weight": my_w,
        }
        if pod_axis is not None:
            metrics["loss"] = jax.lax.pmean(metrics["loss"], pod_axis)
        return (new_params, new_master, fstate.m, fstate.v, fstate.step,
                ep_opt_state, sec_opt_state, metrics)

    # ---- shard_map wiring ---------------------------------------------------
    manual = {learner_axis} | ({pod_axis} if pod_axis else set())

    def param_in_spec(path, leaf):
        if use_ep and is_expert_path(path):
            return P(None, learner_axis)  # [n_units, E, ...] -> shard E
        return P()

    from repro.train.flatten import _path_str as _ps
    params_specs = jax.tree_util.tree_map_with_path(
        lambda p, x: param_in_spec(_ps(p), x), params_abs)
    _, ep_abs = partition_tree(params_abs_local, lambda p: not is_expert_path(p))
    ep_opt_specs = None
    if use_ep:
        ep_opt_abs = jax.eval_shape(ep_opt.init, ep_abs)
        ep_opt_specs = jax.tree.map(
            lambda _: P(), ep_opt_abs)
        # m/v mirror the expert sharding; step is replicated
        ep_opt_specs = type(ep_opt_abs)(
            step=P(),
            m=jax.tree_util.tree_map_with_path(
                lambda p, x: P(None, learner_axis), ep_opt_abs.m),
            v=jax.tree_util.tree_map_with_path(
                lambda p, x: P(None, learner_axis), ep_opt_abs.v),
        )

    sec_opt_specs = P()
    if leafwise:
        sec_opt_abs = jax.eval_shape(sec_opt.init, sec_abs)
        sec_opt_specs = jax.tree.map(lambda _: P(), sec_opt_abs)

    flat_spec = P(learner_axis)
    batch_spec = P((pod_axis, learner_axis) if pod_axis else learner_axis)

    in_specs = (
        params_specs,        # params
        flat_spec,           # master_shard [n*shard_len]
        flat_spec, flat_spec, P(),   # fopt m, v, step
        ep_opt_specs if use_ep else P(),  # ep opt state
        sec_opt_specs,       # sec opt state (leafwise) or dummy
        batch_spec,          # tokens [pods*n, B_l, S]
        batch_spec if cfg.prefix_embeds else P(),  # prefix embeds or dummy
        P(),                 # weights [n]
        P(),                 # counter
        P(),                 # alive [n]
    )
    out_specs = (
        params_specs, flat_spec, flat_spec, flat_spec, P(),
        ep_opt_specs if use_ep else P(),
        sec_opt_specs,
        P(),                 # metrics (replicated)
    )

    def wrapped(params, master, fm, fv, fstep, ep_state, sec_state, tokens,
                prefix, weights, counter, alive):
        if not cfg.prefix_embeds:
            prefix = None
        if not use_ep:
            ep_state = None
        if not leafwise:
            sec_state = None
        out = per_rank_step(params, master, fm, fv, fstep, ep_state,
                            sec_state, tokens, prefix, weights, counter,
                            alive)
        out = list(out)
        if not use_ep:
            out[5] = jnp.zeros(())
        if not leafwise:
            out[6] = jnp.zeros(())
        return tuple(out)

    shard_fn = jax.shard_map(
        wrapped, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        axis_names=frozenset(manual), check_vma=False)

    jit_fn = jax.jit(shard_fn,
                     donate_argnums=(0, 1, 2, 3, 5, 6) if donate else ())

    # ---- state init -----------------------------------------------------------
    def init_state_fn(params):
        sec_p, _ = partition_tree(params, lambda p: not is_expert_path(p))
        if leafwise:
            flat = jnp.zeros((n,), jnp.float32)  # 1 elem/rank placeholder
        else:
            flat = tree_to_flat(sec_p)
            flat = jnp.pad(flat, (0, padded_size - sec_size))
        state = {
            "params": params,
            "master": flat,
            "fm": jnp.zeros_like(flat),
            "fv": jnp.zeros_like(flat),
            "fstep": jnp.zeros((), jnp.int32),
            "ep_opt": None,
            "sec_opt": sec_opt.init(sec_p) if leafwise else None,
            "step": 0,
        }
        if use_ep:
            _, ep_p = partition_tree(params, lambda p: not is_expert_path(p))
            state["ep_opt"] = ep_opt.init(ep_p)
        return state

    def step_fn(state, tokens, prefix=None, weights=None, counter=0,
                alive=None):
        if weights is None:
            weights = jnp.ones((n,), jnp.float32)
        if alive is None:
            alive = jnp.ones((n,), jnp.float32)
        if prefix is None:
            prefix = jnp.zeros((1,), jnp.float32)  # dummy
        ep_state = state["ep_opt"] if use_ep else jnp.zeros(())
        sec_state = state["sec_opt"] if leafwise else jnp.zeros(())
        with jax.set_mesh(mesh):
            (params, master, fm, fv, fstep, ep_state, sec_state,
             metrics) = jit_fn(
                state["params"], state["master"], state["fm"], state["fv"],
                state["fstep"], ep_state, sec_state, tokens, prefix, weights,
                jnp.asarray(counter, jnp.uint32), alive)
        new_state = {
            "params": params, "master": master, "fm": fm, "fv": fv,
            "fstep": fstep, "ep_opt": ep_state if use_ep else None,
            "sec_opt": sec_state if leafwise else None,
            "step": state["step"] + 1,
        }
        return new_state, jax.tree.map(np.asarray, metrics)

    return TrainStepBundle(
        step_fn=step_fn,
        init_state_fn=init_state_fn,
        state_shardings=None,
        batch_spec=batch_spec,
        sec_size=sec_size,
        padded_size=padded_size,
        jit_fn=jit_fn,
        params_abs=params_abs,
        leafwise=leafwise,
        sec_opt_abs=sec_opt_abs if leafwise else None,
    )
