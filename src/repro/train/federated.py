"""FedAvg with SAFE-secure delta aggregation (the paper's use case).

Cross-organizational federated learning (§1): each learner runs ``k``
local optimizer steps on its private shard, then the *model delta*
Δ_l = θ_l − θ_round is securely aggregated — weighted by local sample
counts via the paper's §5.6 weighted-averaging feature, so no learner
reveals its dataset size — and applied to the shared model.

The whole round is one SPMD program: local steps are a lax.scan over the
per-learner microbatches inside the manual region.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.aggregators import SecureAggregator
from repro.models.transformer import Model
from repro.optim.adamw import AdamW
from repro.train.flatten import flat_to_tree, tree_size, tree_to_flat
from repro.train.loss import next_token_loss


@dataclasses.dataclass
class FederatedBundle:
    round_fn: Any
    init_state_fn: Any


def make_federated_round(
    model: Model,
    aggregator: SecureAggregator,
    mesh: Mesh,
    *,
    local_steps: int = 4,
    local_lr: float = 1e-3,
    learner_axis: str = "data",
    pod_axis: Optional[str] = None,
) -> FederatedBundle:
    """Build one FedAvg round: k local AdamW steps then weighted SAFE
    aggregation of the deltas. Aggregator must have cfg.weighted=True to
    exercise §5.6 (falls back to plain mean otherwise)."""
    cfg = model.cfg
    n = aggregator.cfg.num_learners
    local_opt = AdamW(lr=local_lr, weight_decay=0.0, grad_clip=1.0)

    params_abs = jax.eval_shape(model.init, jax.random.key(0))
    psize = tree_size(params_abs)

    def per_rank_round(params, tokens, weights, counter, alive):
        # tokens: [1, local_steps, B_l, S] for this learner
        tokens = tokens.reshape(tokens.shape[1:])
        my_w = weights[jax.lax.axis_index(learner_axis)]

        opt_state = local_opt.init(params)

        def local_step(carry, batch):
            p, s = carry
            def loss_fn(q):
                logits, aux = model.forward(q, batch)
                return next_token_loss(logits, batch, cfg.prefix_embeds) + aux
            loss, grads = jax.value_and_grad(loss_fn)(p)
            p, s = local_opt.update(grads, s, p)
            return (p, s), loss

        (new_params, _), losses = jax.lax.scan(
            local_step, (params, opt_state), tokens)

        delta = tree_to_flat(new_params) - tree_to_flat(params)
        # §5.6: weighted secure mean of deltas; weights stay private
        avg_delta = aggregator.aggregate(delta, counter, alive=alive,
                                         weights=my_w)
        merged = tree_to_flat(params) + avg_delta
        out_params = flat_to_tree(merged, params)
        metrics = {
            "local_loss": jax.lax.pmean(losses.mean(), learner_axis),
            "delta_norm": jnp.sqrt(jnp.sum(jnp.square(avg_delta))),
        }
        return out_params, metrics

    manual = {learner_axis} | ({pod_axis} if pod_axis else set())
    batch_spec = P((pod_axis, learner_axis) if pod_axis else learner_axis)
    shard_fn = jax.shard_map(
        per_rank_round, mesh=mesh,
        in_specs=(P(), batch_spec, P(), P(), P()),
        out_specs=(P(), P()),
        axis_names=frozenset(manual), check_vma=False)
    jit_fn = jax.jit(shard_fn, donate_argnums=(0,))

    def round_fn(params, tokens, weights=None, counter=0, alive=None):
        if weights is None:
            weights = jnp.ones((n,), jnp.float32)
        if alive is None:
            alive = jnp.ones((n,), jnp.float32)
        with jax.set_mesh(mesh):
            params, metrics = jit_fn(params, tokens, weights,
                                     jnp.asarray(counter, jnp.uint32), alive)
        return params, jax.tree.map(np.asarray, metrics)

    return FederatedBundle(round_fn=round_fn,
                           init_state_fn=lambda p: p)
