"""FedAvg with SAFE-secure delta aggregation (the paper's use case).

Cross-organizational federated learning (§1): each learner runs ``k``
local optimizer steps on its private shard, then the *model delta*
Δ_l = θ_l − θ_round is securely aggregated — weighted by local sample
counts via the paper's §5.6 weighted-averaging feature, so no learner
reveals its dataset size — and applied to the shared model.

Two runtimes consume the same local update:

  * :func:`make_federated_round` — the whole round as one SPMD program
    (local steps are a lax.scan inside the shard_map region, deltas go
    through the device-plane chain of ``core/chain.py``);
  * :func:`make_wire_federated` — per-learner standalone jit of the
    *identical* :func:`make_local_update` body, producing the numpy
    callables :func:`repro.net.client.run_federated_round_net` (one
    round, session rebuilt per call) and
    :func:`repro.net.client.run_federated_rounds_net` (R rounds on one
    persistent broker session — key material, connections and counter
    space amortized across rounds, deltas chunk-streamed through the
    hop-level streaming combine) drive over a real broker
    (docs/PROTOCOL.md §6/§11).

Because both paths share one local-update function and both aggregation
planes share one fixed-point/PRF substrate, a wire round's published
delta is bit-identical to the in-SPMD round for the same seeds
(asserted in tests/test_train.py::test_wire_round_delta_bit_identical).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.aggregators import SecureAggregator
from repro.models.transformer import Model
from repro.optim.adamw import AdamW
from repro.train.flatten import flat_to_tree, tree_size, tree_to_flat
from repro.train.loss import next_token_loss


@dataclasses.dataclass
class FederatedBundle:
    round_fn: Any
    init_state_fn: Any


def make_local_update(
    model: Model,
    *,
    local_steps: int = 4,
    local_lr: float = 1e-3,
) -> Callable[[Any, jax.Array], tuple]:
    """One learner's FedAvg local update, free of collectives.

    Returns ``local_update(params, tokens) -> (delta_flat, mean_loss)``
    where ``tokens`` is int32[local_steps, B, S] (one microbatch per
    local optimizer step) and ``delta_flat`` is f32[P] in the canonical
    :mod:`repro.train.flatten` layout. The function contains no
    ``axis_index``/collective ops, so it composes *inside* a shard_map
    region (``make_federated_round``) and compiles standalone per
    learner (``make_wire_federated``) — the factoring that lets the wire
    plane's learners run real local steps.
    """
    cfg = model.cfg
    local_opt = AdamW(lr=local_lr, weight_decay=0.0, grad_clip=1.0)

    def local_update(params, tokens):
        opt_state = local_opt.init(params)

        def local_step(carry, batch):
            p, s = carry

            def loss_fn(q):
                logits, aux = model.forward(q, batch)
                return next_token_loss(logits, batch, cfg.prefix_embeds) + aux

            loss, grads = jax.value_and_grad(loss_fn)(p)
            p, s = local_opt.update(grads, s, p)
            return (p, s), loss

        (new_params, _), losses = jax.lax.scan(
            local_step, (params, opt_state), tokens)
        delta = tree_to_flat(new_params) - tree_to_flat(params)
        return delta, losses.mean()

    return local_update


def apply_delta(params: Any, avg_delta) -> Any:
    """Merge a published average delta back into the parameter tree —
    the single apply formula both runtimes share."""
    merged = tree_to_flat(params) + jnp.asarray(avg_delta, jnp.float32)
    return flat_to_tree(merged, params)


def make_federated_round(
    model: Model,
    aggregator: SecureAggregator,
    mesh: Mesh,
    *,
    local_steps: int = 4,
    local_lr: float = 1e-3,
    learner_axis: str = "data",
    pod_axis: Optional[str] = None,
    return_delta: bool = False,
) -> FederatedBundle:
    """Build one FedAvg round: k local AdamW steps then weighted SAFE
    aggregation of the deltas. Aggregator must have cfg.weighted=True to
    exercise §5.6 (falls back to plain mean otherwise).

    ``return_delta=True`` adds the published f32[P] ``avg_delta`` to the
    metrics dict — the cross-plane parity hook (tests compare it against
    the wire-trained round's published delta bit for bit).
    """
    n = aggregator.cfg.num_learners
    local_update = make_local_update(model, local_steps=local_steps,
                                     local_lr=local_lr)

    def per_rank_round(params, tokens, weights, counter, alive):
        # tokens: [1, local_steps, B_l, S] for this learner
        tokens = tokens.reshape(tokens.shape[1:])
        my_w = weights[jax.lax.axis_index(learner_axis)]

        delta, loss_mean = local_update(params, tokens)
        # §5.6: weighted secure mean of deltas; weights stay private
        avg_delta = aggregator.aggregate(delta, counter, alive=alive,
                                         weights=my_w)
        out_params = apply_delta(params, avg_delta)
        metrics = {
            "local_loss": jax.lax.pmean(loss_mean, learner_axis),
            "delta_norm": jnp.sqrt(jnp.sum(jnp.square(avg_delta))),
        }
        if return_delta:
            metrics["avg_delta"] = avg_delta
        return out_params, metrics

    manual = {learner_axis} | ({pod_axis} if pod_axis else set())
    batch_spec = P((pod_axis, learner_axis) if pod_axis else learner_axis)
    shard_fn = jax.shard_map(
        per_rank_round, mesh=mesh,
        in_specs=(P(), batch_spec, P(), P(), P()),
        out_specs=(P(), P()),
        axis_names=frozenset(manual), check_vma=False)
    jit_fn = jax.jit(shard_fn, donate_argnums=(0,))

    def round_fn(params, tokens, weights=None, counter=0, alive=None):
        if weights is None:
            weights = jnp.ones((n,), jnp.float32)
        if alive is None:
            alive = jnp.ones((n,), jnp.float32)
        with jax.set_mesh(mesh):
            params, metrics = jit_fn(params, tokens, weights,
                                     jnp.asarray(counter, jnp.uint32), alive)
        return params, jax.tree.map(np.asarray, metrics)

    return FederatedBundle(round_fn=round_fn,
                           init_state_fn=lambda p: p)


@dataclasses.dataclass
class WireFederated:
    """JAX-side half of wire-plane federated training.

    ``local_fns[node]`` computes that learner's f32[P] delta from the
    current params (standalone jit — no mesh, no shard_map), and
    ``apply_fn`` merges a published average delta; both are exactly what
    :func:`repro.net.client.run_federated_round_net` consumes, keeping
    ``repro.net`` JAX-free (callables are injected, never imported).
    """

    local_fns: Dict[int, Callable[[Any], np.ndarray]]
    apply_fn: Callable[[Any, np.ndarray], Any]
    payload_words: int
    last_losses: Dict[int, float]

    def words_per_round(self, weighted: bool = True) -> int:
        """Counter words one aggregation round consumes (the weighted
        payload appends one weight word) — what a persistent session's
        :class:`~repro.core.session.RoundCursor` must advance by, and
        the per-round stride the in-SPMD plane's ``counter=`` must match
        for cross-plane bit-parity."""
        return self.payload_words + (1 if weighted else 0)


def make_wire_federated(
    model: Model,
    tokens_by_learner: Dict[int, np.ndarray],
    *,
    local_steps: int = 4,
    local_lr: float = 1e-3,
) -> WireFederated:
    """Build per-learner local-update callables for the wire runtime.

    ``tokens_by_learner`` maps 1-based node ids (paper numbering — the
    same ids the broker chains carry) to that org's private
    int32[local_steps, B, S] microbatches. Every callable shares ONE
    compiled program (learners differ only in data), so an n-org round
    compiles once.
    """
    local_update = make_local_update(model, local_steps=local_steps,
                                     local_lr=local_lr)
    step = jax.jit(local_update)
    params_abs = jax.eval_shape(model.init, jax.random.key(0))
    psize = tree_size(params_abs)
    losses: Dict[int, float] = {}

    def make_fn(node: int, toks: np.ndarray):
        toks = jnp.asarray(toks)

        def fn(params) -> np.ndarray:
            delta, loss = step(params, toks)
            losses[node] = float(loss)
            return np.asarray(delta, np.float32)

        return fn

    local_fns = {node: make_fn(node, toks)
                 for node, toks in sorted(tokens_by_learner.items())}
    return WireFederated(local_fns=local_fns, apply_fn=apply_delta,
                         payload_words=psize, last_losses=losses)
