"""JSONL metrics writer + simple console progress."""
from __future__ import annotations

import json
import os
import time
from typing import Optional


class MetricsLogger:
    def __init__(self, path: Optional[str] = None, print_every: int = 10):
        self.path = path
        self.print_every = print_every
        self._fh = None
        if path:
            os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
            self._fh = open(path, "a", buffering=1)
        self._t0 = time.time()

    def log(self, step: int, **metrics) -> None:
        rec = {"step": step, "time": round(time.time() - self._t0, 3)}
        rec.update({k: float(v) for k, v in metrics.items()})
        if self._fh:
            self._fh.write(json.dumps(rec) + "\n")
        if step % self.print_every == 0:
            kv = " ".join(f"{k}={v:.4f}" if isinstance(v, float) else f"{k}={v}"
                          for k, v in rec.items() if k != "time")
            print(f"[{rec['time']:8.1f}s] {kv}", flush=True)

    def close(self) -> None:
        if self._fh:
            self._fh.close()
