"""Mixture-of-Experts MLP with capacity-based dispatch.

Production layout (MaxText/Switch-style, flop-honest):
  1. router top-k over E experts,
  2. sort token→expert assignments, capacity-capped scatter into
     per-expert buffers [E, C, d] (dropped tokens pass through the
     residual unchanged),
  3. batched expert matmuls [E, C, d] × [E, d, ff] — E·C·d·ff flops,
     i.e. top_k/E of the dense-all-experts cost,
  4. weighted scatter-add back.

Expert weights are sharded over the 'model' axis (expert parallel):
GSPMD turns the dispatch gather/scatter into the all-to-all that
dominates MoE roofline collectives. The auxiliary load-balance loss is
returned to the caller (summed into the train loss).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import _dense_init, mshard


def moe_init(rng, d: int, moe_cfg) -> dict:
    E, ff = moe_cfg.num_experts, moe_cfg.expert_d_ff
    ks = jax.random.split(rng, 5)
    params = {
        "router": _dense_init(ks[0], (d, E), scale=0.02),
        "wi": _dense_init(ks[1], (E, d, ff)),
        "wg": _dense_init(ks[2], (E, d, ff)),
        "wo": _dense_init(ks[3], (E, ff, d)),
    }
    if moe_cfg.num_shared_experts:
        s = moe_cfg.num_shared_experts
        params["shared_wi"] = _dense_init(ks[4], (d, s * ff))
        params["shared_wg"] = _dense_init(ks[4], (d, s * ff))
        params["shared_wo"] = _dense_init(ks[4], (s * ff, d))
    return params


def moe_apply(params: dict, x: jax.Array, moe_cfg, ep_axis=None,
              ep_ranks: int = 1) -> tuple[jax.Array, jax.Array]:
    """x: [B, S, d] -> (y, aux_loss).

    ep_axis: when set (giant-MoE train path), expert weights arrive as the
    *local shard* [E/ep_ranks, ...] of a manual mesh axis and dispatch
    goes through an explicit all_to_all over that axis (DeepSpeed-MoE-style
    expert parallelism over the learner axis; DESIGN.md §3 caveat: expert
    gradients are combined by the a2a transpose, outside the SAFE boundary).
    """
    if ep_axis is not None:
        return _moe_apply_ep(params, x, moe_cfg, ep_axis, ep_ranks)
    B, S, d = x.shape
    E, k = moe_cfg.num_experts, moe_cfg.top_k
    capacity_factor = moe_cfg.capacity_factor
    T = B * S
    xt = x.reshape(T, d)

    logits = (xt @ params["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, assign = jax.lax.top_k(probs, k)  # [T, k]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # Switch aux loss: E * Σ_e (fraction routed to e)·(mean router prob e)
    counts = jnp.zeros((E,), jnp.float32).at[assign.reshape(-1)].add(1.0)
    frac = counts / (T * k)
    aux = E * jnp.sum(frac * probs.mean(0)) * moe_cfg.aux_loss_weight

    # ---- capacity-capped dispatch ----------------------------------------
    C = int(np.ceil(T * k / E * capacity_factor))
    C = max(8, min(C, T))
    flat_assign = assign.reshape(-1)  # [T*k]
    order = jnp.argsort(flat_assign, stable=True)
    sorted_e = flat_assign[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(E))
    pos_in_e = jnp.arange(T * k) - seg_start[sorted_e]
    keep = pos_in_e < C
    # scatter slot ids: dropped entries go to a scratch row
    slot = jnp.where(keep, sorted_e * C + pos_in_e, E * C)
    token_of = order // k
    dispatch_tok = jnp.full((E * C + 1,), T, jnp.int32).at[slot].set(
        token_of.astype(jnp.int32))[: E * C]

    xpad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], 0)
    xe = xpad[dispatch_tok].reshape(E, C, d)  # [E, C, d] — a2a under GSPMD
    # anchor to the expert-weight layout (experts over 'data', expert-ff
    # over 'model' — models/sharding.py); anchoring E over 'model' here
    # would force a full reshard of the dispatch buffers
    xe = mshard(xe, "data", None, None)

    h = jnp.einsum("ecd,edf->ecf", xe, params["wi"].astype(x.dtype))
    h = h * jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe,
                                   params["wg"].astype(x.dtype)))
    h = mshard(h, "data", None, "model")
    ye = jnp.einsum("ecf,efd->ecd", h, params["wo"].astype(x.dtype))
    ye = mshard(ye, "data", None, None)

    # ---- weighted combine --------------------------------------------------
    gates_sorted = gate_vals.reshape(-1)[order]
    gate_of_slot = jnp.zeros((E * C + 1,), x.dtype).at[slot].set(
        gates_sorted.astype(x.dtype))[: E * C]
    contrib = ye.reshape(E * C, d) * gate_of_slot[:, None]
    y = jnp.zeros((T + 1, d), x.dtype).at[dispatch_tok].add(contrib)[:T]

    if moe_cfg.num_shared_experts:
        hs = (xt @ params["shared_wi"].astype(x.dtype)) * jax.nn.silu(
            xt @ params["shared_wg"].astype(x.dtype))
        y = y + hs @ params["shared_wo"].astype(x.dtype)

    return y.reshape(B, S, d), aux


def _dispatch_indices(probs, k: int, E: int, T: int, capacity_factor: float):
    """Shared routing plumbing: returns (dispatch_tok[E*C], gate_of_slot,
    C, aux_frac) — see moe_apply for the algorithm."""
    gate_vals, assign = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    counts = jnp.zeros((E,), jnp.float32).at[assign.reshape(-1)].add(1.0)
    frac = counts / (T * k)
    C = int(np.ceil(T * k / E * capacity_factor))
    C = max(4, min(C, T))
    flat_assign = assign.reshape(-1)
    order = jnp.argsort(flat_assign, stable=True)
    sorted_e = flat_assign[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(E))
    pos_in_e = jnp.arange(T * k) - seg_start[sorted_e]
    keep = pos_in_e < C
    slot = jnp.where(keep, sorted_e * C + pos_in_e, E * C)
    token_of = order // k
    dispatch_tok = jnp.full((E * C + 1,), T, jnp.int32).at[slot].set(
        token_of.astype(jnp.int32))[: E * C]
    gates_sorted = gate_vals.reshape(-1)[order]
    gate_of_slot = jnp.zeros((E * C + 1,), jnp.float32).at[slot].set(
        gates_sorted)[: E * C]
    return dispatch_tok, gate_of_slot, C, frac


def _moe_apply_ep(params: dict, x: jax.Array, moe_cfg, axis: str,
                  n_ranks: int) -> tuple[jax.Array, jax.Array]:
    """Manual expert parallelism over a shard_map-manual mesh axis.

    params['wi'/'wg'/'wo'] are the LOCAL expert shards [E/n, d, ff];
    the router (and shared experts) are replicated. Token buffers do a
    round trip: dispatch [n, E_loc, C, d] --a2a--> compute --a2a--> combine.
    """
    B, S, d = x.shape
    E, k = moe_cfg.num_experts, moe_cfg.top_k
    E_loc = E // n_ranks
    T = B * S
    xt = x.reshape(T, d)

    logits = (xt @ params["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    dispatch_tok, gate_of_slot, C, frac = _dispatch_indices(
        probs, k, E, T, moe_cfg.capacity_factor)
    aux = E * jnp.sum(frac * probs.mean(0)) * moe_cfg.aux_loss_weight

    xpad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], 0)
    xe = xpad[dispatch_tok].reshape(n_ranks, E_loc, C, d)
    # exchange: rank r receives, from every source rank s, the tokens
    # destined for r's local experts — [n_ranks(source), E_loc, C, d]
    xe = jax.lax.all_to_all(xe, axis, split_axis=0, concat_axis=0, tiled=True)
    xe = xe.reshape(n_ranks, E_loc, C, d).transpose(1, 0, 2, 3) \
        .reshape(E_loc, n_ranks * C, d)

    h = jnp.einsum("ecd,edf->ecf", xe, params["wi"].astype(x.dtype))
    h = h * jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe,
                                   params["wg"].astype(x.dtype)))
    ye = jnp.einsum("ecf,efd->ecd", h, params["wo"].astype(x.dtype))

    ye = ye.reshape(E_loc, n_ranks, C, d).transpose(1, 0, 2, 3)
    ye = jax.lax.all_to_all(ye, axis, split_axis=0, concat_axis=0, tiled=True)
    ye = ye.reshape(E, C, d)  # back to sender, global-expert major

    contrib = ye.reshape(E * C, d) * gate_of_slot[:, None].astype(x.dtype)
    y = jnp.zeros((T + 1, d), x.dtype).at[dispatch_tok].add(contrib)[:T]

    if moe_cfg.num_shared_experts:
        hs = (xt @ params["shared_wi"].astype(x.dtype)) * jax.nn.silu(
            xt @ params["shared_wg"].astype(x.dtype))
        y = y + hs @ params["shared_wo"].astype(x.dtype)

    return y.reshape(B, S, d), aux
