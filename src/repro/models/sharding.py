"""Parameter PartitionSpecs (Megatron TP + expert-parallel layout).

Rules are path-based over the tree built by ``Model.init``:

  embed / lm_head       : vocab over 'model'
  attn wq/wk/wv         : [U, d, H·hd]   -> heads over 'model'
  attn wo               : [U, H·hd, d]   -> 'model' on the contracted dim
  mlp wi/wg             : [U, d, ff]     -> ff over 'model'
  mlp wo                : [U, ff, d]     -> 'model' on ff
  moe wi/wg             : [U, E, d, f]   -> experts over 'data', f over 'model'
  moe wo                : [U, E, f, d]   -> experts over 'data', f over 'model'
  mamba in_proj/out_proj, rwkv projections: like mlp
  norms / scalars       : replicated

MoE experts ride the 'data' axis (expert parallelism — DESIGN.md §3):
that matches the manual-EP train path (shard_map in_specs take the same
slice) and gives GSPMD the all-to-all layout when serving.

``Model.init`` params are replicated over 'data' otherwise: the paper's
cross-org semantics (every learner holds the model) — the ZeRO-1 master
vector in the train step is where 'data'-axis state sharding happens.
"""
from __future__ import annotations

import numpy as np
from jax.sharding import PartitionSpec as P

import jax

from repro.models.config import ModelConfig
from repro.train.flatten import _path_str

_COL = {"wq", "wk", "wv", "wi", "wg", "w_proj", "in_proj",
        "wr", "shared_wi", "shared_wg"}
_ROW = {"wo", "out_proj", "shared_wo"}


def _spec_for(path: str, leaf, cfg: ModelConfig) -> P:
    name = path.rsplit("/", 1)[-1]
    nd = np.ndim(leaf)
    if name in ("embed", "lm_head"):
        # [V, d] or [nc, V, d]
        return P("model", None) if nd == 2 else P(None, "model", None)
    moe = "moe/" in path
    if moe and name in ("wi", "wg", "wo"):
        # [U, E, d/f, f/d]: experts over 'data', expert-ff over 'model'
        if name == "wo":
            return P(None, "data", "model", None)
        return P(None, "data", None, "model")
    if name == "router":
        return P(*([None] * nd))
    if name in _COL and nd >= 2:
        return P(*([None] * (nd - 2)), None, "model")
    if name in _ROW and nd >= 2:
        return P(*([None] * (nd - 2)), "model", None)
    return P(*([None] * nd))


def sanitize_spec(spec: P, shape, axes_sizes: dict) -> P:
    """Drop named axes from dims they don't divide (XLA requires exact
    tiling for explicit input shardings — e.g. internvl2's vocab 151655
    is not divisible by 16)."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, part in zip(shape, parts):
        if part is None:
            out.append(None)
            continue
        names = part if isinstance(part, tuple) else (part,)
        size = int(np.prod([axes_sizes.get(a, 1) for a in names]))
        out.append(part if dim % size == 0 else None)
    return P(*out)


def param_pspecs(cfg: ModelConfig, params_abs, axes_sizes: dict | None = None):
    """Pytree of PartitionSpec matching ``Model.init``'s structure."""
    def build(p, x):
        spec = _spec_for(_path_str(p), x, cfg)
        if axes_sizes:
            spec = sanitize_spec(spec, np.shape(x), axes_sizes)
        return spec
    return jax.tree_util.tree_map_with_path(build, params_abs)
