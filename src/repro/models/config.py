"""Model configuration for the architecture zoo.

A model is a pattern of block kinds repeated over the depth, over a shared
decoder substrate. Block kinds:

  'global'   — full-attention GQA transformer block
  'local'    — sliding-window GQA block (window tokens)
  'chunked'  — chunked-local GQA block (attend within fixed chunks;
               llama4 iRoPE-style)
  'moe'      — full-attention block with MoE MLP
  'local_moe'/'chunked_moe' — windowed/chunked attention with MoE MLP
  'mamba2'   — Mamba2 (SSD) state-space block
  'rwkv6'    — RWKV6 (Finch) data-dependent-decay linear attention block
  'shared_attn' — zamba2-style *shared-weight* global attention block
               (one param set reused at every occurrence)

The depth pattern is ``pattern`` repeated ``n_units`` times (layers =
n_units * len(pattern)); parameters are stacked per pattern position so
the forward pass is a ``lax.scan`` over units — compile time is
O(len(pattern)), not O(layers).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

ATTENTION_KINDS = ("global", "local", "chunked", "moe", "local_moe", "chunked_moe",
                   "shared_attn")
RECURRENT_KINDS = ("mamba2", "rwkv6")
BLOCK_KINDS = ATTENTION_KINDS + RECURRENT_KINDS


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    expert_d_ff: int = 1024
    num_shared_experts: int = 0  # llama4-style always-on shared expert
    aux_loss_weight: float = 0.01
    capacity_factor: float = 1.25  # tokens over capacity are dropped


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    pattern: Sequence[str] = ("global",)
    window: int = 4096  # sliding window for 'local' blocks
    chunk: int = 8192  # chunk size for 'chunked' blocks
    moe: Optional[MoEConfig] = None
    # attention details
    qk_norm: bool = False
    logit_softcap: Optional[float] = None  # gemma2 final-logit softcap
    attn_softcap: Optional[float] = None  # gemma2 attention softcap
    rope_theta: float = 10000.0
    # ssm details
    ssm_state: int = 64  # mamba2 state dim per head
    ssm_heads: Optional[int] = None
    rwkv_head_size: int = 64
    # frontends (carve-out stubs): number of prefix embedding positions
    # provided by the modality encoder, or 0 for pure text
    prefix_embeds: int = 0
    # musicgen: parallel codebooks (embedding sum + per-codebook heads)
    num_codebooks: int = 1
    # recurrent blocks carry their own MLP (rwkv channel-mix) or not
    # (zamba2-style: only the shared attention block has an MLP)
    recurrent_mlp: bool = True
    # training details
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    remat: bool = True  # activation-checkpoint each block in train_step
    # sharding: shard params over 'data' too (FSDP) when large
    fsdp: bool = False
    # supports the long_500k shape (sub-quadratic path exists)
    subquadratic: bool = False
    dtype: str = "bfloat16"
    # manual expert parallelism (set by the train-step builder for giant
    # MoEs): experts sharded over this manual mesh axis, dispatch via
    # explicit all_to_all. None -> GSPMD-auto expert sharding.
    ep_axis: Optional[str] = None
    ep_ranks: int = 1

    def __post_init__(self):
        if self.n_layers % len(self.pattern) != 0:
            raise ValueError(
                f"{self.arch_id}: n_layers={self.n_layers} not divisible by "
                f"pattern length {len(self.pattern)}")
        for k in self.pattern:
            if k not in BLOCK_KINDS:
                raise ValueError(f"unknown block kind {k!r}")
        if self.n_heads % max(self.n_kv_heads, 1) != 0:
            raise ValueError("n_heads must be a multiple of n_kv_heads")

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def n_units(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def uses_moe(self) -> bool:
        return any("moe" in k for k in self.pattern)

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, ff, hd = self.d_model, self.d_ff, self.resolved_head_dim
        nh, nkv = self.n_heads, self.n_kv_heads
        total = self.vocab * d * self.num_codebooks  # embed
        if not self.tie_embeddings:
            total += self.vocab * d * self.num_codebooks
        shared_attn_counted = False
        for kind in self.pattern:
            blocks = self.n_units
            if kind == "shared_attn":
                if shared_attn_counted:
                    continue
                blocks = 1
                shared_attn_counted = True
            attn = d * hd * (nh + 2 * nkv) + nh * hd * d
            if kind in ("mamba2",):
                nh_s = self.ssm_heads or (self.d_model // 64)
                inner = nh_s * 64
                attn = d * (2 * inner + 2 * nh_s * self.ssm_state) + inner * d + nh_s * 2
            if kind == "rwkv6":
                H = d // self.rwkv_head_size
                attn = d * d * 4 + d * d  # r,k,v,g(w) projections + out
            if "moe" in kind and self.moe is not None:
                m = self.moe
                mlp = m.num_experts * 3 * d * m.expert_d_ff + d * m.num_experts
                mlp += m.num_shared_experts * 3 * d * m.expert_d_ff
            elif kind in ("mamba2", "rwkv6") and not self.recurrent_mlp:
                mlp = 0
            else:
                mlp = 3 * d * ff
            total += blocks * (attn + mlp + 2 * d)
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if not self.uses_moe or self.moe is None:
            return self.param_count()
        m = self.moe
        full_mlp = m.num_experts * 3 * self.d_model * m.expert_d_ff
        act_mlp = (m.top_k + m.num_shared_experts) * 3 * self.d_model * m.expert_d_ff
        moe_blocks = sum(1 for k in self.pattern if "moe" in k) * self.n_units
        return int(self.param_count() - moe_blocks * (full_mlp - act_mlp))


def reduced(cfg: ModelConfig, n_layers: int = 2, d_model: int = 256,
            vocab: int = 512) -> ModelConfig:
    """Smoke-test variant of the same family (<= 4 experts, d_model <= 512).

    Keeps the pattern (truncated/repeated to n_layers), head grouping
    ratio, and block kinds so the smoke test exercises the same code path
    as the full config.
    """
    pattern = tuple(cfg.pattern)
    if n_layers % len(pattern) != 0:
        # shrink the unit but keep at least one of each distinct kind
        kinds = list(dict.fromkeys(pattern))
        pattern = tuple(kinds[: max(1, n_layers)])
        while n_layers % len(pattern) != 0:
            pattern = pattern[:-1]
    hd = 64
    nh = max(2, d_model // hd)
    ratio = max(1, cfg.n_heads // cfg.n_kv_heads)
    nkv = max(1, nh // ratio)
    nh = nkv * ratio
    moe = None
    if cfg.moe is not None:
        moe = MoEConfig(num_experts=min(4, cfg.moe.num_experts),
                        top_k=min(2, cfg.moe.top_k),
                        expert_d_ff=d_model * 2,
                        num_shared_experts=min(1, cfg.moe.num_shared_experts),
                        # no capacity drops in smoke tests: keeps the
                        # decode-vs-forward consistency check exact
                        capacity_factor=4.0)
    return dataclasses.replace(
        cfg,
        arch_id=cfg.arch_id + "-smoke",
        n_layers=n_layers,
        d_model=d_model,
        n_heads=nh,
        n_kv_heads=nkv,
        head_dim=hd,
        d_ff=d_model * 3,
        vocab=vocab,
        pattern=pattern,
        window=64,
        chunk=64,
        moe=moe,
        ssm_state=16,
        ssm_heads=max(2, d_model // 64),
        rwkv_head_size=32,
        prefix_embeds=min(cfg.prefix_embeds, 8),
        remat=False,
        fsdp=False,
    )
