"""Model zoo: composable decoder substrate + the 10 assigned architectures."""
from repro.models.config import ModelConfig, MoEConfig, reduced
from repro.models.transformer import Model, block_init_cache

__all__ = ["ModelConfig", "MoEConfig", "reduced", "Model", "block_init_cache"]
