"""Recurrent token mixers: Mamba2 (SSD) and RWKV6 (Finch).

Both are linear-recurrence blocks with O(1) decode state — the
sub-quadratic families that carry the long_500k shape. Prefill uses the
chunked (matrix) form: a ``lax.scan`` over chunks with intra-chunk
einsums, which keeps the score tensors bounded ([L, L] per chunk) and
maps onto the MXU; decode is the single-step recurrence.

Simplifications vs the source models (documented in DESIGN.md §5):
  * Mamba2: no depthwise conv1d prefix, single B/C group.
  * RWKV6: learned-constant token-shift lerp (not the LoRA-MLP shift);
    data-dependent decay kept (the defining Finch feature).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import _dense_init, mshard

CHUNK = 64  # prefill chunk length (bounds the [L, L, H, hd] decay tensors)


# ---------------------------------------------------------------------------
# Mamba2 (SSD): S_t = a_t·S_{t-1} + dt_t·(B_t ⊗ x_t),  y_t = S_t·C_t + D·x_t
#   a_t = exp(dt_t * A_h)   (A_h < 0 per head; dt via softplus)
# ---------------------------------------------------------------------------


def mamba2_init(rng, cfg) -> dict:
    d = cfg.d_model
    H = cfg.ssm_heads or (d // 64)
    hd = 64
    N = cfg.ssm_state
    inner = H * hd
    ks = jax.random.split(rng, 4)
    return {
        # fused input projection: [z (gate), x_inner, B, C, dt]
        "in_proj": _dense_init(ks[0], (d, 2 * inner + 2 * N + H)),
        "out_proj": _dense_init(ks[1], (inner, d)),
        "A_log": jnp.zeros((H,), jnp.float32),  # A = -exp(A_log)
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm_scale": jnp.zeros((inner,), jnp.float32),
    }


def _mamba2_split(params, x, cfg):
    d = cfg.d_model
    H = cfg.ssm_heads or (d // 64)
    hd, N = 64, cfg.ssm_state
    inner = H * hd
    proj = x @ params["in_proj"].astype(x.dtype)
    z, xi, Bm, Cm, dt = jnp.split(
        proj, [inner, 2 * inner, 2 * inner + N, 2 * inner + 2 * N], axis=-1)
    B_, S_ = x.shape[0], x.shape[1]
    xi = xi.reshape(B_, S_, H, hd)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # [B,S,H]
    a = jnp.exp(-jnp.exp(params["A_log"]) * dt)  # decay in (0,1)
    return z, xi, Bm.astype(jnp.float32), Cm.astype(jnp.float32), dt, a


def mamba2_apply(params, x, cfg, cache: Optional[dict] = None):
    """x: [B, S, d]. cache: {'state': f32[B,H,hd,N], 'pos'} for decode."""
    B_, S_, d = x.shape
    H = cfg.ssm_heads or (d // 64)
    hd, N = 64, cfg.ssm_state
    z, xi, Bm, Cm, dt, a = _mamba2_split(params, x, cfg)
    xif = xi.astype(jnp.float32)

    if cache is not None and S_ == 1:  # single-step decode
        st = cache["state"]  # [B,H,hd,N]
        st = st * a[:, 0, :, None, None] + jnp.einsum(
            "bh,bhp,bn->bhpn", dt[:, 0], xif[:, 0], Bm[:, 0])
        y = jnp.einsum("bhpn,bn->bhp", st, Cm[:, 0])[:, None]  # [B,1,H,hd]
        new_cache = {"state": st, "pos": cache["pos"] + 1}
    else:
        L = min(CHUNK, S_)
        assert S_ % L == 0, "sequence must be divisible by the scan chunk"
        nc = S_ // L

        def chunk_step(st, inp):
            xc, Bc, Cc, dtc, ac = inp  # [B,L,...]
            clog = jnp.cumsum(jnp.log(jnp.maximum(ac, 1e-20)), axis=1)  # [B,L,H]
            # carry-in: y_state[t] = exp(clog_t)·C_t·S_prev
            y_in = jnp.einsum("blh,bhpn,bln->blhp", jnp.exp(clog), st, Cc)
            # intra-chunk: M[t,s] = exp(clog_t - clog_s)·dt_s  (s <= t)
            rel = jnp.exp(clog[:, :, None, :] - clog[:, None, :, :])  # [B,L,L,H]
            causal = jnp.tril(jnp.ones((L, L), bool))
            M = jnp.where(causal[None, :, :, None], rel, 0.0) * dtc[:, None, :, :]
            ctb = jnp.einsum("bln,bsn->bls", Cc, Bc)  # [B,L,L]
            y_intra = jnp.einsum("blsh,bls,bshp->blhp", M, ctb, xc)
            # state update
            decay_to_end = jnp.exp(clog[:, -1:, :] - clog)  # [B,L,H]
            st_new = st * jnp.exp(clog[:, -1])[:, :, None, None] + jnp.einsum(
                "blh,blh,blhp,bln->bhpn", decay_to_end, dtc, xc, Bc)
            return st_new, y_in + y_intra

        st0 = cache["state"] if cache is not None else \
            jnp.zeros((B_, H, hd, N), jnp.float32)
        inps = (
            xif.reshape(B_, nc, L, H, hd).transpose(1, 0, 2, 3, 4),
            Bm.reshape(B_, nc, L, N).transpose(1, 0, 2, 3),
            Cm.reshape(B_, nc, L, N).transpose(1, 0, 2, 3),
            dt.reshape(B_, nc, L, H).transpose(1, 0, 2, 3),
            a.reshape(B_, nc, L, H).transpose(1, 0, 2, 3),
        )
        st, ys = jax.lax.scan(chunk_step, st0, inps)
        y = ys.transpose(1, 0, 2, 3, 4).reshape(B_, S_, H, hd)
        new_cache = None if cache is None else \
            {"state": st, "pos": cache["pos"] + S_}

    y = y + params["D"][None, None, :, None] * xif
    y = y.reshape(B_, S_, H * hd).astype(x.dtype)
    # gated RMSNorm (mamba2's norm-before-out)
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    yf = yf * jax.lax.rsqrt(var + 1e-6) * (1.0 + params["norm_scale"])
    out = yf.astype(x.dtype) @ params["out_proj"].astype(x.dtype)
    return mshard(out, None, None, None), new_cache


def mamba2_init_cache(cfg, batch: int, dtype=jnp.float32) -> dict:
    H = cfg.ssm_heads or (cfg.d_model // 64)
    return {"state": jnp.zeros((batch, H, 64, cfg.ssm_state), jnp.float32),
            "pos": jnp.zeros((batch,), jnp.int32)}


# ---------------------------------------------------------------------------
# RWKV6 (Finch): S_t = diag(w_t)·S_{t-1} + k_t ⊗ v_t
#   y_t = r_t · (diag(u)·k_t ⊗ v_t + S_{t-1}),  w_t data-dependent
# ---------------------------------------------------------------------------


def rwkv6_init(rng, cfg) -> dict:
    d = cfg.d_model
    hd = cfg.rwkv_head_size
    H = d // hd
    ks = jax.random.split(rng, 8)
    return {
        "wr": _dense_init(ks[0], (d, d)),
        "wk": _dense_init(ks[1], (d, d)),
        "wv": _dense_init(ks[2], (d, d)),
        "wg": _dense_init(ks[3], (d, d)),
        "wo": _dense_init(ks[4], (d, d)),
        # data-dependent decay: w = exp(-exp(w0 + x @ w_proj))
        "w0": jnp.full((d,), -2.0, jnp.float32),
        "w_proj": _dense_init(ks[5], (d, d), scale=0.01),
        "u": jnp.zeros((H, hd), jnp.float32),  # per-head bonus
        # token-shift lerp coefficients per projection
        "mu": jnp.full((5, d), 0.5, jnp.float32),
        "ln_scale": jnp.zeros((d,), jnp.float32),
    }


def _rwkv_shift(x, prev):
    """Token shift: x_{t-1} per position (prev carries the last token)."""
    shifted = jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)
    return shifted


def rwkv6_apply(params, x, cfg, cache: Optional[dict] = None):
    """x: [B, S, d]. cache: {'state': f32[B,H,hd,hd], 'prev': [B,d], 'pos'}."""
    B_, S_, d = x.shape
    hd = cfg.rwkv_head_size
    H = d // hd

    prev = cache["prev"].astype(x.dtype) if cache is not None else \
        jnp.zeros((B_, d), x.dtype)
    xs = _rwkv_shift(x, prev)
    mu = params["mu"].astype(x.dtype)
    xr = x + mu[0] * (xs - x)
    xk = x + mu[1] * (xs - x)
    xv = x + mu[2] * (xs - x)
    xw = x + mu[3] * (xs - x)
    xg = x + mu[4] * (xs - x)

    r = (xr @ params["wr"].astype(x.dtype)).reshape(B_, S_, H, hd)
    k = (xk @ params["wk"].astype(x.dtype)).reshape(B_, S_, H, hd)
    v = (xv @ params["wv"].astype(x.dtype)).reshape(B_, S_, H, hd)
    g = xg @ params["wg"].astype(x.dtype)
    # data-dependent decay (the Finch contribution, arXiv:2404.05892)
    logw = -jnp.exp(params["w0"] + (xw @ params["w_proj"].astype(x.dtype))
                    .astype(jnp.float32))  # [B,S,d] in (-inf, 0)
    logw = logw.reshape(B_, S_, H, hd)
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    u = params["u"]

    if cache is not None and S_ == 1:  # decode
        st = cache["state"]  # [B,H,hd(key),hd(value)]
        kv = jnp.einsum("bhc,bhw->bhcw", kf[:, 0], vf[:, 0])
        y = jnp.einsum("bhc,bhcw->bhw", rf[:, 0], st + u[None, :, :, None] * kv)
        st = jnp.exp(logw[:, 0])[..., None] * st + kv
        y = y[:, None]  # [B,1,H,hd]
        new_cache = {"state": st, "prev": x[:, -1, :], "pos": cache["pos"] + 1}
    else:
        L = min(CHUNK, S_)
        assert S_ % L == 0
        nc = S_ // L

        def chunk_step(st, inp):
            rc, kc, vc, lwc = inp  # [B,L,H,*]
            clog = jnp.cumsum(lwc, axis=1)  # [B,L,H,hd] inclusive
            # carry-in uses state BEFORE this step: decay exp(clog_{t-1})
            clog_prev = clog - lwc  # exclusive cumsum
            y_in = jnp.einsum("blhc,bhcw->blhw", rc * jnp.exp(clog_prev), st)
            # intra: s < t strictly; decay exp(clog_{t-1} - clog_s)
            Dm = jnp.exp(clog_prev[:, :, None] - clog[:, None, :])  # [B,L,L,H,hd]
            strict = jnp.tril(jnp.ones((L, L), bool), k=-1)
            Dm = jnp.where(strict[None, :, :, None, None], Dm, 0.0)
            att = jnp.einsum("blhc,blshc,bshc->blsh", rc, Dm, kc)
            y_intra = jnp.einsum("blsh,bshw->blhw", att, vc)
            # bonus (current token)
            y_bonus = jnp.einsum("blhc,hc,blhc,blhw->blhw",
                                 rc, u, kc, vc)
            # state update: S_new = diag(exp(clog_L)) S + Σ_s exp(clog_L-clog_s) k_s⊗v_s
            dte = jnp.exp(clog[:, -1:, :] - clog)  # [B,L,H,hd]
            st_new = jnp.exp(clog[:, -1])[..., None] * st + jnp.einsum(
                "blhc,blhc,blhw->bhcw", dte, kc, vc)
            return st_new, y_in + y_intra + y_bonus

        st0 = cache["state"] if cache is not None else \
            jnp.zeros((B_, H, hd, hd), jnp.float32)
        inps = tuple(t.reshape(B_, nc, L, H, hd).transpose(1, 0, 2, 3, 4)
                     for t in (rf, kf, vf, logw))
        st, ys = jax.lax.scan(chunk_step, st0, inps)
        y = ys.transpose(1, 0, 2, 3, 4)  # [B,nc,L,H,hd]
        y = y.reshape(B_, S_, H, hd)
        new_cache = None if cache is None else \
            {"state": st, "prev": x[:, -1, :], "pos": cache["pos"] + S_}

    # per-head groupnorm, then output gate
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + 1e-6)
    y = y.reshape(B_, S_, d) * (1.0 + params["ln_scale"])
    y = y.astype(x.dtype) * jax.nn.silu(g)
    out = y @ params["wo"].astype(x.dtype)
    return mshard(out, None, None, None), new_cache


def rwkv6_init_cache(cfg, batch: int, d: int) -> dict:
    hd = cfg.rwkv_head_size
    H = d // hd
    return {"state": jnp.zeros((batch, H, hd, hd), jnp.float32),
            "prev": jnp.zeros((batch, d), jnp.bfloat16),
            "pos": jnp.zeros((batch,), jnp.int32)}
