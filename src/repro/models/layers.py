"""Shared decoder substrate: norms, RoPE, GQA attention, gated MLP.

Functional style: ``*_init(rng, ...) -> params`` (pytrees of jnp arrays)
and ``*_apply(params, x, ...)``. Tensor-parallel sharding is GSPMD-auto
over the 'model' axis; ``mshard`` drops activation anchors so the
propagation picks head/ff sharding (Megatron layout) instead of
replicating.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


def mshard(x: jax.Array, *spec) -> jax.Array:
    """Best-effort sharding anchor (no-op outside a mesh context)."""
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except (ValueError, RuntimeError):
        return x


def _dense_init(rng, shape, scale=None):
    fan_in = shape[0]
    scale = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return jax.random.normal(rng, shape, jnp.float32) * scale


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def rmsnorm_init(d: int):
    return {"scale": jnp.zeros((d,), jnp.float32)}  # (1 + scale) gemma-style


def rmsnorm(params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + params["scale"])).astype(dt)


def rmsnorm_head(scale: jax.Array, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Per-head qk-norm (qwen3): normalize the trailing head_dim."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding, rotate-half convention.

    x: [..., S, H, hd]; positions: broadcastable to [..., S]."""
    hd = x.shape[-1]
    freqs = 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    cos = jnp.cos(angles)[..., None, :]  # [..., S, 1, hd/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------


def attention_init(rng, cfg) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nh, nkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(rng, 4)
    params = {
        "wq": _dense_init(ks[0], (d, nh * hd)),
        "wk": _dense_init(ks[1], (d, nkv * hd)),
        "wv": _dense_init(ks[2], (d, nkv * hd)),
        "wo": _dense_init(ks[3], (nh * hd, d)),
    }
    if cfg.qk_norm:
        params["q_norm"] = jnp.zeros((hd,), jnp.float32)
        params["k_norm"] = jnp.zeros((hd,), jnp.float32)
    return params


def _attn_mask(q_pos: jax.Array, k_pos: jax.Array, kind: str, window: int,
               chunk: int) -> jax.Array:
    """[..., Sq, Sk] boolean mask. q_pos/k_pos: absolute positions."""
    causal = q_pos[..., :, None] >= k_pos[..., None, :]
    if kind == "local":
        causal &= (q_pos[..., :, None] - k_pos[..., None, :]) < window
    elif kind == "chunked":
        causal &= (q_pos[..., :, None] // chunk) == (k_pos[..., None, :] // chunk)
    return causal


def _dense_attention(qg, k_all, v_all, q_pos, k_pos, valid, cfg, base_kind):
    """Unblocked attention (decode and short prefill).

    qg: [B, Sq, nkv, g, hd]; k/v: [B, Sk, nkv, hd]."""
    hd = qg.shape[-1]
    scores = jnp.einsum("bsngh,btnh->bngst", qg, k_all,
                        preferred_element_type=jnp.float32)
    scores = scores / np.sqrt(hd).astype(np.float32)
    if cfg.attn_softcap is not None:
        scores = cfg.attn_softcap * jnp.tanh(scores / cfg.attn_softcap)
    mask = _attn_mask(q_pos, k_pos, base_kind, cfg.window, cfg.chunk)
    mask = mask & valid[..., None, :]
    scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(qg.dtype)
    return jnp.einsum("bngst,btnh->bsngh", probs, v_all)


FLASH_THRESHOLD = 4096  # dense attention above this seq would blow HBM
FLASH_QBLOCK = 2048
FLASH_KBLOCK = 1024


def _flash_attention(qg, k_all, v_all, q_pos, k_pos, cfg, base_kind):
    """Blockwise (FlashAttention-style) online-softmax attention in pure
    jnp — bounds the score matrix to [*, qb, kb] so prefill_32k fits HBM.
    scan over q blocks (outer) and k blocks (inner)."""
    B, Sq, nkv, g, hd = qg.shape
    Sk = k_all.shape[1]

    def _block(S, target):
        # largest divisor of S not exceeding the target block size (VLM
        # prefixes make S non-power-of-two, e.g. 4096+256)
        for b in range(min(target, S), 0, -1):
            if S % b == 0:
                return b
        return S

    qb = _block(Sq, FLASH_QBLOCK)
    kb = _block(Sk, FLASH_KBLOCK)
    nq, nk = Sq // qb, Sk // kb
    scale = 1.0 / np.sqrt(hd)

    qs = qg.reshape(B, nq, qb, nkv, g, hd).transpose(1, 0, 2, 3, 4, 5)
    qp = q_pos.reshape(B, nq, qb).transpose(1, 0, 2)
    ks = k_all.reshape(B, nk, kb, nkv, hd)
    vs = v_all.reshape(B, nk, kb, nkv, hd)
    kp = k_pos.reshape(B, nk, kb)

    def q_step(_, qblk):
        qi, qpi = qblk

        def k_step(carry, j):
            m, l, acc = carry
            kj = jax.lax.dynamic_index_in_dim(ks, j, 1, keepdims=False)
            vj = jax.lax.dynamic_index_in_dim(vs, j, 1, keepdims=False)
            kpj = jax.lax.dynamic_index_in_dim(kp, j, 1, keepdims=False)
            s = jnp.einsum("bsngh,btnh->bngst", qi, kj,
                           preferred_element_type=jnp.float32) * scale
            if cfg.attn_softcap is not None:
                s = cfg.attn_softcap * jnp.tanh(s / cfg.attn_softcap)
            mask = _attn_mask(qpi, kpj, base_kind, cfg.window, cfg.chunk)
            s = jnp.where(mask[:, None, None, :, :], s, -1e30)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bngst,btnh->bngsh", p, vj.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, nkv, g, qb), -1e30, jnp.float32)
        l0 = jnp.zeros((B, nkv, g, qb), jnp.float32)
        a0 = jnp.zeros((B, nkv, g, qb, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(k_step, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out.transpose(0, 3, 1, 2, 4).astype(qg.dtype)  # [B,qb,nkv,g,hd]

    _, outs = jax.lax.scan(q_step, None, (qs, qp))  # [nq, B, qb, nkv, g, hd]
    return outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, nkv, g, hd)


def attention_apply(
    params: dict,
    x: jax.Array,
    cfg,
    kind: str = "global",
    positions: Optional[jax.Array] = None,
    cache: Optional[dict] = None,
) -> tuple[jax.Array, Optional[dict]]:
    """GQA attention. x: [B, S, D].

    Prefill/train: cache=None, S = sequence length.
    Decode: cache={'k','v': [B, S_c, nkv, hd], 'pos': int32[B]}; S == 1.
    Returns (y, new_cache).
    """
    B, S, D = x.shape
    hd = cfg.resolved_head_dim
    nh, nkv = cfg.n_heads, cfg.n_kv_heads
    groups = nh // nkv
    base_kind = "local" if kind.startswith("local") else (
        "chunked" if kind.startswith("chunked") else "global")

    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, 0)

    q = (x @ params["wq"].astype(x.dtype)).reshape(B, S, nh, hd)
    k = (x @ params["wk"].astype(x.dtype)).reshape(B, S, nkv, hd)
    v = (x @ params["wv"].astype(x.dtype)).reshape(B, S, nkv, hd)
    q = mshard(q, None, None, "model", None)
    k = mshard(k, None, None, "model", None)
    v = mshard(v, None, None, "model", None)

    if cfg.qk_norm:
        q = rmsnorm_head(params["q_norm"], q, cfg.norm_eps)
        k = rmsnorm_head(params["k_norm"], k, cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is None or S > 1:
        # train / prefill: attend over the fresh k, v (cache assumed empty
        # at prefill start); when a cache is supplied, fill it with the
        # last S_c tokens so decode can continue from here.
        k_all, v_all = k, v
        k_pos = positions
        q_pos = positions
        valid = jnp.ones((B, S), bool)
        if cache is not None:
            S_c = cache["k"].shape[1]
            S_w = min(S, S_c)
            if base_kind in ("local", "chunked"):
                slots = (jnp.arange(S_w) + (S - S_w)) % S_c
            else:
                slots = jnp.arange(S_w) + (S - S_w)
            ck = cache["k"].at[:, slots].set(k[:, S - S_w:].astype(cache["k"].dtype))
            cv = cache["v"].at[:, slots].set(v[:, S - S_w:].astype(cache["v"].dtype))
            new_cache = {"k": ck, "v": cv, "pos": cache["pos"] + S}
    else:
        S_c = cache["k"].shape[1]
        pos = cache["pos"]  # int32[B] — tokens already in the cache
        bidx = jnp.arange(B)
        if base_kind in ("local", "chunked"):
            # ring buffer: windowed/chunked layers keep S_c slots only —
            # the sub-quadratic cache-memory path used by long_500k
            slot = pos % S_c
            abs_pos = (pos[:, None]
                       - ((pos[:, None] - jnp.arange(S_c)[None, :]) % S_c))
        else:
            slot = jnp.minimum(pos, S_c - 1)
            abs_pos = jnp.broadcast_to(
                jnp.arange(S_c, dtype=jnp.int32)[None, :], (B, S_c))
        k_all = cache["k"].astype(x.dtype).at[bidx, slot].set(k[:, 0])
        v_all = cache["v"].astype(x.dtype).at[bidx, slot].set(v[:, 0])
        new_cache = {"k": k_all, "v": v_all, "pos": pos + 1}
        k_pos = abs_pos
        q_pos = positions
        # slot is valid if already written: 0 <= abs_pos <= pos (ring slots
        # that were never written carry negative abs positions)
        valid = (abs_pos <= pos[:, None]) & (abs_pos >= 0)

    qg = q.reshape(B, S, nkv, groups, hd)
    if cache is None and S > FLASH_THRESHOLD:
        out = _flash_attention(qg, k_all, v_all, q_pos, k_pos, cfg, base_kind)
    else:
        out = _dense_attention(qg, k_all, v_all, q_pos, k_pos, valid, cfg,
                               base_kind)
    out = out.reshape(B, S, nh * hd)
    y = out @ params["wo"].astype(x.dtype)
    return mshard(y, None, None, None), new_cache


def attention_init_cache(cfg, kind: str, batch: int, seq_len: int,
                         dtype=jnp.bfloat16, prefilled: bool = True) -> dict:
    """Decode cache for one attention layer. Windowed layers keep only
    ``window`` slots — the sub-quadratic memory path for long_500k."""
    base_kind = "local" if kind.startswith("local") else (
        "chunked" if kind.startswith("chunked") else "global")
    S_c = min(cfg.window, seq_len) if base_kind == "local" else seq_len
    if base_kind == "chunked":
        S_c = min(cfg.chunk, seq_len)
    hd = cfg.resolved_head_dim
    cache = {
        "k": jnp.zeros((batch, S_c, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, S_c, cfg.n_kv_heads, hd), dtype),
        "pos": jnp.full((batch,), seq_len if prefilled else 0, jnp.int32),
    }
    return cache


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU)
# ---------------------------------------------------------------------------


def mlp_init(rng, d: int, ff: int) -> dict:
    ks = jax.random.split(rng, 3)
    return {
        "wi": _dense_init(ks[0], (d, ff)),
        "wg": _dense_init(ks[1], (d, ff)),
        "wo": _dense_init(ks[2], (ff, d)),
    }


def mlp_apply(params: dict, x: jax.Array) -> jax.Array:
    h = (x @ params["wi"].astype(x.dtype)) * jax.nn.silu(
        x @ params["wg"].astype(x.dtype))
    h = mshard(h, None, None, "model")
    return h @ params["wo"].astype(x.dtype)
