"""Composable decoder: block dispatch + scan-over-units model.

Depth is ``cfg.pattern`` repeated ``cfg.n_units`` times. Parameters (and
decode caches) are stacked per pattern position and the forward pass is a
single ``lax.scan`` over units — compile time and HLO size are
O(len(pattern)), which is what makes the 94-layer MoE dry-runs tractable.

``shared_attn`` positions (zamba2) use one *unstacked* parameter set
reused at every occurrence (weight sharing), closed over by the scan
body.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import (
    attention_apply,
    attention_init,
    attention_init_cache,
    mlp_apply,
    mlp_init,
    mshard,
    rmsnorm,
    rmsnorm_init,
)
from repro.models.moe import moe_apply, moe_init
from repro.models.ssm import (
    mamba2_apply,
    mamba2_init,
    mamba2_init_cache,
    rwkv6_apply,
    rwkv6_init,
    rwkv6_init_cache,
)

Params = Any


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def block_init(rng, cfg: ModelConfig, kind: str) -> Params:
    ks = jax.random.split(rng, 2)
    p: dict = {"ln1": rmsnorm_init(cfg.d_model), "ln2": rmsnorm_init(cfg.d_model)}
    if kind == "mamba2":
        p["mamba"] = mamba2_init(ks[0], cfg)
    elif kind == "rwkv6":
        p["rwkv"] = rwkv6_init(ks[0], cfg)
    else:  # attention kinds (incl. shared_attn, *_moe)
        p["attn"] = attention_init(ks[0], cfg)
    if "moe" in kind and cfg.moe is not None:
        p["moe"] = moe_init(ks[1], cfg.d_model, cfg.moe)
    elif kind in ("mamba2", "rwkv6") and not cfg.recurrent_mlp:
        pass  # zamba2-style: recurrent blocks have no channel-mix MLP
    else:
        p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff)
    return p


def block_apply(params: Params, x: jax.Array, cfg: ModelConfig, kind: str,
                positions, cache: Optional[dict]):
    """Pre-norm residual block. Returns (x, new_cache, aux_loss)."""
    h = rmsnorm(params["ln1"], x, cfg.norm_eps)
    if kind == "mamba2":
        mix, new_cache = mamba2_apply(params["mamba"], h, cfg, cache)
    elif kind == "rwkv6":
        mix, new_cache = rwkv6_apply(params["rwkv"], h, cfg, cache)
    else:
        mix, new_cache = attention_apply(params["attn"], h, cfg, kind,
                                         positions, cache)
    x = x + mix
    aux = jnp.zeros((), jnp.float32)
    if "moe" in params:
        h = rmsnorm(params["ln2"], x, cfg.norm_eps)
        ff, aux = moe_apply(params["moe"], h, cfg.moe,
                            ep_axis=cfg.ep_axis, ep_ranks=cfg.ep_ranks)
    elif "mlp" in params:
        h = rmsnorm(params["ln2"], x, cfg.norm_eps)
        ff = mlp_apply(params["mlp"], h)
    else:  # recurrent block without channel-mix (zamba2)
        ff = jnp.zeros_like(x)
    return x + ff, new_cache, aux


def block_init_cache(cfg: ModelConfig, kind: str, batch: int, seq_len: int,
                     prefilled: bool = True) -> dict:
    if kind == "mamba2":
        c = mamba2_init_cache(cfg, batch)
        if prefilled:
            c = {**c, "pos": jnp.full((batch,), seq_len, jnp.int32)}
        return c
    if kind == "rwkv6":
        c = rwkv6_init_cache(cfg, batch, cfg.d_model)
        if prefilled:
            c = {**c, "pos": jnp.full((batch,), seq_len, jnp.int32)}
        return c
    return attention_init_cache(cfg, kind, batch, seq_len,
                                prefilled=prefilled)


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------


class Model:
    """Functional model: params are explicit pytrees."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # -- init ----------------------------------------------------------------
    def init(self, rng) -> Params:
        cfg = self.cfg
        ks = jax.random.split(rng, len(cfg.pattern) + 3)
        embed_shape = (cfg.num_codebooks, cfg.vocab, cfg.d_model) \
            if cfg.num_codebooks > 1 else (cfg.vocab, cfg.d_model)
        params: dict = {
            "embed": jax.random.normal(ks[0], embed_shape, jnp.float32) * 0.02,
            "final_norm": rmsnorm_init(cfg.d_model),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = jax.random.normal(ks[1], embed_shape,
                                                  jnp.float32) * 0.02
        blocks = []
        shared = None
        for pos, kind in enumerate(cfg.pattern):
            if kind == "shared_attn":
                if shared is None:
                    shared = block_init(ks[2 + pos], cfg, kind)
                # placeholder keeps the stacked-xs structure uniform
                blocks.append({"_shared": jnp.zeros((cfg.n_units,), jnp.float32)})
                continue
            stacked = [block_init(jax.random.fold_in(ks[2 + pos], u), cfg, kind)
                       for u in range(cfg.n_units)]
            blocks.append(jax.tree.map(lambda *xs: jnp.stack(xs), *stacked))
        params["blocks"] = blocks
        if shared is not None:
            params["shared_attn"] = shared
        # store weight matrices in the compute dtype (bf16); norms/scalars
        # stay f32 (the f32 master lives in the ZeRO-1 flat vector)
        if cfg.dtype == "bfloat16":
            params = jax.tree.map(
                lambda x: x.astype(jnp.bfloat16) if x.ndim >= 2 else x, params)
        return params

    # -- forward (train / prefill) -------------------------------------------
    def forward(self, params: Params, tokens: jax.Array,
                prefix_embeds: Optional[jax.Array] = None):
        """tokens: int32[B, S] (or [B, S, nc] multi-codebook).
        prefix_embeds: optional f32[B, P, d] from the modality frontend.
        Returns (logits, aux_loss)."""
        cfg = self.cfg
        x = self._embed(params, tokens)
        if prefix_embeds is not None:
            x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
        B, S, _ = x.shape
        positions = jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, 0)
        x = mshard(x, None, None, None)

        shared = params.get("shared_attn")

        def unit(carry, xs):
            x, aux = carry
            for pos, kind in enumerate(cfg.pattern):
                bp = xs[pos]
                if kind == "shared_attn":
                    bp = shared
                fn = block_apply
                if cfg.remat:
                    fn = jax.checkpoint(
                        functools.partial(block_apply, cfg=cfg, kind=kind),
                        static_argnums=())
                    x, _, a = fn(bp, x, positions=positions, cache=None)
                else:
                    x, _, a = fn(bp, x, cfg, kind, positions, None)
                aux = aux + a
            return (x, aux), None

        (x, aux), _ = jax.lax.scan(unit, (x, jnp.zeros((), jnp.float32)),
                                   tuple(params["blocks"]))
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = self._logits(params, x)
        return logits, aux

    # -- prefill -----------------------------------------------------------
    def prefill(self, params: Params, tokens: jax.Array,
                prefix_embeds: Optional[jax.Array] = None, cache: list = None):
        """Run the prompt through the model, filling the decode caches.
        tokens: int32[B, S]. Returns (last_logits[B, ...], cache) — only
        the final position's logits (full-prompt logits at 32k×vocab would
        dominate HBM for nothing; serving only samples the next token)."""
        cfg = self.cfg
        x = self._embed(params, tokens)
        if prefix_embeds is not None:
            x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
        B, S, _ = x.shape
        if cache is None:
            cache = self.init_cache(B, S, prefilled=False)
        logits, cache = self._run_with_cache(
            params, x, cache,
            jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, 0),
            last_logit_only=True)
        return logits, cache

    # -- decode ---------------------------------------------------------------
    def decode_step(self, params: Params, tokens: jax.Array, cache: list):
        """tokens: int32[B] (or [B, nc]); cache: stacked caches per pattern
        position. Returns (logits[B, vocab...], new_cache)."""
        cfg = self.cfg
        tok = tokens[:, None] if tokens.ndim == 1 else tokens[:, None, :]
        x = self._embed(params, tok)  # [B, 1, d]
        pos0 = cache[0]["pos"][0]  # [n_units, B] -> [B]; all layers agree
        positions = pos0[:, None].astype(jnp.int32)
        logits, new_cache = self._run_with_cache(params, x, cache, positions,
                                                 last_logit_only=True)
        return logits, new_cache

    def _run_with_cache(self, params: Params, x: jax.Array, cache: list,
                        positions: jax.Array, last_logit_only: bool = False):
        cfg = self.cfg
        shared = params.get("shared_attn")

        def unit(carry, xs):
            x = carry
            new_caches = []
            for pos, kind in enumerate(cfg.pattern):
                bp, bc = xs[2 * pos], xs[2 * pos + 1]
                if kind == "shared_attn":
                    bp = shared
                x, nc, _ = block_apply(bp, x, cfg, kind, positions, bc)
                new_caches.append(nc)
            return x, tuple(new_caches)

        xs = []
        for pos in range(len(cfg.pattern)):
            xs.extend([params["blocks"][pos], cache[pos]])
        x, new_cache = jax.lax.scan(unit, x, tuple(xs))
        if last_logit_only:
            x = x[:, -1:]
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = self._logits(params, x)
        if last_logit_only:
            logits = logits[:, 0]
        return logits, list(new_cache)

    def init_cache(self, batch: int, seq_len: int, prefilled: bool = True):
        """Stacked decode caches, one entry per pattern position."""
        cfg = self.cfg
        caches = []
        for kind in cfg.pattern:
            one = block_init_cache(cfg, kind, batch, seq_len, prefilled)
            caches.append(jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (cfg.n_units,) + x.shape),
                one))
        return caches

    # -- shared pieces ---------------------------------------------------------
    def _embed(self, params: Params, tokens: jax.Array) -> jax.Array:
        cfg = self.cfg
        emb = params["embed"].astype(jnp.bfloat16 if cfg.dtype == "bfloat16"
                                     else jnp.float32)
        if cfg.num_codebooks > 1:
            # musicgen: sum the per-codebook embeddings
            x = jnp.zeros(tokens.shape[:2] + (cfg.d_model,), emb.dtype)
            for c in range(cfg.num_codebooks):
                x = x + emb[c][tokens[..., c]]
        else:
            x = emb[tokens]
        return x * jnp.asarray(cfg.d_model, x.dtype) ** 0.5

    def _logits(self, params: Params, x: jax.Array) -> jax.Array:
        cfg = self.cfg
        head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
        head = head.astype(x.dtype)
        if cfg.num_codebooks > 1:
            logits = jnp.einsum("bsd,cvd->bscv", x, head)
        else:
            logits = jnp.einsum("bsd,vd->bsv", x, head)
        logits = logits.astype(jnp.float32)
        if cfg.logit_softcap is not None:
            logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
        return logits
