"""repro — SAFE secure aggregation (Sandholm et al. 2021) as a
production-grade multi-pod JAX framework.

Public API surface:
  repro.core      — SecureAggregator (safe/saf/insec/bon), protocol sim
  repro.crypto    — Threefry PRF, fixed-point ring codec
  repro.kernels   — Pallas TPU masking kernels (+ jnp oracles)
  repro.models    — the 10-architecture zoo
  repro.configs   — get_config / get_smoke_config / all_arch_ids
  repro.train     — make_train_step, make_federated_round
  repro.serve     — ServeEngine, make_serve_step
  repro.launch    — production meshes, multi-pod dry-run, CLIs
"""

__version__ = "1.0.0"
