"""repro — SAFE secure aggregation (Sandholm et al. 2021) as a
production-grade multi-pod JAX framework.

Public API surface:
  repro.topology  — shared ring/subgroup/hierarchical topology layer
  repro.core      — SecureAggregator (safe/saf/insec/bon), protocol sim,
                    AggSession
  repro.crypto    — Threefry PRF, fixed-point ring codec
  repro.kernels   — Pallas TPU masking kernels (+ jnp oracles)
  repro.models    — the 10-architecture zoo
  repro.configs   — get_config / get_smoke_config / all_arch_ids
  repro.train     — make_train_step, make_federated_round
  repro.serve     — ServeEngine, AggregationEngine
  repro.launch    — production meshes, multi-pod dry-run, CLIs

See ARCHITECTURE.md for the two-plane + topology-layer picture.
"""

from repro import compat  # noqa: F401  (installs jax API shims on old jax)

__version__ = "1.0.0"
