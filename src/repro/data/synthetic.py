"""Synthetic federated data: deterministic, non-IID, learnable.

Cross-organizational FL means each learner's data comes from a different
distribution. We model ``n_domains`` Markov token generators (distinct
bigram structure per domain) and give each learner a Dirichlet mixture
over domains — ``alpha`` controls the non-IID-ness (paper §1's
cross-organizational setting; alpha→inf recovers IID).

Everything is counter-based (no stored datasets): batch ``i`` of learner
``l`` is a pure function of (seed, l, i), so the pipeline is infinitely
long, perfectly resumable from a checkpoint step, and identical across
hosts — the properties a production loader must have.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SyntheticMixture:
    vocab: int
    n_domains: int = 8
    seed: int = 0
    order: int = 1  # markov order (bigram)

    def __post_init__(self):
        rng = np.random.RandomState(self.seed)
        # per-domain sparse-ish bigram logits over a reduced "active" vocab
        self.active = min(self.vocab, 4096)
        self._trans = []
        for d in range(self.n_domains):
            # low-rank bigram structure: P(j|i) ∝ exp(u_i · w_j / sqrt(r))
            r = 16
            u = rng.randn(self.active, r).astype(np.float32)
            w = rng.randn(self.active, r).astype(np.float32)
            # per-domain vocabulary bias: each domain prefers its own slice
            # of the vocab (distinct marginals — the cross-org non-IID-ness)
            bias = np.zeros(self.active, np.float32)
            sl = self.active // self.n_domains
            bias[d * sl:(d + 1) * sl] = 2.0
            self._trans.append((u, w, bias))

    def sample(self, domain: int, length: int, rng: np.random.RandomState) -> np.ndarray:
        u, w, bias = self._trans[domain % self.n_domains]
        toks = np.empty(length, np.int64)
        cur = rng.randint(self.active)
        # vectorized-ish: sample in chunks using gumbel trick on logits rows
        for t in range(length):
            logits = u[cur] @ w.T / 4.0 + bias
            g = rng.gumbel(size=self.active).astype(np.float32)
            cur = int(np.argmax(logits + g))
            toks[t] = cur
        return toks % self.vocab


@dataclasses.dataclass
class FederatedTokenStream:
    """Per-learner non-IID batch generator."""

    vocab: int
    num_learners: int
    batch_per_learner: int
    seq_len: int
    alpha: float = 0.5  # dirichlet concentration (non-IID-ness)
    seed: int = 0
    n_domains: int = 8
    num_codebooks: int = 1

    def __post_init__(self):
        self.mixture = SyntheticMixture(self.vocab, self.n_domains, self.seed)
        rng = np.random.RandomState(self.seed + 1)
        self.learner_mix = rng.dirichlet(
            [self.alpha] * self.n_domains, size=self.num_learners)

    def learner_batch(self, learner: int, step: int) -> dict:
        """tokens int32[batch_per_learner, seq_len(, num_codebooks)]."""
        rng = np.random.RandomState(
            (self.seed * 1_000_003 + learner * 7919 + step) % (2**31 - 1))
        shape = (self.batch_per_learner, self.seq_len, self.num_codebooks) \
            if self.num_codebooks > 1 else (self.batch_per_learner, self.seq_len)
        toks = np.empty(shape, np.int32)
        for b in range(self.batch_per_learner):
            domain = rng.choice(self.n_domains, p=self.learner_mix[learner])
            seq = self.mixture.sample(domain, self.seq_len, rng)
            if self.num_codebooks > 1:
                for c in range(self.num_codebooks):
                    toks[b, :, c] = np.roll(seq, c) % self.vocab  # delay pattern
            else:
                toks[b] = seq
        # weight = "sample count" for §5.6 weighted averaging; vary by
        # learner to exercise the weighted path
        weight = float(1000 + 500 * (learner % 4))
        return {"tokens": toks, "weight": weight}

    def global_batch(self, step: int) -> dict:
        """Stacked [num_learners, batch_per_learner, ...] batch (the layout
        the train step shards over the learner axis)."""
        parts = [self.learner_batch(l, step) for l in range(self.num_learners)]
        return {
            "tokens": np.stack([p["tokens"] for p in parts]),
            "weights": np.asarray([p["weight"] for p in parts], np.float32),
        }


def make_federated_batches(cfg, num_learners: int, batch_per_learner: int,
                           seq_len: int, seed: int = 0) -> FederatedTokenStream:
    return FederatedTokenStream(
        vocab=cfg.vocab,
        num_learners=num_learners,
        batch_per_learner=batch_per_learner,
        seq_len=seq_len,
        seed=seed,
        num_codebooks=cfg.num_codebooks,
    )
