"""Data pipeline: synthetic federated token streams."""
from repro.data.synthetic import (
    FederatedTokenStream,
    SyntheticMixture,
    make_federated_batches,
)

__all__ = ["FederatedTokenStream", "SyntheticMixture", "make_federated_batches"]
