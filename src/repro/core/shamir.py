"""Shamir t-of-n secret sharing over GF(p), p = 2^127 - 1.

Used by the BON baseline (Bonawitz et al. CCS'17): each learner shares
(a) the seed of its self-mask b_u and (b) its pairwise-mask secret key
s_u, so the server can reconstruct exactly one of the two per learner —
b_u for survivors, s_uv pads for dropouts — never both.

Pure-Python bignum arithmetic (secrets are 64-bit PRF seeds; n <= a few
hundred), deterministic given the rng.
"""
from __future__ import annotations

import random
from typing import Iterable, Sequence

P = (1 << 127) - 1  # Mersenne prime


def _eval_poly(coeffs: Sequence[int], x: int) -> int:
    acc = 0
    for c in reversed(coeffs):
        acc = (acc * x + c) % P
    return acc


def share(secret: int, t: int, n: int, rng: random.Random) -> list[tuple[int, int]]:
    """Split ``secret`` into n shares, any t of which reconstruct it."""
    if not 0 <= secret < P:
        raise ValueError("secret out of field range")
    if not 1 <= t <= n:
        raise ValueError("need 1 <= t <= n")
    coeffs = [secret] + [rng.randrange(P) for _ in range(t - 1)]
    return [(x, _eval_poly(coeffs, x)) for x in range(1, n + 1)]


def reconstruct(shares: Iterable[tuple[int, int]]) -> int:
    """Lagrange interpolation at 0."""
    pts = list(shares)
    xs = [x for x, _ in pts]
    if len(set(xs)) != len(xs):
        raise ValueError("duplicate share indices")
    acc = 0
    for i, (xi, yi) in enumerate(pts):
        num = den = 1
        for j, (xj, _) in enumerate(pts):
            if i == j:
                continue
            num = (num * (-xj)) % P
            den = (den * (xi - xj)) % P
        acc = (acc + yi * num * pow(den, P - 2, P)) % P
    return acc
