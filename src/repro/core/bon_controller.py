"""BON server state — the Bonawitz-style baseline's aggregator.

The transport-free twin of :class:`repro.core.controller.Controller`,
but for the 4-round pairwise-masking protocol of
``core/bon_protocol.py``: where SAFE's broker is a *mere message
broker* (the paper's point), BON's server is a protocol participant —
it collects Shamir shares, settles the Round-2 roster, reconstructs
dropped-out nodes' secrets and computes the unmasked average itself.
Driving this controller through ``repro/net/broker.py`` puts that
asymmetry on the same transport as SAFE so the two protocols can be
benchmarked head-to-head (benchmarks/bon_wire.py).

Op registry mirrors the SAFE one (``CALL_OPS``/``WAIT_KINDS``): call
ops apply immediately, wait kinds are probe/consume long-polls, and
every successful counted op increments :class:`BonStats` — one counter
per op, summing to the closed form ``bon_protocol.bon_expected_messages``
(the BON analogue of SAFE's §5 accounting, asserted in
tests/test_conformance.py).

Fidelity note: like the sim, this models BON's *traffic and cost*, not
its cryptographic soundness — the Round-0 "key advertisement" carries
the pairwise seed itself in place of a DH public key (the toy pad
derivation needs both endpoints' seeds), so the dropout-recovery path
reconstructs ``s_v`` from the posted Shamir shares and cross-checks it
against the advertisement. The live path's ``b_v`` recovery is
genuinely share-driven: b seeds are never advertised, so unmasking
cannot complete without the Round-1/Round-3 share traffic.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.bon_protocol import bon_pair_pad, bon_self_pad
from repro.core.shamir import P, reconstruct
from repro.crypto.np_impl import NpFixedPoint

#: fire-and-forget client ops (the wire broker applies them under the
#: session condition and notifies waiters)
BON_CALL_OPS = ("bon_advertise", "bon_post_share", "bon_post_masked",
                "bon_post_unmask")

#: long-poll kinds (probe/consume discipline, like WAIT_KINDS)
BON_WAIT_KINDS = ("bon_get_keys", "bon_get_share", "bon_get_roster",
                  "bon_get_average")

BON_OPS = BON_CALL_OPS + BON_WAIT_KINDS

#: ops the broker stamps with its wall clock — the roster settles
#: ``roster_timeout`` after the first masked input when dropouts leave
#: the round short (the server-side dropout wait of bon_protocol's
#: ``global_timeout``)
BON_TIMED_OPS = ("bon_post_masked",)


def seed_to_bytes(seed: int) -> bytes:
    """64-bit seed as wire bytes (the int tag is signed 64-bit)."""
    return int(seed).to_bytes(8, "big")


def seed_from_bytes(raw: bytes) -> int:
    return int.from_bytes(raw, "big")


def share_to_wire(xy: Tuple[int, int]) -> dict:
    """One Shamir share as wire kwargs — y is a GF(2^127−1) element,
    beyond the signed-64-bit int tag, so it travels as 16 bytes."""
    x, y = xy
    return {"x": int(x), "y": int(y).to_bytes(16, "big")}

def share_from_wire(d: dict) -> Tuple[int, int]:
    y = int.from_bytes(d["y"], "big")
    if not 0 <= y < P:
        raise ValueError(f"share y {y} outside GF(2^127-1)")
    return int(d["x"]), y


@dataclasses.dataclass
class BonStats:
    """One counter per counted BON op (the §5-style accounting for the
    baseline; summed by ``total``). Field names are exactly ``BON_OPS``
    — the doc-sync test pins PROTOCOL.md's counted column to them."""

    bon_advertise: int = 0
    bon_post_share: int = 0
    bon_post_masked: int = 0
    bon_post_unmask: int = 0
    bon_get_keys: int = 0
    bon_get_share: int = 0
    bon_get_roster: int = 0
    bon_get_average: int = 0

    @property
    def total(self) -> int:
        return sum(getattr(self, f.name) for f in dataclasses.fields(self))


class BonController:
    """Server state for one BON aggregation round over ``nodes``.

    ``roster_timeout`` (wall seconds) is how long the server waits after
    the first masked input before declaring the missing nodes dropped —
    the roster settles immediately when all n arrive, so clean rounds
    never pay it. ``maybe_close_roster(now)`` is ticked by the broker's
    monitor loop (and by ``bon_post_masked`` itself) since nothing else
    wakes the parked roster waits when only time passes.
    """

    def __init__(self, nodes: List[int], threshold: Optional[int] = None,
                 roster_timeout: float = 1.0, scale_bits: int = 16):
        self.nodes = sorted(int(x) for x in nodes)
        if len(set(self.nodes)) != len(self.nodes) or not self.nodes:
            raise ValueError(f"bad BON node set {nodes!r}")
        n = len(self.nodes)
        self.threshold = int(threshold) if threshold else (n // 2 + 1)
        if not 1 <= self.threshold <= n:
            raise ValueError(
                f"threshold {self.threshold} outside [1, {n}]")
        self.roster_timeout = float(roster_timeout)
        self.scale_bits = int(scale_bits)
        self.stats = BonStats()
        # Round 0: node -> advertised s_pub (the toy pairwise seed)
        self.keys: Dict[int, bytes] = {}
        # Round 1: (src, dst) -> {"b": share, "s": share} wire dicts
        self.shares: Dict[Tuple[int, int], dict] = {}
        # Round 2: node -> masked uint32 vector
        self.masked: Dict[int, np.ndarray] = {}
        self.first_masked_at: Optional[float] = None
        # Round 3 input: settled {"live": [...], "failed": [...]}
        self.roster: Optional[dict] = None
        # (src, subject) -> (x, y) revealed share
        self.unmask: Dict[Tuple[int, int], Tuple[int, int]] = {}
        self._unmask_counts: Dict[int, int] = {}
        self.average: Optional[np.ndarray] = None
        self.shares_reconstructed = 0

    # -- dispatch (same shape as Controller.call/probe/consume) ---------
    def call(self, op: str, **kwargs):
        if op not in BON_CALL_OPS:
            raise ValueError(f"unknown BON call op {op!r}")
        res = getattr(self, op)(**kwargs)
        setattr(self.stats, op, getattr(self.stats, op) + 1)
        return res

    def probe(self, kind: str, **kwargs):
        if kind not in BON_WAIT_KINDS:
            raise ValueError(f"unknown BON wait kind {kind!r}")
        return getattr(self, f"try_{kind}")(**kwargs)

    def consume(self, kind: str, **kwargs):
        res = self.probe(kind, **kwargs)
        if res is None:
            raise ValueError(f"consume {kind} with nothing to consume")
        setattr(self.stats, kind, getattr(self.stats, kind) + 1)
        return res

    def _check_node(self, node) -> int:
        node = int(node)
        if node not in self.nodes:
            raise ValueError(f"node {node} not in this BON round")
        return node

    # -- Round 0: key advertisement -------------------------------------
    def bon_advertise(self, node: int, s_pub: bytes) -> None:
        self.keys[self._check_node(node)] = bytes(s_pub)

    def try_bon_get_keys(self, node: int) -> Optional[dict]:
        self._check_node(node)
        if len(self.keys) < len(self.nodes):
            return None
        return {"s_pub": dict(self.keys)}

    # -- Round 1: share relay -------------------------------------------
    def bon_post_share(self, node: int, to_node: int, b: dict,
                       s: dict) -> None:
        src = self._check_node(node)
        dst = self._check_node(to_node)
        # validate at the boundary — a malformed share would otherwise
        # only blow up inside the final reconstruction
        share_from_wire(b), share_from_wire(s)
        self.shares[(src, dst)] = {"b": dict(b), "s": dict(s)}

    def try_bon_get_share(self, node: int, from_node: int) -> Optional[dict]:
        dst = self._check_node(node)
        src = self._check_node(from_node)
        entry = self.shares.get((src, dst))
        return None if entry is None else dict(entry)

    # -- Round 2: masked input collection -------------------------------
    def bon_post_masked(self, node: int, payload: np.ndarray,
                        now: float = 0.0) -> None:
        node = self._check_node(node)
        arr = np.asarray(payload)
        if arr.dtype != np.uint32 or arr.ndim != 1:
            raise ValueError("masked input must be a flat uint32 vector")
        if self.masked and arr.shape != next(iter(self.masked.values())).shape:
            raise ValueError("masked input length mismatch")
        self.masked[node] = arr
        if self.first_masked_at is None:
            self.first_masked_at = float(now)
        self.maybe_close_roster(float(now))

    def maybe_close_roster(self, now: float) -> bool:
        """Settle the Round-2 roster: immediately once every node posted,
        or ``roster_timeout`` after the first masked input when at least
        ``threshold`` survivors made it. Returns True when the roster
        transitioned (the broker then notifies parked waits)."""
        if self.roster is not None:
            return False
        if len(self.masked) == len(self.nodes):
            pass  # everyone made it — no dropout wait
        elif (self.first_masked_at is not None
              and len(self.masked) >= self.threshold
              and now >= self.first_masked_at + self.roster_timeout):
            pass  # dropouts declared after the server's wait
        else:
            return False
        live = sorted(self.masked)
        self.roster = {"live": live,
                       "failed": sorted(set(self.nodes) - set(live))}
        return True

    def try_bon_get_roster(self, node: int) -> Optional[dict]:
        self._check_node(node)
        if self.roster is None:
            return None
        return {"live": list(self.roster["live"]),
                "failed": list(self.roster["failed"])}

    # -- Rounds 3/4: unmask share reveal + server-side recovery ----------
    def bon_post_unmask(self, node: int, subject: int, x: int,
                        y: bytes) -> None:
        src = self._check_node(node)
        subject = self._check_node(subject)
        if self.roster is None:
            raise ValueError("unmask share before the roster settled")
        if src not in self.roster["live"]:
            raise ValueError(f"node {src} is not a survivor")
        xy = share_from_wire({"x": x, "y": y})
        if (src, subject) not in self.unmask:
            self._unmask_counts[src] = self._unmask_counts.get(src, 0) + 1
        self.unmask[(src, subject)] = xy
        n = len(self.nodes)
        done = all(self._unmask_counts.get(u, 0) >= n - 1
                   for u in self.roster["live"])
        if done and self.average is None:
            self._publish()

    def _subject_shares(self, subject: int) -> list:
        """The revealed shares for one subject, lowest x first — the
        deterministic ``[:threshold]`` slice the sim reconstructs from."""
        got = [xy for (src, subj), xy in self.unmask.items()
               if subj == subject]
        got.sort()
        if len(got) < self.threshold:
            raise ValueError(
                f"only {len(got)} shares for node {subject}, "
                f"threshold {self.threshold}")
        return got[: self.threshold]

    def _publish(self) -> None:
        """The server-side compute SAFE's broker never does: Shamir
        recovery per node, pad regeneration for dropouts, unmask, and
        average publication — bit-identical to ``run_bon_round``'s
        server loop given the same secrets."""
        live = self.roster["live"]
        failed = self.roster["failed"]
        V = next(iter(self.masked.values())).shape[0]
        y_sum = np.zeros(V, np.uint32)
        for u in live:
            y_sum = NpFixedPoint.add(y_sum, self.masked[u])
        correction = np.zeros(V, np.uint32)
        for v in live:  # b_v from its revealed shares; cancel self-mask
            b_v = reconstruct(self._subject_shares(v))
            self.shares_reconstructed += self.threshold
            correction = NpFixedPoint.add(correction, bon_self_pad(b_v, V))
        s_pub = {u: seed_from_bytes(raw) for u, raw in self.keys.items()}
        for v in failed:  # s_v back from shares; regenerate v's pads
            s_v = reconstruct(self._subject_shares(v))
            self.shares_reconstructed += self.threshold
            if s_v != s_pub[v]:
                raise ValueError(
                    f"reconstructed s for node {v} contradicts its "
                    f"Round-0 advertisement (inconsistent shares)")
            for u in live:
                pad = bon_pair_pad(s_pub[u], s_v, u, v, V)
                correction = (NpFixedPoint.add(correction, pad) if u < v
                              else NpFixedPoint.sub(correction, pad))
        total = NpFixedPoint.sub(y_sum, correction)
        codec = NpFixedPoint(self.scale_bits)
        self.average = codec.decode(total) / len(live)

    def try_bon_get_average(self, node: int) -> Optional[dict]:
        self._check_node(node)
        if self.average is None:
            return None
        return {"average": self.average}

    # -- observability ---------------------------------------------------
    def stats_dict(self) -> dict:
        out = dataclasses.asdict(self.stats)
        out["total"] = self.stats.total
        out["shares_reconstructed"] = self.shares_reconstructed
        out["protocol"] = "bon"
        return out
