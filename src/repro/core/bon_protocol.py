"""BON control-plane simulation — Practical Secure Aggregation (CCS'17).

Round-synchronous simulation of the 4-round protocol the paper benchmarks
against (its footnote-1 framing, matching the reference implementation at
github.com/ammartahir24/SecureAggregation):

  Round 0  advertise keys        (2 msgs/node: post + fetch bundle)
  Round 1  share secrets         (each node posts n-1 Shamir share pairs,
                                  fetches its n-1 incoming shares)
  Round 2  masked input collection (post y_u; quadratic PRF work)
  Round 3/4 unmasking             (each survivor posts shares for every
                                  peer: b_u shares of survivors, s_uv
                                  shares of dropouts; server reconstructs)

Real arithmetic (threefry pads + Shamir over GF(2^127-1)); virtual time
from the same CostModel as the SAFE sim, with per-round barriers (the
protocol is server-synchronized). Message counting matches the structure
above — O(n^2) share traffic — which is what Figures 6/8/13 measure.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Optional

import numpy as np

from repro.core.costs import CostModel, EDGE
from repro.core.shamir import reconstruct, share
from repro.crypto.np_impl import NpFixedPoint, keystream_pair_lanes_np

_MASK32 = 0xFFFFFFFF


def _seed_to_key(seed: int) -> np.ndarray:
    return np.array([seed & _MASK32, (seed >> 32) & _MASK32], np.uint32)


def bon_secrets(n: int, threshold: int, seed: int):
    """The round's secret material, in the canonical draw order.

    One ``random.Random(seed)`` stream drawn in a fixed global order —
    all b seeds, all s seeds, all b shares, all s shares — so *any*
    runtime (this sim, the wire learners of ``core/bon_machines.py``)
    that replays the same order derives identical secrets, and the
    published averages can be compared bit-for-bit.

    Returns ``(b_seed, s_seed, b_shares, s_shares)``; the share dicts
    map node -> the ``share()`` list for that node's secret (entry
    ``v - 1`` is the share addressed to node v).
    """
    rng = random.Random(seed)
    b_seed = {u: rng.getrandbits(64) for u in range(1, n + 1)}
    s_seed = {u: rng.getrandbits(64) for u in range(1, n + 1)}
    b_shares = {u: share(b_seed[u], threshold, n, rng)
                for u in range(1, n + 1)}
    s_shares = {u: share(s_seed[u], threshold, n, rng)
                for u in range(1, n + 1)}
    return b_seed, s_seed, b_shares, s_shares


def bon_pair_pad(s_u: int, s_v: int, u: int, v: int, V: int) -> np.ndarray:
    """Pairwise pad between nodes u and v (symmetric in the pair)."""
    lo, hi = (u, v) if u < v else (v, u)
    s_lo, s_hi = (s_u, s_v) if u < v else (s_v, s_u)
    k = _seed_to_key(
        s_lo ^ ((s_hi << 1) & ((1 << 64) - 1)) ^ (lo * 0x9E3779B9 + hi))
    return keystream_pair_lanes_np(k, V, 0)


def bon_self_pad(b_u: int, V: int) -> np.ndarray:
    """Node u's self-mask pad from its b seed."""
    return keystream_pair_lanes_np(_seed_to_key(b_u), V, 0)


def bon_expected_messages(n: int, f: int = 0) -> int:
    """Closed-form BON message count, f dropouts after Round 1.

    Per node: R0 advertise + key fetch (2), R1 share posts + fetches
    (2(n−1)); per survivor: masked post (1), roster/consistency fetch +
    unmask share posts (n), average fetch (1). With ℓ = n − f:

        M_BON(n, f) = 2n + 2n(n−1) + ℓ(n+2) = 2n² + ℓ(n+2)

    Asserted against both the sim's counters and the wire BonStats
    (tests/test_conformance.py) — the BON analogue of SAFE's §5 forms.
    """
    live = n - f
    return 2 * n + 2 * n * (n - 1) + live * (n + 2)


@dataclasses.dataclass
class BonResult:
    average: Optional[np.ndarray]
    virtual_time: float
    messages: int
    bytes_sent: int
    shares_created: int
    shares_reconstructed: int


def run_bon_round(
    values: np.ndarray,
    failed_nodes: tuple[int, ...] | list[int] = (),
    threshold: Optional[int] = None,
    cost: CostModel = EDGE,
    scale_bits: int = 16,
    seed: int = 7,
    global_timeout: float = 0.0,
) -> BonResult:
    """Simulate one BON aggregation over n learners (1-based ids).

    failed_nodes drop out after Round 1 (they shared secrets, then vanish
    — the worst case the protocol is designed for: survivors must reveal
    the dropouts' pairwise seeds so the server can cancel baked-in pads).
    ``global_timeout`` is added once when there are failures (the server's
    wait before declaring dropouts — the paper subtracts this in Fig. 14).
    """
    n, V = values.shape
    t = threshold if threshold is not None else (n // 2 + 1)
    failed = set(failed_nodes)
    live = [u for u in range(1, n + 1) if u not in failed]
    if len(live) < t:
        raise ValueError("not enough survivors to reach the threshold")
    codec = NpFixedPoint(scale_bits)

    msgs = 0
    nbytes = 0
    vtime = 0.0
    shares_created = 0
    shares_reconstructed = 0

    def barrier(per_node_compute: float, per_node_msgs: int, per_node_bytes: int,
                nodes: int) -> None:
        nonlocal msgs, nbytes, vtime
        msgs += per_node_msgs * nodes
        nbytes += per_node_bytes * nodes
        # server-synchronized round: everyone computes in parallel, then
        # the slowest node's messages land; requests serialize on the
        # controller (same resource model as the SAFE event kernel)
        vtime += (per_node_compute
                  + per_node_msgs * cost.message(per_node_bytes or 64)
                  + per_node_msgs * nodes * cost.t_ctrl)

    # ---- Round 0: advertise keys + pairwise agreement ---------------------
    # Unlike SAFE (whose Round 0 is amortized across many aggregations,
    # §5.2 footnote), BON re-runs key agreement every cycle so dropout
    # recovery stays possible — (n-1) agreements per node per round.
    barrier(cost.t_rsa_encrypt + cost.t_keyagree * (n - 1), 2, 128, n)

    # secrets: per-node self-mask seed b_u and pairwise secret s_u, plus
    # their Shamir shares — canonical draw order shared with the wire
    # learners (bon_secrets), so sim and wire derive identical material
    b_seed, s_seed, b_shares, s_shares = bon_secrets(n, t, seed)

    # ---- Round 1: Shamir-share b_u and s_u to all peers -------------------
    for u in range(1, n + 1):
        shares_created += 2 * (n - 1)
    # each node posts n-1 encrypted share pairs and fetches its n-1
    # incoming shares — individually relayed via the server (the O(n²)
    # message traffic the paper's §2 point 1 complains about)
    barrier(cost.t_share * 2 * (n - 1) + cost.encrypt(64, False) * (n - 1),
            2 * (n - 1), 64 * 2 * (n - 1), n)

    # ---- Round 2: masked input collection --------------------------------
    # pairwise pad between u,v: PRF(s_min XOR s_max tagged) — symmetric.
    def pair_pad(u: int, v: int) -> np.ndarray:
        return bon_pair_pad(s_seed[u], s_seed[v], u, v, V)

    y_sum = np.zeros(V, np.uint32)
    for u in live:
        yu = codec.encode(values[u - 1])
        yu = NpFixedPoint.add(yu, bon_self_pad(b_seed[u], V))
        for v in range(1, n + 1):
            if v == u:
                continue
            pad = pair_pad(u, v)
            yu = NpFixedPoint.add(yu, pad) if u < v else NpFixedPoint.sub(yu, pad)
        y_sum = NpFixedPoint.add(y_sum, yu)
    # per-node: n-1 pad expansions + self mask + encode/add; 1 post msg
    barrier(cost.t_prf_word * V * n + cost.t_add_elem * V * (n + 1) + cost.t_rng_word * V,
            1, 4 * V, len(live))
    if failed:
        vtime += global_timeout  # server waits out the dropouts

    # ---- Rounds 3/4: consistency + unmasking ------------------------------
    # Every survivor fetches the settled roster (the consistency check —
    # which peers made Round 2) and posts, per peer, one share: b_v
    # shares for live v, s_v shares for dead v — one message per share,
    # n messages per survivor in all (bon_expected_messages).
    barrier(cost.t_share * (n - 1), n, 64 * (n - 1) + 4 * n, len(live))

    correction = np.zeros(V, np.uint32)
    for v in live:  # reconstruct b_v from t shares, cancel it
        rec = reconstruct(b_shares[v][: t])
        shares_reconstructed += t
        assert rec == b_seed[v]
        correction = NpFixedPoint.add(
            correction, bon_self_pad(rec, V))
    for v in failed:  # reconstruct s_v, regenerate v's pads with survivors
        rec = reconstruct(s_shares[v][: t])
        shares_reconstructed += t
        assert rec == s_seed[v]
        for u in live:
            pad = pair_pad(u, v)
            # u applied sign(u<v ? + : -) of this pad; cancel it
            correction = NpFixedPoint.add(correction, pad) if u < v \
                else NpFixedPoint.sub(correction, pad)
    # server-side reconstruction compute
    vtime += cost.t_share * shares_reconstructed + \
        cost.t_prf_word * V * (len(live) + len(failed) * len(live))

    total = NpFixedPoint.sub(y_sum, correction)
    avg = codec.decode(total) / len(live)
    # distribute the average (1 get per survivor)
    barrier(0.0, 1, 4 * V, len(live))

    return BonResult(
        average=avg,
        virtual_time=vtime,
        messages=msgs,
        bytes_sent=nbytes,
        shares_created=shares_created,
        shares_reconstructed=shares_reconstructed,
    )
