"""SAFE learner state machines — runtime-agnostic protocol coroutines.

The paper's learners (§5.1.1 initiator / §5.1.2 non-initiator, with the
§5.3–5.4 failover paths) as Python generators. They do *real* masking
arithmetic on numpy arrays but never touch a clock, a socket, or the
broker directly: every externally-visible action is a yield,

  ("compute", seconds)                       local work
  ("call",  op, kwargs, nbytes)              non-blocking controller op
  ("wait",  kind, kwargs, nbytes, timeout)   long-poll; resumes with the
                                             result or {"status":"timeout"}

plus the streaming-combine form of the §5.1.2 hot path,

  ("stream", kwargs, nbytes, timeout)        fused receive+combine+post:
                                             a runtime that can stream
                                             chunks performs the per-
                                             chunk combine (kwargs
                                             carries the closure) and
                                             resumes with
                                             {"status": "streamed", ...};
                                             any other runtime treats it
                                             as a plain get_aggregate
                                             wait and the machine falls
                                             back to whole-vector
                                             decrypt/add/encrypt/post —
                                             same bits, same §5 counts,

and the streaming form of the §5.1.1 initiator unmask,

  ("unmask", kwargs, nbytes, timeout)        fused receive+unmask+publish:
                                             a chunk-capable runtime
                                             decrypt-subtract-decodes
                                             chunk k (kwargs carries the
                                             closure) and publishes the
                                             average slice while chunk
                                             k+1 is still on its last
                                             hop, resuming with
                                             {"status": "unmasked", ...};
                                             any other runtime treats it
                                             as a plain get_aggregate
                                             wait — same bits, same
                                             counts (exactly one
                                             post_average either way),

and the final result is returned via StopIteration. Two runtimes drive
the identical coroutines:

  * the discrete-event kernel (``core/protocol.py``) — virtual time,
    closed-form message-count validation;
  * the wire runtime (``net/client.py``) — real asyncio transport to the
    ``net/broker.py`` server, wall-clock timeouts, injected faults.

That both planes share these generators (and the same ``Controller``) is
what makes the wire plane's published average bit-identical to the sim's
for the same seeds and topology.

``timeout`` in a ``wait`` yield is ``None`` (wait forever), a float in
*virtual seconds* (the sim uses it directly; the wire runtime scales it
to wall seconds), or the string ``"aggregation"`` (the broker's
aggregation timeout, §5.4).
"""
from __future__ import annotations

from typing import Any, Dict, Generator, Iterable, Optional

import numpy as np

from repro.core.costs import CostModel, EDGE
from repro.crypto.np_impl import (
    NpFixedPoint,
    derive_key_np,
    derive_pair_key_np,
    keystream_pair_lanes_np,
    keystream_slice_np,
)
from repro.topology import RingTopology

_TAG_HOP_PAD = 0x50
_TAG_INITIATOR_MASK = 0x52

LearnerGen = Generator[tuple, Any, None]


def key_derivations() -> int:
    """Total Threefry key derivations performed by LearnerCrypto objects
    so far (constructions + pair-key cache misses). Persistent-session
    acceptance hinges on this staying flat after Round 0 — the broker
    tests and ``benchmarks/streaming.py`` snapshot it around rounds."""
    return LearnerCrypto._derivations


# ---------------------------------------------------------------------------
# Crypto helpers (real arithmetic; costs accounted separately)
# ---------------------------------------------------------------------------


class LearnerCrypto:
    """Hop encryption for one learner: Threefry one-time pads over Z/2^32Z.

    ``symmetric_only`` models §5.8 pre-negotiation (deep-edge profile);
    otherwise each hop additionally pays the RSA wrap/unwrap (§5.7 hybrid).
    """

    #: class-wide Threefry derivation tally (see :func:`key_derivations`)
    _derivations = 0

    def __init__(self, node: int, provisioning_seed: int, learner_master: int,
                 scale_bits: int = 16, encrypt: bool = True,
                 symmetric_only: bool = False):
        self.node = node
        self.codec = NpFixedPoint(scale_bits)
        self.encrypt_enabled = encrypt
        self.symmetric_only = symmetric_only
        prov = np.array([provisioning_seed & 0xFFFFFFFF,
                         (provisioning_seed >> 32) & 0xFFFFFFFF], np.uint32)
        self._pad_seed = derive_key_np(prov, _TAG_HOP_PAD)
        master = np.array([learner_master & 0xFFFFFFFF,
                           (learner_master >> 32) & 0xFFFFFFFF], np.uint32)
        self._own = derive_key_np(derive_key_np(master, node), _TAG_INITIATOR_MASK)
        # pair keys are derived once per (src, dst) and cached: a
        # persistent multi-round session (and the chunk-granular combine,
        # which touches the pad many times per vector) must not re-derive
        # per use — the Round-0 amortization the paper counts on
        self._pair_keys: Dict[tuple, np.ndarray] = {}
        LearnerCrypto._derivations += 4  # prov tag + master, node, R tags

    def _pair_key(self, src: int, dst: int) -> np.ndarray:
        k = self._pair_keys.get((src, dst))
        if k is None:
            k = derive_pair_key_np(self._pad_seed, src, dst)
            self._pair_keys[(src, dst)] = k
            LearnerCrypto._derivations += 1
        return k

    def pad(self, src: int, dst: int, n: int, counter: int) -> np.ndarray:
        return keystream_pair_lanes_np(self._pair_key(src, dst), n, counter)

    def pad_slice(self, src: int, dst: int, start: int, n: int,
                  counter: int) -> np.ndarray:
        """Words [start, start+n) of the (src→dst, counter) hop pad —
        bit-identical to ``pad(src, dst, total, counter)[start:start+n]``
        (the seekability the chunk-granular combine runs on)."""
        return keystream_slice_np(self._pair_key(src, dst), n, start, counter)

    def mask_r(self, n: int, counter: int) -> np.ndarray:
        return keystream_pair_lanes_np(self._own, n, counter)

    def mask_r_slice(self, start: int, n: int, counter: int) -> np.ndarray:
        """Words [start, start+n) of the initiator mask R — bit-identical
        to ``mask_r(total, counter)[start:start+n]`` (the same keystream
        seekability the chunk-granular combine runs on), so the
        streaming unmask can subtract R chunk by chunk."""
        return keystream_slice_np(self._own, n, start, counter)

    def hop_encrypt(self, plain_ring: np.ndarray, dst: int, counter: int) -> np.ndarray:
        if not self.encrypt_enabled:
            return plain_ring
        return NpFixedPoint.add(plain_ring, self.pad(self.node, dst, plain_ring.size, counter))

    def hop_decrypt(self, cipher: np.ndarray, src: int, counter: int) -> np.ndarray:
        if not self.encrypt_enabled:
            return cipher
        return NpFixedPoint.sub(cipher, self.pad(src, self.node, cipher.size, counter))

    def hop_encrypt_slice(self, plain_chunk: np.ndarray, dst: int,
                          counter: int, start: int) -> np.ndarray:
        if not self.encrypt_enabled:
            return plain_chunk
        return NpFixedPoint.add(plain_chunk, self.pad_slice(
            self.node, dst, start, plain_chunk.size, counter))

    def hop_decrypt_slice(self, cipher_chunk: np.ndarray, src: int,
                          counter: int, start: int) -> np.ndarray:
        if not self.encrypt_enabled:
            return cipher_chunk
        return NpFixedPoint.sub(cipher_chunk, self.pad_slice(
            src, self.node, start, cipher_chunk.size, counter))


# ---------------------------------------------------------------------------
# Learner state machines (paper §5.1.1 / §5.1.2, with §5.3–5.4 failover)
# ---------------------------------------------------------------------------


def safe_learner(
    node: int,
    topology: RingTopology,
    value: np.ndarray,
    crypto: LearnerCrypto,
    cost: CostModel,
    group: int = 0,
    is_initiator: bool = False,
    weight: Optional[float] = None,
    counter: int = 0,
    fail_mode: Optional[str] = None,
    subgroups: int = 1,
    node_base: int = 1,
) -> LearnerGen:
    """One SAFE learner for one aggregation round.

    Successor targeting comes from the shared ``topology`` object (the
    same one the device plane's ppermute schedule is built from);
    ``node_base`` maps 0-based topology ranks onto the sim's node ids.

    fail_mode: None | 'dead' (crashed before round — never spawned by the
    runner, listed here for completeness) | 'after_post' (initiator crash
    of Fig. 5: posts its first aggregate then stops responding).
    """
    codec = crypto.codec
    nxt = topology.successor(node - node_base) + node_base
    payload_f = value if weight is None else np.concatenate(
        [value * weight, np.array([weight], value.dtype)])
    V = payload_f.size
    # base64-wrapped binary ciphertext: ~6 bytes/element on the wire —
    # the "encryption helps with compression" effect of §6.2 (INSEC posts
    # clear-text JSON floats at ~14 bytes/element)
    nbytes = 6 * V

    def enc_cost():
        return cost.encrypt(nbytes, crypto.symmetric_only)

    def _election():
        """§5.4 path after any aggregation timeout: probe the average,
        else ask to become initiator. Returns 'done'|'initiator'|'rejoin'."""
        res = yield ("wait", "get_average", dict(), nbytes, 0.01)
        if res.get("status") != "timeout":
            return "done"
        won = yield ("call", "should_initiate", dict(node=node, group=group), 64)
        if won:
            return "initiator"
        res = yield ("wait", "get_average", dict(), nbytes, 0.01)
        if res.get("status") != "timeout":
            return "done"
        return "rejoin"

    def _post_and_confirm(agg, posted=False):
        """post_aggregate + check_aggregate loop, handling §5.3 reposts and
        round resets. Returns the terminal status dict (status is
        'consumed'|'reset'|'timeout'|'self' — 'self' means every repost
        target was dead and the poster's own aggregate is final).
        ``posted=True`` means the streaming-combine path already shipped
        the encrypted aggregate chunk-by-chunk — skip straight to the
        confirmation loop (repost retargets still re-encrypt ``agg``
        whole, exactly as in the buffered path)."""
        if not posted:
            yield ("compute", enc_cost())
            cipher = crypto.hop_encrypt(agg, nxt, counter)
            yield ("call", "post_aggregate",
                   dict(from_node=node, to_node=nxt, payload=cipher,
                        group=group), nbytes)
        while True:
            st = yield ("wait", "check_aggregate", dict(node=node, group=group),
                        64, "aggregation")
            status = st.get("status")
            if status in ("consumed", "reset", "timeout", "self"):
                return st
            assert status == "repost"
            target = st["to_node"]
            yield ("compute", enc_cost())
            cipher = crypto.hop_encrypt(agg, target, counter)
            yield ("call", "post_aggregate",
                   dict(from_node=node, to_node=target, payload=cipher, group=group),
                   nbytes)

    initiator_now = is_initiator
    while True:  # restarts on initiator failover (§5.4)
        if initiator_now:
            # -- §5.1.1 steps 1-2: mask with R, encrypt for next, post.
            yield ("compute", cost.t_rng_word * V + cost.t_add_elem * V)
            R = crypto.mask_r(V, counter)
            agg = NpFixedPoint.add(codec.encode(payload_f), R)
            if fail_mode == "after_post":
                # Fig. 5 step 3: initiator posts once, then crashes.
                yield ("compute", enc_cost())
                cipher = crypto.hop_encrypt(agg, nxt, counter)
                yield ("call", "post_aggregate",
                       dict(from_node=node, to_node=nxt, payload=cipher, group=group),
                       nbytes)
                return

            st = yield from _post_and_confirm(agg)
            if st["status"] in ("reset", "timeout"):
                verdict = yield from _election()
                if verdict == "done":
                    return
                initiator_now = verdict == "initiator"
                continue

            dec = None
            published = False
            if st["status"] == "self":
                # Lone survivor (§5.3 degenerate case): every repost
                # target was dead, the aggregate never left this node —
                # unmask the local copy, no decrypt hop.
                total = agg
                posted = st["posted"]
            else:
                # -- §5.1.1 steps 3-4: receive final aggregate, unmask.
                # Yielded as "unmask" so a chunk-capable runtime can
                # decrypt-subtract-decode chunk k (the pad and R are
                # both seekable) and publish the average slice while
                # chunk k+1 is still on its last hop — §8's "publication
                # overlaps the final relay". Elementwise over Z/2^32Z,
                # so any chunking produces the same bits as the whole-
                # vector path below, which every other runtime takes by
                # resolving the yield as a plain get_aggregate wait.
                def _unmask_chunk(start: int, cipher_chunk: np.ndarray,
                                  src: int) -> np.ndarray:
                    plain = crypto.hop_decrypt_slice(cipher_chunk, src,
                                                     counter, start)
                    return codec.decode(NpFixedPoint.sub(
                        plain, crypto.mask_r_slice(
                            start, cipher_chunk.size, counter)))

                res = yield ("unmask",
                             dict(node=node, group=group,
                                  unmask=_unmask_chunk, payload_words=V,
                                  weighted=weight is not None),
                             nbytes, "aggregation")
                if res.get("status") == "timeout":
                    verdict = yield from _election()
                    if verdict == "done":
                        return
                    initiator_now = verdict == "initiator"
                    continue
                if res.get("status") == "unmasked":
                    # chunk-granular unmask done on the fly; `decoded`
                    # is the assembled plaintext sum, `published` says
                    # whether the streamed post_average landed (a
                    # superseded upload falls back to a whole post).
                    dec = res["decoded"]
                    posted = res["posted"]
                    published = res.get("published", False)
                else:
                    yield ("compute", cost.decrypt(nbytes, crypto.symmetric_only))
                    total = crypto.hop_decrypt(res["aggregate"], res["from_node"], counter)
                    posted = res["posted"]  # §5.3: contributor count from controller
            if dec is None:
                yield ("compute", cost.t_add_elem * V * 2)
                total = NpFixedPoint.sub(total, R)
                dec = codec.decode(total)
            if weight is not None:
                avg = dec[:-1] / max(dec[-1], 1e-12)
                wavg = dec[-1] / posted
            else:
                avg = dec / posted
                wavg = None
            if not published:
                yield ("call", "post_average",
                       dict(node=node, average=avg, group=group,
                            weight_avg=wavg), nbytes)
            if subgroups > 1:
                # §5.5: group initiators must fetch the cross-group average.
                yield ("wait", "get_average", dict(), nbytes, None)
            return
        else:
            # -- §5.1.2 non-initiator. The receive+combine+forward hop is
            # the chain's hot path; yield it as a "stream" so a chunk-
            # capable runtime can decrypt/add/re-encrypt chunk k (the pad
            # is seekable, `_combine_chunk`) and ship it downstream while
            # chunk k+1 is still in flight — the §8 pipelined schedule
            # inside one hop. Runtimes without streaming resolve the
            # yield as a plain get_aggregate wait and the classic whole-
            # vector path below runs, bit-identical.
            enc_payload_box: list = []

            def _enc_payload() -> np.ndarray:
                if not enc_payload_box:
                    enc_payload_box.append(codec.encode(payload_f))
                return enc_payload_box[0]

            def _combine_chunk(start: int, cipher_chunk: np.ndarray,
                               src: int):
                """Chunk-granular §5.1.2 combine on words
                [start, start+len): returns (outgoing ciphertext for
                ``nxt``, combined plaintext kept for repost/unmask).
                Elementwise over Z/2^32Z, so any chunking of the vector
                produces the same bits as the whole-vector path."""
                plain = crypto.hop_decrypt_slice(cipher_chunk, src,
                                                 counter, start)
                comb = NpFixedPoint.add(
                    plain, _enc_payload()[start:start + cipher_chunk.size])
                out = crypto.hop_encrypt_slice(comb, nxt, counter, start)
                return out, comb

            if fail_mode is None:
                res = yield ("stream",
                             dict(node=node, group=group, to_node=nxt,
                                  combine=_combine_chunk,
                                  payload_words=V),
                             nbytes, "aggregation")
            else:
                res = yield ("wait", "get_aggregate",
                             dict(node=node, group=group), nbytes,
                             "aggregation")
            if res.get("status") == "timeout":
                verdict = yield from _election()
                if verdict == "done":
                    return
                initiator_now = verdict == "initiator"
                continue
            if fail_mode == "dead":
                return
            if res.get("status") == "streamed":
                # chunk-combined on the fly; `combined` is the assembled
                # plaintext partial (for repost retargets), `uploaded`
                # says whether the streamed post landed (a superseded
                # upload falls back to a whole-vector post here).
                agg = res["combined"]
                st = yield from _post_and_confirm(agg,
                                                  posted=res["uploaded"])
            else:
                yield ("compute", cost.decrypt(nbytes, crypto.symmetric_only))
                agg = crypto.hop_decrypt(res["aggregate"], res["from_node"],
                                         counter)
                yield ("compute", cost.t_add_elem * V)
                agg = NpFixedPoint.add(agg, codec.encode(payload_f))
                st = yield from _post_and_confirm(agg)
            if st["status"] == "reset":
                continue  # round restarted — rejoin the new chain
            if st["status"] == "timeout":
                # §5.4: the posting was never consumed within the
                # aggregation timeout (its target died with the chain
                # otherwise complete). Enter the election path right
                # away — same as the initiator's handling above. Waiting
                # on get_average here instead loses the race against the
                # round reset: the new chain would run (and publish)
                # without this survivor's contribution.
                verdict = yield from _election()
                if verdict == "done":
                    return
                initiator_now = verdict == "initiator"
                continue
            # 'self' falls through to get_average: the poster's own
            # aggregate was declared final, the (re-elected) round will
            # publish without further input from this node.

            res = yield ("wait", "get_average", dict(), nbytes, "aggregation")
            if res.get("status") == "timeout":
                verdict = yield from _election()
                if verdict == "done":
                    return
                initiator_now = verdict == "initiator"
                continue
            return


def insec_learner(node: int, value: np.ndarray, cost: CostModel,
                  group: int = 0, post_to: int = -1) -> LearnerGen:
    """INSEC baseline: post raw parameters, read back the average."""
    nbytes = 14 * value.size  # clear-text JSON floats
    yield ("call", "post_aggregate",
           dict(from_node=node, to_node=post_to, payload=value, group=group), nbytes)
    yield ("wait", "get_average", dict(), nbytes, None)
    return


# ---------------------------------------------------------------------------
# Round construction shared by both runtimes
# ---------------------------------------------------------------------------


def build_round_machines(
    values: np.ndarray,
    topo: RingTopology,
    groups: Dict[int, list],
    initiators: set,
    *,
    mode: str = "safe",
    weights: Optional[np.ndarray] = None,
    cost: CostModel = EDGE,
    symmetric_only: bool = False,
    scale_bits: int = 16,
    provisioning_seed: int = 0xC0FFEE,
    learner_master: int = 0x5EED,
    counter: int = 0,
    subgroups: int = 1,
    failed: Iterable[int] = (),
    initiator_fails: bool = False,
    crypto_cache: Optional[Dict[int, LearnerCrypto]] = None,
) -> Dict[int, LearnerGen]:
    """Build one generator per live learner for one aggregation round.

    This is the single place that wires values/keys/topology into the
    state machines — ``run_safe_round`` (discrete-event) and
    ``net.client.run_safe_round_net`` (wire) both call it, so "same
    seeds, same topology" means *the same coroutines* in both planes.
    Returns ``{node_id: generator}`` for nodes not in ``failed``.

    ``crypto_cache`` (node → LearnerCrypto, filled on first use) lets a
    persistent multi-round session reuse each learner's derived key
    material across rounds — no key re-derivation after Round 0, the
    paper's amortization. Callers own counter bookkeeping: the cache is
    only sound while ``counter`` advances past every previous round's
    pad words (``core.session.RoundCursor``).
    """
    failed = set(failed)
    machines: Dict[int, LearnerGen] = {}
    for g, chain in groups.items():
        for node in chain:
            if node in failed:
                continue  # crashed before the aggregation started
            val = values[node - 1]
            w = None if weights is None else float(weights[node - 1])
            if mode == "insec":
                machines[node] = insec_learner(
                    node, val if w is None else val * w, cost, group=g)
                continue
            crypto = None if crypto_cache is None else crypto_cache.get(node)
            if crypto is None:
                crypto = LearnerCrypto(
                    node, provisioning_seed, learner_master, scale_bits,
                    encrypt=(mode == "safe"), symmetric_only=symmetric_only)
                if crypto_cache is not None:
                    crypto_cache[node] = crypto
            is_init = node in initiators
            fail_mode = ("after_post"
                         if (initiator_fails and g == 0 and is_init) else None)
            machines[node] = safe_learner(
                node, topo, val, crypto, cost, group=g,
                is_initiator=is_init, weight=w, counter=counter,
                fail_mode=fail_mode, subgroups=subgroups)
    return machines
