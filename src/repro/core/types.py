"""Configuration types for the SAFE aggregation core."""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.crypto.fixedpoint import DEFAULT_SCALE_BITS
from repro.topology import RingTopology


@dataclasses.dataclass(frozen=True)
class ChainConfig:
    """Static configuration of a secure-aggregation chain.

    Attributes:
      axis: mesh axis name the learners live on (one learner per rank).
      num_learners: chain length n (must equal the mesh axis size).
      scale_bits: fixed-point fractional bits for the ring encoding.
      mode: 'safe'  — chain with hop pads + initiator mask (paper SAFE);
            'saf'   — chain with initiator mask only, no hop pads (paper SAF);
            'insec' — plain psum of raw values (paper INSEC baseline);
            'bon'   — pairwise-mask baseline (Bonawitz et al. CCS'17).
      pipelined: False — paper-faithful sequential whole-vector chain
                 (n-1 serial hops of the full vector);
                 True  — beyond-paper rotated-initiator segment pipeline
                 (ring-reduce schedule, ~2V bytes/link; DESIGN.md §8).
      subgroups: number of parallel chains g (paper §5.5). Must divide
                 num_learners; each subgroup needs >= 3 members for the
                 paper's privacy guarantee (enforced at construction).
      weighted: carry a per-learner weight through the aggregate so the
                 published value is the weighted mean (paper §5.6).
      pod_axis: optional mesh axis for hierarchical federation (§5.10):
                 intra-pod chains then cross-pod average of group averages.
      unroll: unroll the hop loop in HLO (preferred for n <= 64: exposes
                 the collective schedule to the roofline parser and lets
                 XLA overlap; fori_loop otherwise).
    """

    axis: str = "data"
    num_learners: int = 16
    scale_bits: int = DEFAULT_SCALE_BITS
    mode: str = "safe"
    pipelined: bool = False
    subgroups: int = 1
    weighted: bool = False
    pod_axis: Optional[str] = None
    unroll: bool = True

    def __post_init__(self) -> None:
        if self.mode not in ("safe", "saf", "insec", "bon"):
            raise ValueError(f"unknown mode {self.mode!r}")
        # topology construction checks divisibility; the privacy bound
        # (>= 3 members per ring, paper §5.3/§5.5) applies to the masked
        # chain modes only
        topo = RingTopology(self.num_learners, self.subgroups)
        if self.mode in ("safe", "saf"):
            topo.validate_privacy()

    @property
    def topology(self) -> RingTopology:
        """Ring geometry shared with the sim plane (repro.topology)."""
        return RingTopology(self.num_learners, self.subgroups)

    @property
    def group_size(self) -> int:
        return self.num_learners // self.subgroups


@dataclasses.dataclass(frozen=True)
class RoundKeys:
    """Per-round key material (host-provisioned, device-resident).

    provisioning_seed: uint32[2] master seed from which pairwise hop keys
      are derived (models the out-of-band Round-0 exchange; DESIGN.md §6).
    learner_seed: uint32[2] per-learner private seed (initiator mask R and
      BON self-mask b_i are keystreams from it).
    counter_base: first fresh counter word for this round (host-allocated
      via ``crypto.prf.RoundCounter`` so pads are never reused).
    """

    provisioning_seed: object  # jax.Array uint32[2]
    learner_seed: object  # jax.Array uint32[2] (per-rank, distinct)
    counter_base: object  # jax.Array uint32 scalar or int
