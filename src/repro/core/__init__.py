"""SAFE secure aggregation core — the paper's contribution as composable JAX.

Data plane: ``chain`` (SAFE/SAF), ``bon`` (Bonawitz baseline), ``insec``
(plain mean), unified behind ``aggregators.SecureAggregator``.

Control plane: ``controller`` + ``protocol`` (message-level broker,
learners, progress monitor, failover) — the paper's actual REST system.
"""
from repro.core.types import ChainConfig, RoundKeys
from repro.core.aggregators import SecureAggregator, make_aggregator, make_round_keys
from repro.core.chain import (
    chain_aggregate_sequential,
    chain_aggregate_pipelined,
    chain_aggregate_batched,
)
from repro.core.bon import bon_aggregate
from repro.core.insec import insec_aggregate
from repro.core.session import AggSession

__all__ = [
    "ChainConfig",
    "RoundKeys",
    "SecureAggregator",
    "make_aggregator",
    "make_round_keys",
    "chain_aggregate_sequential",
    "chain_aggregate_pipelined",
    "chain_aggregate_batched",
    "bon_aggregate",
    "insec_aggregate",
    "AggSession",
]
