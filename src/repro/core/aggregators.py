"""Aggregator interface — SAFE and baselines as pluggable components.

``SecureAggregator`` is the first-class framework object: the federated
trainer, the benchmarks, and the dry-run all consume it. The per-rank
``aggregate`` method composes inside any shard_map region that is manual
over the learner axis; ``aggregate_sharded`` is a standalone jit entry
point for tests/benchmarks.

Key provisioning model (DESIGN.md §6): a ``provisioning_seed`` models the
Round-0 out-of-band exchange (pairwise hop keys are KDF(provisioning,
i, j)); each learner's private seed is KDF(learner_master, rank). In a
real deployment learner_master never leaves the learner — here it is a
simulation input, and the privacy argument is carried by the control-plane
tests (controller never observes an unmasked value).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.bon import bon_aggregate
from repro.core.chain import chain_aggregate_pipelined, chain_aggregate_sequential
from repro.core.insec import insec_aggregate
from repro.core.types import ChainConfig, RoundKeys
from repro.crypto.prf import RoundCounter, derive_key


def make_round_keys(
    provisioning_seed: int,
    learner_master: int,
    counter_base: int,
    rank: Optional[jax.Array] = None,
    axis: str = "data",
    domain: int = 0,
) -> RoundKeys:
    """Build per-rank RoundKeys inside a shard_map region.

    ``domain`` separates keystreams when one round aggregates multiple
    vectors (leaf-wise aggregation of a parameter tree): each domain gets
    independent derived keys, so 32-bit counter space is per-leaf."""
    if rank is None:
        rank = jax.lax.axis_index(axis)
    prov = derive_key(jnp.array([provisioning_seed & 0xFFFFFFFF,
                                 (provisioning_seed >> 32) & 0xFFFFFFFF],
                                dtype=jnp.uint32), domain)
    master = jnp.array([learner_master & 0xFFFFFFFF,
                        (learner_master >> 32) & 0xFFFFFFFF], dtype=jnp.uint32)
    learner = derive_key(derive_key(master, domain), rank)
    return RoundKeys(provisioning_seed=prov, learner_seed=learner,
                     counter_base=jnp.asarray(counter_base, jnp.uint32))


@dataclasses.dataclass
class SecureAggregator:
    """Pluggable secure-mean over a mesh axis.

    mode is taken from ``cfg.mode``: insec | saf | safe | bon;
    ``cfg.pipelined`` selects the beyond-paper schedule for saf/safe.
    """

    cfg: ChainConfig
    provisioning_seed: int = 0xC0FFEE
    learner_master: int = 0x5EED
    _counters: RoundCounter = dataclasses.field(default_factory=RoundCounter)

    # ---- host-side key/counter management -------------------------------
    def reserve_round(self, nwords: int) -> int:
        """Reserve fresh counter space for one aggregation round.

        SAFE uses one pad word per payload word per edge; BON uses one per
        pair. A single monotone space sized by the worst case keeps the
        no-reuse invariant simple.
        """
        return self._counters.reserve(int(nwords))

    # ---- per-rank (inside shard_map) ------------------------------------
    def aggregate(
        self,
        values: jax.Array,
        counter_base: int | jax.Array = 0,
        alive: Optional[jax.Array] = None,
        weights: Optional[jax.Array] = None,
        domain: int = 0,
        rotate: jax.Array | int = 0,
    ) -> jax.Array:
        """Secure mean of per-rank f32[V] over cfg.axis. Call inside
        shard_map (manual over cfg.axis). ``rotate`` shifts the initiator
        role per round (paper §8 collusion mitigation)."""
        keys = make_round_keys(self.provisioning_seed, self.learner_master,
                               counter_base, axis=self.cfg.axis,
                               domain=domain)
        mode = self.cfg.mode
        if mode == "insec":
            return insec_aggregate(values, self.cfg, alive, weights)
        if mode == "bon":
            return bon_aggregate(values, keys, self.cfg, alive)
        if self.cfg.pipelined:
            return chain_aggregate_pipelined(values, keys, self.cfg, alive,
                                             weights)
        return chain_aggregate_sequential(values, keys, self.cfg, alive,
                                          weights, rotate=rotate)

    def aggregate_tree(
        self,
        tree: Any,
        counter_base: int | jax.Array = 0,
        alive: Optional[jax.Array] = None,
        weights: Optional[jax.Array] = None,
    ) -> Any:
        """Secure mean of an arbitrary pytree (gradients / model deltas)."""
        flat, unravel = ravel_pytree(tree)
        avg = self.aggregate(flat.astype(jnp.float32), counter_base, alive, weights)
        return unravel(avg)

    # ---- standalone entry point ------------------------------------------
    def aggregate_sharded(
        self,
        mesh: Mesh,
        global_values: jax.Array,
        counter_base: int | jax.Array = 0,
        alive: Optional[jax.Array] = None,
        weights: Optional[jax.Array] = None,
    ) -> jax.Array:
        """Aggregate a [n, V] learner-major matrix sharded over cfg.axis.

        Returns the [V] published mean (identical on every learner —
        asserted by out_specs replication).
        """
        cfg = self.cfg
        if alive is None:
            alive = jnp.ones((cfg.num_learners,), jnp.float32)
        if weights is None:
            weights = jnp.ones((cfg.num_learners,), jnp.float32)

        def per_rank(vals, alive_, w):
            return self.aggregate(
                vals.reshape(vals.shape[-1]), counter_base, alive_, w.reshape(())
            )

        manual = {cfg.axis} | ({cfg.pod_axis} if cfg.pod_axis else set())
        shard_fn = jax.shard_map(
            per_rank,
            mesh=mesh,
            in_specs=(P(cfg.axis), P(), P(cfg.axis)),
            out_specs=P(),
            axis_names=frozenset(manual),
            check_vma=False,
        )
        with jax.set_mesh(mesh):
            return jax.jit(shard_fn)(global_values, alive, weights)


_REGISTRY: dict[str, Callable[..., ChainConfig]] = {}


def make_aggregator(
    mode: str,
    num_learners: int,
    axis: str = "data",
    *,
    pipelined: bool = False,
    subgroups: int = 1,
    weighted: bool = False,
    pod_axis: Optional[str] = None,
    scale_bits: int = 16,
    unroll: bool = True,
    provisioning_seed: int = 0xC0FFEE,
    learner_master: int = 0x5EED,
) -> SecureAggregator:
    """Factory used by configs / CLI (``--aggregator safe`` etc.)."""
    cfg = ChainConfig(
        axis=axis,
        num_learners=num_learners,
        scale_bits=scale_bits,
        mode=mode,
        pipelined=pipelined,
        subgroups=subgroups,
        weighted=weighted,
        pod_axis=pod_axis,
        unroll=unroll,
    )
    return SecureAggregator(cfg, provisioning_seed, learner_master)
