"""Virtual-time cost models for the control-plane protocol simulation.

The paper evaluates two platforms (§6, §7):

  * *edge*      — Python learners on a desktop-class box (RSA+AES hybrid).
  * *deep-edge* — busybox/openssl on TP-Link Wi-Fi routers, symmetric keys
                  pre-negotiated because RSA private-key ops are too slow.

The constants below are calibrated to the same order of magnitude as the
paper's measurements (e.g. edge: ~0.1 s for 3-node/1-feature SAFE,
deep-edge: ~1 s for 3 nodes) so the benchmark curves are directly
comparable in *shape* and *ratio*; absolute values are documented as
model parameters, not measurements.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Tuple


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Per-operation virtual-time costs, in seconds."""

    name: str = "edge"
    # Network: one controller round trip (request+response, LAN).
    t_msg: float = 0.002
    # Per-byte serialization + transfer cost (JSON over HTTPS).
    t_byte: float = 1.5e-7
    # RSA-2048: wrap/unwrap one AES session key (desktop-class CPU).
    t_rsa_encrypt: float = 0.0003
    t_rsa_decrypt: float = 0.0015
    # AES-256: per byte (stream).
    t_aes_byte: float = 2.0e-9
    # PRF keystream generation, per 4-byte word (BON pad expansion and the
    # SAFE initiator mask both pay this).
    t_prf_word: float = 6.0e-9
    # Vector add / fixed-point codec, per element.
    t_add_elem: float = 3.0e-9
    # Secure random generation, per word (initiator mask R, BON b_u).
    t_rng_word: float = 2.0e-8
    # Shamir share create/reconstruct, per share (BON only).
    t_share: float = 3.0e-5
    # Pairwise key agreement (BON Round 0: RSA keypair generation +
    # agreement per peer, re-run every aggregation cycle for failover —
    # §2 point 1; RSA-2048 keygen is ~100 ms, which is what makes BON
    # "deteriorate already at 8-10 nodes", Fig. 6).
    t_keyagree: float = 0.1
    # Controller bookkeeping per request (requests serialize on it).
    t_ctrl: float = 0.001
    # Controller per-byte handling: INSEC must PARSE the JSON float
    # payload (it averages it); SAFE's broker relays an opaque blob —
    # the paper's "mere message broker" advantage (§6.2 compression).
    t_parse_byte: float = 3.0e-8
    t_relay_byte: float = 2.0e-9
    # INSEC controller averaging: the server re-averages the n posted
    # arrays when serving results — O(n·V) per request, the quadratic-ish
    # server burden SAFE avoids by making the initiator compute the mean.
    t_avg_elem: float = 2.0e-8

    def encrypt(self, nbytes: int, symmetric_only: bool) -> float:
        """Hybrid (RSA-wrapped AES) or pre-negotiated symmetric encrypt."""
        c = self.t_aes_byte * nbytes
        if not symmetric_only:
            c += self.t_rsa_encrypt
        return c

    def decrypt(self, nbytes: int, symmetric_only: bool) -> float:
        c = self.t_aes_byte * nbytes
        if not symmetric_only:
            c += self.t_rsa_decrypt
        return c

    def message(self, nbytes: int = 256) -> float:
        return self.t_msg + self.t_byte * nbytes

    @classmethod
    def fit(cls, samples: Iterable[Tuple[Dict[str, float], float]],
            base: "CostModel" = None, name: str = "fitted"
            ) -> Tuple["CostModel", Dict[str, float]]:
        """Calibrate cost constants from measured timings.

        ``samples`` is an iterable of ``(features, seconds)`` pairs where
        ``features`` maps constant names (``t_msg``, ``t_byte``, ...) to
        their multiplier in that measurement — e.g. an RPC echo of a
        1 KiB payload under a model ``t = t_msg + nbytes*t_byte`` is
        ``({"t_msg": 1, "t_byte": 1024}, measured_seconds)``. Solves the
        nonnegative least-squares system over the union of feature names
        (plain lstsq, negatives clipped to 0 — adequate for the
        well-separated micro-benchmarks this calibrates), returning a
        new model with fitted fields replacing ``base``'s (default
        :data:`EDGE`) and a residual report::

            {"rms": ..., "max": ..., "r2": ..., "n_samples": ...}

        so benchmark output can state how well the linear model explains
        the measurements instead of asserting it.
        """
        import numpy as np

        base = base if base is not None else EDGE
        samples = list(samples)
        if not samples:
            raise ValueError("need at least one sample to fit")
        names = sorted({k for feats, _ in samples for k in feats})
        valid = {f.name for f in dataclasses.fields(cls)} - {"name"}
        unknown = set(names) - valid
        if unknown:
            raise ValueError(f"unknown cost constants: {sorted(unknown)}")
        A = np.array([[feats.get(k, 0.0) for k in names]
                      for feats, _ in samples], dtype=np.float64)
        y = np.array([t for _, t in samples], dtype=np.float64)
        coef, *_ = np.linalg.lstsq(A, y, rcond=None)
        coef = np.maximum(coef, 0.0)
        pred = A @ coef
        resid = y - pred
        ss_tot = float(np.sum((y - y.mean()) ** 2))
        report = {
            "rms": float(np.sqrt(np.mean(resid ** 2))),
            "max": float(np.max(np.abs(resid))),
            "r2": (1.0 - float(np.sum(resid ** 2)) / ss_tot
                   if ss_tot > 0 else 1.0),
            "n_samples": len(samples),
        }
        fitted = dataclasses.replace(
            base, name=name,
            **{k: float(v) for k, v in zip(names, coef)})
        return fitted, report


EDGE = CostModel(name="edge")

# Archer C7 (QCA9558 @ 720 MHz): busybox+curl per-request overhead
# dominates (~150-200 ms TLS handshake + process startup), crypto 30-100x
# slower than desktop.
DEEP_EDGE = CostModel(
    name="deep_edge",
    t_msg=0.17,
    t_byte=2.0e-7,
    t_rsa_encrypt=0.02,
    t_rsa_decrypt=0.35,  # why the paper pre-negotiates symmetric keys (§7)
    t_aes_byte=6.0e-8,
    t_prf_word=2.0e-7,
    t_add_elem=1.0e-7,
    t_rng_word=2.0e-6,  # "generating random numbers is quite slow" (§7)
    t_share=1.0e-3,
    t_keyagree=2.0,
    t_ctrl=0.001,
)

COST_MODELS = {"edge": EDGE, "deep_edge": DEEP_EDGE}
