"""The SAFE controller — a message broker with progress monitoring.

Faithful Python port of the paper's Flask controller (Appendix A): it
stores opaque ciphertext messages until consumed, tracks per-group
progress, orchestrates reposts after timeouts, re-elects initiators, and
distributes the final average. It never decrypts, never aggregates
(except averaging the already-anonymized subgroup averages, §5.5), and
never holds key material — the paper's "mere message broker".

Used by the discrete-event protocol simulation (``core/protocol.py``) and
by the paper-figure benchmarks. Every client-visible operation increments
the message counters that validate §5's closed-form counts.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import numpy as np

#: Shared op registry — the single source of truth for what a client can
#: ask the broker. ``CALL_OPS`` are fire-and-forget requests (the state
#: machines' ``("call", …)`` yields); ``WAIT_KINDS`` are long-polls
#: (``("wait", …)`` yields) with a non-counting ``try_*`` probe and a
#: counting consumer. Both the discrete-event kernel
#: (``core/protocol.py``) and the wire broker (``net/broker.py``)
#: dispatch through this table, so the two planes cannot drift — and
#: docs/PROTOCOL.md §7 documents it, with ``tests/test_docs.py``
#: asserting the book's table matches these sets (and ``MessageStats``'
#: fields) exactly, so the spec cannot drift either.
CALL_OPS = frozenset({
    "post_aggregate", "post_average", "should_initiate",
    "register_key", "get_key",
})
#: call ops that take the broker clock (``now=``); key-exchange ops do not.
TIMED_OPS = frozenset({"post_aggregate", "post_average", "should_initiate"})
WAIT_KINDS = frozenset({"get_aggregate", "check_aggregate", "get_average"})


@dataclasses.dataclass
class MessageStats:
    """Client->controller request counters, by operation."""

    post_aggregate: int = 0
    check_aggregate: int = 0
    get_aggregate: int = 0
    post_average: int = 0
    get_average: int = 0
    should_initiate: int = 0
    register_key: int = 0
    get_key: int = 0

    @property
    def aggregation_total(self) -> int:
        """Messages in the aggregation itself (paper's 4n count excludes
        the one-time Round-0 key exchange, §5.2)."""
        return (
            self.post_aggregate
            + self.check_aggregate
            + self.get_aggregate
            + self.post_average
            + self.get_average
            + self.should_initiate
        )

    @property
    def key_exchange_total(self) -> int:
        return self.register_key + self.get_key


@dataclasses.dataclass
class _Posting:
    """A stored ciphertext message (opaque to the controller)."""

    payload: Any
    from_node: int
    time: float


class Controller:
    """In-process broker implementing the paper's six operations.

    All state is per-group (paper §5.5); group 0 is the default. The
    controller knows chain order only as an opaque node-id list per group
    (it must, to pick repost targets — exactly as in the paper where it
    "requests the sending node to re-encrypt and resend to a new target").
    """

    def __init__(self, groups: Dict[int, list[int]], aggregation_timeout: float = 30.0):
        self.groups = {g: list(nodes) for g, nodes in groups.items()}
        self.aggregation_timeout = aggregation_timeout
        self.stats = MessageStats()
        # group -> node -> _Posting
        self._aggregates: Dict[int, Dict[int, _Posting]] = {g: {} for g in groups}
        # group -> node -> {"status": ...} (repost/consumed signal per §A)
        self._repost: Dict[int, Dict[int, dict]] = {g: {} for g in groups}
        # group -> {"average": vec, "weight_avg": float, "posted": int}
        self._average: Dict[int, Optional[dict]] = {g: None for g in groups}
        # group -> count of learners that successfully posted (for §5.3's
        # "initiator is informed how many nodes posted")
        self._posted: Dict[int, int] = {g: 0 for g in groups}
        self._skipped: Dict[int, set] = {g: set() for g in groups}
        self._initiator: Dict[int, Optional[int]] = {g: None for g in groups}
        self._round_start: Dict[int, float] = {g: 0.0 for g in groups}
        self._keys: Dict[int, Any] = {}
        # Registered public/symmetric keys: node -> key blob (opaque).
        self._global_average: Optional[dict] = None
        # Monotone round index: bumped by advance_round() (cross-round
        # pipelining, PROTOCOL.md §11). reset_round() restarts the SAME
        # logical round and leaves it untouched.
        self.round_index: int = 0

    # ------------------------------------------------------------------
    # Uniform op dispatch (shared by the sim kernel and the wire broker)
    # ------------------------------------------------------------------
    def call(self, op: str, **kwargs) -> Any:
        """Invoke a fire-and-forget client op by name (see ``CALL_OPS``)."""
        if op not in CALL_OPS:
            raise ValueError(f"unknown call op {op!r}")
        return getattr(self, op)(**kwargs)

    def probe(self, kind: str, **kwargs) -> Optional[Any]:
        """Non-counting availability probe for a long-poll kind."""
        if kind not in WAIT_KINDS:
            raise ValueError(f"unknown wait kind {kind!r}")
        return getattr(self, f"try_{kind}")(**kwargs)

    def consume(self, kind: str, **kwargs) -> Any:
        """Counting resolution of a long-poll kind; the caller must have
        seen a non-None ``probe`` first."""
        if kind not in WAIT_KINDS:
            raise ValueError(f"unknown wait kind {kind!r}")
        return getattr(self, kind)(**kwargs)

    # ------------------------------------------------------------------
    # Round 0: key exchange (2 messages per node: register + retrieve)
    # ------------------------------------------------------------------
    def register_key(self, node: int, key_blob: Any) -> None:
        self.stats.register_key += 1
        self._keys[node] = key_blob

    def get_key(self, node: int) -> Any:
        self.stats.get_key += 1
        return self._keys.get(node)

    # ------------------------------------------------------------------
    # Round 1: chain aggregation
    # ------------------------------------------------------------------
    def post_aggregate(self, from_node: int, to_node: int, payload: Any,
                       group: int = 0, now: float = 0.0) -> None:
        self.stats.post_aggregate += 1
        if self._initiator[group] is None:
            self._initiator[group] = from_node
            self._round_start[group] = now
        self._aggregates[group][to_node] = _Posting(payload, from_node, now)
        self._posted[group] += 1
        # Poster will long-poll check_aggregate; target will long-poll
        # get_aggregate — mark the poster's check as pending.
        self._repost[group][from_node] = {"status": "pending"}

    def try_get_aggregate(self, node: int, group: int = 0) -> Optional[dict]:
        """Non-counting availability probe used by the event kernel; the
        actual client call is get_aggregate()."""
        posting = self._aggregates[group].get(node)
        if posting is None:
            return None
        # _posted counts successful post_aggregate calls net of removed
        # postings (order_repost decrements), i.e. the n-f contributors
        # the initiator divides by (§5.3).
        return {
            "aggregate": posting.payload,
            "from_node": posting.from_node,
            "posted": self._posted[group],
            "time": posting.time,
        }

    def get_aggregate(self, node: int, group: int = 0) -> dict:
        """Consume the message addressed to ``node`` (long-poll resolved)."""
        self.stats.get_aggregate += 1
        result = self.try_get_aggregate(node, group)
        assert result is not None, "kernel resolved a wait without data"
        posting = self._aggregates[group].pop(node)
        # Poster's check_aggregate resolves to consumed.
        self._repost[group][posting.from_node] = {"status": "consumed"}
        return result

    def try_check_aggregate(self, node: int, group: int = 0) -> Optional[dict]:
        st = self._repost[group].get(node)
        if st is None or st.get("status") == "pending":
            return None
        return st

    def check_aggregate(self, node: int, group: int = 0) -> dict:
        self.stats.check_aggregate += 1
        st = self.try_check_aggregate(node, group)
        assert st is not None
        if st.get("status") != "consumed":
            # one-shot repost instruction
            self._repost[group][node] = {"status": "pending"}
        return st

    # ------------------------------------------------------------------
    # Progress failover (§5.3) — called by the external progress monitor.
    # ------------------------------------------------------------------
    def stuck_posting(self, group: int, now: float, timeout: float):
        """Return (poster, failed_target) if a posting has been waiting
        longer than ``timeout``, else None."""
        for to_node, posting in self._aggregates[group].items():
            if now - posting.time > timeout:
                return posting.from_node, to_node
        return None

    def order_repost(self, group: int, poster: int, failed: int) -> int:
        """Instruct ``poster`` (via its pending check_aggregate) to
        re-encrypt for the node after ``failed`` on the chain."""
        chain = self.groups[group]
        idx = chain.index(failed)
        new_target = chain[(idx + 1) % len(chain)]
        self._skipped[group].add(failed)
        # Remove the unconsumed posting and flag the poster.
        self._aggregates[group].pop(failed, None)
        self._posted[group] -= 1
        if new_target == poster:
            # The repost target wrapped all the way around: every other
            # group member is dead (§5.3 degenerate case). The poster's
            # own aggregate IS the final one — signal that instead of
            # bouncing the posting through dead nodes forever.
            self._posted[group] += 1  # the poster remains a contributor
            self._repost[group][poster] = {"status": "self",
                                           "posted": self._posted[group]}
            return poster
        self._repost[group][poster] = {"status": "repost", "to_node": new_target}
        return new_target

    # ------------------------------------------------------------------
    # Round 2: average distribution
    # ------------------------------------------------------------------
    def post_average(self, node: int, average: np.ndarray, group: int = 0,
                     weight_avg: Optional[float] = None, now: float = 0.0) -> None:
        self.stats.post_average += 1
        self._average[group] = {
            "average": average,
            "weight_avg": weight_avg,
            "initiator": node,
            "time": now,
        }
        self._maybe_publish_global()

    def _maybe_publish_global(self) -> None:
        """§5.5: once every group initiator posted, publish the average of
        the group averages (the only arithmetic the controller ever does,
        on already-anonymized values)."""
        if any(self._average[g] is None for g in self.groups):
            return
        avgs = [self._average[g]["average"] for g in self.groups]
        wavgs = [self._average[g]["weight_avg"] for g in self.groups]
        glob = np.mean(np.stack(avgs), axis=0)
        gw = None
        if all(w is not None for w in wavgs):
            gw = float(np.mean(wavgs))
        self._global_average = {
            "average": glob,
            "weight_avg": gw,
            "time": max(self._average[g].get("time", 0.0) for g in self.groups),
        }

    def try_get_average(self) -> Optional[dict]:
        return self._global_average

    def get_average(self) -> dict:
        self.stats.get_average += 1
        assert self._global_average is not None
        return self._global_average

    # ------------------------------------------------------------------
    # Initiator failover (§5.4)
    # ------------------------------------------------------------------
    def should_initiate(self, node: int, group: int = 0, now: float = 0.0) -> bool:
        """First asker after an aggregation timeout becomes initiator."""
        self.stats.should_initiate += 1
        if self._average[group] is not None:
            return False
        if now - self._round_start[group] <= self.aggregation_timeout:
            return False
        # reset group round state; first asker wins. Nodes still parked on
        # a stale check_aggregate learn the round restarted ("reset") so
        # they rejoin the new chain instead of hanging to their deadline.
        self._aggregates[group].clear()
        self._repost[group] = {
            other: {"status": "reset"} for other in self.groups[group] if other != node
        }
        self._posted[group] = 0
        self._skipped[group] = set()
        self._initiator[group] = node
        self._round_start[group] = now
        return True

    def reset_round(self) -> None:
        """Start a fresh aggregation round (new FL iteration)."""
        for g in self.groups:
            self._aggregates[g].clear()
            self._repost[g].clear()
            self._average[g] = None
            self._posted[g] = 0
            self._skipped[g] = set()
            self._initiator[g] = None
        self._global_average = None

    def advance_round(self) -> Optional[dict]:
        """Complete the current round and open the next (§11 pipelining).

        Same controller-state effect as :meth:`reset_round`, but bumps
        ``round_index`` and returns the outgoing round's published
        global average — the caller (the broker's ``advance_round``
        handler) uses the index to deliver transfer buffers that were
        parked for the new round. Non-destructive at the transport
        layer: the broker keeps round r+1 buffers across the boundary,
        whereas ``reset_round`` drops every transfer.
        """
        published = self._global_average
        self.reset_round()
        self.round_index += 1
        return published


class HierarchicalController:
    """§5.10: child controllers post anonymized group averages upward.

    The parent is itself a plain averaging point — no encryption needed
    (the posted values are already averages over >= 3 learners).
    """

    def __init__(self, children: list[Controller]):
        self.children = children
        self.up_messages = 0

    def collect(self) -> dict:
        avgs = []
        for child in self.children:
            res = child.try_get_average()
            assert res is not None, "child aggregation incomplete"
            self.up_messages += 1  # child -> parent post
            avgs.append(res["average"])
        return {"average": np.mean(np.stack(avgs), axis=0)}
