"""The SAFE controller — a message broker with progress monitoring.

Faithful Python port of the paper's Flask controller (Appendix A): it
stores opaque ciphertext messages until consumed, tracks per-group
progress, orchestrates reposts after timeouts, re-elects initiators, and
distributes the final average. It never decrypts, never aggregates
(except averaging the already-anonymized subgroup averages, §5.5), and
never holds key material — the paper's "mere message broker".

Used by the discrete-event protocol simulation (``core/protocol.py``) and
by the paper-figure benchmarks. Every client-visible operation increments
the message counters that validate §5's closed-form counts.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import numpy as np

#: Shared op registry — the single source of truth for what a client can
#: ask the broker. ``CALL_OPS`` are fire-and-forget requests (the state
#: machines' ``("call", …)`` yields); ``WAIT_KINDS`` are long-polls
#: (``("wait", …)`` yields) with a non-counting ``try_*`` probe and a
#: counting consumer. Both the discrete-event kernel
#: (``core/protocol.py``) and the wire broker (``net/broker.py``)
#: dispatch through this table, so the two planes cannot drift — and
#: docs/PROTOCOL.md §7 documents it, with ``tests/test_docs.py``
#: asserting the book's table matches these sets (and ``MessageStats``'
#: fields) exactly, so the spec cannot drift either.
CALL_OPS = frozenset({
    "post_aggregate", "post_average", "should_initiate",
    "register_key", "get_key",
})
#: call ops that take the broker clock (``now=``); key-exchange ops do not.
TIMED_OPS = frozenset({"post_aggregate", "post_average", "should_initiate"})
WAIT_KINDS = frozenset({"get_aggregate", "check_aggregate", "get_average"})

#: §5.10 hierarchical (parent-broker) ops: a child org posts its
#: already-anonymized group average upward and fetches the cross-org
#: global back down. Counted in :class:`HierStats` (the parent level's
#: own closed form — 2·(c−f) for c child orgs, f crashed), never in
#: :class:`MessageStats`, so the per-chain §5 forms are unperturbed.
#: ``post_org_average`` takes the parent broker's clock (elision timing).
HIER_OPS = frozenset({"post_org_average", "get_org_average"})
HIER_TIMED_OPS = frozenset({"post_org_average"})


@dataclasses.dataclass
class MessageStats:
    """Client->controller request counters, by operation."""

    post_aggregate: int = 0
    check_aggregate: int = 0
    get_aggregate: int = 0
    post_average: int = 0
    get_average: int = 0
    should_initiate: int = 0
    register_key: int = 0
    get_key: int = 0

    @property
    def aggregation_total(self) -> int:
        """Messages in the aggregation itself (paper's 4n count excludes
        the one-time Round-0 key exchange, §5.2)."""
        return (
            self.post_aggregate
            + self.check_aggregate
            + self.get_aggregate
            + self.post_average
            + self.get_average
            + self.should_initiate
        )

    @property
    def key_exchange_total(self) -> int:
        return self.register_key + self.get_key


@dataclasses.dataclass
class HierStats:
    """Parent-level request counters (§5.10), by operation.

    Deliberately separate from :class:`MessageStats`: the parent hop is
    its own level with its own closed form — c surviving child orgs
    each post one group average up and fetch one global back down, so
    ``hierarchy_total == 2 * (c - f)`` with f whole-org crashes."""

    post_org_average: int = 0
    get_org_average: int = 0

    @property
    def hierarchy_total(self) -> int:
        return self.post_org_average + self.get_org_average


@dataclasses.dataclass
class _Posting:
    """A stored ciphertext message (opaque to the controller)."""

    payload: Any
    from_node: int
    time: float


class Controller:
    """In-process broker implementing the paper's six operations.

    All state is per-group (paper §5.5); group 0 is the default. The
    controller knows chain order only as an opaque node-id list per group
    (it must, to pick repost targets — exactly as in the paper where it
    "requests the sending node to re-encrypt and resend to a new target").
    """

    def __init__(self, groups: Dict[int, list[int]], aggregation_timeout: float = 30.0):
        self.groups = {g: list(nodes) for g, nodes in groups.items()}
        self.aggregation_timeout = aggregation_timeout
        self.stats = MessageStats()
        # group -> node -> _Posting
        self._aggregates: Dict[int, Dict[int, _Posting]] = {g: {} for g in groups}
        # group -> node -> {"status": ...} (repost/consumed signal per §A)
        self._repost: Dict[int, Dict[int, dict]] = {g: {} for g in groups}
        # group -> {"average": vec, "weight_avg": float, "posted": int}
        self._average: Dict[int, Optional[dict]] = {g: None for g in groups}
        # group -> count of learners that successfully posted (for §5.3's
        # "initiator is informed how many nodes posted")
        self._posted: Dict[int, int] = {g: 0 for g in groups}
        self._skipped: Dict[int, set] = {g: set() for g in groups}
        # group -> nodes that consumed a posting this round. A learner
        # consumes exactly once per chain pass, so a consumed node can
        # never be a viable repost target (§5.3 × §5.4 interaction): it
        # will not issue another get_aggregate until the round resets.
        self._consumed: Dict[int, set] = {g: set() for g in groups}
        # group -> to_node keys of postings the monitor declared stalled
        # (no viable repost target): left in place for the §5.4 election
        # to sweep up, and skipped by stuck_posting so the monitor does
        # not spin on them.
        self._stalled: Dict[int, set] = {g: set() for g in groups}
        self._initiator: Dict[int, Optional[int]] = {g: None for g in groups}
        self._round_start: Dict[int, float] = {g: 0.0 for g in groups}
        self._keys: Dict[int, Any] = {}
        # Registered public/symmetric keys: node -> key blob (opaque).
        self._global_average: Optional[dict] = None
        # Monotone round index: bumped by advance_round() (cross-round
        # pipelining, PROTOCOL.md §11). reset_round() restarts the SAME
        # logical round and leaves it untouched.
        self.round_index: int = 0

    # ------------------------------------------------------------------
    # Uniform op dispatch (shared by the sim kernel and the wire broker)
    # ------------------------------------------------------------------
    def call(self, op: str, **kwargs) -> Any:
        """Invoke a fire-and-forget client op by name (see ``CALL_OPS``)."""
        if op not in CALL_OPS:
            raise ValueError(f"unknown call op {op!r}")
        return getattr(self, op)(**kwargs)

    def probe(self, kind: str, **kwargs) -> Optional[Any]:
        """Non-counting availability probe for a long-poll kind."""
        if kind not in WAIT_KINDS:
            raise ValueError(f"unknown wait kind {kind!r}")
        return getattr(self, f"try_{kind}")(**kwargs)

    def consume(self, kind: str, **kwargs) -> Any:
        """Counting resolution of a long-poll kind; the caller must have
        seen a non-None ``probe`` first."""
        if kind not in WAIT_KINDS:
            raise ValueError(f"unknown wait kind {kind!r}")
        return getattr(self, kind)(**kwargs)

    # ------------------------------------------------------------------
    # Round 0: key exchange (2 messages per node: register + retrieve)
    # ------------------------------------------------------------------
    def register_key(self, node: int, key_blob: Any) -> None:
        self.stats.register_key += 1
        self._keys[node] = key_blob

    def get_key(self, node: int) -> Any:
        self.stats.get_key += 1
        return self._keys.get(node)

    # ------------------------------------------------------------------
    # Round 1: chain aggregation
    # ------------------------------------------------------------------
    def post_aggregate(self, from_node: int, to_node: int, payload: Any,
                       group: int = 0, now: float = 0.0) -> None:
        self.stats.post_aggregate += 1
        if self._initiator[group] is None:
            self._initiator[group] = from_node
            self._round_start[group] = now
        self._aggregates[group][to_node] = _Posting(payload, from_node, now)
        self._stalled[group].discard(to_node)  # fresh posting supersedes a stall
        self._posted[group] += 1
        # Poster will long-poll check_aggregate; target will long-poll
        # get_aggregate — mark the poster's check as pending.
        self._repost[group][from_node] = {"status": "pending"}

    def try_get_aggregate(self, node: int, group: int = 0) -> Optional[dict]:
        """Non-counting availability probe used by the event kernel; the
        actual client call is get_aggregate()."""
        posting = self._aggregates[group].get(node)
        if posting is None:
            return None
        # _posted counts successful post_aggregate calls net of removed
        # postings (order_repost decrements), i.e. the n-f contributors
        # the initiator divides by (§5.3).
        return {
            "aggregate": posting.payload,
            "from_node": posting.from_node,
            "posted": self._posted[group],
            "time": posting.time,
        }

    def get_aggregate(self, node: int, group: int = 0) -> dict:
        """Consume the message addressed to ``node`` (long-poll resolved)."""
        self.stats.get_aggregate += 1
        result = self.try_get_aggregate(node, group)
        assert result is not None, "kernel resolved a wait without data"
        posting = self._aggregates[group].pop(node)
        self._consumed[group].add(node)
        # Poster's check_aggregate resolves to consumed.
        self._repost[group][posting.from_node] = {"status": "consumed"}
        return result

    def try_check_aggregate(self, node: int, group: int = 0) -> Optional[dict]:
        st = self._repost[group].get(node)
        if st is None or st.get("status") == "pending":
            return None
        return st

    def check_aggregate(self, node: int, group: int = 0) -> dict:
        self.stats.check_aggregate += 1
        st = self.try_check_aggregate(node, group)
        assert st is not None
        if st.get("status") != "consumed":
            # one-shot repost instruction
            self._repost[group][node] = {"status": "pending"}
        return st

    # ------------------------------------------------------------------
    # Progress failover (§5.3) — called by the external progress monitor.
    # ------------------------------------------------------------------
    def stuck_posting(self, group: int, now: float, timeout: float):
        """Return (poster, failed_target) if a posting has been waiting
        longer than ``timeout``, else None."""
        for to_node, posting in self._aggregates[group].items():
            if to_node in self._stalled[group]:
                continue  # already declared unrecoverable until the round resets
            if now - posting.time > timeout:
                return posting.from_node, to_node
        return None

    def order_repost(self, group: int, poster: int,
                     failed: int) -> Optional[int]:
        """Instruct ``poster`` (via its pending check_aggregate) to
        re-encrypt for the next *viable* node after ``failed`` on the
        chain.

        Viable means not already skipped and not already consumed this
        round: a learner that performed its get_aggregate has moved past
        its receive slot and will never issue another one this round, so
        retargeting it strands the posting forever (the §5.3 × §5.4
        silently-wrong-average bug — the monitor used to walk a stuck
        posting around live-but-finished nodes until the wrap produced a
        spurious "self" verdict that dropped the survivor's contribution).

        Returns the new target, ``poster`` for the degenerate
        all-others-dead "self" verdict, or ``None`` when no viable target
        exists (chain finished but its consumer died): the posting is
        left in place, marked stalled, and the §5.4 aggregation-timeout
        election recovers the round.
        """
        chain = self.groups[group]
        idx = chain.index(failed)
        new_target = None
        for step in range(1, len(chain)):
            cand = chain[(idx + step) % len(chain)]
            if cand == poster:
                break
            if cand in self._skipped[group] or cand in self._consumed[group]:
                continue
            new_target = cand
            break
        if new_target is None:
            others = [x for x in chain if x != poster]
            if all(x == failed or x in self._skipped[group] for x in others):
                # Every other group member is dead (§5.3 degenerate
                # case). The poster's own aggregate IS the final one —
                # signal that instead of bouncing the posting through
                # dead nodes forever.
                self._skipped[group].add(failed)
                self._aggregates[group].pop(failed, None)
                # net _posted unchanged: the poster remains a contributor
                self._repost[group][poster] = {"status": "self",
                                               "posted": self._posted[group]}
                return poster
            # Remaining members are alive but already consumed: the chain
            # is complete except for its dead consumer. Stall — leave the
            # posting (and _posted/_skipped) untouched so the poster's
            # check_aggregate times out and the §5.4 election restarts
            # the round with nothing stranded.
            self._stalled[group].add(failed)
            return None
        self._skipped[group].add(failed)
        # Remove the unconsumed posting and flag the poster.
        self._aggregates[group].pop(failed, None)
        self._posted[group] -= 1
        self._repost[group][poster] = {"status": "repost", "to_node": new_target}
        return new_target

    # ------------------------------------------------------------------
    # Round 2: average distribution
    # ------------------------------------------------------------------
    def post_average(self, node: int, average: np.ndarray, group: int = 0,
                     weight_avg: Optional[float] = None, now: float = 0.0) -> None:
        self.stats.post_average += 1
        self._average[group] = {
            "average": average,
            "weight_avg": weight_avg,
            "initiator": node,
            "time": now,
        }
        self._maybe_publish_global()

    def _maybe_publish_global(self) -> None:
        """§5.5: once every group initiator posted, publish the average of
        the group averages (the only arithmetic the controller ever does,
        on already-anonymized values)."""
        if any(self._average[g] is None for g in self.groups):
            return
        avgs = [self._average[g]["average"] for g in self.groups]
        wavgs = [self._average[g]["weight_avg"] for g in self.groups]
        glob = np.mean(np.stack(avgs), axis=0)
        gw = None
        if all(w is not None for w in wavgs):
            gw = float(np.mean(wavgs))
        self._global_average = {
            "average": glob,
            "weight_avg": gw,
            "time": max(self._average[g].get("time", 0.0) for g in self.groups),
        }

    def try_get_average(self) -> Optional[dict]:
        return self._global_average

    def get_average(self) -> dict:
        self.stats.get_average += 1
        assert self._global_average is not None
        return self._global_average

    # ------------------------------------------------------------------
    # Initiator failover (§5.4)
    # ------------------------------------------------------------------
    def should_initiate(self, node: int, group: int = 0, now: float = 0.0) -> bool:
        """First asker after an aggregation timeout becomes initiator."""
        self.stats.should_initiate += 1
        if self._average[group] is not None:
            return False
        if now - self._round_start[group] <= self.aggregation_timeout:
            return False
        # reset group round state; first asker wins. Nodes still parked on
        # a stale check_aggregate learn the round restarted ("reset") so
        # they rejoin the new chain instead of hanging to their deadline.
        self._aggregates[group].clear()
        self._repost[group] = {
            other: {"status": "reset"} for other in self.groups[group] if other != node
        }
        self._posted[group] = 0
        self._skipped[group] = set()
        self._consumed[group] = set()
        self._stalled[group] = set()
        self._initiator[group] = node
        self._round_start[group] = now
        return True

    def reset_round(self) -> None:
        """Start a fresh aggregation round (new FL iteration)."""
        for g in self.groups:
            self._aggregates[g].clear()
            self._repost[g].clear()
            self._average[g] = None
            self._posted[g] = 0
            self._skipped[g] = set()
            self._consumed[g] = set()
            self._stalled[g] = set()
            self._initiator[g] = None
        self._global_average = None

    def advance_round(self) -> Optional[dict]:
        """Complete the current round and open the next (§11 pipelining).

        Same controller-state effect as :meth:`reset_round`, but bumps
        ``round_index`` and returns the outgoing round's published
        global average — the caller (the broker's ``advance_round``
        handler) uses the index to deliver transfer buffers that were
        parked for the new round. Non-destructive at the transport
        layer: the broker keeps round r+1 buffers across the boundary,
        whereas ``reset_round`` drops every transfer.
        """
        published = self._global_average
        self.reset_round()
        self.round_index += 1
        return published


def combine_org_averages(avgs: list, wavgs: Optional[list] = None) -> dict:
    """The §5.10 parent verdict: average the already-anonymized group
    averages (the only arithmetic a parent ever does — same fold as the
    §5.5 cross-group publish, so sim and wire hierarchies are
    bit-identical by construction). Shared by
    :class:`HierarchicalController` (sim) and :class:`ParentController`
    (wire parent broker)."""
    out = {"average": np.mean(np.stack(avgs), axis=0)}
    gw = None
    if wavgs and all(w is not None for w in wavgs):
        gw = float(np.mean(wavgs))
    out["weight_avg"] = gw
    return out


class HierarchicalController:
    """§5.10: child controllers post anonymized group averages upward.

    The parent is itself a plain averaging point — no encryption needed
    (the posted values are already averages over >= 3 learners).
    """

    def __init__(self, children: list[Controller]):
        self.children = children
        self.up_messages = 0
        self.elided: tuple = ()

    def collect(self, elide_incomplete: bool = False) -> dict:
        """Average the children's published averages.

        ``elide_incomplete=True`` is the §5.10 whole-org-crash verdict:
        a child whose aggregation never published is dropped from the
        parent average exactly like a dead learner is dropped from a
        chain — the surviving orgs' fold is unchanged. The elided child
        indices land in ``self.elided`` (and the result dict)."""
        avgs, wavgs, elided = [], [], []
        for idx, child in enumerate(self.children):
            res = child.try_get_average()
            if res is None:
                if elide_incomplete:
                    elided.append(idx)
                    continue
                raise AssertionError("child aggregation incomplete")
            self.up_messages += 1  # child -> parent post
            avgs.append(res["average"])
            wavgs.append(res.get("weight_avg"))
        assert avgs, "every child org crashed — nothing to publish"
        self.elided = tuple(elided)
        out = combine_org_averages(avgs, wavgs)
        out["elided"] = self.elided
        return out


class ParentController:
    """§5.10 parent-broker state: the wire-plane twin of
    :class:`HierarchicalController`.

    Tracks which child orgs posted their group average this round and
    publishes the cross-org global once all expected orgs posted — or,
    after the parent's aggregation timeout, with the missing orgs
    *elided* exactly like dead learners (the whole-org-crash failover).
    Never sees an individual contribution: the upward posts are already
    averages over >= 3 learners (RingTopology.validate_privacy), which
    is the paper's anonymization argument for the org boundary.

    Pure synchronous state like :class:`Controller` — the wire broker
    wraps it in its own locking/long-poll machinery. The fold is
    :func:`combine_org_averages`, shared with the sim, so parent
    averages are bit-identical across planes by construction.
    """

    def __init__(self, orgs: list[int], aggregation_timeout: float = 30.0):
        assert orgs, "a parent session needs at least one child org"
        self.orgs = list(orgs)
        self.aggregation_timeout = aggregation_timeout
        self.stats = HierStats()
        self._averages: Dict[int, dict] = {}  # org -> posted payload
        self._published: Optional[dict] = None
        self._round_start = 0.0
        self._started = False
        self.crashed_orgs: tuple = ()

    def post_org_average(self, org: int, average: np.ndarray,
                         weight_avg: Optional[float] = None,
                         now: float = 0.0) -> None:
        if org not in self.orgs:
            raise ValueError(f"unknown org {org!r}")
        self.stats.post_org_average += 1
        if not self._started:
            self._started = True
            self._round_start = now
        self._averages[org] = {
            "average": average, "weight_avg": weight_avg, "time": now,
        }
        if all(o in self._averages for o in self.orgs):
            self._publish(())

    def _publish(self, crashed: tuple) -> None:
        # org-id order, present orgs only — the same fold order the sim
        # twin uses over its surviving children
        present = [o for o in self.orgs if o in self._averages]
        out = combine_org_averages(
            [self._averages[o]["average"] for o in present],
            [self._averages[o]["weight_avg"] for o in present])
        out["time"] = max(self._averages[o]["time"] for o in present)
        out["orgs"] = present
        out["crashed_orgs"] = list(crashed)
        self.crashed_orgs = crashed
        self._published = out

    def maybe_elide(self, now: float) -> bool:
        """Progress-monitor hook (the parent-level §5.3/§5.4 analogue):
        once the aggregation timeout passes with at least one org
        posted, publish without the stragglers. Returns True when a
        publish happened (the caller wakes parked waiters)."""
        if self._published is not None or not self._averages:
            return False
        if now - self._round_start <= self.aggregation_timeout:
            return False
        crashed = tuple(o for o in self.orgs if o not in self._averages)
        self._publish(crashed)
        return True

    def try_get_org_average(self) -> Optional[dict]:
        return self._published

    def get_org_average(self) -> dict:
        self.stats.get_org_average += 1
        assert self._published is not None
        return self._published

    def peek_org(self, org: int) -> Optional[dict]:
        """Uncounted (admin-class) view of one org's posted average."""
        return self._averages.get(org)

    def reset_round(self) -> None:
        self._averages.clear()
        self._published = None
        self._started = False
        self._round_start = 0.0
        self.crashed_orgs = ()
