"""Discrete-event simulation of the SAFE protocol (control plane).

This is the paper's distributed system (Figures 3–5) as a deterministic
discrete-event simulation: learner state machines (Python generators),
the broker ``Controller``, and the external progress monitor, exchanging
*real* masked fixed-point payloads (numpy Threefry pads — the data path
the TPU plane also uses), while a :class:`~repro.core.costs.CostModel`
accumulates virtual time for network / crypto / vector ops.

Outputs per run: the published average (asserted against the clear-text
mean in tests), per-op message counters (validating §5's closed forms),
virtual completion time (the paper's "aggregation time" axis), and byte
counters.

Learner coroutine protocol — generators yield:
  ("compute", seconds)                       local work
  ("call",  op, kwargs, nbytes)              non-blocking controller op
  ("wait",  kind, kwargs, nbytes, timeout)   long-poll; resumes with the
                                             result or {"status":"timeout"}
and return their final result via StopIteration.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Callable, Dict, Generator, Iterable, Optional

import numpy as np

from repro.core.controller import Controller
from repro.core.costs import CostModel, EDGE
from repro.crypto.np_impl import (
    NpFixedPoint,
    derive_key_np,
    derive_pair_key_np,
    keystream_pair_lanes_np,
)
from repro.topology import RingTopology

_TAG_HOP_PAD = 0x50
_TAG_INITIATOR_MASK = 0x52

LearnerGen = Generator[tuple, Any, None]


# ---------------------------------------------------------------------------
# Crypto helpers (real arithmetic; costs accounted separately)
# ---------------------------------------------------------------------------


class LearnerCrypto:
    """Hop encryption for one learner: Threefry one-time pads over Z/2^32Z.

    ``symmetric_only`` models §5.8 pre-negotiation (deep-edge profile);
    otherwise each hop additionally pays the RSA wrap/unwrap (§5.7 hybrid).
    """

    def __init__(self, node: int, provisioning_seed: int, learner_master: int,
                 scale_bits: int = 16, encrypt: bool = True,
                 symmetric_only: bool = False):
        self.node = node
        self.codec = NpFixedPoint(scale_bits)
        self.encrypt_enabled = encrypt
        self.symmetric_only = symmetric_only
        prov = np.array([provisioning_seed & 0xFFFFFFFF,
                         (provisioning_seed >> 32) & 0xFFFFFFFF], np.uint32)
        self._pad_seed = derive_key_np(prov, _TAG_HOP_PAD)
        master = np.array([learner_master & 0xFFFFFFFF,
                           (learner_master >> 32) & 0xFFFFFFFF], np.uint32)
        self._own = derive_key_np(derive_key_np(master, node), _TAG_INITIATOR_MASK)

    def pad(self, src: int, dst: int, n: int, counter: int) -> np.ndarray:
        k = derive_pair_key_np(self._pad_seed, src, dst)
        return keystream_pair_lanes_np(k, n, counter)

    def mask_r(self, n: int, counter: int) -> np.ndarray:
        return keystream_pair_lanes_np(self._own, n, counter)

    def hop_encrypt(self, plain_ring: np.ndarray, dst: int, counter: int) -> np.ndarray:
        if not self.encrypt_enabled:
            return plain_ring
        return NpFixedPoint.add(plain_ring, self.pad(self.node, dst, plain_ring.size, counter))

    def hop_decrypt(self, cipher: np.ndarray, src: int, counter: int) -> np.ndarray:
        if not self.encrypt_enabled:
            return cipher
        return NpFixedPoint.sub(cipher, self.pad(src, self.node, cipher.size, counter))


# ---------------------------------------------------------------------------
# Learner state machines (paper §5.1.1 / §5.1.2, with §5.3–5.4 failover)
# ---------------------------------------------------------------------------


def safe_learner(
    node: int,
    topology: RingTopology,
    value: np.ndarray,
    crypto: LearnerCrypto,
    cost: CostModel,
    group: int = 0,
    is_initiator: bool = False,
    weight: Optional[float] = None,
    counter: int = 0,
    fail_mode: Optional[str] = None,
    subgroups: int = 1,
    node_base: int = 1,
) -> LearnerGen:
    """One SAFE learner for one aggregation round.

    Successor targeting comes from the shared ``topology`` object (the
    same one the device plane's ppermute schedule is built from);
    ``node_base`` maps 0-based topology ranks onto the sim's node ids.

    fail_mode: None | 'dead' (crashed before round — never spawned by the
    runner, listed here for completeness) | 'after_post' (initiator crash
    of Fig. 5: posts its first aggregate then stops responding).
    """
    codec = crypto.codec
    nxt = topology.successor(node - node_base) + node_base
    payload_f = value if weight is None else np.concatenate(
        [value * weight, np.array([weight], value.dtype)])
    V = payload_f.size
    # base64-wrapped binary ciphertext: ~6 bytes/element on the wire —
    # the "encryption helps with compression" effect of §6.2 (INSEC posts
    # clear-text JSON floats at ~14 bytes/element)
    nbytes = 6 * V

    def enc_cost():
        return crypto.codec.scale_bits * 0 + cost.encrypt(nbytes, crypto.symmetric_only)

    def _election():
        """§5.4 path after any aggregation timeout: probe the average,
        else ask to become initiator. Returns 'done'|'initiator'|'rejoin'."""
        res = yield ("wait", "get_average", dict(), nbytes, 0.01)
        if res.get("status") != "timeout":
            return "done"
        won = yield ("call", "should_initiate", dict(node=node, group=group), 64)
        if won:
            return "initiator"
        res = yield ("wait", "get_average", dict(), nbytes, 0.01)
        if res.get("status") != "timeout":
            return "done"
        return "rejoin"

    def _post_and_confirm(agg):
        """post_aggregate + check_aggregate loop, handling §5.3 reposts and
        round resets. Returns the terminal status dict (status is
        'consumed'|'reset'|'timeout'|'self' — 'self' means every repost
        target was dead and the poster's own aggregate is final)."""
        yield ("compute", enc_cost())
        cipher = crypto.hop_encrypt(agg, nxt, counter)
        yield ("call", "post_aggregate",
               dict(from_node=node, to_node=nxt, payload=cipher, group=group), nbytes)
        while True:
            st = yield ("wait", "check_aggregate", dict(node=node, group=group),
                        64, "aggregation")
            status = st.get("status")
            if status in ("consumed", "reset", "timeout", "self"):
                return st
            assert status == "repost"
            target = st["to_node"]
            yield ("compute", enc_cost())
            cipher = crypto.hop_encrypt(agg, target, counter)
            yield ("call", "post_aggregate",
                   dict(from_node=node, to_node=target, payload=cipher, group=group),
                   nbytes)

    initiator_now = is_initiator
    while True:  # restarts on initiator failover (§5.4)
        if initiator_now:
            # -- §5.1.1 steps 1-2: mask with R, encrypt for next, post.
            yield ("compute", cost.t_rng_word * V + cost.t_add_elem * V)
            R = crypto.mask_r(V, counter)
            agg = NpFixedPoint.add(codec.encode(payload_f), R)
            if fail_mode == "after_post":
                # Fig. 5 step 3: initiator posts once, then crashes.
                yield ("compute", enc_cost())
                cipher = crypto.hop_encrypt(agg, nxt, counter)
                yield ("call", "post_aggregate",
                       dict(from_node=node, to_node=nxt, payload=cipher, group=group),
                       nbytes)
                return

            st = yield from _post_and_confirm(agg)
            if st["status"] in ("reset", "timeout"):
                verdict = yield from _election()
                if verdict == "done":
                    return
                initiator_now = verdict == "initiator"
                continue

            if st["status"] == "self":
                # Lone survivor (§5.3 degenerate case): every repost
                # target was dead, the aggregate never left this node —
                # unmask the local copy, no decrypt hop.
                total = agg
                posted = st["posted"]
            else:
                # -- §5.1.1 steps 3-4: receive final aggregate, unmask.
                res = yield ("wait", "get_aggregate", dict(node=node, group=group),
                             nbytes, "aggregation")
                if res.get("status") == "timeout":
                    verdict = yield from _election()
                    if verdict == "done":
                        return
                    initiator_now = verdict == "initiator"
                    continue
                yield ("compute", cost.decrypt(nbytes, crypto.symmetric_only))
                total = crypto.hop_decrypt(res["aggregate"], res["from_node"], counter)
                posted = res["posted"]  # §5.3: contributor count from controller
            yield ("compute", cost.t_add_elem * V * 2)
            total = NpFixedPoint.sub(total, R)
            dec = codec.decode(total)
            if weight is not None:
                avg = dec[:-1] / max(dec[-1], 1e-12)
                wavg = dec[-1] / posted
            else:
                avg = dec / posted
                wavg = None
            yield ("call", "post_average",
                   dict(node=node, average=avg, group=group, weight_avg=wavg), nbytes)
            if subgroups > 1:
                # §5.5: group initiators must fetch the cross-group average.
                yield ("wait", "get_average", dict(), nbytes, None)
            return
        else:
            # -- §5.1.2 non-initiator.
            res = yield ("wait", "get_aggregate", dict(node=node, group=group),
                         nbytes, "aggregation")
            if res.get("status") == "timeout":
                verdict = yield from _election()
                if verdict == "done":
                    return
                initiator_now = verdict == "initiator"
                continue
            if fail_mode == "dead":
                return
            yield ("compute", cost.decrypt(nbytes, crypto.symmetric_only))
            agg = crypto.hop_decrypt(res["aggregate"], res["from_node"], counter)
            yield ("compute", cost.t_add_elem * V)
            agg = NpFixedPoint.add(agg, codec.encode(payload_f))

            st = yield from _post_and_confirm(agg)
            if st["status"] == "reset":
                continue  # round restarted — rejoin the new chain
            # 'timeout' falls through to get_average, whose own timeout
            # handles an aborted round.

            res = yield ("wait", "get_average", dict(), nbytes, "aggregation")
            if res.get("status") == "timeout":
                verdict = yield from _election()
                if verdict == "done":
                    return
                initiator_now = verdict == "initiator"
                continue
            return


def insec_learner(node: int, value: np.ndarray, cost: CostModel,
                  group: int = 0, post_to: int = -1) -> LearnerGen:
    """INSEC baseline: post raw parameters, read back the average."""
    nbytes = 14 * value.size  # clear-text JSON floats
    yield ("call", "post_aggregate",
           dict(from_node=node, to_node=post_to, payload=value, group=group), nbytes)
    yield ("wait", "get_average", dict(), nbytes, None)
    return


# ---------------------------------------------------------------------------
# Discrete-event kernel
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Task:
    node: int
    gen: LearnerGen
    time: float = 0.0
    waiting: Optional[tuple] = None  # (kind, kwargs, nbytes, deadline)
    done: bool = False
    result: Any = None


@dataclasses.dataclass
class SimResult:
    average: Optional[np.ndarray]
    weight_avg: Optional[float]
    virtual_time: float
    stats: Any
    bytes_sent: int
    monitor_reposts: int
    initiator_elections: int


class ProtocolSimulation:
    """Event kernel driving learners + controller + progress monitor."""

    def __init__(self, controller: Controller, cost: CostModel = EDGE,
                 progress_timeout: float = 1.0, monitor_interval: float = 0.25,
                 parse_payloads: bool = False):
        self.ctrl = controller
        self.cost = cost
        # INSEC: the controller must parse (and average) the payloads;
        # SAFE/SAF: opaque ciphertext relay (paper's broker-only role)
        self.parse_payloads = parse_payloads
        self.progress_timeout = progress_timeout
        self.monitor_interval = monitor_interval
        self.tasks: Dict[int, _Task] = {}
        self.bytes_sent = 0
        self.monitor_reposts = 0
        self.initiator_elections = 0
        # The controller is a shared resource: requests serialize on it
        # (the reason even INSEC scales linearly in nodes, Fig. 7). The
        # event loop feeds requests in chronological order — every
        # controller interaction is its own event — so a simple busy-until
        # ratchet is exact single-server FIFO queueing.
        self._server_free_at = 0.0

    def _server(self, t: float, nbytes: int = 64) -> float:
        """FIFO single-server: request arriving at t completes at
        max(free, t) + handling. Handling scales with payload size —
        parsed JSON for INSEC, opaque relay for SAFE — and INSEC serving
        additionally re-averages the n posted arrays (O(n·V)/request)."""
        cost = self.cost.t_ctrl
        if self.parse_payloads:
            cost += self.cost.t_parse_byte * nbytes
            n = len(self.tasks)
            cost += self.cost.t_avg_elem * n * (nbytes // 14)
        else:
            cost += self.cost.t_relay_byte * nbytes
        start = max(self._server_free_at, t)
        self._server_free_at = start + cost
        return self._server_free_at

    def spawn(self, node: int, gen: LearnerGen, start: float = 0.0) -> None:
        self.tasks[node] = _Task(node=node, gen=gen, time=start)

    # -- controller op dispatch (counts messages + bytes) -----------------
    def _dispatch(self, task: _Task, op: str, kwargs: dict, nbytes: int) -> Any:
        self.bytes_sent += nbytes
        task.time = self._server(task.time + self.cost.message(nbytes), nbytes)
        now = task.time
        if op == "post_aggregate":
            return self.ctrl.post_aggregate(now=now, **kwargs)
        if op == "post_average":
            return self.ctrl.post_average(now=now, **kwargs)
        if op == "should_initiate":
            won = self.ctrl.should_initiate(now=now, **kwargs)
            if won:
                self.initiator_elections += 1
            return won
        raise ValueError(f"unknown call op {op}")

    def _peek_wait(self, kind: str, kwargs: dict) -> Optional[Any]:
        """Non-consuming availability probe (event-queue ordering)."""
        if kind == "__call__":
            return {}  # plain calls are always ready
        if kind == "get_aggregate":
            return self.ctrl.try_get_aggregate(**kwargs)
        if kind == "check_aggregate":
            return self.ctrl.try_check_aggregate(**kwargs)
        if kind == "get_average":
            return self.ctrl.try_get_average()
        raise ValueError(f"unknown wait kind {kind}")

    def _try_wait(self, task: _Task, kind: str, kwargs: dict) -> Optional[Any]:
        if kind == "get_aggregate":
            if self.ctrl.try_get_aggregate(**kwargs) is None:
                return None
            return self.ctrl.get_aggregate(**kwargs)
        if kind == "check_aggregate":
            if self.ctrl.try_check_aggregate(**kwargs) is None:
                return None
            return self.ctrl.check_aggregate(**kwargs)
        if kind == "get_average":
            if self.ctrl.try_get_average() is None:
                return None
            return self.ctrl.get_average()
        raise ValueError(f"unknown wait kind {kind}")

    def run(self, max_virtual_time: float = 3600.0) -> SimResult:
        """Discrete-event loop: process exactly one event at a time in
        global virtual-time order (so controller serialization sees
        requests chronologically), with the progress monitor as a
        recurring event source."""
        next_monitor = self.monitor_interval
        guard = 0
        while not all(t.done for t in self.tasks.values()):
            guard += 1
            if guard > 2_000_000:
                raise RuntimeError("simulation did not converge")

            # gather candidate events: (time, priority, node, action, task)
            events = []
            for task in self.tasks.values():
                if task.done:
                    continue
                if task.waiting is None:
                    events.append((task.time, 0, task.node, "run", task))
                    continue
                kind, kwargs, nbytes, deadline = task.waiting
                peek = self._peek_wait(kind, kwargs)
                if peek is not None:
                    avail = peek.get("time", 0.0) if isinstance(peek, dict) else 0.0
                    events.append((max(task.time, avail), 1, task.node,
                                   "resolve", task))
                elif deadline is not None:
                    events.append((deadline, 2, task.node, "timeout", task))

            if not events:
                # everything parked with no deadline: only the monitor can
                # unstick the chain (ordering a repost, §5.3)
                if next_monitor > max_virtual_time:
                    raise RuntimeError("aggregation exceeded max virtual time")
                self._monitor_tick(next_monitor)
                next_monitor += self.monitor_interval
                continue

            events.sort(key=lambda e: e[:3])
            etime, _, _, action, task = events[0]
            if next_monitor <= etime:
                # the monitor fires between events on its own schedule
                if next_monitor > max_virtual_time:
                    raise RuntimeError("aggregation exceeded max virtual time")
                self._monitor_tick(next_monitor)
                next_monitor += self.monitor_interval
                continue  # a repost order may create an earlier event

            if action == "run":
                self._step(task, None)
            elif action == "resolve":
                kind, kwargs, nbytes, _ = task.waiting
                if kind == "__call__":
                    op, call_kwargs = kwargs
                    task.waiting = None
                    res = self._dispatch(task, op, call_kwargs, nbytes)
                    self._step(task, res)
                else:
                    res = self._try_wait(task, kind, kwargs)
                    assert res is not None
                    self.bytes_sent += nbytes
                    avail = res.get("time", 0.0) if isinstance(res, dict) else 0.0
                    t = self._server(max(task.time, avail), nbytes)
                    task.time = t + self.cost.message(nbytes)
                    task.waiting = None
                    self._step(task, res)
            else:  # timeout
                task.time = max(task.time, etime)
                task.waiting = None
                self._step(task, {"status": "timeout"})

        avg = self.ctrl.try_get_average()
        return SimResult(
            average=None if avg is None else avg["average"],
            weight_avg=None if avg is None else avg.get("weight_avg"),
            virtual_time=max(t.time for t in self.tasks.values()),
            stats=self.ctrl.stats,
            bytes_sent=self.bytes_sent,
            monitor_reposts=self.monitor_reposts,
            initiator_elections=self.initiator_elections,
        )

    def _sim_now(self) -> float:
        live = [t.time for t in self.tasks.values() if not t.done]
        return max(live) if live else max(t.time for t in self.tasks.values())

    def _monitor_tick(self, now: float) -> None:
        """External progress monitor (§5.3): detect stuck postings and
        order reposts; unstick aggregation-timeout waits (§5.4)."""
        for task in self.tasks.values():
            if not task.done:
                task.time = max(task.time, now)
        for group in self.ctrl.groups:
            stuck = self.ctrl.stuck_posting(group, now, self.progress_timeout)
            if stuck is not None:
                poster, failed = stuck
                if self.tasks.get(poster) is None or self.tasks[poster].done:
                    continue  # poster itself gone — aggregation timeout path
                self.ctrl.order_repost(group, poster, failed)
                self.monitor_reposts += 1
        # aggregation-timeout waits are handled in run() via deadlines; the
        # tick just advanced the clock so those deadlines can fire.

    def _step(self, task: _Task, send_value: Any) -> None:
        """Advance one learner until it parks, finishes, or yields compute."""
        try:
            while True:
                item = task.gen.send(send_value)  # send(None) primes/continues
                kind = item[0]
                if kind == "compute":
                    task.time += item[1]
                    send_value = None
                    continue
                if kind == "call":
                    # park: every controller interaction is its own event,
                    # so the FIFO server sees requests chronologically
                    _, op, kwargs, nbytes = item
                    task.waiting = ("__call__", (op, kwargs), nbytes, None)
                    return
                if kind == "wait":
                    _, wkind, kwargs, nbytes, timeout = item
                    deadline = None
                    if timeout == "aggregation":
                        deadline = task.time + self.ctrl.aggregation_timeout
                    elif isinstance(timeout, (int, float)):
                        deadline = task.time + timeout
                    task.time += self.cost.message(64)  # long-poll request
                    task.waiting = (wkind, kwargs, nbytes, deadline)
                    return
                raise ValueError(f"unknown yield {item!r}")
        except StopIteration as stop:
            task.done = True
            task.result = stop.value


# ---------------------------------------------------------------------------
# Runner: build + run one aggregation round
# ---------------------------------------------------------------------------


def run_safe_round(
    values: np.ndarray,
    mode: str = "safe",
    subgroups: int = 1,
    failed_nodes: Iterable[int] = (),
    initiator_fails: bool = False,
    weights: Optional[np.ndarray] = None,
    cost: CostModel = EDGE,
    aggregation_timeout: float = 8.0,
    progress_timeout: float = 1.0,
    symmetric_only: bool = False,
    scale_bits: int = 16,
    provisioning_seed: int = 0xC0FFEE,
    learner_master: int = 0x5EED,
    counter: int = 0,
) -> SimResult:
    """Simulate one full aggregation round.

    values: f32[n, V]; node ids are 1..n (paper numbering), chain order is
    id order, split into ``subgroups`` contiguous groups (§5.5).
    failed_nodes: 1-based ids of learners dead before the round (the
    paper's failover experiment takes out nodes 4-6 after key exchange).
    initiator_fails: group-0 initiator posts once then crashes (Fig. 5).
    """
    n, V = values.shape
    assert mode in ("safe", "saf", "insec")
    # Shared topology layer: the SAME object family the device plane's
    # ppermute schedule and initiator election are built from.
    topo = RingTopology(n, subgroups)
    if mode in ("safe", "saf"):
        topo.validate_privacy()
    groups = topo.group_chains(node_base=1)
    ctrl = Controller(groups, aggregation_timeout=aggregation_timeout)
    sim = ProtocolSimulation(ctrl, cost, progress_timeout=progress_timeout,
                             parse_payloads=(mode == "insec"))
    failed = set(failed_nodes)
    # Round-start initiators: elected over the all-alive bitmap (a node
    # dead before the round is *discovered* by timeout, §5.4 — the
    # control plane does not know it up front).
    initiators = {r + 1 for r in topo.elect_initiators()}

    for g, chain in groups.items():
        for node in chain:
            if node in failed:
                continue  # crashed before the aggregation started
            val = values[node - 1]
            w = None if weights is None else float(weights[node - 1])
            if mode == "insec":
                gen = insec_learner(node, val if w is None else val * w, cost, group=g)
            else:
                crypto = LearnerCrypto(
                    node, provisioning_seed, learner_master, scale_bits,
                    encrypt=(mode == "safe"), symmetric_only=symmetric_only)
                is_init = node in initiators
                fail_mode = "after_post" if (initiator_fails and g == 0 and is_init) else None
                gen = safe_learner(
                    node, topo, val, crypto, cost, group=g,
                    is_initiator=is_init, weight=w, counter=counter,
                    fail_mode=fail_mode, subgroups=subgroups)
            sim.spawn(node, gen)

    if mode == "insec":
        _drive_insec(ctrl, sim, groups, failed, weights)
        return sim.run()
    return sim.run()


def _drive_insec(ctrl: Controller, sim: ProtocolSimulation, groups, failed, weights):
    """INSEC controller-side averaging: once all live nodes posted, the
    controller averages raw values (it sees everything — the point of the
    baseline). Implemented as a zero-cost shim around the broker."""
    import types

    orig_post = ctrl.post_aggregate
    expected = sum(len([x for x in chain if x not in failed]) for chain in groups.values())
    posted_vals = []

    def patched(from_node, to_node, payload, group=0, now=0.0):
        orig_post(from_node, to_node, payload, group, now)
        posted_vals.append(np.asarray(payload, np.float64))
        if len(posted_vals) == expected:
            avg = np.mean(np.stack(posted_vals), axis=0).astype(np.float32)
            # controller publishes directly (not a client message)
            ctrl._global_average = {"average": avg, "weight_avg": None}

    ctrl.post_aggregate = patched
