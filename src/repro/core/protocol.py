"""Discrete-event simulation of the SAFE protocol (control plane).

This is the paper's distributed system (Figures 3–5) as a deterministic
discrete-event simulation: learner state machines (Python generators),
the broker ``Controller``, and the external progress monitor, exchanging
*real* masked fixed-point payloads (numpy Threefry pads — the data path
the TPU plane also uses), while a :class:`~repro.core.costs.CostModel`
accumulates virtual time for network / crypto / vector ops.

Outputs per run: the published average (asserted against the clear-text
mean in tests), per-op message counters (validating §5's closed forms),
virtual completion time (the paper's "aggregation time" axis), and byte
counters.

The learner coroutines themselves live in ``core/machines.py`` (yield
protocol documented there) — they are runtime-agnostic and are also
driven, unmodified, over a real asyncio transport by ``repro.net``.
This module is the *virtual-time* runtime: the discrete-event kernel,
FIFO controller-server queueing, and the progress monitor.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, Optional

import numpy as np

from repro.core.controller import (
    CALL_OPS,
    TIMED_OPS,
    Controller,
    HierarchicalController,
)
from repro.core.costs import CostModel, EDGE

# Re-exported for backwards compatibility: the state machines moved to
# core/machines.py so the wire runtime (repro/net) can drive them too.
from repro.core.machines import (  # noqa: F401
    LearnerCrypto,
    LearnerGen,
    build_round_machines,
    insec_learner,
    safe_learner,
)
from repro.topology import RingTopology


# ---------------------------------------------------------------------------
# Discrete-event kernel
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Task:
    node: int
    gen: LearnerGen
    time: float = 0.0
    waiting: Optional[tuple] = None  # (kind, kwargs, nbytes, deadline)
    done: bool = False
    result: Any = None


@dataclasses.dataclass
class SimResult:
    average: Optional[np.ndarray]
    weight_avg: Optional[float]
    virtual_time: float
    stats: Any
    bytes_sent: int
    monitor_reposts: int
    initiator_elections: int


class ProtocolSimulation:
    """Event kernel driving learners + controller + progress monitor."""

    def __init__(self, controller: Controller, cost: CostModel = EDGE,
                 progress_timeout: float = 1.0, monitor_interval: float = 0.25,
                 parse_payloads: bool = False):
        self.ctrl = controller
        self.cost = cost
        # INSEC: the controller must parse (and average) the payloads;
        # SAFE/SAF: opaque ciphertext relay (paper's broker-only role)
        self.parse_payloads = parse_payloads
        self.progress_timeout = progress_timeout
        self.monitor_interval = monitor_interval
        self.tasks: Dict[int, _Task] = {}
        self.bytes_sent = 0
        self.monitor_reposts = 0
        self.initiator_elections = 0
        # The controller is a shared resource: requests serialize on it
        # (the reason even INSEC scales linearly in nodes, Fig. 7). The
        # event loop feeds requests in chronological order — every
        # controller interaction is its own event — so a simple busy-until
        # ratchet is exact single-server FIFO queueing.
        self._server_free_at = 0.0

    def _server(self, t: float, nbytes: int = 64) -> float:
        """FIFO single-server: request arriving at t completes at
        max(free, t) + handling. Handling scales with payload size —
        parsed JSON for INSEC, opaque relay for SAFE — and INSEC serving
        additionally re-averages the n posted arrays (O(n·V)/request)."""
        cost = self.cost.t_ctrl
        if self.parse_payloads:
            cost += self.cost.t_parse_byte * nbytes
            n = len(self.tasks)
            cost += self.cost.t_avg_elem * n * (nbytes // 14)
        else:
            cost += self.cost.t_relay_byte * nbytes
        start = max(self._server_free_at, t)
        self._server_free_at = start + cost
        return self._server_free_at

    def spawn(self, node: int, gen: LearnerGen, start: float = 0.0) -> None:
        self.tasks[node] = _Task(node=node, gen=gen, time=start)

    # -- controller op dispatch (counts messages + bytes) -----------------
    def _dispatch(self, task: _Task, op: str, kwargs: dict, nbytes: int) -> Any:
        if op not in CALL_OPS:
            raise ValueError(f"unknown call op {op}")
        self.bytes_sent += nbytes
        task.time = self._server(task.time + self.cost.message(nbytes), nbytes)
        if op in TIMED_OPS:
            kwargs = dict(kwargs, now=task.time)
        res = self.ctrl.call(op, **kwargs)
        if op == "should_initiate" and res:
            self.initiator_elections += 1
        return res

    def _peek_wait(self, kind: str, kwargs: dict) -> Optional[Any]:
        """Non-consuming availability probe (event-queue ordering)."""
        if kind == "__call__":
            return {}  # plain calls are always ready
        return self.ctrl.probe(kind, **kwargs)

    def _try_wait(self, task: _Task, kind: str, kwargs: dict) -> Optional[Any]:
        if self.ctrl.probe(kind, **kwargs) is None:
            return None
        return self.ctrl.consume(kind, **kwargs)

    def run(self, max_virtual_time: float = 3600.0) -> SimResult:
        """Discrete-event loop: process exactly one event at a time in
        global virtual-time order (so controller serialization sees
        requests chronologically), with the progress monitor as a
        recurring event source."""
        next_monitor = self.monitor_interval
        guard = 0
        while not all(t.done for t in self.tasks.values()):
            guard += 1
            if guard > 2_000_000:
                raise RuntimeError("simulation did not converge")

            # gather candidate events: (time, priority, node, action, task)
            events = []
            for task in self.tasks.values():
                if task.done:
                    continue
                if task.waiting is None:
                    events.append((task.time, 0, task.node, "run", task))
                    continue
                kind, kwargs, nbytes, deadline = task.waiting
                peek = self._peek_wait(kind, kwargs)
                if peek is not None:
                    avail = peek.get("time", 0.0) if isinstance(peek, dict) else 0.0
                    events.append((max(task.time, avail), 1, task.node,
                                   "resolve", task))
                elif deadline is not None:
                    events.append((deadline, 2, task.node, "timeout", task))

            if not events:
                # everything parked with no deadline: only the monitor can
                # unstick the chain (ordering a repost, §5.3)
                if next_monitor > max_virtual_time:
                    raise RuntimeError("aggregation exceeded max virtual time")
                self._monitor_tick(next_monitor)
                next_monitor += self.monitor_interval
                continue

            events.sort(key=lambda e: e[:3])
            etime, _, _, action, task = events[0]
            if next_monitor <= etime:
                # the monitor fires between events on its own schedule
                if next_monitor > max_virtual_time:
                    raise RuntimeError("aggregation exceeded max virtual time")
                self._monitor_tick(next_monitor)
                next_monitor += self.monitor_interval
                continue  # a repost order may create an earlier event

            if action == "run":
                self._step(task, None)
            elif action == "resolve":
                kind, kwargs, nbytes, _ = task.waiting
                if kind == "__call__":
                    op, call_kwargs = kwargs
                    task.waiting = None
                    res = self._dispatch(task, op, call_kwargs, nbytes)
                    self._step(task, res)
                else:
                    res = self._try_wait(task, kind, kwargs)
                    assert res is not None
                    self.bytes_sent += nbytes
                    avail = res.get("time", 0.0) if isinstance(res, dict) else 0.0
                    t = self._server(max(task.time, avail), nbytes)
                    task.time = t + self.cost.message(nbytes)
                    task.waiting = None
                    self._step(task, res)
            else:  # timeout
                task.time = max(task.time, etime)
                task.waiting = None
                self._step(task, {"status": "timeout"})

        avg = self.ctrl.try_get_average()
        return SimResult(
            average=None if avg is None else avg["average"],
            weight_avg=None if avg is None else avg.get("weight_avg"),
            virtual_time=max(t.time for t in self.tasks.values()),
            stats=self.ctrl.stats,
            bytes_sent=self.bytes_sent,
            monitor_reposts=self.monitor_reposts,
            initiator_elections=self.initiator_elections,
        )

    def _sim_now(self) -> float:
        live = [t.time for t in self.tasks.values() if not t.done]
        return max(live) if live else max(t.time for t in self.tasks.values())

    def _monitor_tick(self, now: float) -> None:
        """External progress monitor (§5.3): detect stuck postings and
        order reposts; unstick aggregation-timeout waits (§5.4)."""
        for task in self.tasks.values():
            if not task.done:
                task.time = max(task.time, now)
        for group in self.ctrl.groups:
            stuck = self.ctrl.stuck_posting(group, now, self.progress_timeout)
            if stuck is not None:
                poster, failed = stuck
                if self.tasks.get(poster) is None or self.tasks[poster].done:
                    continue  # poster itself gone — aggregation timeout path
                if self.ctrl.order_repost(group, poster, failed) is None:
                    continue  # stalled: §5.4 election will recover, no repost
                self.monitor_reposts += 1
        # aggregation-timeout waits are handled in run() via deadlines; the
        # tick just advanced the clock so those deadlines can fire.

    def _step(self, task: _Task, send_value: Any) -> None:
        """Advance one learner until it parks, finishes, or yields compute."""
        try:
            while True:
                item = task.gen.send(send_value)  # send(None) primes/continues
                kind = item[0]
                if kind == "compute":
                    task.time += item[1]
                    send_value = None
                    continue
                if kind == "call":
                    # park: every controller interaction is its own event,
                    # so the FIFO server sees requests chronologically
                    _, op, kwargs, nbytes = item
                    task.waiting = ("__call__", (op, kwargs), nbytes, None)
                    return
                if kind == "stream":
                    # the fused receive+combine+post yield: virtual time
                    # has no transfer to overlap, so the kernel lowers it
                    # to the plain get_aggregate wait — the machine sees
                    # no "streamed" status and takes the whole-vector
                    # fallback, keeping bits, counts and timing exactly
                    # the pre-streaming discrete-event behaviour
                    _, skwargs, nbytes, timeout = item
                    item = ("wait", "get_aggregate",
                            dict(node=skwargs["node"],
                                 group=skwargs["group"]), nbytes, timeout)
                    kind = "wait"
                if kind == "unmask":
                    # the fused receive+unmask+publish yield (§5.1.1
                    # streaming form): lowered to the plain
                    # get_aggregate wait for the same reason as
                    # "stream" above — the machine sees no "unmasked"
                    # status and takes the whole-vector fallback,
                    # keeping bits, counts and timing exact
                    _, ukwargs, nbytes, timeout = item
                    item = ("wait", "get_aggregate",
                            dict(node=ukwargs["node"],
                                 group=ukwargs["group"]), nbytes, timeout)
                    kind = "wait"
                if kind == "wait":
                    _, wkind, kwargs, nbytes, timeout = item
                    deadline = None
                    if timeout == "aggregation":
                        deadline = task.time + self.ctrl.aggregation_timeout
                    elif isinstance(timeout, (int, float)):
                        deadline = task.time + timeout
                    task.time += self.cost.message(64)  # long-poll request
                    task.waiting = (wkind, kwargs, nbytes, deadline)
                    return
                raise ValueError(f"unknown yield {item!r}")
        except StopIteration as stop:
            task.done = True
            task.result = stop.value


# ---------------------------------------------------------------------------
# Runner: build + run one aggregation round
# ---------------------------------------------------------------------------


def run_safe_round(
    values: np.ndarray,
    mode: str = "safe",
    subgroups: int = 1,
    failed_nodes: Iterable[int] = (),
    initiator_fails: bool = False,
    weights: Optional[np.ndarray] = None,
    cost: CostModel = EDGE,
    aggregation_timeout: float = 8.0,
    progress_timeout: float = 1.0,
    symmetric_only: bool = False,
    scale_bits: int = 16,
    provisioning_seed: int = 0xC0FFEE,
    learner_master: int = 0x5EED,
    counter: int = 0,
) -> SimResult:
    """Simulate one full aggregation round.

    values: f32[n, V]; node ids are 1..n (paper numbering), chain order is
    id order, split into ``subgroups`` contiguous groups (§5.5).
    failed_nodes: 1-based ids of learners dead before the round (the
    paper's failover experiment takes out nodes 4-6 after key exchange).
    initiator_fails: group-0 initiator posts once then crashes (Fig. 5).
    """
    n, V = values.shape
    assert mode in ("safe", "saf", "insec")
    # Shared topology layer: the SAME object family the device plane's
    # ppermute schedule and initiator election are built from.
    topo = RingTopology(n, subgroups)
    if mode in ("safe", "saf"):
        topo.validate_privacy()
    groups = topo.group_chains(node_base=1)
    ctrl = Controller(groups, aggregation_timeout=aggregation_timeout)
    sim = ProtocolSimulation(ctrl, cost, progress_timeout=progress_timeout,
                             parse_payloads=(mode == "insec"))
    failed = set(failed_nodes)
    # Round-start initiators: elected over the all-alive bitmap (a node
    # dead before the round is *discovered* by timeout, §5.4 — the
    # control plane does not know it up front).
    initiators = {r + 1 for r in topo.elect_initiators()}

    machines = build_round_machines(
        values, topo, groups, initiators, mode=mode, weights=weights,
        cost=cost, symmetric_only=symmetric_only, scale_bits=scale_bits,
        provisioning_seed=provisioning_seed, learner_master=learner_master,
        counter=counter, subgroups=subgroups, failed=failed,
        initiator_fails=initiator_fails)
    for node, gen in machines.items():
        sim.spawn(node, gen)

    if mode == "insec":
        _drive_insec(ctrl, sim, groups, failed, weights)
        return sim.run()
    return sim.run()


@dataclasses.dataclass
class HierSimResult:
    """One §5.10 chain-of-chains round: per-org SAFE rounds + parent fold."""

    average: Optional[np.ndarray]   # parent (cross-org) average
    weight_avg: Optional[float]
    org_results: Dict[int, SimResult]  # per child org, surviving orgs only
    org_averages: Dict[int, np.ndarray]
    elided_orgs: tuple              # whole-org crashes (parent-level §5.3)
    up_messages: int                # child -> parent posts (HierarchicalController)


def run_hierarchical_round_sim(
    values: np.ndarray,
    orgs: int = 3,
    failed_orgs: Iterable[int] = (),
    failed_nodes: Iterable[int] = (),
    initiator_fails: bool = False,
    weights: Optional[np.ndarray] = None,
    cost: CostModel = EDGE,
    aggregation_timeout: float = 8.0,
    progress_timeout: float = 1.0,
    monitor_interval: float = 0.25,
    symmetric_only: bool = False,
    scale_bits: int = 16,
    provisioning_seed: int = 0xC0FFEE,
    learner_master: int = 0x5EED,
    counter: int = 0,
) -> HierSimResult:
    """Simulate one §5.10 hierarchical round: the n learners split into
    ``orgs`` contiguous child orgs, each org runs its own full SAFE
    chain (failover included) against its OWN controller, and
    :class:`HierarchicalController` folds the surviving orgs' averages.

    The per-org machines are built from the SAME global topology and
    crypto seeds as the flat ``run_safe_round(values, subgroups=orgs)``
    run, so each surviving org's published average — and, with no org
    crashed, the parent average itself — is bit-identical to the flat
    sim's. This is the sim twin the wire plane's hierarchical rounds
    are asserted against.

    ``failed_orgs``: 0-based org indices crashed whole (never run — the
    parent elides them). ``failed_nodes`` / ``initiator_fails`` follow
    the flat API (``initiator_fails`` crashes group 0's initiator after
    its first post, Fig. 5 — inside child org 0 here).
    """
    n, V = values.shape
    topo = RingTopology(n, orgs)
    topo.validate_privacy()
    groups = topo.group_chains(node_base=1)
    initiators = {r + 1 for r in topo.elect_initiators()}
    failed = set(failed_nodes)
    dead_orgs = set(failed_orgs)
    machines = build_round_machines(
        values, topo, groups, initiators, mode="safe", weights=weights,
        cost=cost, symmetric_only=symmetric_only, scale_bits=scale_bits,
        provisioning_seed=provisioning_seed, learner_master=learner_master,
        counter=counter, subgroups=orgs, failed=failed,
        initiator_fails=initiator_fails)

    children: list[Controller] = []
    org_results: Dict[int, SimResult] = {}
    org_averages: Dict[int, np.ndarray] = {}
    for g, chain in groups.items():
        ctrl = Controller({g: chain}, aggregation_timeout=aggregation_timeout)
        children.append(ctrl)
        if g in dead_orgs:
            continue  # whole org offline: its controller never publishes
        sim = ProtocolSimulation(ctrl, cost, progress_timeout=progress_timeout,
                                 monitor_interval=monitor_interval)
        for node in chain:
            if node in machines:  # dead-before-round nodes are never built
                sim.spawn(node, machines[node])
        org_results[g] = sim.run()
        org_averages[g] = org_results[g].average

    parent = HierarchicalController(children)
    out = parent.collect(elide_incomplete=bool(dead_orgs))
    return HierSimResult(
        average=out["average"],
        weight_avg=out.get("weight_avg"),
        org_results=org_results,
        org_averages=org_averages,
        elided_orgs=out.get("elided", ()),
        up_messages=parent.up_messages,
    )


def _drive_insec(ctrl: Controller, sim: ProtocolSimulation, groups, failed, weights):
    """INSEC controller-side averaging: once all live nodes posted, the
    controller averages raw values (it sees everything — the point of the
    baseline). Implemented as a zero-cost shim around the broker."""
    import types

    orig_post = ctrl.post_aggregate
    expected = sum(len([x for x in chain if x not in failed]) for chain in groups.values())
    posted_vals = []

    def patched(from_node, to_node, payload, group=0, now=0.0):
        orig_post(from_node, to_node, payload, group, now)
        posted_vals.append(np.asarray(payload, np.float64))
        if len(posted_vals) == expected:
            avg = np.mean(np.stack(posted_vals), axis=0).astype(np.float32)
            # controller publishes directly (not a client message)
            ctrl._global_average = {"average": avg, "weight_avg": None}

    ctrl.post_aggregate = patched
