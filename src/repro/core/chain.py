"""SAFE chain aggregation — SPMD data plane.

Everything here runs *inside* a ``jax.shard_map`` region that is manual
over the learner axis (``cfg.axis``); one mesh rank = one learner. The
logical chain of the paper's Figure 2 becomes a ``ppermute`` ring.

Two schedules are provided:

  * ``chain_aggregate_sequential`` — paper-faithful Round 1: the full
    masked vector makes n-1 serial hops around the ring. This is the
    baseline recorded in EXPERIMENTS.md §Perf.
  * ``chain_aggregate_pipelined`` — beyond-paper rotated-initiator segment
    pipeline: the vector is split into n segments, segment s is initiated
    (and finally unmasked) by rank s, and all segments move concurrently
    in a ring-reduce schedule. Same privacy invariant (every in-flight
    value is masked by some rank's private R plus the hop pad), but
    ~2V bytes/link instead of (n-1)·V.

Failover: an ``alive`` bitmap (decided *between* rounds by the host
control plane — ``repro.topology.failover.AliveTracker``) compacts the
chain: dead ranks forward-and-repad without contributing, and the
published mean divides by ``popcount(alive)``, matching §5.3's "average
over n-f survivors". The initiator is the first alive rank (§5.4
re-election semantics).

All ring geometry — ppermute pairs, neighbours, initiator election —
comes from ``repro.topology`` (the same objects the discrete-event sim
consumes), so the two planes cannot diverge on topology semantics.

``chain_aggregate_batched`` runs S independent sessions — each with its
own keys, counters, alive bitmap and rotation — through one program; it
is the device substrate of ``serve/agg_engine.AggregationEngine``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.crypto.fixedpoint import FixedPointCodec
from repro.crypto.prf import derive_key, derive_pair_key, keystream_pair_lanes
from repro.core.types import ChainConfig, RoundKeys
from repro.topology import elect_initiator_local

# Domain-separation tags for derive_key.
_TAG_INITIATOR_MASK = 0x52  # 'R'
_TAG_HOP_PAD = 0x50  # 'P'


def _hop_pads(keys: RoundKeys, rank, topo, nwords: int, use_pads: bool):
    """Outgoing/incoming one-time pads for this rank's ring edges.

    pad_out is keyed on (rank -> next), pad_in on (prev -> rank); the same
    edge key is derived by both endpoints, so pads cancel hop by hop.
    SAF mode (no hop encryption) uses zero pads — the controller-visible
    traffic is then only protected by the initiator mask, exactly the
    paper's SAF ablation.
    """
    if not use_pads:
        z = jnp.zeros((nwords,), jnp.uint32)
        return z, z
    prv, nxt = topo.neighbors(rank)
    seed = derive_key(keys.provisioning_seed, _TAG_HOP_PAD)
    k_out = derive_pair_key(seed, rank, nxt)
    k_in = derive_pair_key(seed, prv, rank)
    base = jnp.asarray(keys.counter_base, jnp.uint32)
    pad_out = keystream_pair_lanes(k_out, nwords, base)
    pad_in = keystream_pair_lanes(k_in, nwords, base)
    return pad_out, pad_in


def _initiator_mask(keys: RoundKeys, nwords: int, counter_base) -> jax.Array:
    """The single mask R (paper §5.2) — a keystream from this learner's
    private seed. Never shared with the controller or any other learner."""
    k = derive_key(keys.learner_seed, _TAG_INITIATOR_MASK)
    return keystream_pair_lanes(k, nwords, counter_base)


def chain_aggregate_sequential(
    values: jax.Array,
    keys: RoundKeys,
    cfg: ChainConfig,
    alive: jax.Array | None = None,
    weights: jax.Array | None = None,
    rotate: jax.Array | int = 0,
) -> jax.Array:
    """Paper-faithful SAFE Round 1 over one (sub)group ring.

    Args:
      values: f32[V] — this learner's local feature/parameter vector
        (per-rank view inside shard_map).
      keys: RoundKeys (learner_seed must differ per rank).
      cfg: ChainConfig. ``cfg.mode`` must be 'safe' or 'saf'.
      alive: optional f32/bool[n] liveness bitmap (replicated across ranks);
        dead ranks forward-and-repad, contributing nothing.
      weights: optional f32 scalar per rank — weighted averaging (§5.6):
        the aggregate carries (w·x, w) and the published value is
        Σw·x / Σw, without revealing any individual w.
      rotate: per-round initiator rotation (paper §8: "randomize the order
        between each round to limit the likelihood of two colluding nodes
        being able to get useful data from intermediaries on a consistent
        basis"). The ring edges (and hop keys) are fixed; the initiator
        role starts ``rotate`` positions later each round.

    Returns:
      f32[V] — the (weighted) mean over alive learners, identical on every
      rank (the paper's post_average/get_average distribution).
    """
    assert cfg.mode in ("safe", "saf"), cfg.mode
    topo = cfg.topology
    n, m = cfg.num_learners, cfg.group_size
    axis = cfg.axis
    rank = jax.lax.axis_index(axis)
    codec = FixedPointCodec(cfg.scale_bits)

    if alive is None:
        alive = jnp.ones((n,), jnp.float32)
    alive = jnp.asarray(alive, jnp.float32)
    my_alive = alive[rank]

    if cfg.weighted:
        w = jnp.asarray(1.0 if weights is None else weights, jnp.float32)
        payload = jnp.concatenate([values * w, jnp.array([w], values.dtype)])
    else:
        payload = values
    nwords = payload.shape[0]

    ev = codec.encode(payload) * my_alive.astype(jnp.uint32)
    pad_out, pad_in = _hop_pads(keys, rank, topo, nwords, cfg.mode == "safe")
    R = _initiator_mask(keys, nwords, keys.counter_base)

    # Initiator of each subgroup ring: shared election formula from the
    # topology layer (§5.4 re-election + §8 round-order randomization).
    g0 = topo.group_start(rank)
    group_alive = jax.lax.dynamic_slice(alive, (g0,), (m,))
    init_local = elect_initiator_local(group_alive, rotate, xp=jnp)
    init_rank = g0 + init_local
    is_init = rank == init_rank

    # Hop 0: the initiator posts enc<x_init + R> to its successor.
    x = jnp.where(is_init, ev + R + pad_out, jnp.zeros_like(ev))

    perm = topo.ring_permutation()

    def hop(t, x):
        x = jax.lax.ppermute(x, axis, perm)
        # The rank t local-steps after the initiator combines now:
        active = rank == g0 + (init_local + t) % m
        delta = ev - pad_in + pad_out  # decrypt, add local, re-encrypt
        return x + jnp.where(active, delta, jnp.zeros_like(ev))

    if cfg.unroll:
        for t in range(1, m):
            x = hop(t, x)
    else:
        x = jax.lax.fori_loop(1, m, hop, x)

    # Final hop back to the initiator, which unmasks.
    x = jax.lax.ppermute(x, axis, perm)
    total = x - pad_in - R  # Σ enc(x_i) over the group, exact in Z/2^32Z

    count = jnp.sum(group_alive)
    if cfg.weighted:
        s = codec.decode(total)
        group_avg = s[:-1] / jnp.maximum(s[-1], 1e-12)
    else:
        group_avg = codec.decode_mean(total, jnp.maximum(count, 1.0))

    # Only the initiator holds the real average — broadcast it (the
    # paper's post_average / get_average round-trip).
    return _publish(group_avg, is_init, cfg, broadcast=True)


def chain_aggregate_pipelined(
    values: jax.Array,
    keys: RoundKeys,
    cfg: ChainConfig,
    alive: jax.Array | None = None,
    weights: jax.Array | None = None,
) -> jax.Array:
    """Beyond-paper rotated-initiator segment pipeline (DESIGN.md §8).

    The vector is padded to m segments (m = group size); segment s is
    initiated, masked (R_s from rank s's private seed) and finally
    unmasked by local rank s. All m segments traverse the ring
    concurrently in a reduce-scatter schedule, then an all_gather
    republishes the full mean. Privacy invariant unchanged: every value a
    non-owner sees is offset by another rank's private mask.
    """
    assert cfg.mode in ("safe", "saf"), cfg.mode
    topo = cfg.topology
    n, m = cfg.num_learners, cfg.group_size
    axis = cfg.axis
    rank = jax.lax.axis_index(axis)
    codec = FixedPointCodec(cfg.scale_bits)

    if alive is None:
        alive = jnp.ones((n,), jnp.float32)
    alive = jnp.asarray(alive, jnp.float32)
    my_alive = alive[rank]

    if cfg.weighted:
        w = jnp.asarray(1.0 if weights is None else weights, jnp.float32)
        payload = jnp.concatenate([values * w, jnp.array([w], values.dtype)])
    else:
        payload = values
    V = payload.shape[0]
    seg = -(-V // m)  # ceil
    pad_len = seg * m - V
    payload = jnp.pad(payload, (0, pad_len))

    ev = (codec.encode(payload) * my_alive.astype(jnp.uint32)).reshape(m, seg)

    g0 = topo.group_start(rank)
    lrank = topo.local_index(rank)
    group_alive = jax.lax.dynamic_slice(alive, (g0,), (m,))

    # Per-(edge, segment) pads: counter offset s*seg keeps streams disjoint.
    prv, nxt = topo.neighbors(rank)
    use_pads = cfg.mode == "safe"
    base = jnp.asarray(keys.counter_base, jnp.uint32)
    if use_pads:
        seedp = derive_key(keys.provisioning_seed, _TAG_HOP_PAD)
        k_out = derive_pair_key(seedp, rank, nxt)
        k_in = derive_pair_key(seedp, prv, rank)
        pads_out = keystream_pair_lanes(k_out, m * seg, base).reshape(m, seg)
        pads_in = keystream_pair_lanes(k_in, m * seg, base).reshape(m, seg)
    else:
        pads_out = pads_in = jnp.zeros((m, seg), jnp.uint32)

    # This rank's own segment mask R_lrank (it is the initiator of segment
    # lrank on its subgroup ring).
    R_own = _initiator_mask(keys, seg, base)

    perm = topo.ring_permutation()

    # Step 0: every rank starts its own segment's chain.
    s = lrank
    c = ev[s] + R_own + pads_out[s]

    def step(t, c):
        c = jax.lax.ppermute(c, axis, perm)
        s = (lrank - t) % m  # segment id now resident on this rank
        return c - pads_in[s] + ev[s] + pads_out[s]

    if cfg.unroll:
        for t in range(1, m):
            c = step(t, c)
    else:
        c = jax.lax.fori_loop(1, m, step, c)

    # One final hop returns segment lrank to its initiator, which unmasks.
    c = jax.lax.ppermute(c, axis, perm)
    total_seg = c - pads_in[lrank] - R_own  # Σ_i enc(x_i)[segment lrank]

    # Republish: all_gather the unmasked segment sums (aggregates are
    # public by protocol — this is the paper's average distribution).
    total = jax.lax.all_gather(total_seg, axis, tiled=True)
    # all_gather over the full axis concatenates all n ranks; with
    # subgroups each group's segments repeat per group — slice ours.
    if cfg.subgroups > 1:
        total = jax.lax.dynamic_slice(total, (g0 * seg,), (m * seg,))
    total = total[: m * seg]

    count = jnp.sum(group_alive)
    if cfg.weighted:
        sdec = codec.decode(total)[:V]
        group_avg = sdec[:-1] / jnp.maximum(sdec[-1], 1e-12)
    else:
        group_avg = codec.decode_mean(total[:V], jnp.maximum(count, 1.0))
        group_avg = group_avg[: values.shape[0]]

    # The all_gather already distributed the group result to every member,
    # so only cross-group averaging (not a broadcast) is needed.
    is_init = rank == g0  # publication anchor for cross-group averaging
    return _publish(group_avg, is_init, cfg, broadcast=False)


def _publish(group_avg: jax.Array, is_init, cfg: ChainConfig, *, broadcast: bool) -> jax.Array:
    """Cross-group and cross-pod publication (paper §5.5, §5.10).

    With g subgroups the controller averages the g group averages; with a
    pod axis, child controllers post group averages to the parent (§5.10)
    — a plain mean over the pod axis, no encryption needed since group
    averages are already anonymized over >= 3 learners.

    Args:
      broadcast: True when ``group_avg`` is only valid on the group
        initiator (sequential schedule) and must be distributed; False
        when every group member already holds it (pipelined schedule).
    """
    if cfg.subgroups > 1 or broadcast:
        # Each group's initiator posts its average; everyone receives the
        # mean of the g posted averages (g = 1 reduces to a broadcast).
        contrib = jnp.where(is_init, group_avg, jnp.zeros_like(group_avg))
        avg = jax.lax.psum(contrib, cfg.axis) / cfg.subgroups
    else:
        avg = group_avg
    if cfg.pod_axis is not None:
        avg = jax.lax.pmean(avg, cfg.pod_axis)
    return avg


def chain_aggregate_batched(
    values: jax.Array,
    prov_seeds: jax.Array,
    learner_seeds: jax.Array,
    counter_bases: jax.Array,
    cfg: ChainConfig,
    alive: jax.Array,
    weights: jax.Array | None = None,
    rotate: jax.Array | None = None,
) -> jax.Array:
    """S independent SAFE rounds through one program (per-rank view).

    Each session s runs the exact arithmetic of
    ``chain_aggregate_sequential`` — its own derived keys, counter space,
    alive bitmap and initiator rotation — so session s's published mean
    is bit-identical to a standalone single-session run with the same
    inputs (asserted by tests/test_session_engine.py). The batch is a
    ``vmap`` over the session dim: the hop structure (ppermute schedule)
    is shared, so S rounds cost one collective per hop instead of S.

    Args:
      values: f32[S, V] — this rank's vector for each session.
      prov_seeds: uint32[S, 2] — per-session *derived* provisioning key
        (the output of ``derive_key(seed_words, domain)``, i.e. exactly
        what ``make_round_keys`` puts in ``RoundKeys.provisioning_seed``).
      learner_seeds: uint32[S, 2] — per-session per-rank private seed
        (``RoundKeys.learner_seed``).
      counter_bases: uint32[S] — per-session fresh counter base.
      cfg: shared ChainConfig (one topology/mode for the whole batch —
        the engine's slots are homogeneous, like ServeEngine's).
      alive: f32[S, n] per-session liveness bitmaps.
      weights: optional f32[S] per-session weight of this rank.
      rotate: optional i32[S] per-session initiator rotation.

    Returns:
      f32[S, V] published (weighted) means, identical on every rank.
    """
    S = values.shape[0]
    if rotate is None:
        rotate = jnp.zeros((S,), jnp.int32)
    if weights is None and cfg.weighted:
        weights = jnp.ones((S,), jnp.float32)

    def one(v, prov, learner, ctr, al, rot, w):
        keys = RoundKeys(provisioning_seed=prov, learner_seed=learner,
                         counter_base=ctr)
        return chain_aggregate_sequential(v, keys, cfg, alive=al,
                                          weights=w, rotate=rot)

    if cfg.weighted:
        return jax.vmap(one)(values, prov_seeds, learner_seeds,
                             counter_bases, alive, rotate, weights)
    return jax.vmap(
        lambda v, p, l, c, a, r: one(v, p, l, c, a, r, None)
    )(values, prov_seeds, learner_seeds, counter_bases, alive, rotate)
