"""INSEC baseline — plain insecure aggregation (paper's control condition).

Learners post raw parameters to the controller, which averages them: a
plain psum/pmean over the learner axis. No masks, no privacy — the
reference point for all of the paper's overhead figures.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import ChainConfig


def insec_aggregate(
    values: jax.Array,
    cfg: ChainConfig,
    alive: jax.Array | None = None,
    weights: jax.Array | None = None,
) -> jax.Array:
    """Plain (weighted) mean over alive learners."""
    axis = cfg.axis
    rank = jax.lax.axis_index(axis)
    if alive is None:
        alive = jnp.ones((cfg.num_learners,), jnp.float32)
    alive = jnp.asarray(alive, jnp.float32)
    my_alive = alive[rank]

    w = jnp.asarray(1.0 if weights is None else weights, jnp.float32) * my_alive
    num = jax.lax.psum(values * w, axis)
    den = jax.lax.psum(w, axis)
    avg = num / jnp.maximum(den, 1e-12)
    if cfg.pod_axis is not None:
        avg = jax.lax.pmean(avg, cfg.pod_axis)
    return avg
