"""BON learner state machines — generator twins of ``bon_protocol``.

Same yield protocol as :mod:`repro.core.machines` (``("call", op,
kwargs, nbytes)`` / ``("wait", kind, kwargs, nbytes, timeout)``), so
:func:`repro.net.client.drive_learner` runs them over the real broker
unchanged. Each learner replays :func:`bon_protocol.bon_secrets`'s
canonical draw order and uses only its own rows, so every runtime
derives identical secret material and the wire average is bit-identical
to ``run_bon_round`` with the same seed (the pads cancel exactly, so
the published bits are the fixed-point sum of the survivors' encoded
values either way — asserted, not assumed, in
tests/test_conformance.py).

Per-node message trace (the closed form of
``bon_protocol.bon_expected_messages``):

  Round 0   bon_advertise + bon_get_keys                   2
  Round 1   (n−1) bon_post_share + (n−1) bon_get_share     2(n−1)
  — dropouts stop here (``fail_after_round1``) —
  Round 2   bon_post_masked                                1
  Round 3   bon_get_roster + (n−1) bon_post_unmask         n
  Round 4   bon_get_average                                1
"""
from __future__ import annotations

from typing import Dict, Iterable

import numpy as np

from repro.core.bon_controller import seed_from_bytes, seed_to_bytes, \
    share_to_wire
from repro.core.bon_protocol import bon_pair_pad, bon_secrets, bon_self_pad
from repro.crypto.np_impl import NpFixedPoint

#: nominal wire sizes for the yield protocol's nbytes hints (the wire
#: runtime measures real frames; these only feed virtual accounting)
_SHARE_BYTES = 64
_KEY_BYTES = 128


def bon_learner(node: int, n: int, value_row: np.ndarray, *,
                threshold: int, seed: int, scale_bits: int = 16,
                fail_after_round1: bool = False):
    """One BON learner's full round as a generator state machine."""
    b_seed, s_seed, b_shares, s_shares = bon_secrets(n, threshold, seed)
    V = int(value_row.shape[0])
    codec = NpFixedPoint(scale_bits)

    # ---- Round 0: advertise + fetch everyone's advertisement ----------
    yield ("call", "bon_advertise",
           {"node": node, "s_pub": seed_to_bytes(s_seed[node])}, _KEY_BYTES)
    keys = yield ("wait", "bon_get_keys", {"node": node},
                  _KEY_BYTES * n, "aggregation")
    s_pub = {int(u): seed_from_bytes(raw)
             for u, raw in keys["s_pub"].items()}

    # ---- Round 1: post my share pair to each peer, fetch theirs -------
    peers = [v for v in range(1, n + 1) if v != node]
    for v in peers:
        yield ("call", "bon_post_share",
               {"node": node, "to_node": v,
                "b": share_to_wire(b_shares[node][v - 1]),
                "s": share_to_wire(s_shares[node][v - 1])}, _SHARE_BYTES)
    received: Dict[int, dict] = {}
    for v in peers:
        received[v] = yield ("wait", "bon_get_share",
                             {"node": node, "from_node": v},
                             _SHARE_BYTES, "aggregation")

    if fail_after_round1:
        # the worst-case dropout the protocol is designed for: secrets
        # are shared, then the node vanishes before masking its input
        return None

    # ---- Round 2: masked input ----------------------------------------
    yu = codec.encode(value_row)
    yu = NpFixedPoint.add(yu, bon_self_pad(b_seed[node], V))
    for v in peers:
        pad = bon_pair_pad(s_pub[node], s_pub[v], node, v, V)
        yu = (NpFixedPoint.add(yu, pad) if node < v
              else NpFixedPoint.sub(yu, pad))
    yield ("call", "bon_post_masked", {"node": node, "payload": yu}, 4 * V)

    # ---- Round 3: consistency roster + reveal one share per peer ------
    roster = yield ("wait", "bon_get_roster", {"node": node}, 4 * n,
                    "aggregation")
    failed = set(roster["failed"])
    for v in peers:
        # live peer: reveal its b share (cancel its self-mask); dead
        # peer: reveal its s share (server regenerates its pair pads)
        kind = "s" if v in failed else "b"
        xy = received[v][kind]
        yield ("call", "bon_post_unmask",
               {"node": node, "subject": v,
                "x": xy["x"], "y": xy["y"]}, _SHARE_BYTES)

    # ---- Round 4: fetch the published average -------------------------
    res = yield ("wait", "bon_get_average", {"node": node}, 4 * V,
                 "aggregation")
    return np.asarray(res["average"])


def build_bon_machines(values: np.ndarray, *,
                       failed_nodes: Iterable[int] = (),
                       threshold: int, seed: int,
                       scale_bits: int = 16) -> Dict[int, object]:
    """Generators for every node (1-based), dropouts included — unlike
    SAFE's ``build_round_machines``, BON's failed nodes *do* run Rounds
    0–1 (they advertise and share secrets, then vanish)."""
    values = np.asarray(values, np.float32)
    n = values.shape[0]
    failed = {int(x) for x in failed_nodes}
    return {
        u: bon_learner(u, n, values[u - 1], threshold=threshold, seed=seed,
                       scale_bits=scale_bits,
                       fail_after_round1=u in failed)
        for u in range(1, n + 1)
    }
