"""Aggregation sessions — per-tenant state for the multi-session engine.

One :class:`AggSession` is one tenant's aggregation stream: its own key
material (provisioning seed + learner master), its own monotone counter
space (pads are never reused across that session's rounds), its own
alive bitmap / weights, and its own initiator-rotation schedule (§8).
Sessions are host-side control-plane objects; the device plane only ever
sees the uint32 key/counter arrays the engine batches out of them.

The session is deliberately the same shape as a single-session run:
round r of a session uses counter_base = r * words_per_round and
rotate = rotate0 + r, exactly what ``SecureAggregator`` + ``RoundCounter``
produce for a standalone loop — which is what makes the engine's batched
output bit-identical to S independent runs (the acceptance property).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.crypto.prf import RoundCounter


class RoundCursor:
    """Per-round counter-base bookkeeping for persistent multi-round
    sessions (wire plane): round r's pads start at a fresh base, so key
    material survives R rounds with no pad reuse — the wire twin of
    ``AggSession.reserve_counter`` for the device engine.

    ``words_per_round`` is the vector length the pads cover (payload
    words, +1 when weighted — the same convention every existing caller
    of ``counter=`` uses). Reservation delegates to
    :class:`~repro.crypto.prf.RoundCounter`, inheriting its pre-mutation
    uint32 overflow guard: when the counter space runs out the session
    must rotate keys (Round 0 again), never silently wrap.
    """

    def __init__(self, words_per_round: int, counter0: int = 0):
        if words_per_round < 1:
            raise ValueError(
                f"words_per_round must be >= 1, got {words_per_round}")
        self.words_per_round = int(words_per_round)
        self._rc = RoundCounter()
        if counter0:
            self._rc.reserve(int(counter0))  # externally consumed space

    @property
    def rounds_remaining(self) -> int:
        """Rounds still reservable before a Round-0 key rotation is due."""
        return self._rc.remaining // self.words_per_round

    def next_round(self) -> int:
        """Reserve and return the next round's counter base."""
        return self._rc.reserve(self.words_per_round)


def seed_words(seed: int) -> np.ndarray:
    """uint32[2] little-endian words of a 64-bit seed — the exact host
    conversion ``make_round_keys`` applies before key derivation."""
    return np.array([seed & 0xFFFFFFFF, (seed >> 32) & 0xFFFFFFFF], np.uint32)


@dataclasses.dataclass
class AggSession:
    """One tenant's aggregation stream (host control-plane state).

    Attributes:
      sid: engine-assigned session id.
      values: f32[n, V] — the learner-major contribution matrix for the
        next round (the engine re-reads it each round, so a trainer can
        update it between rounds).
      provisioning_seed / learner_master: this session's Round-0 key
        material (independent per tenant).
      rounds: how many aggregation rounds the session requests.
      alive: f32[n] liveness bitmap (None = all alive).
      weights: f32[n] per-learner weights (only read by weighted configs).
      rotate0: initiator rotation of round 0; round r uses rotate0 + r.
    """

    sid: int
    values: np.ndarray
    provisioning_seed: int = 0xC0FFEE
    learner_master: int = 0x5EED
    rounds: int = 1
    alive: Optional[np.ndarray] = None
    weights: Optional[np.ndarray] = None
    rotate0: int = 0

    def __post_init__(self) -> None:
        self.values = np.asarray(self.values, np.float32)
        if self.alive is None:
            self.alive = np.ones((self.values.shape[0],), np.float32)
        self.alive = np.asarray(self.alive, np.float32)
        if self.weights is None:
            self.weights = np.ones((self.values.shape[0],), np.float32)
        self.weights = np.asarray(self.weights, np.float32)
        self.results: List[np.ndarray] = []
        self.rounds_done: int = 0
        self._counters = RoundCounter()

    # ---- engine interface ------------------------------------------------
    @property
    def done(self) -> bool:
        return self.rounds_done >= self.rounds

    @property
    def rotate(self) -> int:
        """Initiator rotation for the upcoming round (§8)."""
        return self.rotate0 + self.rounds_done

    def reserve_counter(self, nwords: int) -> int:
        """Fresh counter base for the upcoming round (no pad reuse)."""
        return self._counters.reserve(nwords)

    def record_result(self, published: np.ndarray) -> None:
        self.results.append(np.asarray(published))
        self.rounds_done += 1

    def key_words(self) -> tuple[np.ndarray, np.ndarray]:
        """(provisioning, master) uint32[2] word pairs for the device."""
        return seed_words(self.provisioning_seed), seed_words(self.learner_master)
