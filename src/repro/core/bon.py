"""BON baseline — Practical Secure Aggregation (Bonawitz et al., CCS'17).

Device data plane of the pairwise-masking protocol the paper compares
against. Each learner u masks its vector with

    y_u = x_u + b_u + Σ_{v>u} PRF(s_uv) − Σ_{v<u} PRF(s_uv)   (mod 2^32)

where s_uv is the pairwise seed (Diffie-Hellman in the real protocol; here
derived from the provisioning seed — see DESIGN.md §6) and b_u is the
per-learner self-mask guarding against false-dropout unmasking. The server
sums all y_u — pairwise pads cancel — then removes Σ b_u, which survivors
reveal via t-of-n Shamir shares (the share plumbing lives in the
control-plane simulation, ``core/protocol.py``; here the surviving ranks
simply contribute their b_u streams in the unmasking round, which is the
arithmetic the shares reconstruct).

Cost signature (why SAFE wins): every rank expands n−1 pairwise PRF
streams over the full vector — O(n·V) PRF work per rank and O(n²·V) total,
vs O(V) per rank for SAFE's two hop pads; plus the O(n²) share traffic in
the control plane.

Dropout (alive bitmap): for a dead learner v, every survivor u reveals its
pairwise seed s_uv so the server can recompute and cancel v's pads that
are baked into the survivors' y_u. Arithmetic below mirrors that: dead
ranks contribute nothing, and survivors' pads referencing dead ranks are
explicitly recomputed and subtracted (this is why BON failover touches all
remaining nodes — paper §2 point 3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.crypto.fixedpoint import FixedPointCodec
from repro.crypto.prf import derive_key, derive_pair_key, keystream_pair_lanes
from repro.core.types import ChainConfig, RoundKeys

_TAG_PAIRWISE = 0x42  # 'B'
_TAG_SELFMASK = 0x62  # 'b'


def bon_aggregate(
    values: jax.Array,
    keys: RoundKeys,
    cfg: ChainConfig,
    alive: jax.Array | None = None,
) -> jax.Array:
    """BON secure mean over the learner axis (per-rank, inside shard_map)."""
    n = cfg.num_learners
    axis = cfg.axis
    rank = jax.lax.axis_index(axis)
    codec = FixedPointCodec(cfg.scale_bits)

    if alive is None:
        alive = jnp.ones((n,), jnp.float32)
    alive = jnp.asarray(alive, jnp.float32)
    my_alive = alive[rank]

    V = values.shape[0]
    ev = codec.encode(values) * my_alive.astype(jnp.uint32)

    pair_seed = derive_key(keys.provisioning_seed, _TAG_PAIRWISE)
    base = jnp.asarray(keys.counter_base, jnp.uint32)

    # Pairwise masks: O(n) keystreams of length V *per rank* — the
    # quadratic total work that dominates BON's scaling (Figs. 6, 8).
    masked = ev
    for v in range(n):
        # s_uv is symmetric: both ends derive the same key for the
        # unordered pair (min, max); the sign depends on the order.
        lo = jnp.minimum(rank, v)
        hi = jnp.maximum(rank, v)
        k_uv = derive_pair_key(pair_seed, lo, hi)
        pad = keystream_pair_lanes(k_uv, V, base)
        sign_pos = rank < v  # +pad if u < v else -pad
        not_self = rank != v
        # Pads involving a dead peer are still *applied* by survivors
        # (they were applied before the dropout was known) …
        contrib = jnp.where(sign_pos, pad, jnp.uint32(0) - pad)
        masked = masked + jnp.where(not_self & (my_alive > 0), contrib, jnp.uint32(0))

    # Self-mask b_u.
    b_key = derive_key(keys.learner_seed, _TAG_SELFMASK)
    b_u = keystream_pair_lanes(b_key, V, base)
    masked = masked + jnp.where(my_alive > 0, b_u, jnp.uint32(0))

    # Round 3: server sums the posted y_u. Pairwise pads between two
    # *live* ranks cancel in the sum.
    y_sum = jax.lax.psum(masked, axis)

    # Round 4 (unmasking): survivors reveal Shamir shares of (a) b_u for
    # every live u, (b) s_uv for every dead v. The reconstructed streams
    # are subtracted server-side; arithmetically:
    correction = jnp.where(my_alive > 0, b_u, jnp.uint32(0))
    for v in range(n):
        lo = jnp.minimum(rank, v)
        hi = jnp.maximum(rank, v)
        k_uv = derive_pair_key(pair_seed, lo, hi)
        pad = keystream_pair_lanes(k_uv, V, base)
        sign_pos = rank < v
        dead_peer = (alive[v] <= 0) & (rank != v) & (my_alive > 0)
        contrib = jnp.where(sign_pos, pad, jnp.uint32(0) - pad)
        correction = correction + jnp.where(dead_peer, contrib, jnp.uint32(0))
    total = y_sum - jax.lax.psum(correction, axis)

    count = jnp.maximum(jnp.sum(alive), 1.0)
    avg = codec.decode_mean(total, count)
    if cfg.pod_axis is not None:
        avg = jax.lax.pmean(avg, cfg.pod_axis)
    return avg
