"""Sharding-aware pytree checkpointing (msgpack + zstd/gzip).

Layout: ``<dir>/step_<N>/manifest.msgpack.<ext>`` holding the tree
structure, dtypes, shapes and (for sharded arrays) the PartitionSpec that
produced them, plus one raw buffer blob. Arrays are gathered to host
before writing (fine at the model sizes the examples train; a real
multi-host deployment would write per-shard files — the manifest format
already carries what that needs).

Compression: zstd when the ``zstandard`` package is available, otherwise
stdlib gzip. The codec is the format tag — it is recorded both in the
file extension (``.zst`` / ``.gz``) and in the blob's magic bytes, and
restores auto-detect it, so checkpoints written under either codec read
back on any host.

Restores are exact (bit-level) and include the optimizer state and the
data-pipeline step, so training resumes deterministically — property-
tested in tests/test_checkpoint.py.
"""
from __future__ import annotations

import gzip
import os
import re
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:  # optional dependency — gzip fallback below covers its absence
    import zstandard as zstd
except ModuleNotFoundError:
    zstd = None

_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"
_GZIP_MAGIC = b"\x1f\x8b"


def _compress(data: bytes) -> tuple[bytes, str]:
    """Compress with the best available codec; returns (blob, extension)."""
    if zstd is not None:
        return zstd.ZstdCompressor(level=3).compress(data), "zst"
    return gzip.compress(data, compresslevel=6), "gz"


def _decompress(blob: bytes) -> bytes:
    """Codec auto-detection by magic bytes (the on-disk format tag)."""
    if blob[:4] == _ZSTD_MAGIC:
        if zstd is None:
            raise RuntimeError(
                "checkpoint is zstd-compressed but the zstandard package is "
                "not installed on this host")
        return zstd.ZstdDecompressor().decompress(blob)
    if blob[:2] == _GZIP_MAGIC:
        return gzip.decompress(blob)
    return blob  # raw (uncompressed legacy blob)


def _write_tagged(path_base: str, data: bytes) -> None:
    """Write ``<path_base>.<ext>`` for the active codec, removing any
    stale sibling written under the other codec — re-saving a step on a
    host with different compression must not leave an old blob that a
    later restore would silently prefer."""
    blob, ext = _compress(data)
    with open(f"{path_base}.{ext}", "wb") as f:
        f.write(blob)
    for other in ("zst", "gz", ""):
        if other != ext:
            stale = f"{path_base}.{other}" if other else path_base
            if os.path.exists(stale):
                os.remove(stale)


def _read_tagged(path_base: str) -> bytes:
    """Read ``<path_base>.{zst,gz}`` (or bare), whichever exists."""
    for ext in ("zst", "gz", ""):
        p = f"{path_base}.{ext}" if ext else path_base
        if os.path.exists(p):
            with open(p, "rb") as f:
                return _decompress(f.read())
    raise FileNotFoundError(f"no checkpoint blob at {path_base}.(zst|gz)")


def _np_dtype(name: str) -> np.dtype:
    """Resolve dtype names including the ml_dtypes extras (bfloat16...)."""
    try:
        return np.dtype(name)
    except (TypeError, AttributeError):
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def _encode_tree(tree: Any) -> tuple[list[dict], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    metas, blobs = [], []
    for leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        metas.append({
            "dtype": str(arr.dtype),
            "shape": list(arr.shape),
            "nbytes": int(arr.nbytes),
        })
        blobs.append(arr.tobytes())
    return metas, (treedef, blobs)


def save_checkpoint(directory: str, step: int, tree: Any,
                    extra: Optional[dict] = None) -> str:
    """Write a checkpoint; returns its path."""
    path = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(path, exist_ok=True)
    metas, (treedef, blobs) = _encode_tree(tree)
    manifest = {
        "step": step,
        "treedef": str(treedef),  # audit only; structure restored from skeleton
        "leaves": metas,
        "extra": extra or {},
    }
    _write_tagged(os.path.join(path, "manifest.msgpack"),
                  msgpack.packb(manifest))
    _write_tagged(os.path.join(path, "buffers.bin"), b"".join(blobs))
    return path


def restore_checkpoint(directory: str, step: int, skeleton: Any) -> tuple[Any, dict]:
    """Restore into the structure of ``skeleton`` (shapes/dtypes checked)."""
    path = os.path.join(directory, f"step_{step:08d}")
    manifest = msgpack.unpackb(_read_tagged(os.path.join(path, "manifest.msgpack")))
    raw = _read_tagged(os.path.join(path, "buffers.bin"))
    leaves, treedef = jax.tree.flatten(skeleton)
    assert len(leaves) == len(manifest["leaves"]), (
        f"checkpoint has {len(manifest['leaves'])} leaves, skeleton "
        f"{len(leaves)} — structure changed since save")
    out, off = [], 0
    for leaf, meta in zip(leaves, manifest["leaves"]):
        n = meta["nbytes"]
        arr = np.frombuffer(raw[off:off + n], dtype=_np_dtype(meta["dtype"])) \
            .reshape(meta["shape"]).copy()
        off += n
        exp_shape = tuple(np.shape(leaf))
        assert tuple(arr.shape) == exp_shape, (
            f"shape mismatch: ckpt {arr.shape} vs skeleton {exp_shape}")
        dev = jnp.asarray(arr)
        if hasattr(leaf, "sharding") and leaf.sharding is not None:
            try:
                dev = jax.device_put(dev, leaf.sharding)
            except (ValueError, RuntimeError):
                pass
        out.append(dev)
    return jax.tree.unflatten(treedef, out), manifest["extra"]


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for name in os.listdir(directory)
             if (m := re.match(r"step_(\d+)$", name))]
    return max(steps) if steps else None
