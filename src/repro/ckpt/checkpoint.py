"""Sharding-aware pytree checkpointing (msgpack + zstd).

Layout: ``<dir>/step_<N>/manifest.msgpack.zst`` holding the tree
structure, dtypes, shapes and (for sharded arrays) the PartitionSpec that
produced them, plus one raw buffer blob. Arrays are gathered to host
before writing (fine at the model sizes the examples train; a real
multi-host deployment would write per-shard files — the manifest format
already carries what that needs).

Restores are exact (bit-level) and include the optimizer state and the
data-pipeline step, so training resumes deterministically — property-
tested in tests/test_checkpoint.py.
"""
from __future__ import annotations

import os
import re
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np
import zstandard as zstd


def _np_dtype(name: str) -> np.dtype:
    """Resolve dtype names including the ml_dtypes extras (bfloat16...)."""
    try:
        return np.dtype(name)
    except (TypeError, AttributeError):
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def _encode_tree(tree: Any) -> tuple[list[dict], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    metas, blobs = [], []
    for leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        metas.append({
            "dtype": str(arr.dtype),
            "shape": list(arr.shape),
            "nbytes": int(arr.nbytes),
        })
        blobs.append(arr.tobytes())
    return metas, (treedef, blobs)


def save_checkpoint(directory: str, step: int, tree: Any,
                    extra: Optional[dict] = None) -> str:
    """Write a checkpoint; returns its path."""
    path = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(path, exist_ok=True)
    metas, (treedef, blobs) = _encode_tree(tree)
    manifest = {
        "step": step,
        "treedef": str(treedef),  # audit only; structure restored from skeleton
        "leaves": metas,
        "extra": extra or {},
    }
    cctx = zstd.ZstdCompressor(level=3)
    with open(os.path.join(path, "manifest.msgpack.zst"), "wb") as f:
        f.write(cctx.compress(msgpack.packb(manifest)))
    with open(os.path.join(path, "buffers.bin.zst"), "wb") as f:
        f.write(cctx.compress(b"".join(blobs)))
    return path


def restore_checkpoint(directory: str, step: int, skeleton: Any) -> tuple[Any, dict]:
    """Restore into the structure of ``skeleton`` (shapes/dtypes checked)."""
    path = os.path.join(directory, f"step_{step:08d}")
    dctx = zstd.ZstdDecompressor()
    with open(os.path.join(path, "manifest.msgpack.zst"), "rb") as f:
        manifest = msgpack.unpackb(dctx.decompress(f.read()))
    with open(os.path.join(path, "buffers.bin.zst"), "rb") as f:
        raw = dctx.decompress(f.read())
    leaves, treedef = jax.tree.flatten(skeleton)
    assert len(leaves) == len(manifest["leaves"]), (
        f"checkpoint has {len(manifest['leaves'])} leaves, skeleton "
        f"{len(leaves)} — structure changed since save")
    out, off = [], 0
    for leaf, meta in zip(leaves, manifest["leaves"]):
        n = meta["nbytes"]
        arr = np.frombuffer(raw[off:off + n], dtype=_np_dtype(meta["dtype"])) \
            .reshape(meta["shape"]).copy()
        off += n
        exp_shape = tuple(np.shape(leaf))
        assert tuple(arr.shape) == exp_shape, (
            f"shape mismatch: ckpt {arr.shape} vs skeleton {exp_shape}")
        dev = jnp.asarray(arr)
        if hasattr(leaf, "sharding") and leaf.sharding is not None:
            try:
                dev = jax.device_put(dev, leaf.sharding)
            except (ValueError, RuntimeError):
                pass
        out.append(dev)
    return jax.tree.unflatten(treedef, out), manifest["extra"]


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for name in os.listdir(directory)
             if (m := re.match(r"step_(\d+)$", name))]
    return max(steps) if steps else None
