"""AdamW — tree form and flat-sharded (ZeRO-1) form.

``AdamW`` is the standard pytree optimizer (used by the federated local
steps and the smoke tests).

``FlatAdamW`` operates on a *flat f32 vector shard*: since SAFE publishes
the aggregated gradient as a public flat vector anyway (the chain output,
DESIGN.md §3), each learner rank can own 1/n of the optimizer state and
update only its slice — ZeRO-1 over the learner axis at zero privacy
cost (the aggregated average is public by protocol). The updated shards
are all-gathered back into the parameter tree by the train step.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: Optional[float] = 1.0

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.asarray(self.lr)

    def init(self, params) -> AdamState:
        zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return AdamState(jnp.zeros((), jnp.int32), zeros,
                         jax.tree.map(jnp.copy, zeros))

    def update(self, grads, state: AdamState, params):
        step = state.step + 1
        if self.grad_clip is not None:
            gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                                 for g in jax.tree.leaves(grads)))
            scale = jnp.minimum(1.0, self.grad_clip / jnp.maximum(gnorm, 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        b1, b2 = self.b1, self.b2
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                         state.m, grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) *
                         jnp.square(g.astype(jnp.float32)), state.v, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(p, m_, v_):
            u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + self.eps)
            u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, AdamState(step, m, v)


@dataclasses.dataclass(frozen=True)
class FlatAdamW:
    """AdamW on a flat f32 shard (elementwise — safe to shard anyhow)."""
    lr: Callable[[jax.Array], jax.Array] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.asarray(self.lr)

    def init(self, nelem: int) -> AdamState:
        z = jnp.zeros((nelem,), jnp.float32)
        return AdamState(jnp.zeros((), jnp.int32), z, jnp.zeros_like(z))

    def update(self, grad_shard: jax.Array, state: AdamState,
               param_shard: jax.Array):
        step = state.step + 1
        g = grad_shard.astype(jnp.float32)
        m = self.b1 * state.m + (1 - self.b1) * g
        v = self.b2 * state.v + (1 - self.b2) * jnp.square(g)
        bc1 = 1 - self.b1 ** step.astype(jnp.float32)
        bc2 = 1 - self.b2 ** step.astype(jnp.float32)
        u = (m / bc1) / (jnp.sqrt(v / bc2) + self.eps)
        u = u + self.weight_decay * param_shard.astype(jnp.float32)
        new_shard = param_shard.astype(jnp.float32) - self._lr(step) * u
        return new_shard, AdamState(step, m, v)
