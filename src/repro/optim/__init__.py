"""Optimizers and schedules (built from scratch — no optax)."""
from repro.optim.adamw import AdamW, FlatAdamW
from repro.optim.schedules import cosine_schedule, linear_warmup_cosine

__all__ = ["AdamW", "FlatAdamW", "cosine_schedule", "linear_warmup_cosine"]
