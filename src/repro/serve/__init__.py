"""Serving: prefill/decode engine + multi-session aggregation engine."""
from repro.serve.engine import ServeEngine, make_serve_step
from repro.serve.agg_engine import AggregationEngine

__all__ = ["ServeEngine", "make_serve_step", "AggregationEngine"]
