"""Multi-session aggregation engine: S concurrent SAFE rounds, one program.

The aggregation sibling of :class:`~repro.serve.engine.ServeEngine`: a
fixed batch of S *slots*, each holding one tenant's
:class:`~repro.core.session.AggSession`. Every ``step()`` admits queued
sessions into free slots and runs ONE compiled shard_map program that
advances every occupied slot by one aggregation round —
``chain_aggregate_batched`` vmaps the session dim, so S rounds share one
ppermute schedule (one collective per hop instead of S) and one XLA
dispatch. Finished sessions are evicted; empty slots ride along masked
out (their published output is discarded).

Per-slot independence is total: keys, counter spaces, alive bitmaps and
initiator rotations are per-session, and the batched arithmetic is
bit-identical to S standalone single-session runs (asserted in
tests/test_session_engine.py). Slots are homogeneous in (n, V, mode,
topology) — one compiled program — exactly as ServeEngine slots share
one stacked cache shape.

Throughput: benchmarks/multi_session.py measures rounds/sec vs. the
unbatched loop at S ∈ {1, 8, 32}.

Behind the wire broker (``net/broker.py`` ``submit_session`` /
``wait_session``, docs/PROTOCOL.md §7) an engine instance serves many
TCP tenants; those ops still carry whole sessions in single frames —
chunk-streamed engine submissions are a ROADMAP open item.
"""
from __future__ import annotations

from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.chain import chain_aggregate_batched
from repro.core.session import AggSession
from repro.core.types import ChainConfig
from repro.crypto.prf import derive_key


class AggregationEngine:
    """Slot-based scheduler batching S SAFE sessions through one program.

    Args:
      mesh: mesh whose ``cfg.axis`` dimension is the learner axis.
      cfg: shared ChainConfig (mode must be 'safe' or 'saf'; the
        sequential schedule is the batched substrate).
      slots: S — max concurrent sessions per step.
      payload_words: V — per-learner vector length every session uses.
    """

    def __init__(self, mesh: Mesh, cfg: ChainConfig, slots: int = 8,
                 payload_words: int = 1024):
        if cfg.mode not in ("safe", "saf"):
            raise ValueError("AggregationEngine batches the chain modes "
                             f"('safe'/'saf'), got {cfg.mode!r}")
        self.mesh = mesh
        self.cfg = cfg
        self.slots = slots
        self.V = payload_words
        self.n = cfg.num_learners
        # counter words one round consumes (weighted carries Σw as an
        # extra ring word) — sessions advance their counter by this much
        self.words_per_round = self.V + 1 if cfg.weighted else self.V
        self.slot_sessions: List[Optional[AggSession]] = [None] * slots
        self.queue: List[AggSession] = []
        self.steps = 0
        self.rounds_completed = 0
        self._next_sid = 0
        #: optional completion hook: called synchronously from step()
        #: with each AggSession the moment it finishes its last round
        #: (used by net/broker.py to resolve wire-side wait_session
        #: long-polls without scanning slots).
        self.on_complete: Optional[Callable[[AggSession], None]] = None
        self._program = self._build_program()

    # ---- compiled program ------------------------------------------------
    def _build_program(self):
        cfg, S = self.cfg, self.slots

        def per_rank(vals, prov_w, master_w, ctrs, alive, wts, rots):
            # vals arrives [S, 1, V] (this rank's slice of the learner dim)
            vals = vals.reshape(S, self.V)
            rank = jax.lax.axis_index(cfg.axis)
            # per-session key derivation — the exact make_round_keys
            # chain (domain 0), vmapped over the session dim
            prov_d = jax.vmap(lambda w: derive_key(w, 0))(prov_w)
            learner_d = jax.vmap(
                lambda w: derive_key(derive_key(w, 0), rank))(master_w)
            w_r = wts[:, rank] if cfg.weighted else None
            return chain_aggregate_batched(
                vals, prov_d, learner_d, ctrs, cfg, alive,
                weights=w_r, rotate=rots)

        shard_fn = jax.shard_map(
            per_rank,
            mesh=self.mesh,
            in_specs=(P(None, cfg.axis), P(), P(), P(), P(), P(), P()),
            out_specs=P(),
            axis_names=frozenset({cfg.axis}),
            check_vma=False,
        )
        return jax.jit(shard_fn)

    # ---- host-side scheduling -------------------------------------------
    def submit(self, values: np.ndarray, *, rounds: int = 1,
               provisioning_seed: int = 0xC0FFEE,
               learner_master: int = 0x5EED,
               alive: Optional[np.ndarray] = None,
               weights: Optional[np.ndarray] = None,
               rotate0: int = 0) -> AggSession:
        """Queue a session. values: f32[n, V]."""
        values = np.asarray(values, np.float32)
        if values.shape != (self.n, self.V):
            raise ValueError(
                f"session shape {values.shape} != engine slots' "
                f"({self.n}, {self.V})")
        if rounds < 1:
            raise ValueError(f"rounds must be >= 1, got {rounds}")
        sess = AggSession(self._next_sid, values, provisioning_seed,
                          learner_master, rounds, alive, weights, rotate0)
        self._next_sid += 1
        self.queue.append(sess)
        return sess

    def _admit(self) -> None:
        for i, s in enumerate(self.slot_sessions):
            if s is None and self.queue:
                self.slot_sessions[i] = self.queue.pop(0)

    @property
    def active(self) -> int:
        return sum(s is not None for s in self.slot_sessions)

    def step(self) -> int:
        """Admit + advance every occupied slot one round. Returns the
        number of session-rounds completed this step."""
        self._admit()
        if self.active == 0:
            return 0
        S, n, V = self.slots, self.n, self.V
        vals = np.zeros((S, n, V), np.float32)
        prov_w = np.zeros((S, 2), np.uint32)
        master_w = np.zeros((S, 2), np.uint32)
        ctrs = np.zeros((S,), np.uint32)
        alive = np.ones((S, n), np.float32)
        wts = np.ones((S, n), np.float32)
        rots = np.zeros((S,), np.int32)
        for i, sess in enumerate(self.slot_sessions):
            if sess is None:
                continue  # masked slot: all-alive zeros, result discarded
            vals[i] = sess.values
            prov_w[i], master_w[i] = sess.key_words()
            rots[i] = sess.rotate
            ctrs[i] = np.uint32(sess.reserve_counter(self.words_per_round)
                                & 0xFFFFFFFF)
            alive[i] = sess.alive
            wts[i] = sess.weights

        with jax.set_mesh(self.mesh):
            out = self._program(jnp.asarray(vals), jnp.asarray(prov_w),
                                jnp.asarray(master_w), jnp.asarray(ctrs),
                                jnp.asarray(alive), jnp.asarray(wts),
                                jnp.asarray(rots))
        out = np.asarray(jax.block_until_ready(out))

        completed = 0
        for i, sess in enumerate(self.slot_sessions):
            if sess is None:
                continue
            sess.record_result(out[i])
            completed += 1
            if sess.done:
                self.slot_sessions[i] = None
                if self.on_complete is not None:
                    self.on_complete(sess)
        self.steps += 1
        self.rounds_completed += completed
        return completed

    def run_until_done(self, max_steps: int = 10_000) -> None:
        while (self.queue or self.active) and self.steps < max_steps:
            self.step()
