"""Batched serving engine: continuous prefill + decode.

``make_serve_step`` builds the jitted one-token decode step the dry-run
lowers for the decode_32k / long_500k shapes: ONE new token against a KV
cache (or recurrent state) of ``seq_len``.

``ServeEngine`` is the host-side loop the serving example drives: a
fixed-size batch of slots, each slot holding one request's cache; new
requests are prefilled into free slots, finished ones evicted. (Slot
caches share one stacked cache pytree — eviction is a masked reset, so
the decode step stays a single compiled program.)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.transformer import Model


def cache_pspecs(cache_abs, batch_sharded: bool,
                 seq_axis: Optional[str] = None, model_size: int = 1):
    """PartitionSpecs for a stacked decode cache (pytree-parallel).

    batch_sharded: shard the batch dim over 'data' (decode_32k).
    seq_axis: shard the attention-cache sequence dim instead (long_500k,
    batch=1 — the beyond-paper sequence-parallel KV layout).
    Attention k/v are [n_units, B, S_c, n_kv, hd]; recurrent states
    [n_units, B, H, ...]; pos [n_units, B]. Head dims shard over 'model'
    only when divisible (GQA kv counts are often < the TP degree)."""
    def heads(leaf, dim):
        return "model" if np.shape(leaf)[dim] % max(model_size, 1) == 0 else None

    def spec_for(leaf):
        nd = np.ndim(leaf)
        if nd == 5:  # attention kv
            if batch_sharded:
                return P(None, "data", None, heads(leaf, 3), None)
            if seq_axis:
                return P(None, None, seq_axis, heads(leaf, 3), None)
            return P(None, None, None, heads(leaf, 3), None)
        if nd == 4:  # mamba2 / rwkv6 state [U, B, H, ...]
            return P(None, "data" if batch_sharded else None,
                     heads(leaf, 2), None)
        if nd == 3:  # rwkv prev [U, B, d]
            return P(None, "data" if batch_sharded else None, None)
        if nd == 2:  # pos [U, B]
            return P(None, "data") if batch_sharded else P()
        return P()

    return jax.tree.map(spec_for, cache_abs)


def make_serve_step(model: Model, mesh: Optional[Mesh] = None):
    """Jitted (params, tokens, cache) -> (logits, cache) one-token step."""
    step = jax.jit(model.decode_step)

    def serve_step(params, tokens, cache):
        if mesh is not None:
            with jax.set_mesh(mesh):
                return step(params, tokens, cache)
        return step(params, tokens, cache)

    return serve_step


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # int32[S]
    max_new: int = 32
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Host-side batched serving loop (the serving example's core)."""

    def __init__(self, model: Model, params, batch_slots: int = 4,
                 max_seq: int = 512, temperature: float = 0.0, seed: int = 0):
        self.model = model
        self.params = params
        self.slots = batch_slots
        self.max_seq = max_seq
        self.temperature = temperature
        self.rng = np.random.RandomState(seed)
        self.cache = model.init_cache(batch_slots, max_seq, prefilled=False)
        self.slot_req: list[Optional[Request]] = [None] * batch_slots
        self.queue: list[Request] = []
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(model.prefill)
        self.steps = 0

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.slot_req):
            if r is None:
                return i
        return None

    def _admit(self) -> None:
        while self.queue and (slot := self._free_slot()) is not None:
            req = self.queue.pop(0)
            self.slot_req[slot] = req
            # prefill this request alone, then splice its cache into the slot
            one_cache = self.model.init_cache(1, self.max_seq, prefilled=False)
            toks = jnp.asarray(req.prompt[None, :], jnp.int32)
            logits, one_cache = self._prefill(self.params, toks, cache=one_cache)
            req.generated = [int(jnp.argmax(logits[0]))]
            self.cache = jax.tree.map(
                lambda full, one: full.at[:, slot].set(one[:, 0]),
                self.cache, one_cache)

    def _sample(self, logits: jax.Array) -> np.ndarray:
        if self.temperature <= 0:
            return np.asarray(jnp.argmax(logits, axis=-1))
        p = np.asarray(jax.nn.softmax(logits / self.temperature, axis=-1))
        return np.array([self.rng.choice(p.shape[-1], p=row) for row in p])

    def step(self) -> None:
        """Admit + one decode step for all active slots."""
        self._admit()
        active = [r for r in self.slot_req if r is not None]
        if not active:
            return
        last = np.zeros((self.slots,), np.int32)
        for i, r in enumerate(self.slot_req):
            if r is not None and r.generated:
                last[i] = r.generated[-1]
        logits, self.cache = self._decode(self.params,
                                          jnp.asarray(last), self.cache)
        nxt = self._sample(logits)
        self.steps += 1
        for i, r in enumerate(self.slot_req):
            if r is None:
                continue
            r.generated.append(int(nxt[i]))
            if len(r.generated) >= r.max_new:
                r.done = True
                self.slot_req[i] = None

    def run_until_done(self, max_steps: int = 10_000) -> None:
        while (self.queue or any(self.slot_req)) and self.steps < max_steps:
            self.step()
