"""Pallas TPU kernels for the SAFE masking hot spots.

threefry_mask_add — fused keystream + fixed-point encode + masked add
chain_combine     — fused SAFE non-initiator hop (decrypt+add+re-encrypt)
bon_mask          — fused BON pairwise masking (baseline hot spot)

Each kernel has a pure-jnp oracle in ``ref.py`` and a jit'd wrapper in
``ops.py`` (interpret=True automatically off-TPU).
"""
from repro.kernels.ops import mask_add, chain_combine, bon_mask

__all__ = ["mask_add", "chain_combine", "bon_mask"]
