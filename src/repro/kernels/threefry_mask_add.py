"""Fused Threefry keystream + fixed-point encode + masked add (Pallas/TPU).

The device hot spot of SAFE: every chain hop and the initiator step
stream a large parameter vector through "generate pad, encode, add".
Unfused, that is three HBM round trips (pad materialization, encode,
add); this kernel does one read of ``x`` and one write of the masked
ciphertext — the pad never touches HBM.

TPU adaptation notes (DESIGN.md §4):
  * masking is element-wise VPU work — the roofline is HBM bandwidth, so
    fusion is the whole optimization;
  * blocks are (block_rows, 128): lane-dim 128 matches the VPU/VREG lane
    width, block_rows a multiple of 8 for f32 sublane packing;
  * each element evaluates the full Threefry-2x32 block for its counter
    and selects its lane — lane-redundant (2× VPU flops) but gather-free
    and layout-preserving; the VPU has headroom at 0.36 B/flop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_ROTATIONS = ((13, 15, 26, 6), (17, 29, 16, 24))
_PARITY = np.uint32(0x1BD11BDA)  # np, not jnp: a jnp scalar would be a
# captured constant inside the Pallas kernel body

LANE = 128  # VPU lane width


def as_u32_scalar(x):
    """uint32 scalar from python int (wrapping) or traced value."""
    if isinstance(x, (int, np.integer)):
        return jnp.asarray(np.uint32(int(x) & 0xFFFFFFFF))
    return jnp.asarray(x, jnp.uint32)
DEFAULT_BLOCK_ROWS = 64  # 64×128 u32 = 32 KiB / block operand — fits VMEM easily


def _rotl32(x, d: int):
    return (x << d) | (x >> (32 - d))


def threefry2x32_block(k0, k1, x0, x1):
    """Threefry-2x32 (20 rounds) on uint32 blocks — VPU-only arithmetic."""
    ks0, ks1 = k0, k1
    ks2 = ks0 ^ ks1 ^ _PARITY
    x0 = x0 + ks0
    x1 = x1 + ks1
    ks = (ks0, ks1, ks2)
    for i in range(5):
        for r in _ROTATIONS[i % 2]:
            x0 = x0 + x1
            x1 = _rotl32(x1, r)
            x1 = x1 ^ x0
        x0 = x0 + ks[(i + 1) % 3]
        x1 = x1 + ks[(i + 2) % 3] + jnp.uint32(i + 1)
    return x0, x1


def pad_for_block(k0, k1, base, block_shape, row_offset):
    """uint32 keystream for a (rows, LANE) tile starting at flat offset
    ``row_offset*LANE``, matching crypto.prf.keystream_pair_lanes:
    word i = lane (i & 1) of Threefry(key, base + i//2)."""
    rows, lanes = block_shape
    row = jax.lax.broadcasted_iota(jnp.uint32, block_shape, 0)
    col = jax.lax.broadcasted_iota(jnp.uint32, block_shape, 1)
    linear = (row + row_offset) * jnp.uint32(lanes) + col
    ctr = base + (linear >> 1)
    lane_sel = (linear & jnp.uint32(1)).astype(jnp.bool_)
    y0, y1 = threefry2x32_block(k0, k1, ctr, jnp.zeros_like(ctr))
    return jnp.where(lane_sel, y1, y0)


def encode_block(x, scale_bits: int):
    """f32 -> uint32 ring element (round-to-nearest-even), matching
    crypto.fixedpoint.FixedPointCodec.encode."""
    scaled = jnp.round(x.astype(jnp.float32) * jnp.float32(2.0**scale_bits))
    return scaled.astype(jnp.int32).view("uint32")


def _mask_add_kernel(scalars, x_ref, o_ref, *, scale_bits: int, block_rows: int):
    i = pl.program_id(0)
    pad = pad_for_block(scalars[0], scalars[1], scalars[2], x_ref.shape,
                        jnp.uint32(i * block_rows))
    o_ref[...] = encode_block(x_ref[...], scale_bits) + pad


@functools.partial(jax.jit, static_argnames=("scale_bits", "block_rows", "interpret"))
def mask_add(
    x: jax.Array,
    key: jax.Array,
    counter_base: jax.Array | int = 0,
    *,
    scale_bits: int = 16,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
) -> jax.Array:
    """out[i] = encode(x[i]) + PRF(key, base + i)  (mod 2^32), fused.

    x: f32[V] (any V — padded internally to a whole tile grid).
    key: uint32[2]. Returns uint32[V].
    """
    V = x.shape[0]
    elems = block_rows * LANE
    vpad = (-V) % elems
    x2 = jnp.pad(x, (0, vpad)).reshape(-1, LANE)
    nblocks = x2.shape[0] // block_rows

    scalars = jnp.concatenate(
        [jnp.asarray(key, jnp.uint32).reshape(2),
         as_u32_scalar(counter_base).reshape(1)])

    out = pl.pallas_call(
        functools.partial(_mask_add_kernel, scale_bits=scale_bits,
                          block_rows=block_rows),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(nblocks,),
            # index maps receive (grid_idx, scalar_ref) under scalar prefetch
            in_specs=[pl.BlockSpec((block_rows, LANE), lambda i, s: (i, 0))],
            out_specs=pl.BlockSpec((block_rows, LANE), lambda i, s: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct(x2.shape, jnp.uint32),
        interpret=interpret,
    )(scalars, x2)
    return out.reshape(-1)[:V]
