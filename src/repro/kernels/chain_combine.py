"""Fused SAFE chain hop: decrypt + add-local + re-encrypt (Pallas/TPU).

The complete non-initiator step (paper §5.1.2 step 2) in one HBM pass:

    out = cipher − PRF(k_in, ctr) + encode(x_local) + PRF(k_out, ctr)

Both pads are generated in-register (VPU) and never materialized; the
kernel reads ``cipher`` and ``x`` once and writes ``out`` once — 12 bytes
of HBM traffic per element instead of 28+ for the unfused sequence
(pad_in read+write, decrypt read+write, encode, pad_out read+write, add).
Roofline: memory-bound; see benchmarks/kernel_bench.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.threefry_mask_add import (
    LANE,
    as_u32_scalar,
    DEFAULT_BLOCK_ROWS,
    encode_block,
    pad_for_block,
)


def _chain_combine_kernel(scalars, cipher_ref, x_ref, o_ref, *,
                          scale_bits: int, block_rows: int):
    i = pl.program_id(0)
    off = jnp.uint32(i * block_rows)
    # scalars = [kin0, kin1, kout0, kout1, base]
    pad_in = pad_for_block(scalars[0], scalars[1], scalars[4], cipher_ref.shape, off)
    pad_out = pad_for_block(scalars[2], scalars[3], scalars[4], cipher_ref.shape, off)
    o_ref[...] = cipher_ref[...] - pad_in + encode_block(x_ref[...], scale_bits) + pad_out


@functools.partial(jax.jit, static_argnames=("scale_bits", "block_rows", "interpret"))
def chain_combine(
    cipher: jax.Array,
    x: jax.Array,
    key_in: jax.Array,
    key_out: jax.Array,
    counter_base: jax.Array | int = 0,
    *,
    scale_bits: int = 16,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
) -> jax.Array:
    """One fused chain hop. cipher: uint32[V], x: f32[V] -> uint32[V]."""
    V = cipher.shape[0]
    elems = block_rows * LANE
    vpad = (-V) % elems
    c2 = jnp.pad(cipher, (0, vpad)).reshape(-1, LANE)
    x2 = jnp.pad(x, (0, vpad)).reshape(-1, LANE)
    nblocks = c2.shape[0] // block_rows

    scalars = jnp.concatenate([
        jnp.asarray(key_in, jnp.uint32).reshape(2),
        jnp.asarray(key_out, jnp.uint32).reshape(2),
        as_u32_scalar(counter_base).reshape(1),
    ])

    out = pl.pallas_call(
        functools.partial(_chain_combine_kernel, scale_bits=scale_bits,
                          block_rows=block_rows),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(nblocks,),
            in_specs=[
                pl.BlockSpec((block_rows, LANE), lambda i, s: (i, 0)),
                pl.BlockSpec((block_rows, LANE), lambda i, s: (i, 0)),
            ],
            out_specs=pl.BlockSpec((block_rows, LANE), lambda i, s: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct(c2.shape, jnp.uint32),
        interpret=interpret,
    )(scalars, c2, x2)
    return out.reshape(-1)[:V]
