"""Fused SAFE chain hop: decrypt + add-local + re-encrypt (Pallas/TPU).

The complete non-initiator step (paper §5.1.2 step 2) in one HBM pass:

    out = cipher − PRF(k_in, ctr) + encode(x_local) + PRF(k_out, ctr)

Both pads are generated in-register (VPU) and never materialized; the
kernel reads ``cipher`` and ``x`` once and writes ``out`` once — 12 bytes
of HBM traffic per element instead of 28+ for the unfused sequence
(pad_in read+write, decrypt read+write, encode, pad_out read+write, add).
Roofline: memory-bound; see benchmarks/kernel_bench.py.

``chain_combine_batched`` is the multi-session form backing
``serve/agg_engine.py``: a leading session dim S with *per-session* key
and counter scalars delivered via scalar prefetch — the grid walks
(session, block) and each session's keys are read from SMEM at
``program_id(0)``, so S tenants' hops stream through one kernel launch
with zero per-session dispatch overhead.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.threefry_mask_add import (
    LANE,
    as_u32_scalar,
    DEFAULT_BLOCK_ROWS,
    encode_block,
    pad_for_block,
)


def _chain_combine_kernel(scalars, cipher_ref, x_ref, o_ref, *,
                          scale_bits: int, block_rows: int):
    i = pl.program_id(0)
    off = jnp.uint32(i * block_rows)
    # scalars = [kin0, kin1, kout0, kout1, base]
    pad_in = pad_for_block(scalars[0], scalars[1], scalars[4], cipher_ref.shape, off)
    pad_out = pad_for_block(scalars[2], scalars[3], scalars[4], cipher_ref.shape, off)
    o_ref[...] = cipher_ref[...] - pad_in + encode_block(x_ref[...], scale_bits) + pad_out


@functools.partial(jax.jit, static_argnames=("scale_bits", "block_rows", "interpret"))
def chain_combine(
    cipher: jax.Array,
    x: jax.Array,
    key_in: jax.Array,
    key_out: jax.Array,
    counter_base: jax.Array | int = 0,
    *,
    scale_bits: int = 16,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
) -> jax.Array:
    """One fused chain hop. cipher: uint32[V], x: f32[V] -> uint32[V]."""
    V = cipher.shape[0]
    elems = block_rows * LANE
    vpad = (-V) % elems
    c2 = jnp.pad(cipher, (0, vpad)).reshape(-1, LANE)
    x2 = jnp.pad(x, (0, vpad)).reshape(-1, LANE)
    nblocks = c2.shape[0] // block_rows

    scalars = jnp.concatenate([
        jnp.asarray(key_in, jnp.uint32).reshape(2),
        jnp.asarray(key_out, jnp.uint32).reshape(2),
        as_u32_scalar(counter_base).reshape(1),
    ])

    out = pl.pallas_call(
        functools.partial(_chain_combine_kernel, scale_bits=scale_bits,
                          block_rows=block_rows),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(nblocks,),
            in_specs=[
                pl.BlockSpec((block_rows, LANE), lambda i, s: (i, 0)),
                pl.BlockSpec((block_rows, LANE), lambda i, s: (i, 0)),
            ],
            out_specs=pl.BlockSpec((block_rows, LANE), lambda i, s: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct(c2.shape, jnp.uint32),
        interpret=interpret,
    )(scalars, c2, x2)
    return out.reshape(-1)[:V]


def _chain_combine_batched_kernel(scalars, cipher_ref, x_ref, o_ref, *,
                                  scale_bits: int, block_rows: int):
    s = pl.program_id(0)  # session
    i = pl.program_id(1)  # block within the session's vector
    off = jnp.uint32(i * block_rows)
    # per-session scalars at s*5: [kin0, kin1, kout0, kout1, base]
    b = s * 5
    shape = (block_rows, LANE)
    pad_in = pad_for_block(scalars[b], scalars[b + 1], scalars[b + 4],
                           shape, off)
    pad_out = pad_for_block(scalars[b + 2], scalars[b + 3], scalars[b + 4],
                            shape, off)
    o_ref[0] = (cipher_ref[0] - pad_in
                + encode_block(x_ref[0], scale_bits) + pad_out)


@functools.partial(jax.jit, static_argnames=("scale_bits", "block_rows",
                                             "interpret"))
def chain_combine_batched(
    cipher: jax.Array,
    x: jax.Array,
    keys_in: jax.Array,
    keys_out: jax.Array,
    counter_bases: jax.Array,
    *,
    scale_bits: int = 16,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
) -> jax.Array:
    """S fused chain hops, one per session, in one kernel launch.

    Per session s the arithmetic is exactly ``chain_combine`` under that
    session's keys/counter — bit-identical to S separate calls (asserted
    in tests/test_kernels.py).

    Args:
      cipher: uint32[S, V] incoming hop ciphertexts.
      x: f32[S, V] local vectors.
      keys_in / keys_out: uint32[S, 2] per-session edge keys.
      counter_bases: uint32[S] per-session counter bases.

    Returns:
      uint32[S, V] outgoing ciphertexts.
    """
    S, V = cipher.shape
    elems = block_rows * LANE
    vpad = (-V) % elems
    c3 = jnp.pad(cipher, ((0, 0), (0, vpad))).reshape(S, -1, LANE)
    x3 = jnp.pad(x, ((0, 0), (0, vpad))).reshape(S, -1, LANE)
    nblocks = c3.shape[1] // block_rows

    # flat SMEM table [S*5]: rows of (kin0, kin1, kout0, kout1, base)
    scalars = jnp.concatenate([
        jnp.asarray(keys_in, jnp.uint32).reshape(S, 2),
        jnp.asarray(keys_out, jnp.uint32).reshape(S, 2),
        jnp.asarray(counter_bases, jnp.uint32).reshape(S, 1),
    ], axis=1).reshape(-1)

    out = pl.pallas_call(
        functools.partial(_chain_combine_batched_kernel,
                          scale_bits=scale_bits, block_rows=block_rows),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(S, nblocks),
            in_specs=[
                pl.BlockSpec((1, block_rows, LANE), lambda s, i, ref: (s, i, 0)),
                pl.BlockSpec((1, block_rows, LANE), lambda s, i, ref: (s, i, 0)),
            ],
            out_specs=pl.BlockSpec((1, block_rows, LANE),
                                   lambda s, i, ref: (s, i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct(c3.shape, jnp.uint32),
        interpret=interpret,
    )(scalars, c3, x3)
    return out.reshape(S, -1)[:, :V]
