"""Pure-jnp oracles for the Pallas kernels.

Each function is the bit-exact specification its kernel is validated
against (tests/test_kernels.py sweeps shapes and dtypes with
assert_array_equal — these are integer kernels, so *equality*, not
allclose).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.crypto.fixedpoint import FixedPointCodec
from repro.crypto.prf import keystream_pair_lanes


def mask_add_ref(x: jax.Array, key: jax.Array, counter_base, scale_bits: int = 16) -> jax.Array:
    """out = encode(x) + PRF(key, base..)  (mod 2^32).

    The SAFE initiator step (§5.2 step 1: add R to the local vector) and,
    with the hop key, the encrypt half of every chain hop.
    """
    codec = FixedPointCodec(scale_bits)
    pad = keystream_pair_lanes(key, x.shape[0], counter_base)
    return codec.encode(x) + pad


def chain_combine_ref(
    cipher: jax.Array,
    x: jax.Array,
    key_in: jax.Array,
    key_out: jax.Array,
    counter_base,
    scale_bits: int = 16,
) -> jax.Array:
    """out = cipher − PRF(key_in) + encode(x) + PRF(key_out)  (mod 2^32).

    The entire SAFE non-initiator hop (§5.1.2 step 2: decrypt, add local
    vector, re-encrypt) in one pass.
    """
    codec = FixedPointCodec(scale_bits)
    n = cipher.shape[0]
    pad_in = keystream_pair_lanes(key_in, n, counter_base)
    pad_out = keystream_pair_lanes(key_out, n, counter_base)
    return cipher - pad_in + codec.encode(x) + pad_out


def chain_combine_batched_ref(
    cipher: jax.Array,
    x: jax.Array,
    keys_in: jax.Array,
    keys_out: jax.Array,
    counter_bases: jax.Array,
    scale_bits: int = 16,
) -> jax.Array:
    """Session-batched chain hop: row s is ``chain_combine_ref`` under
    session s's keys/counter. Oracle for ``chain_combine_batched``."""
    return jnp.stack([
        chain_combine_ref(cipher[s], x[s], keys_in[s], keys_out[s],
                          counter_bases[s], scale_bits)
        for s in range(cipher.shape[0])
    ])


def bon_mask_ref(
    x: jax.Array,
    keys: jax.Array,
    signs: jax.Array,
    counter_base,
    scale_bits: int = 16,
) -> jax.Array:
    """out = encode(x) + Σ_j signs[j]·PRF(keys[j])  (mod 2^32).

    The BON masking step: one self-mask plus n−1 pairwise pads per
    learner — the quadratic-work baseline. keys: uint32[m, 2];
    signs: int32[m] in {+1, −1}.
    """
    codec = FixedPointCodec(scale_bits)
    n = x.shape[0]
    acc = codec.encode(x)
    for j in range(keys.shape[0]):
        pad = keystream_pair_lanes(keys[j], n, counter_base)
        acc = jnp.where(signs[j] > 0, acc + pad, acc - pad)
    return acc
