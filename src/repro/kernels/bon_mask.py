"""Fused BON pairwise masking (Pallas/TPU) — the baseline's hot spot.

    out = encode(x) + Σ_j signs[j] · PRF(keys[j], ctr)   (mod 2^32)

One learner's Round-2 masking applies m = n−1 pairwise pads plus the
self-mask: unfused that is m full keystream materializations (8·m bytes
of HBM traffic per element); fused, the pads are accumulated in VMEM and
the traffic is the same 12 bytes/element as a single SAFE hop — but the
VPU work is m× larger, which is exactly the O(n) compute asymmetry the
paper exploits (§2: SAFE needs 2 pads/hop regardless of n). The kernel
makes the comparison fair: BON's wall-clock disadvantage on TPU is
*compute*, not an artifact of naive fusion.

keys/signs arrive via scalar prefetch (SMEM) — they are O(n) words.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.threefry_mask_add import (
    LANE,
    as_u32_scalar,
    DEFAULT_BLOCK_ROWS,
    encode_block,
    pad_for_block,
)


def _bon_mask_kernel(scalars, x_ref, o_ref, *, scale_bits: int,
                     block_rows: int, num_keys: int):
    i = pl.program_id(0)
    off = jnp.uint32(i * block_rows)
    acc = encode_block(x_ref[...], scale_bits)
    base = scalars[3 * num_keys]
    for j in range(num_keys):  # static unroll: n is a trace-time constant
        pad = pad_for_block(scalars[3 * j], scalars[3 * j + 1], base,
                            x_ref.shape, off)
        sign_pos = scalars[3 * j + 2] > 0
        acc = jnp.where(sign_pos, acc + pad, acc - pad)
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("scale_bits", "block_rows", "interpret"))
def bon_mask(
    x: jax.Array,
    keys: jax.Array,
    signs: jax.Array,
    counter_base: jax.Array | int = 0,
    *,
    scale_bits: int = 16,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
) -> jax.Array:
    """x: f32[V]; keys: uint32[m, 2]; signs: int32[m] (+1/−1) -> uint32[V]."""
    V = x.shape[0]
    m = keys.shape[0]
    elems = block_rows * LANE
    vpad = (-V) % elems
    x2 = jnp.pad(x, (0, vpad)).reshape(-1, LANE)
    nblocks = x2.shape[0] // block_rows

    # scalar layout: [k0_j, k1_j, sign_j]*m + [base]; sign encoded 1/0
    packed = jnp.concatenate([
        jnp.concatenate([
            jnp.asarray(keys, jnp.uint32),
            (jnp.asarray(signs, jnp.int32) > 0).astype(jnp.uint32).reshape(-1, 1),
        ], axis=1).reshape(-1),
        as_u32_scalar(counter_base).reshape(1),
    ])

    out = pl.pallas_call(
        functools.partial(_bon_mask_kernel, scale_bits=scale_bits,
                          block_rows=block_rows, num_keys=m),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(nblocks,),
            in_specs=[pl.BlockSpec((block_rows, LANE), lambda i, s: (i, 0))],
            out_specs=pl.BlockSpec((block_rows, LANE), lambda i, s: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct(x2.shape, jnp.uint32),
        interpret=interpret,
    )(packed, x2)
    return out.reshape(-1)[:V]
