"""Public jit'd entry points for the Pallas kernels.

``interpret`` defaults to True on CPU hosts (this container) and False on
real TPU backends — callers never need to pass it. The chain data plane
(`core/chain.py`) can route its mask arithmetic through these via
``use_kernels=True`` in the high-level ops below.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.threefry_mask_add import mask_add as _mask_add
from repro.kernels.chain_combine import (
    chain_combine as _chain_combine,
    chain_combine_batched as _chain_combine_batched,
)
from repro.kernels.bon_mask import bon_mask as _bon_mask


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _u32(counter_base):
    """Python ints wrap into uint32 before crossing the jit boundary."""
    if isinstance(counter_base, (int, np.integer)):
        return np.uint32(int(counter_base) & 0xFFFFFFFF)
    return counter_base


def mask_add(x, key, counter_base=0, *, scale_bits: int = 16,
             interpret: bool | None = None):
    """Fused encode+mask (SAFE initiator step / encrypt half of a hop)."""
    if interpret is None:
        interpret = _default_interpret()
    return _mask_add(x, key, _u32(counter_base), scale_bits=scale_bits,
                     interpret=interpret)


def chain_combine(cipher, x, key_in, key_out, counter_base=0, *,
                  scale_bits: int = 16, interpret: bool | None = None):
    """Fused SAFE non-initiator hop (decrypt + add + re-encrypt)."""
    if interpret is None:
        interpret = _default_interpret()
    return _chain_combine(cipher, x, key_in, key_out, _u32(counter_base),
                          scale_bits=scale_bits, interpret=interpret)


def chain_combine_batched(cipher, x, keys_in, keys_out, counter_bases, *,
                          scale_bits: int = 16,
                          interpret: bool | None = None):
    """Fused multi-session chain hop (one launch for S sessions' hops;
    per-session keys via scalar prefetch — serve/agg_engine substrate)."""
    if interpret is None:
        interpret = _default_interpret()
    return _chain_combine_batched(cipher, x, keys_in, keys_out,
                                  counter_bases, scale_bits=scale_bits,
                                  interpret=interpret)


def bon_mask(x, keys, signs, counter_base=0, *, scale_bits: int = 16,
             interpret: bool | None = None):
    """Fused BON pairwise masking (baseline hot spot)."""
    if interpret is None:
        interpret = _default_interpret()
    return _bon_mask(x, keys, signs, _u32(counter_base), scale_bits=scale_bits,
                     interpret=interpret)


__all__ = ["mask_add", "chain_combine", "chain_combine_batched", "bon_mask"]
