"""Binary wire codec for the SAFE broker protocol.

Compact, length-prefixed, versioned framing for every controller op in
:mod:`repro.core.controller` plus the engine-plane session ops. The
design goals, in order:

  1. **Exactness** — masked payloads are uint32 ring elements and the
     published average must survive the wire bit-for-bit, so arrays
     travel as raw little-endian bytes with their dtype tagged (no JSON
     float round-tripping; this is also 2–3x smaller than the base64
     JSON the paper's Flask broker shipped, §6.2).
  2. **Self-description** — requests/responses carry a one-byte version
     and a tagged value tree, so the codec round-trips every op payload
     (property-tested in ``tests/test_wire.py``) and unknown frames fail
     loudly instead of misparsing.
  3. **No heavyweight deps** — pure ``struct`` + numpy; the broker can
     run on a host with no JAX installed.

Frame layout (everything big-endian except raw array bytes, which are
little-endian numpy canonical):

    frame    := u32 body_len | body                (body_len <= MAX_FRAME)
    request  := u8 version | u8 opcode | value     (value: kwargs dict)
    response := u8 version | u8 status | value     (status 0 ok, 1 error)

Value encoding is a tagged tree: ``u8 tag`` followed by tag-specific
bytes — None/bool singletons, i64 ints, f64 floats, length-prefixed
utf-8 strings and bytes, lists, dicts (arbitrary encodable keys, so
``{group: [nodes]}`` int-keyed maps survive), and ndarrays
(``u8 dtype | u8 ndim | u32 dims… | raw``).

Arrays larger than one frame do not travel as one value: the chunked
transfer path (``post_chunk``/``get_chunk``, rules in docs/PROTOCOL.md
§6) splits a flat vector into ``chunk_words``-sized slices with
per-chunk sequence numbers, streamed as ordinary frames and reassembled
by :class:`ChunkAssembler`. The authoritative spec for the whole layer
— frames, opcodes, value tags, chunking, versioning — is
``docs/PROTOCOL.md``; ``tests/test_docs.py`` asserts its tables match
the registries below, so the book cannot silently drift from the code.

**Zero-copy discipline** (docs/PROTOCOL.md §12): decoding works over
any buffer object — ``bytes``, ``bytearray`` or a ``memoryview`` at a
non-zero offset into a larger receive buffer — without slicing it into
intermediate ``bytes``. ``decode_request(..., copy_arrays=False)``
returns array values as read-only ``np.frombuffer`` views straight into
the frame buffer (the broker's store-and-forward path relays these
views untouched); the default ``copy_arrays=True`` hands out writable
copies, which the state machines require. On the encode side the
``*_parts`` variants return a list of buffer segments — small scalars
coalesced into ``bytearray`` runs, large arrays/bytes as zero-copy
``memoryview``s — for ``StreamWriter.writelines`` scatter-gather sends,
so a relayed chunk is materialized exactly once (at the socket read).
"""
from __future__ import annotations

import asyncio
import struct
from typing import Any, Iterable, Optional, Tuple

import numpy as np

#: bump on breaking frame-layout changes; decoders reject other versions.
WIRE_VERSION = 1

#: hard cap on one frame's body — a 64 MiB vector is ~16M ring words,
#: far beyond any payload this repo ships; bigger lengths are treated as
#: stream corruption rather than an allocation request.
MAX_FRAME = 64 << 20


class WireError(Exception):
    """Protocol-level failure (broker returned an error response)."""


class WireDecodeError(WireError):
    """Malformed frame: bad version, unknown tag/opcode, truncation."""


# ---------------------------------------------------------------------------
# Opcodes — every controller op plus session management / engine plane
# ---------------------------------------------------------------------------

OPS: Tuple[str, ...] = (
    # session management
    "create_session",
    # controller call ops (core/controller.CALL_OPS)
    "post_aggregate",
    "post_average",
    "should_initiate",
    "register_key",
    "get_key",
    # controller long-poll kinds (core/controller.WAIT_KINDS)
    "check_aggregate",
    "get_aggregate",
    "get_average",
    # observability / admin (non-counting, mirrors the sim kernel's view)
    "peek_average",
    "get_stats",
    "reset_round",
    # engine plane (serve/agg_engine.AggregationEngine behind the broker)
    "submit_session",
    "wait_session",
    # session teardown (a long-lived broker must not accumulate tenants)
    "delete_session",
    # chunked transfer plane (docs/PROTOCOL.md §6) — transport frames
    # for arrays larger than one frame; never counted in MessageStats
    "post_chunk",
    "get_chunk",
    # sharded deployments (docs/PROTOCOL.md §12): the shard topology a
    # client uses to dial a session's owning worker directly. Appended
    # per the §9 additive-opcode policy — no version bump.
    "get_shard_map",
    # observability plane (docs/PROTOCOL.md §13): a live metrics
    # snapshot — rounds/s, latency percentiles, backlog, per-session
    # series. Admin-class: never counted in MessageStats, never timed.
    # Appended per the §9 additive-opcode policy — no version bump.
    "get_metrics",
    # BON baseline plane (docs/PROTOCOL.md §14): the Bonawitz-style
    # 4-round protocol (core/bon_controller.py) on the same transport,
    # for the head-to-head bake-off of benchmarks/bon_wire.py. Counted
    # in BonStats (never MessageStats). Appended per §9 — no bump.
    "bon_advertise",
    "bon_post_share",
    "bon_post_masked",
    "bon_post_unmask",
    "bon_get_keys",
    "bon_get_share",
    "bon_get_roster",
    "bon_get_average",
    # cross-round pipelining (docs/PROTOCOL.md §11): non-destructive
    # round boundary for persistent sessions — completes the current
    # round and opens the next without dropping round r+1 transfer
    # buffers already in flight. Admin-class: never counted, never
    # timed. Appended per the §9 additive-opcode policy — no bump.
    "advance_round",
    # hierarchical chain-of-chains (docs/PROTOCOL.md §15, paper §5.10):
    # a child org's broker posts its anonymized group average UP to a
    # parent session (post_org_average, counted+timed in HierStats) and
    # long-polls the folded parent average back DOWN (get_org_average,
    # counted in HierStats). Never counted in MessageStats — the §5
    # per-org closed forms stay exact. Appended per §9 — no bump.
    "post_org_average",
    "get_org_average",
)
OPCODE = {name: i + 1 for i, name in enumerate(OPS)}
OPNAME = {i + 1: name for i, name in enumerate(OPS)}

#: Value-tag registry (names are the canonical spellings used by
#: docs/PROTOCOL.md §4 — the doc-sync test compares this mapping).
VALUE_TAGS = {
    "none": 0,
    "true": 1,
    "false": 2,
    "int": 3,
    "float": 4,
    "str": 5,
    "bytes": 6,
    "list": 7,
    "dict": 8,
    "array": 9,
}

_T_NONE = VALUE_TAGS["none"]
_T_TRUE = VALUE_TAGS["true"]
_T_FALSE = VALUE_TAGS["false"]
_T_INT = VALUE_TAGS["int"]
_T_FLOAT = VALUE_TAGS["float"]
_T_STR = VALUE_TAGS["str"]
_T_BYTES = VALUE_TAGS["bytes"]
_T_LIST = VALUE_TAGS["list"]
_T_DICT = VALUE_TAGS["dict"]
_T_ARRAY = VALUE_TAGS["array"]

#: array dtype codes — little-endian canonical forms only (public: the
#: doc-sync test pins docs/PROTOCOL.md §5 to this table)
ARRAY_DTYPES = {
    0: np.dtype("<u4"),
    1: np.dtype("<f4"),
    2: np.dtype("<f8"),
    3: np.dtype("<i4"),
    4: np.dtype("<i8"),
    5: np.dtype("<u1"),
}
_DTYPES = ARRAY_DTYPES
_DTYPE_CODES = {dt.str: code for code, dt in _DTYPES.items()}


# ---------------------------------------------------------------------------
# Value tree
# ---------------------------------------------------------------------------

#: arrays / bytes at or above this many bytes travel as their own
#: zero-copy segment in the parts encoders; smaller ones are coalesced
#: into the adjacent scalar run (one tiny iovec per int is slower than
#: one memcpy).
_SEGMENT_BYTES = 1024


def _tail(parts: list) -> bytearray:
    """The growable scalar run at the end of ``parts``."""
    if not parts or not isinstance(parts[-1], bytearray):
        parts.append(bytearray())
    return parts[-1]


def _enc_value(v: Any, parts: list) -> None:
    """Append ``v``'s encoding to ``parts`` — bytearray runs for
    scalars/headers, zero-copy buffer segments for bulk arrays/bytes."""
    out = _tail(parts)
    if v is None:
        out.append(_T_NONE)
    elif v is True:
        out.append(_T_TRUE)
    elif v is False:
        out.append(_T_FALSE)
    elif isinstance(v, (int, np.integer)):
        out.append(_T_INT)
        out += struct.pack(">q", int(v))
    elif isinstance(v, (float, np.floating)):
        out.append(_T_FLOAT)
        out += struct.pack(">d", float(v))
    elif isinstance(v, str):
        raw = v.encode("utf-8")
        out.append(_T_STR)
        out += struct.pack(">I", len(raw))
        out += raw
    elif isinstance(v, (bytes, bytearray, memoryview)):
        raw = memoryview(v)
        if raw.ndim != 1 or raw.itemsize != 1:
            raw = raw.cast("B")
        out.append(_T_BYTES)
        out += struct.pack(">I", raw.nbytes)
        if raw.nbytes >= _SEGMENT_BYTES:
            parts.append(raw)  # zero-copy: the segment references v
        else:
            out += raw
    elif isinstance(v, np.ndarray):
        dt = v.dtype.newbyteorder("<")
        code = _DTYPE_CODES.get(dt.str)
        if code is None:
            raise WireError(f"unsupported array dtype {v.dtype}")
        if v.ndim > 255:
            raise WireError(f"array rank {v.ndim} too large")
        out.append(_T_ARRAY)
        out += struct.pack(">BB", code, v.ndim)
        for d in v.shape:
            out += struct.pack(">I", d)
        arr = np.ascontiguousarray(v, dtype=dt)  # no-op when already so
        if arr.nbytes >= _SEGMENT_BYTES and arr.ndim > 0:
            # scatter-gather segment straight over the array's memory —
            # works for read-only views (relayed chunks) too
            parts.append(memoryview(arr).cast("B"))
        else:
            out += arr.tobytes()
    elif isinstance(v, (list, tuple)):
        out.append(_T_LIST)
        out += struct.pack(">I", len(v))
        for item in v:
            _enc_value(item, parts)
    elif isinstance(v, dict):
        out.append(_T_DICT)
        out += struct.pack(">I", len(v))
        for k, item in v.items():
            _enc_value(k, parts)
            _enc_value(item, parts)
    else:
        raise WireError(f"unencodable value of type {type(v).__name__}")


def parts_nbytes(parts: Iterable) -> int:
    """Total byte length of a parts list (buffers of any itemsize)."""
    return sum(memoryview(p).nbytes for p in parts)


class _Cursor:
    """Bounds-checked reader over one frame body — any buffer object,
    including a ``memoryview`` at a non-zero offset into a larger
    receive buffer (the zero-copy decode contract, PROTOCOL.md §12)."""

    __slots__ = ("buf", "pos")

    def __init__(self, buf, pos: int = 0):
        mv = memoryview(buf)
        if mv.ndim != 1 or mv.itemsize != 1:
            mv = mv.cast("B")
        self.buf = mv
        self.pos = pos

    def take(self, n: int) -> memoryview:
        if n < 0 or self.pos + n > len(self.buf):
            raise WireDecodeError(
                f"truncated frame: need {n} bytes at offset {self.pos}, "
                f"have {len(self.buf) - self.pos}")
        chunk = self.buf[self.pos:self.pos + n]
        self.pos += n
        return chunk

    def u8(self) -> int:
        return self.take(1)[0]

    def u32(self) -> int:
        return struct.unpack(">I", self.take(4))[0]


def _dec_value(cur: _Cursor, copy_arrays: bool = True) -> Any:
    tag = cur.u8()
    if tag == _T_NONE:
        return None
    if tag == _T_TRUE:
        return True
    if tag == _T_FALSE:
        return False
    if tag == _T_INT:
        return struct.unpack(">q", cur.take(8))[0]
    if tag == _T_FLOAT:
        return struct.unpack(">d", cur.take(8))[0]
    if tag == _T_STR:
        return str(cur.take(cur.u32()), "utf-8")
    if tag == _T_BYTES:
        return bytes(cur.take(cur.u32()))
    if tag == _T_ARRAY:
        code, ndim = struct.unpack(">BB", cur.take(2))
        dt = _DTYPES.get(code)
        if dt is None:
            raise WireDecodeError(f"unknown array dtype code {code}")
        shape = tuple(cur.u32() for _ in range(ndim))
        count = 1
        for d in shape:  # python ints: no silent overflow on huge dims
            count *= d
        nbytes = count * dt.itemsize
        if nbytes > len(cur.buf) - cur.pos:
            raise WireDecodeError(
                f"array shape {shape} claims more bytes than the frame holds")
        # decode straight out of the frame buffer: a writable copy by
        # default (frombuffer views are read-only and the state machines
        # do arithmetic on received payloads) — or, for relay paths that
        # never mutate (copy_arrays=False), the view itself, which keeps
        # the frame buffer alive and is re-encoded as a zero-copy
        # segment on the way out
        arr = np.frombuffer(cur.buf, dtype=dt, count=count,
                            offset=cur.pos).reshape(shape)
        if copy_arrays:
            arr = arr.copy()
        else:
            # pin read-only even over writable source buffers
            # (bytearray receive buffers): relay views must never be
            # mutated, or the shared frame bytes corrupt under fan-out
            arr.flags.writeable = False
        cur.pos += nbytes
        return arr
    if tag == _T_LIST:
        return [_dec_value(cur, copy_arrays) for _ in range(cur.u32())]
    if tag == _T_DICT:
        n = cur.u32()
        out = {}
        for _ in range(n):
            k = _dec_value(cur, copy_arrays)
            out[k] = _dec_value(cur, copy_arrays)
        return out
    raise WireDecodeError(f"unknown value tag {tag}")


def encode_value(v: Any) -> bytes:
    return b"".join(encode_value_parts(v))


def encode_value_parts(v: Any) -> list:
    """Encode one value as a list of buffer segments (see module doc)."""
    parts: list = []
    _enc_value(v, parts)
    return parts


def decode_value(buf, copy_arrays: bool = True) -> Any:
    cur = _Cursor(buf)
    v = _dec_value(cur, copy_arrays)
    if cur.pos != len(cur.buf):
        raise WireDecodeError(
            f"{len(cur.buf) - cur.pos} trailing bytes after value")
    return v


# ---------------------------------------------------------------------------
# Requests / responses / frames
# ---------------------------------------------------------------------------


def encode_request_parts(op: str, kwargs: dict) -> list:
    """Request body as buffer segments: version, opcode, kwargs tree."""
    code = OPCODE.get(op)
    if code is None:
        raise WireError(f"unknown op {op!r}")
    parts: list = [bytearray(struct.pack(">BB", WIRE_VERSION, code))]
    _enc_value(dict(kwargs), parts)
    return parts


def encode_request(op: str, kwargs: dict) -> bytes:
    """Request body (unframed): version, opcode, kwargs value-tree."""
    return b"".join(encode_request_parts(op, kwargs))


def decode_request(body, copy_arrays: bool = True) -> Tuple[str, dict]:
    cur = _Cursor(body)
    version, code = struct.unpack(">BB", cur.take(2))
    if version != WIRE_VERSION:
        raise WireDecodeError(f"wire version {version} != {WIRE_VERSION}")
    op = OPNAME.get(code)
    if op is None:
        raise WireDecodeError(f"unknown opcode {code}")
    kwargs = _dec_value(cur, copy_arrays)
    if cur.pos != len(cur.buf):
        raise WireDecodeError("trailing bytes after request")
    if not isinstance(kwargs, dict):
        raise WireDecodeError("request kwargs must decode to a dict")
    return op, kwargs


_ST_OK = 0
_ST_ERR = 1


def encode_response_parts(payload: Any) -> list:
    parts: list = [bytearray(struct.pack(">BB", WIRE_VERSION, _ST_OK))]
    _enc_value(payload, parts)
    return parts


def encode_response(payload: Any) -> bytes:
    return b"".join(encode_response_parts(payload))


def encode_error(message: str) -> bytes:
    parts: list = [bytearray(struct.pack(">BB", WIRE_VERSION, _ST_ERR))]
    _enc_value(message, parts)
    return b"".join(parts)


def decode_response(body, copy_arrays: bool = True) -> Any:
    """Decode a response body; raises :class:`WireError` on error status."""
    cur = _Cursor(body)
    version, status = struct.unpack(">BB", cur.take(2))
    if version != WIRE_VERSION:
        raise WireDecodeError(f"wire version {version} != {WIRE_VERSION}")
    payload = _dec_value(cur, copy_arrays)
    if cur.pos != len(cur.buf):
        raise WireDecodeError("trailing bytes after response")
    if status == _ST_ERR:
        raise WireError(str(payload))
    if status != _ST_OK:
        raise WireDecodeError(f"unknown response status {status}")
    return payload


def encode_frame(body: bytes) -> bytes:
    if len(body) > MAX_FRAME:
        raise WireError(f"frame body {len(body)} exceeds MAX_FRAME")
    return struct.pack(">I", len(body)) + body


def encode_frame_parts(body_parts: list) -> list:
    """Frame a parts-encoded body for ``StreamWriter.writelines``: the
    u32 length prefix followed by the body segments, no concatenation —
    bulk segments go to the socket straight from where they already
    live (the §12 scatter-gather send)."""
    total = parts_nbytes(body_parts)
    if total > MAX_FRAME:
        raise WireError(f"frame body {total} exceeds MAX_FRAME")
    return [struct.pack(">I", total)] + body_parts


# ---------------------------------------------------------------------------
# Chunked array transfer (docs/PROTOCOL.md §6)
# ---------------------------------------------------------------------------

#: default chunk size in array elements — 1 Mi ring words = 4 MiB of
#: uint32 per chunk, comfortably inside MAX_FRAME with headers to spare.
DEFAULT_CHUNK_WORDS = 1 << 20

#: default number of get_chunk requests a downloading client keeps in
#: flight ahead of the chunk it is processing. 2 keeps the socket and
#: the combine busy simultaneously without triple-buffering memory;
#: picked by the prefetch-depth ablation in ``benchmarks/streaming.py``
#: (depth 1 leaves the link idle during each combine, depth 4 measured
#: no further gain on the localhost profile).
DEFAULT_PREFETCH_DEPTH = 2


#: words below which the chunk-granular streaming combine loses to the
#: buffered reassemble-then-combine path: per-chunk crypto calls, ack
#: round-trips and the aux connection cost a fixed overhead that short
#: vectors cannot amortize (BENCH_streaming.json measured the streamed
#: hop at x0.92 of buffered for V=4096 rounds). Clients resolve a
#: ``("stream", ...)`` yield to the buffered path below this threshold
#: unless the caller forces streaming; 16Ki words = 64 KiB of ring
#: payload, several chunks' worth at every benchmarked chunk size.
MIN_STREAM_WORDS = 1 << 14


def num_chunks(words: int, chunk_words: int) -> int:
    """Chunks needed for a ``words``-element vector (>= 1: a zero-length
    vector still travels as one empty chunk so metadata arrives)."""
    if chunk_words < 1:
        raise WireError(f"chunk_words must be >= 1, got {chunk_words}")
    return max(1, -(-words // chunk_words))


def chunk_slice(arr: np.ndarray, seq: int, chunk_words: int) -> np.ndarray:
    """Chunk ``seq`` of a flat array: elements [seq*cw, (seq+1)*cw).

    The last chunk is short when the length is not a multiple of
    ``chunk_words``; a length that is an exact multiple produces no
    empty trailing chunk (the boundary case tests pin this down).
    """
    if arr.ndim != 1:
        raise WireError(
            f"chunked transfer carries flat vectors, got rank {arr.ndim}")
    return arr[seq * chunk_words:(seq + 1) * chunk_words]


class ChunkAssembler:
    """Reassemble one chunked transfer; order-independent, duplicate-safe.

    Chunks may arrive in any order (each carries its own ``seq`` and the
    transfer-wide ``total``); a repeated ``seq`` overwrites (at-least-
    once delivery upstream is safe because chunk payloads are immutable
    within one transfer id). ``add`` returns True once every chunk is
    present; ``assemble`` concatenates in sequence order.
    """

    __slots__ = ("total", "chunks")

    def __init__(self, total: int):
        if total < 1:
            raise WireDecodeError(f"transfer total must be >= 1, got {total}")
        self.total = total
        self.chunks: dict = {}

    def add(self, seq: int, payload: np.ndarray) -> bool:
        if not 0 <= seq < self.total:
            raise WireDecodeError(
                f"chunk seq {seq} outside transfer of {self.total}")
        if not isinstance(payload, np.ndarray) or payload.ndim != 1:
            raise WireDecodeError("chunk payload must be a flat ndarray")
        self.chunks[seq] = payload
        return self.complete

    @property
    def complete(self) -> bool:
        return len(self.chunks) == self.total

    def assemble(self) -> np.ndarray:
        if not self.complete:
            missing = sorted(set(range(self.total)) - set(self.chunks))
            raise WireDecodeError(f"transfer missing chunks {missing[:8]}")
        return np.concatenate([self.chunks[s] for s in range(self.total)])


async def read_frame(reader) -> Optional[bytes]:
    """Read one length-prefixed frame from an asyncio StreamReader.

    Returns None on clean EOF at a frame boundary; raises
    WireDecodeError on oversize lengths (stream corruption) and
    ``asyncio.IncompleteReadError`` on mid-frame EOF.
    """
    try:
        header = await reader.readexactly(4)
    except asyncio.IncompleteReadError as e:
        if not e.partial:
            return None  # clean EOF between frames
        raise
    (length,) = struct.unpack(">I", header)
    if length > MAX_FRAME:
        raise WireDecodeError(f"frame length {length} exceeds MAX_FRAME")
    return await reader.readexactly(length)
