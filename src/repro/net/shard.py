"""Sharded broker runtime: N worker processes behind one port.

``BENCH_net_load.json`` showed the single asyncio loop — not the
aggregation math — is the wall between the protocol plane and the
ROADMAP's many-tenants story: one process tops out near 25 rounds/s at
S=4 while the batched engine plane absorbs hundreds. The paper's
controller is "a mere message broker" (§5, Appendix A), and a broker
shards trivially: session state is per-tenant (one ``Controller`` +
transfer buffers per session, no cross-session reads), so partitioning
*sessions* across worker processes needs no cross-process coordination
at all. This module does exactly that:

  * :class:`ShardBroker` — the per-worker broker: the unmodified
    :class:`~repro.net.broker.SafeBroker` loop plus shard-aware session
    addressing. Worker ``i`` of ``N`` allocates session ids with
    ``sid % N == i`` (the consistent hash is the id itself, stable
    across processes and restarts: :func:`shard_of`), answers
    ``get_shard_map``, and redirects any op for a session it does not
    own to the owner's direct port — the shared-nothing control
    channel is the static port map, distributed once at startup.
  * :class:`ShardedBroker` — the manager: spawns N worker processes,
    each binding the one shared port with ``SO_REUSEPORT`` (the kernel
    load-balances first contacts) plus a direct per-shard port
    (sessions are pinned to their owner; clients dial it directly once
    ``create_session`` reveals it). On platforms without
    ``SO_REUSEPORT`` — or with ``use_reuseport=False`` — a tiny
    accept-and-hand-off dispatcher serves the shared port instead,
    answering every first contact with the §12 redirect.

The engine plane stays unsharded (one device program wants one
process); sharded workers run the protocol + chunk planes only.
Workers are plain ``multiprocessing`` spawn targets — numpy-only, like
everything under ``repro.net``.
"""
from __future__ import annotations

import asyncio
import itertools
import multiprocessing
import socket
from typing import Optional, Tuple

from repro.net import wire
from repro.net.broker import SafeBroker
from repro.obs import MetricsRegistry

Addr = Tuple[str, int]


def shard_of(session: int, shards: int) -> int:
    """The worker that owns ``session`` — a pure function of the id, so
    every process (and the doc, §12) computes the same routing. Session
    ids are allocated by the owner with ``sid % shards == shard_index``,
    which makes the id itself the consistent hash."""
    if shards < 1:
        raise ValueError(f"shards must be >= 1, got {shards}")
    return int(session) % int(shards)


class ShardBroker(SafeBroker):
    """One shard worker: a SafeBroker that owns sessions with
    ``sid % num_shards == shard_index`` and redirects the rest.

    The redirect is a normal OK response ``{"status": "redirect",
    "shard": k, "port": p}`` (PROTOCOL.md §12): the client re-dials the
    owner's direct port and replays the request. ``create_session``
    responses carry this worker's ``shard``/``port`` so session-aware
    runtimes dial the owner directly and never bounce again."""

    def __init__(self, shard_index: int, num_shards: int, **broker_kw):
        if not 0 <= shard_index < num_shards:
            raise ValueError(
                f"shard_index {shard_index} outside 0..{num_shards - 1}")
        super().__init__(**broker_kw)
        self.shard_index = shard_index
        self.num_shards = num_shards
        # sid allocation IS the shard hash: count(i, N) ≡ i (mod N)
        self._sids = itertools.count(shard_index, num_shards)
        self.shard_ports: list = []
        self.direct_port: Optional[int] = None
        self.redirects = 0

    def set_shard_map(self, ports) -> None:
        """Install the cluster's direct-port map (one entry per shard),
        distributed by the manager once every worker has bound."""
        ports = [int(p) for p in ports]
        if len(ports) != self.num_shards:
            raise ValueError(
                f"port map has {len(ports)} entries for "
                f"{self.num_shards} shards")
        self.shard_ports = ports
        self.direct_port = ports[self.shard_index]

    def _shard_map(self) -> dict:
        # workers hold no fleet-liveness view (shared-nothing): their
        # map reports every peer alive; the MANAGER's dispatcher path
        # (ShardedBroker._dispatch_conn / get_shard_map) is the
        # authoritative source for shard_alive — it owns the worker
        # process handles (ISSUE 7 death visibility)
        return {"shards": self.num_shards, "shard": self.shard_index,
                "ports": list(self.shard_ports),
                "shard_alive": [True] * self.num_shards}

    async def _dispatch(self, op: str, kwargs: dict):
        sid = kwargs.get("session")
        if isinstance(sid, int) \
                and shard_of(sid, self.num_shards) != self.shard_index:
            owner = shard_of(sid, self.num_shards)
            self.redirects += 1
            self._m_redirects.inc()
            return {"status": "redirect", "shard": owner,
                    "port": self.shard_ports[owner]}
        res = await super()._dispatch(op, kwargs)
        if op == "create_session" and isinstance(res, dict):
            res = dict(res, shard=self.shard_index,
                       port=self.direct_port or 0)
        return res


async def _shard_serve(shard_index: int, num_shards: int, host: str,
                       shared_port: Optional[int], broker_kw: dict,
                       conn, stop_ev) -> None:
    broker = ShardBroker(shard_index, num_shards, **broker_kw)
    _, direct_port = await broker.start(host, 0)
    loop = asyncio.get_running_loop()
    conn.send(direct_port)
    ports = await loop.run_in_executor(None, conn.recv)
    broker.set_shard_map(ports)
    if shared_port is not None:
        # join the SO_REUSEPORT group on the shared port — the kernel
        # spreads first contacts across the workers' listeners
        await broker.add_listener(host, shared_port, reuse_port=True)
    conn.send("serving")
    try:
        while not stop_ev.is_set():
            await asyncio.sleep(0.05)
    finally:
        await broker.stop()


def _shard_worker_main(shard_index: int, num_shards: int, host: str,
                       shared_port: Optional[int], broker_kw: dict,
                       conn, stop_ev) -> None:
    """Spawn target for one worker process (module-level: picklable)."""
    asyncio.run(_shard_serve(shard_index, num_shards, host, shared_port,
                             broker_kw, conn, stop_ev))


class ShardedBroker:
    """Manager for an N-process sharded broker (protocol plane).

    ``start()`` spawns the workers, distributes the direct-port map and
    returns the one shared address clients dial first; ``stop()`` shuts
    the fleet down. Broker keyword args (``aggregation_timeout``,
    ``progress_timeout``, ``monitor_interval``) forward to every worker.

    ``use_reuseport=None`` auto-detects ``SO_REUSEPORT``; without it the
    shared port is served by an in-process accept-and-hand-off
    dispatcher that answers every request with the owner's redirect
    (create_session round-robins across shards). Either way, session
    traffic flows worker-direct after first contact — the manager is
    never on the data path.
    """

    def __init__(self, shards: int = 2, *, host: str = "127.0.0.1",
                 use_reuseport: Optional[bool] = None,
                 start_timeout: float = 60.0, **broker_kw):
        if shards < 1:
            raise ValueError(f"shards must be >= 1, got {shards}")
        self.shards = shards
        self.host = host
        self.use_reuseport = (hasattr(socket, "SO_REUSEPORT")
                              if use_reuseport is None else use_reuseport)
        self.start_timeout = start_timeout
        self.broker_kw = dict(broker_kw)
        self.shard_ports: list = []
        self.shared_port: Optional[int] = None
        self._procs: list = []
        self._pipes: list = []
        self._stop_ev = None
        self._reserve_sock: Optional[socket.socket] = None
        self._dispatcher: Optional[asyncio.AbstractServer] = None
        self._rr = itertools.count()
        # shard-death visibility (ISSUE 7): the manager owns the worker
        # process handles, so it is the one place liveness is observable
        # without a heartbeat protocol. Deaths are marked lazily on the
        # dispatcher/get_shard_map path; full rebalancing stays a future
        # ROADMAP item — dead shards' sessions error, they don't move.
        self.metrics = MetricsRegistry()
        self._m_shard_deaths = self.metrics.counter(
            "safe_shard_deaths_total")
        self._dead: set = set()

    def dead_shards(self) -> set:
        """Re-check worker liveness and return the dead shard indices.
        A ``stop()``-ed fleet reports whatever was marked before the
        teardown (the handles are gone)."""
        for i, proc in enumerate(self._procs):
            if i not in self._dead and not proc.is_alive():
                self._dead.add(i)
                self._m_shard_deaths.inc()
        return set(self._dead)

    @property
    def shard_deaths(self) -> int:
        return self._m_shard_deaths.value

    async def _recv(self, pipe, what: str):
        loop = asyncio.get_running_loop()
        ok = await loop.run_in_executor(None, pipe.poll, self.start_timeout)
        if not ok:
            raise RuntimeError(
                f"shard worker did not report {what} within "
                f"{self.start_timeout}s")
        return await loop.run_in_executor(None, pipe.recv)

    async def start(self) -> Addr:
        """Spawn the workers; returns the shared (host, port)."""
        shared_port = None
        if self.use_reuseport:
            # reserve the shared port with a bound-but-never-listening
            # SO_REUSEPORT socket: TCP routes connections only to
            # LISTENING members of the reuseport group, so this holds
            # the number without stealing a single connect
            sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
            sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEPORT, 1)
            sock.bind((self.host, 0))
            self._reserve_sock = sock
            shared_port = sock.getsockname()[1]
        # spawn (not fork): workers re-import repro.net fresh — no
        # inherited event loops, and safe under a JAX-initialized parent
        ctx = multiprocessing.get_context("spawn")
        self._stop_ev = ctx.Event()
        for i in range(self.shards):
            parent, child = ctx.Pipe()
            proc = ctx.Process(
                target=_shard_worker_main,
                args=(i, self.shards, self.host, shared_port,
                      self.broker_kw, child, self._stop_ev),
                daemon=True)
            proc.start()
            child.close()
            self._procs.append(proc)
            self._pipes.append(parent)
        ports = [int(await self._recv(p, "its direct port"))
                 for p in self._pipes]
        self.shard_ports = ports
        for pipe in self._pipes:
            pipe.send(ports)
        for pipe in self._pipes:  # all shared listeners up before we
            await self._recv(pipe, "serving")  # hand the addr out
        if not self.use_reuseport:
            self._dispatcher = await asyncio.start_server(
                self._dispatch_conn, self.host, 0)
            shared_port = self._dispatcher.sockets[0].getsockname()[1]
        self.shared_port = shared_port
        return self.host, shared_port

    async def _dispatch_conn(self, reader, writer) -> None:
        """The SO_REUSEPORT fallback: a dispatcher that owns no session
        state and hands every first contact off to its shard with the
        §12 redirect (create_session round-robins — the chosen worker
        then allocates a sid it owns)."""
        try:
            while True:
                body = await wire.read_frame(reader)
                if body is None:
                    break
                try:
                    op, kwargs = wire.decode_request(body,
                                                     copy_arrays=False)
                    sid = kwargs.get("session")
                    dead = self.dead_shards()
                    if op == "get_shard_map":
                        out = wire.encode_response_parts(
                            {"shards": self.shards, "shard": None,
                             "ports": list(self.shard_ports),
                             "shard_alive": [i not in dead
                                             for i in range(self.shards)],
                             "shard_deaths": len(dead)})
                    elif isinstance(sid, int):
                        owner = shard_of(sid, self.shards)
                        if owner in dead:
                            # fail fast instead of redirecting the
                            # client into a dead worker's port (a hang
                            # or a bare connection refusal): the session
                            # is gone with its shard — rebalancing is a
                            # future ROADMAP item
                            raise wire.WireError(
                                f"shard {owner} is dead; session {sid} "
                                f"is unavailable (no rebalancing)")
                        out = wire.encode_response_parts(
                            {"status": "redirect", "shard": owner,
                             "port": self.shard_ports[owner]})
                    else:
                        live = [i for i in range(self.shards)
                                if i not in dead]
                        if not live:
                            raise wire.WireError(
                                "every shard worker is dead")
                        owner = live[next(self._rr) % len(live)]
                        out = wire.encode_response_parts(
                            {"status": "redirect", "shard": owner,
                             "port": self.shard_ports[owner]})
                except wire.WireError as e:
                    out = [wire.encode_error(str(e))]
                writer.writelines(wire.encode_frame_parts(out))
                await writer.drain()
        except (asyncio.IncompleteReadError, ConnectionResetError,
                wire.WireDecodeError, asyncio.CancelledError):
            pass
        finally:
            writer.close()
            try:
                await writer.wait_closed()
            except Exception:  # noqa: BLE001
                pass

    async def stop(self) -> None:
        loop = asyncio.get_running_loop()
        if self._dispatcher is not None:
            self._dispatcher.close()
            await self._dispatcher.wait_closed()
            self._dispatcher = None
        if self._stop_ev is not None:
            self._stop_ev.set()
        for proc in self._procs:
            await loop.run_in_executor(None, proc.join, 5.0)
        for proc in self._procs:
            if proc.is_alive():
                proc.terminate()
                await loop.run_in_executor(None, proc.join, 1.0)
        self._procs.clear()
        for pipe in self._pipes:
            pipe.close()
        self._pipes.clear()
        if self._reserve_sock is not None:
            self._reserve_sock.close()
            self._reserve_sock = None
