"""Wire-plane learner runtime: the SAFE state machines over asyncio.

Drives the *identical* generator coroutines from
:mod:`repro.core.machines` — the ones the discrete-event kernel runs in
virtual time — over a real TCP transport to :class:`~repro.net.broker.
SafeBroker`, mapping each yield onto awaits:

  ("compute", seconds)          -> optional scaled ``asyncio.sleep``
  ("call", op, kwargs, nbytes)  -> one request/response RPC
  ("wait", kind, kwargs, nbytes, timeout)
                                -> long-poll RPC; the broker parks the
                                   request until data or timeout
  ("stream", ...), ("unmask", ...)
                                -> fused chunk-granular hops: receive+
                                   combine+post (non-initiator) and
                                   receive+unmask+publish (initiator),
                                   overlapped chunk-by-chunk; both lower
                                   to the plain wait when unchunked

Because the machines, the ``Controller`` and the round construction
(:func:`~repro.core.machines.build_round_machines`) are shared with the
sim, the published average here is bit-identical to the sim's for the
same seeds/topology, and the ``MessageStats`` counters still satisfy
§5's closed forms (asserted in ``tests/test_net.py``).

Payloads larger than ``chunk_words`` stream over the chunked transfer
plane (docs/PROTOCOL.md §6) transparently: the runtime splits uploads
into ``post_chunk`` frames and pulls downloads chunk-by-chunk via
``get_chunk`` — with one request kept in flight ahead of the chunk
being processed, and the broker relaying chunks downstream before the
upload completes, so chain hops overlap the way the §8 pipelined
schedule overlaps segments. The state machines never see chunks: the
logical consume still happens (with ``elide_payload`` so the bulk bytes
travel exactly once) and the reassembled array is injected into its
response, keeping bits and §5 message counts identical to the
unchunked path.

Faults are injected at this layer via :mod:`repro.net.faults`
interceptors — latency, request drops (with at-most-once retry: a
dropped frame never reached the broker), and crash/churn schedules.

:func:`run_federated_round_net` is the training entry point: each
learner runs a real local FedAvg step (an injected callable — this
module stays JAX-free; :func:`repro.train.federated.make_wire_federated`
builds the callables) and ships its model delta through the broker.
"""
from __future__ import annotations

import asyncio
import collections
import dataclasses
import itertools
import time
from typing import Any, Callable, Dict, Iterable, Mapping, Optional, Tuple

import numpy as np

from repro.core.bon_machines import build_bon_machines
from repro.core.bon_protocol import bon_expected_messages
from repro.core.costs import CostModel, EDGE
from repro.core.machines import LearnerCrypto, LearnerGen, build_round_machines
from repro.core.session import RoundCursor
from repro.net import wire
from repro.net.faults import DropPacket, Interceptor, LearnerCrashed
from repro.topology import RingTopology

Addr = Tuple[str, int]

#: auto-chunk threshold: payloads above this many elements stream even
#: when the caller didn't ask for chunking (4·8M = 32 MiB of uint32 —
#: half of MAX_FRAME, so headers/retries never graze the frame cap).
AUTO_CHUNK_WORDS = 8 << 20

#: adaptive chunk sizing targets this many chunks per payload — enough
#: for the §8 pipeline to overlap transfer and crypto, few enough that
#: per-chunk framing overhead stays negligible.
AUTO_CHUNK_TARGET = 8

_xfer_ids = itertools.count(1)


class ShardDeadError(wire.WireError):
    """The session's owning shard worker is gone (PROTOCOL.md §12):
    the dispatcher refused the session as unavailable, a redirect dialed
    a dead worker's port, or the worker's socket closed under a live
    request. Sessions never migrate (no rebalancing), so the one
    deterministic recovery is to create a FRESH session — which a
    surviving shard will own — and re-run the round (tested end-to-end
    in tests/test_shard.py; repro.net.loadgen tenants do exactly this).
    Subclasses :class:`~repro.net.wire.WireError`, so callers that treat
    shard death as any other broker failure keep working."""


def backoff_delay(attempt: int, *, base: float, cap: float = 0.5,
                  seed: int = 0) -> float:
    """Capped exponential backoff with deterministic jitter.

    ``base * 2**attempt`` capped at ``cap``, scaled by a multiplicative
    jitter in ``[0.5, 1.0)`` derived from a Knuth hash of
    ``(seed, attempt)`` — NOT from a global RNG, so fault-injection
    tests replay the exact same sleep schedule run after run. Shared by
    the drop-retry loop (:meth:`WireClient._send`) and the
    busy/retry-after loop (:meth:`WireClient.request`); ``seed`` is the
    node id, so co-tenant learners desynchronize instead of
    thundering-herding the broker on the same tick.
    """
    h = ((seed * 1_000_003 + attempt) * 2_654_435_761) & 0xFFFFFFFF
    return min(cap, base * (1 << min(attempt, 16))) * (0.5 + h / 2**33)


def auto_chunk_words(payload_words: int,
                     cost: Optional[CostModel] = None) -> int:
    """Derive a chunk size from the payload size (ISSUE 7 satellite),
    optionally floored by the link's bandwidth-delay product (ISSUE 9).

    Targets :data:`AUTO_CHUNK_TARGET` chunks per payload, clamped to a
    multiple of ``wire.MIN_STREAM_WORDS`` (so the streaming combine's
    small-chunk regression regime is never entered) and capped at
    ``wire.DEFAULT_CHUNK_WORDS`` (so no chunk approaches the frame
    limit). Payloads at or below one ``MIN_STREAM_WORDS`` quantum come
    back larger than the payload — i.e. unchunked, which is faster for
    small vectors (BENCH_streaming.json's small-n ablation).

    With a fitted :class:`~repro.core.costs.CostModel`, the target is
    additionally floored at the link's bandwidth-delay product —
    ``t_msg`` is the per-message round trip and ``1/t_byte`` the
    bandwidth, so ``t_msg/t_byte`` bytes (÷8 for the 8-byte fixed-point
    words) is the smallest chunk that keeps the pipe full: any smaller
    and each chunk's ack round-trip outweighs its transfer time, which
    is exactly the regime the 50 ms WAN profile's fixed-8192 ablation
    sits in (BENCH_streaming.json's WAN row). On the stock EDGE model
    the BDP floor (~1.7k words) sits below one ``MIN_STREAM_WORDS``
    quantum, so LAN-scale sizing is unchanged.
    """
    target = -(-int(payload_words) // AUTO_CHUNK_TARGET)  # ceil div
    if cost is not None:
        bdp_words = cost.t_msg / cost.t_byte / 8.0
        target = max(target, int(bdp_words))
    quanta = max(1, round(target / wire.MIN_STREAM_WORDS))
    return min(quanta * wire.MIN_STREAM_WORDS, wire.DEFAULT_CHUNK_WORDS)


def _resolve_chunk_words(chunk_words, payload_words: int,
                         cost: Optional[CostModel] = None):
    """The shared chunk-size defaulting rule: ``"auto"`` derives from
    the payload and the link's cost model (RTT-aware — see
    :func:`auto_chunk_words`); ``None`` stays unchunked until the
    payload clears ``AUTO_CHUNK_WORDS`` and then derives the same way
    (which at that scale is exactly ``wire.DEFAULT_CHUNK_WORDS`` — the
    legacy fixed default, so existing byte-level expectations hold); an
    int is taken as-is."""
    if chunk_words == "auto":
        return auto_chunk_words(payload_words, cost)
    if chunk_words is None and payload_words > AUTO_CHUNK_WORDS:
        return auto_chunk_words(payload_words, cost)
    return chunk_words


class WireClient:
    """One connection to the broker; one outstanding request at a time
    (the learner state machines are strictly sequential). The chunk
    loops below briefly keep a second request in flight — that is safe
    on the same connection because the broker answers frames in order."""

    def __init__(self, host: str, port: int, node: int = 0,
                 interceptor: Optional[Interceptor] = None,
                 retry_backoff: float = 0.02,
                 token: Optional[str] = None, ssl=None):
        self.host = host
        self.port = port
        self.node = node
        self.interceptor = interceptor
        self.retry_backoff = retry_backoff
        # transport hardening (PROTOCOL.md §15): the bearer token stamped
        # onto every session-addressed request. Learned automatically
        # from create_session / reset_round responses on this connection,
        # or set explicitly (a learner client carries its own node token)
        self.token = token
        #: per-node token grant from the last create_session/reset_round
        #: this client performed (the admin redistributes these)
        self.node_tokens: Optional[dict] = None
        # optional TLS: an ssl.SSLContext (or True for default verify)
        # handed to open_connection
        self._ssl = ssl
        self.bytes_sent = 0
        self.bytes_received = 0
        self.requests = 0
        self.chunk_frames = 0
        self.streamed_combines = 0
        self._reader: Optional[asyncio.StreamReader] = None
        self._writer: Optional[asyncio.StreamWriter] = None
        self._aux: Optional["WireClient"] = None

    async def connect(self) -> "WireClient":
        self._reader, self._writer = await asyncio.open_connection(
            self.host, self.port, ssl=self._ssl)
        return self

    def set_token(self, token: Optional[str]) -> None:
        """Adopt a (possibly rotated) bearer token — aux channel
        included, so a streaming combine started after a reset_round
        rotation authenticates on both connections."""
        self.token = token
        if self._aux is not None:
            self._aux.token = token

    @property
    def total_bytes_sent(self) -> int:
        """Bytes sent including a still-open aux channel (whose counters
        only fold into this client on close)."""
        return self.bytes_sent + (
            self._aux.bytes_sent if self._aux is not None else 0)

    async def aux(self) -> "WireClient":
        """Lazily-connected second connection to the same broker — the
        upload channel of the streaming combine (inbound chunks arrive
        on this connection while outbound chunks ship on the aux one, so
        neither direction queues behind the other's responses). Shares
        the node id and interceptor (churn schedules count ops across
        both), and folds its byte counters into this client on close."""
        if self._aux is None:
            self._aux = await WireClient(
                self.host, self.port, node=self.node,
                interceptor=self.interceptor,
                retry_backoff=self.retry_backoff,
                token=self.token, ssl=self._ssl).connect()
        return self._aux

    async def close(self) -> None:
        if self._aux is not None:
            aux, self._aux = self._aux, None
            await aux.close()
            self.bytes_sent += aux.bytes_sent
            self.bytes_received += aux.bytes_received
            self.requests += aux.requests
            self.chunk_frames += aux.chunk_frames
        if self._writer is not None:
            self._writer.close()
            try:
                await self._writer.wait_closed()
            except Exception:  # noqa: BLE001
                pass
            self._writer = None
            self._reader = None

    # -- low-level halves (chunk pipelining needs send/recv split) --------
    async def _send(self, op: str, kwargs: dict) -> None:
        """Fire one request frame, interceptor-gated (drops retry here —
        the frame never left, so resending is at-most-once). Sent as a
        scatter-gather parts list (PROTOCOL.md §12): bulk array payloads
        go to the socket from where they already live, uncopied."""
        if self.token is not None and "session" in kwargs \
                and "token" not in kwargs:
            # §15: stamp the bearer token onto every session-addressed
            # request (a copy — the caller's kwargs stay replayable)
            kwargs = dict(kwargs, token=self.token)
        framed = wire.encode_frame_parts(
            wire.encode_request_parts(op, kwargs))
        nbytes = wire.parts_nbytes(framed)
        attempt = 0
        while True:
            if self.interceptor is not None:
                try:
                    await self.interceptor.on_request(
                        self.node, op, nbytes)
                except DropPacket:
                    # capped exponential + deterministic jitter: a bursty
                    # drop schedule stops hammering the loop, and the
                    # schedule replays exactly (seeded by node id)
                    await asyncio.sleep(backoff_delay(
                        attempt, base=self.retry_backoff, seed=self.node))
                    attempt += 1
                    continue
            self._writer.writelines(framed)
            await self._writer.drain()
            self.bytes_sent += nbytes
            self.requests += 1
            return

    async def _recv(self, op: str) -> Any:
        try:
            resp = await wire.read_frame(self._reader)
        except (ConnectionResetError, asyncio.IncompleteReadError) as exc:
            # the worker died mid-request — deterministic surface
            # instead of a raw OSError escaping the learner task
            raise ShardDeadError(
                f"connection lost mid-{op} (worker dead?): {exc}") from exc
        if resp is None:
            raise ShardDeadError(
                f"broker closed the connection mid-{op}")
        self.bytes_received += len(resp) + 4
        if self.interceptor is not None:
            await self.interceptor.on_response(self.node, op, len(resp) + 4)
        try:
            return wire.decode_response(resp)
        except wire.WireError as exc:
            # the §12 dispatcher names a dead owner in its error — map
            # it onto the typed surface the recovery path switches on
            if "is dead" in str(exc):
                raise ShardDeadError(str(exc)) from exc
            raise

    async def redirect(self, port: int) -> None:
        """Move this client (and any aux channel) to another broker
        port — the §12 shard redirect. Subsequent requests, including
        the split-send chunk loops, dial the new port."""
        if self._aux is not None:
            aux, self._aux = self._aux, None
            await aux.close()
            self.bytes_sent += aux.bytes_sent
            self.bytes_received += aux.bytes_received
            self.requests += aux.requests
            self.chunk_frames += aux.chunk_frames
        if self._writer is not None:
            self._writer.close()
            try:
                await self._writer.wait_closed()
            except Exception:  # noqa: BLE001
                pass
        self.port = int(port)
        try:
            await self.connect()
        except OSError as exc:
            # a dead shard worker's port refuses/RSTs — surface a clear
            # error instead of letting the raw OSError (or a hang on a
            # half-open socket) escape to the learner task
            raise ShardDeadError(
                f"redirect to port {port} failed — shard worker "
                f"unreachable (dead?): {exc}") from exc

    async def request(self, op: str, kwargs: dict) -> Any:
        """One RPC. A DropPacket from the interceptor loses the frame
        *before* transmission; we back off and retry (safe: the broker
        never saw it). LearnerCrashed propagates to the runtime.

        A ``{"status": "redirect", "port": p}`` response (a sharded
        broker, PROTOCOL.md §12) reconnects to the owning shard and
        replays the request — sessions never migrate, so at most one
        hop settles every subsequent op onto the right worker.

        A ``{"status": "busy", "retry_after": t}`` response (admission
        control, PROTOCOL.md §13) sleeps at least ``retry_after`` —
        raised to the capped-exponential backoff as rejections repeat —
        then replays the same frame. The broker rejected it wholesale
        (nothing was buffered), so the replay is exact-once in effect."""
        await self._send(op, kwargs)
        res = await self._recv(op)
        hops = 0
        attempt = 0
        while isinstance(res, dict):
            if (res.get("status") == "redirect"
                    and res.get("port") is not None):
                hops += 1
                if hops > 4:
                    raise wire.WireError(
                        f"redirect loop for {op} (port {res.get('port')})")
                await self.redirect(int(res["port"]))
            elif res.get("status") == "busy":
                await asyncio.sleep(max(
                    float(res.get("retry_after") or 0.0),
                    backoff_delay(attempt, base=self.retry_backoff,
                                  seed=self.node)))
                attempt += 1
            else:
                break
            await self._send(op, kwargs)
            res = await self._recv(op)
        if op in ("create_session", "reset_round") \
                and isinstance(res, dict) and res.get("token") is not None:
            # §15: adopt the (possibly rotated) session token and hold
            # the per-node grant for the caller to redistribute
            self.token = res["token"]
            self.node_tokens = res.get("node_tokens")
            if self._aux is not None:
                self._aux.token = self.token
        return res

    # -- chunked transfer plane (docs/PROTOCOL.md §6) ---------------------
    async def post_chunked(self, op: str, kwargs: dict, payload_field: str,
                           session: int, chunk_words: int) -> None:
        """Upload one logical post as a chunk stream. Keeps one frame in
        flight ahead of the previous response, so the broker can relay
        chunk k downstream while chunk k+1 is still on this socket.

        An upload the broker supersedes or drops mid-stream (the round
        reset under us, or another active transfer owns the slot) is
        swallowed, not raised: the state machine's own
        ``check_aggregate`` / timeout path observes that the post never
        landed and recovers through the §5.3/§5.4 machinery — exactly
        as it would for an unchunked post lost to a reset.

        A chunk refused by admission control (``status: "busy"``,
        PROTOCOL.md §13 — only possible while this transfer has nothing
        buffered yet, since continuations are always admitted) is noted
        and replayed after the pipelined pass: the replay rides
        :meth:`request`, whose busy loop honors ``retry_after``, and
        once ONE chunk lands the rest are continuations."""
        arr = np.ascontiguousarray(kwargs[payload_field]).ravel()
        total = wire.num_chunks(arr.size, chunk_words)
        meta = {k: v for k, v in kwargs.items() if k != payload_field}
        xfer = next(_xfer_ids)
        busy: list = []

        def frame(seq: int) -> dict:
            return dict(meta, session=session, op=op, xfer=xfer, seq=seq,
                        total=total, chunk_words=chunk_words,
                        payload=wire.chunk_slice(arr, seq, chunk_words))

        await self._send("post_chunk", frame(0))
        for seq in range(1, total):
            await self._send("post_chunk", frame(seq))
            self.chunk_frames += 1
            res = await self._recv("post_chunk")  # ack of frame(seq-1)
            if res.get("status") == "busy":
                busy.append(seq - 1)
            elif res.get("superseded"):
                # drain the frame already in flight, then stop wasting
                # bytes — this upload lost its slot
                self.chunk_frames += 1
                await self._recv("post_chunk")
                return
        self.chunk_frames += 1
        res = await self._recv("post_chunk")  # ack of the last frame
        if res.get("status") == "busy":
            busy.append(total - 1)
        elif res.get("superseded"):
            return
        for seq in busy:
            res = await self.request("post_chunk", frame(seq))
            self.chunk_frames += 1
            if res.get("superseded"):
                return

    async def _chunk_stream(self, kind: str, kwargs: dict, session: int,
                            chunk_words: int, deadline: Optional[float],
                            depth: int, on_chunk=None, on_restart=None,
                            on_meta=None):
        """Shared inbound chunk pump: pull one logical array chunk-by-
        chunk with up to ``depth`` get_chunk requests in flight ahead of
        the chunk being processed (requests for the lowest missing seqs;
        responses come back in request order on this connection).

        ``on_chunk(seq, payload, from_node, total)`` fires (awaited) for
        every chunk first seen under the current transfer identity — the
        streaming combine's hook. An identity change mid-stream (the
        array was reposted / re-elected away) restarts assembly and
        fires ``on_restart()`` so a partially-combined buffer is
        abandoned, never mixed across identities. ``on_meta(res)`` fires
        (sync) with each raw chunk response of the current identity —
        the streaming unmask reads the broker's post-completion
        ``posted`` count off it.

        Returns ``(assembler, consume_guard_time)`` on completion or a
        ``{"status": "timeout"}`` dict when the deadline lapses."""
        loop = asyncio.get_running_loop()

        def remaining() -> Optional[float]:
            return None if deadline is None else deadline - loop.time()

        def chunk_req(seq: int) -> dict:
            return dict(kwargs, session=session, kind=kind, seq=seq,
                        words=chunk_words, timeout=remaining())

        async def drain(inflight) -> None:
            for _ in range(len(inflight)):
                await self._recv("get_chunk")
                self.chunk_frames += 1
            inflight.clear()

        asm: Optional[wire.ChunkAssembler] = None
        xid: Any = None
        tid: Any = None  # consume-guard timestamp of the current identity
        inflight: collections.deque = collections.deque()
        cursor = 1  # lowest seq never requested under the current identity
        await self._send("get_chunk", chunk_req(0))
        inflight.append(0)
        while True:
            rem = remaining()
            if rem is not None and rem <= 0:
                await drain(inflight)  # each request carried a deadline
                return {"status": "timeout"}
            res = await self._recv("get_chunk")
            inflight.popleft()
            self.chunk_frames += 1
            if res.get("status") == "timeout":
                await drain(inflight)
                return res
            if (asm is None or res.get("xfer") != xid
                    or int(res["total"]) != asm.total):
                # first chunk — or the transfer identity changed under
                # us (the array was reposted / re-elected away):
                # restart assembly rather than mix two transfers
                restarted = asm is not None
                asm = wire.ChunkAssembler(int(res["total"]))
                xid = res.get("xfer")
                tid = None
                cursor = 0
                if restarted and on_restart is not None:
                    on_restart()
            if on_meta is not None:
                on_meta(res)
            if res.get("time") is not None:
                tid = res["time"]
            seq = int(res["seq"])
            fresh = seq not in asm.chunks
            done = asm.add(seq, res["payload"])
            if fresh and on_chunk is not None:
                await on_chunk(seq, res["payload"], res.get("from_node"),
                               asm.total)
            if done:
                if inflight:  # stale prefetches from before a restart
                    await drain(inflight)
                return asm, tid
            # top the pipeline up to `depth`; the ascending cursor finds
            # the lowest unrequested chunk in O(1) amortized (it only
            # rewinds on an identity restart, where the in-flight checks
            # keep requests unique), and each request rides ahead of the
            # broker-side wait (and, in the streaming combine, of the
            # chunk's crypto)
            while len(inflight) < depth:
                while cursor < asm.total and (cursor in asm.chunks
                                              or cursor in inflight):
                    cursor += 1
                if cursor >= asm.total:
                    break
                await self._send("get_chunk", chunk_req(cursor))
                inflight.append(cursor)
                cursor += 1

    async def get_chunked(self, kind: str, kwargs: dict, session: int,
                          chunk_words: int, deadline: Optional[float],
                          depth: int = wire.DEFAULT_PREFETCH_DEPTH) -> Any:
        """Pull one logical array as a chunk stream, then issue the
        logical consume (``elide_payload=True``) and inject the
        reassembled array into its response. Returns the consume
        response, or ``{"status": "timeout"}`` when the deadline lapses
        mid-stream (matching the plain long-poll contract)."""
        got = await self._chunk_stream(kind, kwargs, session, chunk_words,
                                       deadline, depth)
        if isinstance(got, dict):
            return got  # timeout
        asm, tid = got
        loop = asyncio.get_running_loop()
        # the logical consume, guarded by the streamed entry's
        # timestamp: the broker refuses to consume (and elide) any
        # OTHER posting — a reset racing us parks into the normal
        # timeout path instead of corrupting the round
        final = await self.request(kind, dict(
            kwargs, session=session, elide_payload=True,
            expect_time=tid,
            timeout=None if deadline is None else deadline - loop.time()))
        if final.get("status") == "timeout":
            return final
        field = "aggregate" if kind == "get_aggregate" else "average"
        return dict(final, **{field: asm.assemble()})

    async def stream_combine(self, skwargs: dict, session: int,
                             chunk_words: int, deadline: Optional[float],
                             depth: int = wire.DEFAULT_PREFETCH_DEPTH,
                             round_tag: Optional[int] = None) -> Any:
        """The fused §5.1.2 hop: pull the inbound aggregate chunk-by-
        chunk and, per chunk, run the machine's combine closure
        (seekable-pad decrypt + add + re-encrypt) and ship the result
        downstream via ``post_chunk`` on the aux connection — chunk k's
        crypto and upload overlap chunk k+1's transfer, and the broker
        relays uploaded chunks onward before this upload completes (§8's
        pipelined schedule end-to-end on the wire).

        Resolves the machine's ``("stream", ...)`` yield with
        ``{"status": "streamed", "combined": <plaintext partial>,
        "uploaded": bool, ...consume fields...}``. An upstream identity
        change restarts the combine under a fresh upload xfer (the
        broker replaces our own older stream; stale frames can't clobber
        it); a superseded upload degrades to ``uploaded=False`` and the
        machine posts the whole vector itself. Timeouts match the plain
        long-poll contract."""
        node = skwargs["node"]
        group = skwargs["group"]
        to_node = skwargs["to_node"]
        combine = skwargs["combine"]
        up = await self.aux()
        loop = asyncio.get_running_loop()

        rkw = {} if round_tag is None else {"round": round_tag}
        st = {"xfer": next(_xfer_ids), "dead": False, "complete": False,
              "sent": 0}
        acks: collections.deque = collections.deque()  # xfer per sent frame
        combs: Dict[int, np.ndarray] = {}

        async def drain_ack() -> None:
            ack = await up._recv("post_chunk")
            up.chunk_frames += 1
            xf = acks.popleft()
            if xf != st["xfer"]:
                return  # ack of an abandoned stream
            if ack.get("superseded") or ack.get("status") == "busy":
                # lost the slot — or admission control refused the
                # stream (§13). Either way stop uploading; the machine
                # falls back to posting the whole vector itself, and
                # THAT path retries busy via request()
                st["dead"] = True
            elif ack.get("complete"):
                st["complete"] = True

        async def on_chunk(seq, payload, src, total) -> None:
            out, comb = combine(seq * chunk_words, payload, src)
            combs[seq] = comb
            if st["dead"]:
                return
            await up._send("post_chunk", dict(
                rkw, session=session, op="post_aggregate", xfer=st["xfer"],
                seq=seq, total=total, chunk_words=chunk_words,
                from_node=node, to_node=to_node, group=group,
                payload=out))
            acks.append(st["xfer"])
            st["sent"] += 1
            while len(acks) > depth:
                await drain_ack()

        def on_restart() -> None:
            # upstream identity changed under a partial combine: abandon
            # it — fresh upload xfer (replaces our older stream at the
            # broker), fresh plaintext buffer
            combs.clear()
            st.update(xfer=next(_xfer_ids), dead=False, complete=False,
                      sent=0)

        got = await self._chunk_stream(
            "get_aggregate", dict(rkw, node=node, group=group), session,
            chunk_words, deadline, depth, on_chunk=on_chunk,
            on_restart=on_restart)
        while acks:
            await drain_ack()
        if isinstance(got, dict):
            return got  # timeout (partial upload is left to go stale)
        asm, tid = got
        uploaded = (st["complete"] and not st["dead"]
                    and st["sent"] == asm.total)
        # the counted consume of the inbound posting, expect_time-guarded
        # exactly like the buffered path
        final = await self.request("get_aggregate", dict(
            rkw, node=node, group=group, session=session,
            elide_payload=True, expect_time=tid,
            timeout=None if deadline is None else deadline - loop.time()))
        if final.get("status") == "timeout":
            return final
        if uploaded:
            self.streamed_combines += 1
        combined = np.concatenate([combs[s] for s in range(asm.total)])
        return dict(final, status="streamed", combined=combined,
                    uploaded=uploaded)

    async def unmask_stream(self, ukwargs: dict, session: int,
                            chunk_words: int, deadline: Optional[float],
                            depth: int = wire.DEFAULT_PREFETCH_DEPTH,
                            round_tag: Optional[int] = None) -> Any:
        """The fused §5.1.1 initiator tail: pull the final hop's
        aggregate chunk-by-chunk and, per chunk, run the machine's
        unmask closure (hop decrypt + subtract the R slice + decode) —
        then, the moment the posting's contributor count is known
        (``posted`` rides the broker's post-completion chunk
        responses), publish the decoded average chunk-by-chunk via
        ``post_chunk`` on the aux connection. Chunk k's unmask and
        publish overlap chunk k+1's last hop, so the round's published
        average starts shipping while the tail of the aggregate is
        still on the wire — the §8 pipeline extended through the
        initiator's own endpoint.

        Resolves the machine's ``("unmask", ...)`` yield with
        ``{"status": "unmasked", "decoded": <plaintext>, "posted": k,
        "published": bool, ...consume fields...}``. Each published
        average chunk is ``decoded_chunk / posted`` — elementwise, so
        the assembled average is bit-identical to the machine's own
        whole-vector ``dec / posted``. A superseded or refused
        publication (or a ``posted`` count that only arrives with the
        consume — e.g. a round still parked behind the §11 window)
        degrades to ``published=False`` and the machine posts the whole
        average itself; an upstream identity change restarts the decode
        under a fresh upload xfer. Timeouts match the plain long-poll
        contract (the machine's §5.4 election path)."""
        node = ukwargs["node"]
        group = ukwargs["group"]
        unmask = ukwargs["unmask"]
        up = await self.aux()
        loop = asyncio.get_running_loop()

        rkw = {} if round_tag is None else {"round": round_tag}
        st = {"xfer": next(_xfer_ids), "dead": False, "complete": False,
              "sent": 0, "posted": None, "total": None}
        acks: collections.deque = collections.deque()  # xfer per sent frame
        decs: Dict[int, np.ndarray] = {}
        shipped: set = set()

        async def drain_ack() -> None:
            ack = await up._recv("post_chunk")
            up.chunk_frames += 1
            xf = acks.popleft()
            if xf != st["xfer"]:
                return  # ack of an abandoned stream
            if ack.get("superseded") or ack.get("status") == "busy":
                st["dead"] = True
            elif ack.get("complete"):
                st["complete"] = True

        async def ship(seq: int) -> None:
            await up._send("post_chunk", dict(
                rkw, session=session, op="post_average", xfer=st["xfer"],
                seq=seq, total=st["total"], chunk_words=chunk_words,
                node=node, group=group, weight_avg=None,
                payload=decs[seq] / st["posted"]))
            acks.append(st["xfer"])
            st["sent"] += 1
            shipped.add(seq)
            while len(acks) > depth:
                await drain_ack()

        def on_meta(res: dict) -> None:
            if res.get("posted") is not None:
                st["posted"] = int(res["posted"])

        async def on_chunk(seq, payload, src, total) -> None:
            st["total"] = total
            decs[seq] = unmask(seq * chunk_words, payload, src)
            if st["dead"] or st["posted"] is None:
                # the upstream upload hasn't completed (its logical post
                # hasn't executed), so the contributor count isn't known
                # yet — decode now, ship the backlog when it is
                return
            for s in sorted(decs):
                if s not in shipped and not st["dead"]:
                    await ship(s)

        def on_restart() -> None:
            decs.clear()
            shipped.clear()
            st.update(xfer=next(_xfer_ids), dead=False, complete=False,
                      sent=0, posted=None, total=None)

        got = await self._chunk_stream(
            "get_aggregate", dict(rkw, node=node, group=group), session,
            chunk_words, deadline, depth, on_chunk=on_chunk,
            on_restart=on_restart, on_meta=on_meta)
        while acks:
            await drain_ack()
        if isinstance(got, dict):
            return got  # timeout (a partial publication goes stale)
        asm, tid = got
        # the counted consume of the inbound posting, expect_time-guarded
        # exactly like the buffered path
        final = await self.request("get_aggregate", dict(
            rkw, node=node, group=group, session=session,
            elide_payload=True, expect_time=tid,
            timeout=None if deadline is None else deadline - loop.time()))
        if final.get("status") == "timeout":
            return final
        posted = st["posted"]
        if posted is None:
            posted = int(final["posted"])
        published = (st["complete"] and not st["dead"]
                     and st["sent"] == asm.total)
        decoded = np.concatenate([decs[s] for s in range(asm.total)])
        return dict(final, status="unmasked", decoded=decoded,
                    posted=posted, published=published)

    # -- engine plane over the chunk ops (oversized payloads) -------------
    async def submit_session_chunked(self, kwargs: dict,
                                     chunk_words: int) -> dict:
        """``submit_session`` whose ``values`` ride the §6 chunk plane —
        for contribution matrices beyond one frame (the broker reshapes
        the reassembled flat vector to its engine's (n, V)). Returns
        ``{"sid": ...}`` like the plain op."""
        values = np.ascontiguousarray(
            np.asarray(kwargs["values"], np.float32)).ravel()
        total = wire.num_chunks(values.size, chunk_words)
        meta = {k: v for k, v in kwargs.items() if k != "values"}
        xfer = next(_xfer_ids)
        sid = None
        for seq in range(total):
            res = await self.request("post_chunk", dict(
                meta, op="submit_session", node=self.node, xfer=xfer,
                seq=seq, total=total, chunk_words=chunk_words,
                payload=wire.chunk_slice(values, seq, chunk_words)))
            self.chunk_frames += 1
            if res.get("complete"):
                sid = res["sid"]
        return {"sid": sid}

    async def wait_session_chunked(self, sid: int, *,
                                   timeout: Optional[float] = None,
                                   chunk_words: int =
                                   wire.DEFAULT_CHUNK_WORDS) -> dict:
        """``wait_session`` whose results ride the §6 chunk plane — for
        rounds × V beyond one frame. The elided handshake carries
        completion; the flat round-major results stream as get_chunk
        frames and are reshaped back to per-round arrays here.
        ``timeout`` bounds the WHOLE call (one shared deadline, like
        every other long-poll), not each chunk."""
        loop = asyncio.get_running_loop()
        deadline = None if timeout is None else loop.time() + float(timeout)

        def remaining() -> Optional[float]:
            return None if deadline is None else deadline - loop.time()

        final = await self.request("wait_session", {
            "sid": sid, "timeout": timeout, "elide_results": True})
        if final.get("status") != "done":
            return final
        rounds = int(final["rounds"])
        parts, total, seq = [], None, 0
        while total is None or seq < total:
            rem = remaining()
            if rem is not None and rem <= 0:
                return {"status": "timeout"}
            res = await self.request("get_chunk", {
                "kind": "wait_session", "sid": sid, "seq": seq,
                "words": chunk_words, "timeout": rem})
            if res.get("status") == "timeout":
                return res
            self.chunk_frames += 1
            total = int(res["total"])
            parts.append(res["payload"])
            seq += 1
        flat = (np.concatenate(parts) if parts
                else np.empty(0, np.float32))
        V = flat.size // rounds if rounds else 0
        return {"status": "done", "rounds": rounds,
                "results": [flat[r * V:(r + 1) * V] for r in range(rounds)]}


async def drive_learner(gen: LearnerGen, client: WireClient, session: int,
                        *, aggregation_timeout: float,
                        timeout_scale: float = 1.0,
                        compute_scale: float = 0.0,
                        chunk_words: Optional[int] = None,
                        payload_words: Optional[int] = None,
                        prefetch_depth: Optional[int] = None,
                        stream: Optional[bool] = None,
                        round_tag: Optional[int] = None) -> Any:
    """Run one state machine to completion over the wire.

    ``timeout`` mapping for ``wait`` yields: ``"aggregation"`` becomes
    the session's wall-clock aggregation timeout, numeric (virtual
    seconds) scale by ``timeout_scale``, ``None`` waits forever.
    ``compute_scale`` turns the machines' virtual compute costs into
    wall sleeps (0 = infinitely fast learners; the default, since the
    wire plane measures transport, not the cost model).

    With ``chunk_words`` set and ``payload_words`` (the round's vector
    length, weighted word included) exceeding it, array traffic takes
    the chunked plane; the machines are driven unchanged either way.
    ``prefetch_depth`` caps in-flight chunk requests (default
    ``wire.DEFAULT_PREFETCH_DEPTH``). ``stream`` governs the chunk-
    granular combine for the machine's ``("stream", ...)`` yield:
    ``None`` (default) streams only when the payload clears
    ``wire.MIN_STREAM_WORDS`` — below that the per-chunk overhead loses
    to the buffered path (the small-n regression in
    BENCH_streaming.json) and the whole chunk plane is bypassed (the
    payload rides one frame anyway); ``True`` forces streaming,
    ``False`` disables it but keeps the buffered chunk plane (the
    ablation baseline of ``benchmarks/streaming.py``). Either path is
    bit- and count-identical.

    ``round_tag`` stamps every logical op and chunk frame with a §11
    round number: the broker parks ops tagged for a future round until
    ``advance_round`` opens it, while tagged chunk frames buffer (and
    relay) within the in-flight window — the cross-round pipelining
    used by :meth:`PersistentNetSession.run_rounds_pipelined`.
    """
    chunked = (chunk_words is not None and payload_words is not None
               and payload_words > chunk_words)
    stream_auto = stream is None
    if stream is None:
        stream = (payload_words is not None
                  and payload_words >= wire.MIN_STREAM_WORDS)
    if (chunked and stream_auto and not stream
            and payload_words * 8 + 65536 <= wire.MAX_FRAME):
        # small-payload fast path (ISSUE 9 satellite): below the
        # streaming threshold the chunk plane only adds per-chunk
        # get_chunk/consume handshakes (the x0.81 small-n row in
        # BENCH_streaming.json), and a payload this size rides one
        # frame with room to spare — skip chunking wholesale. An
        # explicit ``stream=False`` keeps the buffered chunk plane (the
        # ablation baseline and the chunk-plane unit tests).
        chunked = False
    depth = (wire.DEFAULT_PREFETCH_DEPTH if prefetch_depth is None
             else max(1, int(prefetch_depth)))
    loop = asyncio.get_running_loop()

    def tag(kw: dict) -> dict:
        return kw if round_tag is None else dict(kw, round=round_tag)

    def wall_timeout(timeout) -> Optional[float]:
        if timeout == "aggregation":
            return aggregation_timeout
        if timeout is None:
            return None
        return float(timeout) * timeout_scale

    send_value = None
    while True:
        try:
            item = gen.send(send_value)
        except StopIteration as stop:
            return stop.value
        kind = item[0]
        if kind == "compute":
            if compute_scale > 0.0:
                await asyncio.sleep(item[1] * compute_scale)
            send_value = None
        elif kind == "call":
            _, op, kwargs, _nbytes = item
            payload_field = {"post_aggregate": "payload",
                             "post_average": "average"}.get(op)
            arr = kwargs.get(payload_field) if payload_field else None
            if (chunked and isinstance(arr, np.ndarray)
                    and arr.size > chunk_words):
                await client.post_chunked(op, tag(kwargs), payload_field,
                                          session, chunk_words)
                send_value = None
            else:
                send_value = await client.request(
                    op, dict(tag(kwargs), session=session))
        elif kind == "wait":
            _, wkind, kwargs, _nbytes, timeout = item
            wall = wall_timeout(timeout)
            if chunked and wkind in ("get_aggregate", "get_average"):
                deadline = None if wall is None else loop.time() + wall
                send_value = await client.get_chunked(
                    wkind, tag(kwargs), session, chunk_words, deadline,
                    depth)
            else:
                send_value = await client.request(
                    wkind, dict(tag(kwargs), session=session,
                                timeout=wall))
        elif kind == "stream":
            # the fused receive+combine+post hop: stream when the
            # payload is chunked, otherwise resolve as the plain
            # get_aggregate wait (the machine falls back to the
            # whole-vector combine — identical bits and counts)
            _, skwargs, _nbytes, timeout = item
            wall = wall_timeout(timeout)
            wait_kw = tag(dict(node=skwargs["node"],
                               group=skwargs["group"]))
            if chunked and stream:
                deadline = None if wall is None else loop.time() + wall
                send_value = await client.stream_combine(
                    skwargs, session, chunk_words, deadline, depth,
                    round_tag=round_tag)
            elif chunked:
                deadline = None if wall is None else loop.time() + wall
                send_value = await client.get_chunked(
                    "get_aggregate", wait_kw, session, chunk_words,
                    deadline, depth)
            else:
                send_value = await client.request(
                    "get_aggregate",
                    dict(wait_kw, session=session, timeout=wall))
        elif kind == "unmask":
            # the fused receive+unmask+publish initiator tail: stream
            # when the payload is chunked and unweighted (the weighted
            # average needs the decoded vector's trailing weight word
            # before any element divides), otherwise resolve as the
            # plain get_aggregate wait — the machine falls back to the
            # whole-vector unmask, identical bits and counts either way
            _, ukwargs, _nbytes, timeout = item
            wall = wall_timeout(timeout)
            wait_kw = tag(dict(node=ukwargs["node"],
                               group=ukwargs["group"]))
            if chunked and stream and not ukwargs.get("weighted"):
                deadline = None if wall is None else loop.time() + wall
                send_value = await client.unmask_stream(
                    ukwargs, session, chunk_words, deadline, depth,
                    round_tag=round_tag)
            elif chunked:
                deadline = None if wall is None else loop.time() + wall
                send_value = await client.get_chunked(
                    "get_aggregate", wait_kw, session, chunk_words,
                    deadline, depth)
            else:
                send_value = await client.request(
                    "get_aggregate",
                    dict(wait_kw, session=session, timeout=wall))
        else:
            raise ValueError(f"unknown yield {item!r}")


async def _drive_round_machines(machines: Dict[int, LearnerGen], acquire,
                                release, session: int, *,
                                aggregation_timeout: float,
                                timeout_scale: float, compute_scale: float,
                                chunk_words: Optional[int],
                                payload_words: int,
                                prefetch_depth: Optional[int],
                                stream: Optional[bool],
                                round_tag: Optional[int] = None):
    """Drive one round's machines to completion, one task per live
    learner — the round core shared by :func:`run_safe_round_net` and
    :class:`PersistentNetSession`. ``acquire(node)`` supplies the node's
    connected client; ``release(node, client, crashed)`` disposes or
    retains it afterwards. Returns ``(wall_s, crashed_nodes,
    streamed_combines)``; the first learner exception (other than a
    churn crash) re-raises after every task settled."""
    crashed: list = []
    streamed = [0]

    async def one(node: int, gen: LearnerGen) -> Any:
        client = await acquire(node)
        before = client.streamed_combines
        node_crashed = False
        try:
            return await drive_learner(
                gen, client, session,
                aggregation_timeout=aggregation_timeout,
                timeout_scale=timeout_scale, compute_scale=compute_scale,
                chunk_words=chunk_words, payload_words=payload_words,
                prefetch_depth=prefetch_depth, stream=stream,
                round_tag=round_tag)
        except LearnerCrashed:
            node_crashed = True
            crashed.append(node)  # mid-round churn: learner just stops
            return None
        finally:
            streamed[0] += client.streamed_combines - before
            await release(node, client, node_crashed)

    t0 = time.perf_counter()
    # return_exceptions: let every learner settle (each releases its
    # own connection) instead of abandoning running tasks on the first
    # error, then surface the first failure
    settled = await asyncio.gather(
        *(one(node, gen) for node, gen in machines.items()),
        return_exceptions=True)
    for r in settled:
        if isinstance(r, BaseException):
            raise r
    return time.perf_counter() - t0, tuple(crashed), streamed[0]


@dataclasses.dataclass
class NetResult:
    """Wire-plane mirror of :class:`repro.core.protocol.SimResult` —
    ``stats`` is the broker's MessageStats as a dict (plus totals and
    the chunk-plane frame counters)."""

    average: Optional[np.ndarray]
    weight_avg: Optional[float]
    wall_time: float
    stats: Dict[str, int]
    bytes_sent: int
    monitor_reposts: int
    initiator_elections: int
    crashed_nodes: tuple = ()
    #: hops that ran the chunk-granular streaming combine end-to-end
    #: (inbound decrypt+add+re-encrypt per chunk, outbound landed)
    streamed_combines: int = 0


async def run_safe_round_net(
    values: np.ndarray,
    addr: Addr,
    *,
    mode: str = "safe",
    subgroups: int = 1,
    failed_nodes: Iterable[int] = (),
    initiator_fails: bool = False,
    weights: Optional[np.ndarray] = None,
    cost: CostModel = EDGE,
    aggregation_timeout: Optional[float] = None,
    symmetric_only: bool = False,
    scale_bits: int = 16,
    provisioning_seed: int = 0xC0FFEE,
    learner_master: int = 0x5EED,
    counter: int = 0,
    interceptor: Optional[Interceptor] = None,
    timeout_scale: float = 1.0,
    compute_scale: float = 0.0,
    chunk_words: Optional[int] = None,
    prefetch_depth: Optional[int] = None,
    stream: Optional[bool] = None,
    ssl=None,
) -> NetResult:
    """One full aggregation round over the wire — the transport twin of
    :func:`repro.core.protocol.run_safe_round` (same signature spirit,
    wall-clock timeouts). Builds the same topology, elects the same
    initiators, constructs the same machines, then runs one asyncio
    task + one TCP connection per live learner against the broker at
    ``addr``.

    ``failed_nodes`` are dead before the round (their clients never
    start — discovered by the broker's monitor, §5.3). ``mode`` must be
    'safe' or 'saf': INSEC needs a parsing, averaging controller, which
    the wire broker deliberately is not (the paper's point).

    ``chunk_words`` enables the chunked transfer plane for payloads
    longer than that many elements; by default it switches on
    automatically once the payload could not safely fit one frame
    (AUTO_CHUNK_WORDS). Pass the string ``"auto"`` to derive the chunk
    size from the payload instead (:func:`auto_chunk_words` — ~8
    chunks, clamped to ``MIN_STREAM_WORDS`` multiples). Chunked hops run the chunk-granular streaming
    combine (crypto overlapped with transfer inside each hop) when the
    payload clears ``wire.MIN_STREAM_WORDS`` — ``stream=True`` forces
    it, ``stream=False`` disables it (see :func:`drive_learner`);
    ``prefetch_depth`` caps each learner's in-flight chunk requests
    (default ``wire.DEFAULT_PREFETCH_DEPTH``).

    Against a sharded broker (:class:`repro.net.shard.ShardedBroker`)
    the ``create_session`` response names the owning shard's direct
    port; every learner dials it straight away, so the round never pays
    a redirect bounce past first contact.
    """
    if mode not in ("safe", "saf"):
        raise ValueError(f"wire plane runs 'safe'/'saf', got {mode!r}")
    values = np.asarray(values, np.float32)
    n, V = values.shape
    payload_words = V + 1 if weights is not None else V
    chunk_words = _resolve_chunk_words(chunk_words, payload_words, cost)
    topo = RingTopology(n, subgroups)
    topo.validate_privacy()
    groups = topo.group_chains(node_base=1)
    initiators = {r + 1 for r in topo.elect_initiators()}
    failed = set(failed_nodes)

    machines = build_round_machines(
        values, topo, groups, initiators, mode=mode, weights=weights,
        cost=cost, symmetric_only=symmetric_only, scale_bits=scale_bits,
        provisioning_seed=provisioning_seed, learner_master=learner_master,
        counter=counter, subgroups=subgroups, failed=failed,
        initiator_fails=initiator_fails)

    admin = await WireClient(*addr, ssl=ssl).connect()
    sid = None
    try:
        created = await admin.request("create_session", {
            "groups": groups, "aggregation_timeout": aggregation_timeout})
        sid = created["session"]
        wall_agg = created["aggregation_timeout"]
        # sharded broker: the session lives on one worker — dial its
        # direct port so learners land on the owner without a bounce
        learner_addr = ((addr[0], int(created["port"]))
                        if created.get("port") else addr)

        async def acquire(node: int) -> WireClient:
            # §15: each learner authenticates as ITSELF — its node token
            # from the create_session grant (the broker refuses a post
            # or consume under any other node's identity)
            tok = (admin.node_tokens or {}).get(node, admin.token)
            return await WireClient(*learner_addr, node=node,
                                    interceptor=interceptor,
                                    token=tok, ssl=ssl).connect()

        async def release(node: int, client: WireClient, _crashed: bool):
            await client.close()  # folds the aux channel's counters in
            admin.bytes_sent += client.bytes_sent

        wall, crashed, streamed = await _drive_round_machines(
            machines, acquire, release, sid,
            aggregation_timeout=wall_agg, timeout_scale=timeout_scale,
            compute_scale=compute_scale, chunk_words=chunk_words,
            payload_words=payload_words, prefetch_depth=prefetch_depth,
            stream=stream)

        stats = await admin.request("get_stats", {"session": sid})
        final = await admin.request("peek_average", {"session": sid})
    finally:
        # free the tenant on the broker even when a learner errored —
        # a long-lived broker must not accumulate one Controller per
        # round (best-effort: the broker may already be gone)
        if sid is not None:
            try:
                await admin.request("delete_session", {"session": sid})
            except Exception:  # noqa: BLE001
                pass
        await admin.close()

    return NetResult(
        average=None if final is None else final["average"],
        weight_avg=None if final is None else final.get("weight_avg"),
        wall_time=wall,
        stats=stats,
        bytes_sent=admin.bytes_sent,
        monitor_reposts=stats["monitor_reposts"],
        initiator_elections=stats["initiator_elections"],
        crashed_nodes=crashed,
        streamed_combines=streamed,
    )


@dataclasses.dataclass
class HierNetResult:
    """One §5.10 chain-of-chains round over real brokers — the wire twin
    of :class:`repro.core.protocol.HierSimResult`. ``average`` is the
    parent's cross-org fold; ``org_results`` holds each surviving org's
    own :class:`NetResult` (whose ``average`` is the org-level fold, the
    one anonymized vector that crossed the trust boundary upward);
    ``parent_stats`` is the parent session's ``get_stats`` dict, whose
    ``hierarchy_total`` satisfies the parent-level closed form
    ``2(c - f)`` — one up-post plus one down-fetch per surviving org."""

    average: Optional[np.ndarray]
    weight_avg: Optional[float]
    wall_time: float
    org_results: Dict[int, NetResult]
    org_averages: Dict[int, np.ndarray]
    elided_orgs: tuple
    parent_stats: Dict[str, Any]


async def run_hierarchical_round_net(
    values: np.ndarray,
    parent_addr: Addr,
    child_addrs: Mapping[int, Addr],
    *,
    failed_orgs: Iterable[int] = (),
    failed_nodes: Iterable[int] = (),
    initiator_fails: bool = False,
    weights: Optional[np.ndarray] = None,
    cost: CostModel = EDGE,
    aggregation_timeout: Optional[float] = None,
    parent_timeout: Optional[float] = None,
    symmetric_only: bool = False,
    scale_bits: int = 16,
    provisioning_seed: int = 0xC0FFEE,
    learner_master: int = 0x5EED,
    counter: int = 0,
    timeout_scale: float = 1.0,
    compute_scale: float = 0.0,
    chunk_words: Optional[int] = None,
) -> HierNetResult:
    """One hierarchical round on the wire (paper §5.10, PROTOCOL.md
    §15): each child org runs its own FULL SAFE chain — failover
    included — on its own broker, whose session posts the org's
    anonymized average UP to the parent session at ``parent_addr`` and
    serves the parent's fold back to its learners. A whole org in
    ``failed_orgs`` never runs: the parent elides it after its
    aggregation timeout, exactly like a dead learner inside a chain.

    ``child_addrs`` maps org id (0-based, one per topology subgroup) to
    that org's broker address; several orgs may share one broker (they
    get separate sessions). The topology, seeds and machine construction
    are the GLOBAL ones of ``run_safe_round(values, subgroups=len
    (child_addrs))`` — so every org average, and the parent fold, is
    bit-identical to the flat sim/wire planes (asserted in
    tests/test_conformance.py)."""
    values = np.asarray(values, np.float32)
    n, V = values.shape
    orgs = sorted(int(g) for g in child_addrs)
    payload_words = V + 1 if weights is not None else V
    topo = RingTopology(n, len(orgs))
    topo.validate_privacy()
    groups = topo.group_chains(node_base=1)
    initiators = {r + 1 for r in topo.elect_initiators()}
    failed = set(failed_nodes)
    dead_orgs = {int(g) for g in failed_orgs}

    machines = build_round_machines(
        values, topo, groups, initiators, mode="safe", weights=weights,
        cost=cost, symmetric_only=symmetric_only, scale_bits=scale_bits,
        provisioning_seed=provisioning_seed, learner_master=learner_master,
        counter=counter, subgroups=len(orgs), failed=failed,
        initiator_fails=initiator_fails)

    parent = await WireClient(*parent_addr).connect()
    children: Dict[int, WireClient] = {}
    psid = None
    child_sids: Dict[int, int] = {}
    try:
        created = await parent.request("create_session", {
            # the placeholder chain keeps the call shape; the parent's
            # protocol state lives in its ParentController
            "groups": {0: [0]}, "orgs": orgs,
            "aggregation_timeout": parent_timeout})
        psid = created["session"]
        wall_parent = created["aggregation_timeout"]

        async def run_org(g: int) -> Tuple[int, NetResult]:
            chain = groups[g]
            admin = await WireClient(*child_addrs[g]).connect()
            children[g] = admin
            made = await admin.request("create_session", {
                "groups": {g: chain},
                "aggregation_timeout": aggregation_timeout,
                "upstream": {
                    "host": parent_addr[0], "port": parent_addr[1],
                    "session": psid, "org": g, "token": parent.token,
                    # the child's down-fetch must outlast the parent's
                    # whole-org elision window
                    "timeout": wall_parent + 5.0,
                }})
            sid = made["session"]
            child_sids[g] = sid
            wall_agg = made["aggregation_timeout"]
            learner_addr = ((child_addrs[g][0], int(made["port"]))
                            if made.get("port") else child_addrs[g])
            org_machines = {node: machines[node] for node in chain
                            if node in machines}

            async def acquire(node: int) -> WireClient:
                tok = (admin.node_tokens or {}).get(node, admin.token)
                return await WireClient(*learner_addr, node=node,
                                        token=tok).connect()

            async def release(node: int, client: WireClient, _c: bool):
                await client.close()
                admin.bytes_sent += client.bytes_sent

            wall, crashed, streamed = await _drive_round_machines(
                org_machines, acquire, release, sid,
                aggregation_timeout=wall_agg, timeout_scale=timeout_scale,
                compute_scale=compute_scale, chunk_words=chunk_words,
                payload_words=payload_words, prefetch_depth=None,
                stream=None)
            stats = await admin.request("get_stats", {"session": sid})
            # the child's peek is the ORG average — the learners got the
            # parent fold, but the org-level bits are what went upward
            org_avg = await admin.request("peek_average", {"session": sid})
            return g, NetResult(
                average=None if org_avg is None else org_avg["average"],
                weight_avg=(None if org_avg is None
                            else org_avg.get("weight_avg")),
                wall_time=wall, stats=stats, bytes_sent=admin.bytes_sent,
                monitor_reposts=stats["monitor_reposts"],
                initiator_elections=stats["initiator_elections"],
                crashed_nodes=crashed, streamed_combines=streamed)

        live = [g for g in orgs if g not in dead_orgs]
        if not live:
            raise ValueError("every child org is in failed_orgs")
        t0 = time.perf_counter()
        settled = await asyncio.gather(*(run_org(g) for g in live))
        wall = time.perf_counter() - t0
        org_results = {g: r for g, r in settled}

        # every surviving org's learners finished, which means the fold
        # was published and distributed — the peek cannot race it
        fold = await parent.request("peek_average", {"session": psid})
        pstats = await parent.request("get_stats", {"session": psid})
    finally:
        for g, admin in children.items():
            try:
                if g in child_sids:
                    await admin.request("delete_session",
                                        {"session": child_sids[g]})
            except Exception:  # noqa: BLE001
                pass
            await admin.close()
        if psid is not None:
            try:
                await parent.request("delete_session", {"session": psid})
            except Exception:  # noqa: BLE001
                pass
        await parent.close()

    return HierNetResult(
        average=None if fold is None else fold["average"],
        weight_avg=None if fold is None else fold.get("weight_avg"),
        wall_time=wall,
        org_results=org_results,
        org_averages={g: r.average for g, r in org_results.items()},
        elided_orgs=tuple(pstats.get("crashed_orgs", ())),
        parent_stats=pstats,
    )


@dataclasses.dataclass
class BonNetResult:
    """One BON round over the wire (the baseline's NetResult twin).
    ``stats`` is the broker's BonStats as a dict (one counter per
    ``bon_*`` op plus ``total`` and ``shares_reconstructed``)."""

    average: Optional[np.ndarray]
    wall_time: float
    stats: Dict[str, int]
    bytes_sent: int
    messages: int
    expected_messages: int
    crashed_nodes: tuple = ()


async def run_bon_round_net(
    values: np.ndarray,
    addr: Addr,
    *,
    failed_nodes: Iterable[int] = (),
    threshold: Optional[int] = None,
    seed: int = 7,
    scale_bits: int = 16,
    roster_timeout: float = 0.5,
    aggregation_timeout: Optional[float] = None,
    interceptor: Optional[Interceptor] = None,
    timeout_scale: float = 1.0,
) -> BonNetResult:
    """One BON aggregation over the real broker — the transport twin of
    :func:`repro.core.bon_protocol.run_bon_round`, so the Bonawitz-style
    baseline and SAFE are measured on the *same* wire (ISSUE 8; the
    paper's §6.1 comparison was half cost-model before this).

    Unlike SAFE, ``failed_nodes`` here run Rounds 0–1 over real sockets
    (advertise, share secrets) and then vanish — the protocol's
    designed-for worst case. The broker's BON session declares them
    dropped ``roster_timeout`` wall-seconds after the first masked
    input, and the server-side recovery (Shamir reconstruction + pad
    regeneration — the compute SAFE's "mere message broker" never does)
    runs inside the broker process.

    Per-op traffic is counted in ``BonStats`` with the same only-
    consumption-counts discipline as MessageStats; a completed clean
    round totals exactly ``bon_expected_messages(n, f)``. Payloads are
    single-frame by design (a masked vector at BON's practical n is far
    below MAX_FRAME); the chunk plane is not wired to ``bon_*`` ops.
    """
    values = np.asarray(values, np.float32)
    n, V = values.shape
    t = int(threshold) if threshold else (n // 2 + 1)
    failed = {int(x) for x in failed_nodes}
    if n - len(failed) < t:
        raise ValueError("not enough survivors to reach the threshold")

    machines = build_bon_machines(
        values, failed_nodes=failed, threshold=t, seed=seed,
        scale_bits=scale_bits)

    admin = await WireClient(*addr).connect()
    sid = None
    try:
        created = await admin.request("create_session", {
            "groups": {0: list(range(1, n + 1))},
            "aggregation_timeout": aggregation_timeout,
            "protocol": "bon", "threshold": t,
            "roster_timeout": roster_timeout, "scale_bits": scale_bits})
        sid = created["session"]
        wall_agg = created["aggregation_timeout"]
        learner_addr = ((addr[0], int(created["port"]))
                        if created.get("port") else addr)

        async def acquire(node: int) -> WireClient:
            tok = (admin.node_tokens or {}).get(node, admin.token)
            return await WireClient(*learner_addr, node=node,
                                    interceptor=interceptor,
                                    token=tok).connect()

        async def release(node: int, client: WireClient, _crashed: bool):
            await client.close()
            admin.bytes_sent += client.bytes_sent

        wall, crashed, _ = await _drive_round_machines(
            machines, acquire, release, sid,
            aggregation_timeout=wall_agg, timeout_scale=timeout_scale,
            compute_scale=0.0, chunk_words=None, payload_words=V,
            prefetch_depth=None, stream=False)

        stats = await admin.request("get_stats", {"session": sid})
        final = await admin.request("peek_average", {"session": sid})
    finally:
        if sid is not None:
            try:
                await admin.request("delete_session", {"session": sid})
            except Exception:  # noqa: BLE001
                pass
        await admin.close()

    return BonNetResult(
        average=None if final is None else final["average"],
        wall_time=wall,
        stats=stats,
        bytes_sent=admin.bytes_sent,
        messages=stats["total"],
        expected_messages=bon_expected_messages(n, len(failed) +
                                                len(crashed)),
        crashed_nodes=crashed,
    )


class PersistentNetSession:
    """One broker session, one set of learner connections, R rounds.

    The per-round path of :func:`run_safe_round_net` rebuilds everything
    every round: a fresh broker session, n fresh TCP connections, and n
    fresh :class:`LearnerCrypto` objects (full key re-derivation). This
    class keeps all three alive across rounds — ``reset_round`` clears
    the controller's round state between rounds, a
    :class:`repro.core.session.RoundCursor` hands each round a fresh
    counter base (no pad reuse), and the crypto cache means **no key
    derivation after Round 0** — the paper's Round-0 amortization, at
    the transport. Each round's published average is bit-identical to
    an independent ``run_safe_round(values, counter=base)`` sim round,
    and the per-round MessageStats delta still satisfies the §5 closed
    forms (asserted in tests/test_net.py).

    Usage::

        sess = PersistentNetSession(addr, n, chunk_words=4096)
        await sess.open()
        try:
            for r in range(R):
                res = await sess.run_round(values_r)
        finally:
            await sess.close()

    (or ``async with PersistentNetSession(...) as sess:``.)
    """

    def __init__(self, addr: Addr, n: int, *,
                 mode: str = "safe",
                 subgroups: int = 1,
                 cost: CostModel = EDGE,
                 aggregation_timeout: Optional[float] = None,
                 symmetric_only: bool = False,
                 scale_bits: int = 16,
                 provisioning_seed: int = 0xC0FFEE,
                 learner_master: int = 0x5EED,
                 interceptor: Optional[Interceptor] = None,
                 timeout_scale: float = 1.0,
                 compute_scale: float = 0.0,
                 chunk_words: Optional[int] = None,
                 prefetch_depth: Optional[int] = None,
                 stream: Optional[bool] = None,
                 words_per_round: Optional[int] = None,
                 counter0: int = 0):
        if mode not in ("safe", "saf"):
            raise ValueError(f"wire plane runs 'safe'/'saf', got {mode!r}")
        self.addr = addr
        self.n = n
        self.mode = mode
        self.subgroups = subgroups
        self.cost = cost
        self.aggregation_timeout = aggregation_timeout
        self.symmetric_only = symmetric_only
        self.scale_bits = scale_bits
        self.provisioning_seed = provisioning_seed
        self.learner_master = learner_master
        self.interceptor = interceptor
        self.timeout_scale = timeout_scale
        self.compute_scale = compute_scale
        self.chunk_words = chunk_words
        self.prefetch_depth = prefetch_depth
        self.stream = stream
        self._words_per_round = words_per_round
        self._counter0 = counter0
        self.topo = RingTopology(n, subgroups)
        self.topo.validate_privacy()
        self.groups = self.topo.group_chains(node_base=1)
        self.initiators = {r + 1 for r in self.topo.elect_initiators()}
        self.sid: Optional[int] = None
        self.rounds_done = 0
        self._admin: Optional[WireClient] = None
        self._clients: Dict[int, WireClient] = {}
        self._crypto_cache: Dict[int, LearnerCrypto] = {}
        self._cursor: Optional[RoundCursor] = None
        self._wall_agg: float = 30.0
        self._prev_stats: Dict[str, int] = {}
        self._prev_bytes = 0
        self._closed_bytes = 0  # bytes of connections dropped mid-session
        self._learner_addr: Addr = addr  # owning shard's addr after open()
        # §11 cross-round pipelining state: in-flight round tasks
        # (ordered — rounds collect oldest-first), the broker's round
        # counter as last reported by advance_round, one connection set
        # per pipeline slot (two concurrent rounds must never share a
        # connection: its request/response pairing is sequential, and a
        # future-round op PARKS), and whether a plain run_round left a
        # published round that the next pipelined round must close out
        self._pipe: collections.deque = collections.deque()
        self._pipe_clients: Dict[Tuple[int, int], WireClient] = {}
        self._pipe_window: Optional[int] = None
        self._broker_round = 0
        self._plain_pending = False

    async def open(self) -> "PersistentNetSession":
        self._admin = await WireClient(*self.addr).connect()
        created = await self._admin.request("create_session", {
            "groups": self.groups,
            "aggregation_timeout": self.aggregation_timeout})
        self.sid = created["session"]
        self._wall_agg = created["aggregation_timeout"]
        # sharded broker: pin every learner connection to the session's
        # owning shard (see run_safe_round_net)
        self._learner_addr = ((self.addr[0], int(created["port"]))
                              if created.get("port") else self.addr)
        return self

    async def __aenter__(self) -> "PersistentNetSession":
        return await self.open()

    async def __aexit__(self, *exc) -> None:
        await self.close()

    def _node_token(self, node: int) -> Optional[str]:
        """The node's CURRENT credential (§15): its entry in the admin's
        latest grant (create_session or the last reset_round rotation),
        falling back to the session token."""
        if self._admin is None:
            return None
        grant = self._admin.node_tokens or {}
        return grant.get(node, self._admin.token)

    async def _client(self, node: int) -> WireClient:
        c = self._clients.get(node)
        if c is None:
            c = await WireClient(*self._learner_addr, node=node,
                                 interceptor=self.interceptor,
                                 token=self._node_token(node)).connect()
            self._clients[node] = c
        return c

    async def _drop_client(self, node: int) -> None:
        c = self._clients.pop(node, None)
        if c is not None:
            await c.close()
            self._closed_bytes += c.bytes_sent

    def _total_bytes(self) -> int:
        return (self._admin.bytes_sent + self._closed_bytes
                + sum(c.total_bytes_sent for c in self._clients.values())
                + sum(c.total_bytes_sent
                      for c in self._pipe_clients.values()))

    # -- §11 cross-round pipelining ---------------------------------------
    @property
    def pipeline_depth(self) -> int:
        """Rounds launched but not yet collected."""
        return len(self._pipe)

    async def _pipe_client(self, node: int, slot: int) -> WireClient:
        key = (node, slot)
        c = self._pipe_clients.get(key)
        if c is None:
            c = await WireClient(*self._learner_addr, node=node,
                                 interceptor=self.interceptor,
                                 token=self._node_token(node)).connect()
            self._pipe_clients[key] = c
        return c

    async def start_round_pipelined(self, values: np.ndarray, *,
                                    weights: Optional[np.ndarray] = None,
                                    failed_nodes: Iterable[int] = (),
                                    initiator_fails: bool = False,
                                    window: int = 2) -> None:
        """Launch one aggregation round WITHOUT waiting for the previous
        round to finish — the §11 cross-round pipeline. Every op and
        chunk frame is tagged with the round's broker round number: the
        broker buffers (and relays) the new round's chunk streams while
        the previous round's tail drains, parking only the logical ops
        until :meth:`collect_round_pipelined` advances the boundary.
        The counter base still comes from the session's
        :class:`~repro.core.session.RoundCursor` — pad streams never
        collide across overlapped rounds.

        At most ``window`` rounds may be in flight (the broker sheds
        frames beyond its own ``inflight_rounds`` window anyway); each
        in-flight round drives its learners over a dedicated connection
        set, because a future-round op PARKS and would head-of-line
        block the previous round on a shared connection."""
        if self._pipe_window is None:
            self._pipe_window = max(1, int(window))
        if len(self._pipe) >= self._pipe_window:
            raise RuntimeError(
                "pipeline window full — collect_round_pipelined first")
        values = np.asarray(values, np.float32)
        if values.shape[0] != self.n:
            raise ValueError(
                f"values has {values.shape[0]} rows for n={self.n}")
        V = values.shape[1]
        payload_words = V + 1 if weights is not None else V
        if self._cursor is None:
            self._cursor = RoundCursor(
                self._words_per_round or payload_words, self._counter0)
        if payload_words > self._cursor.words_per_round:
            raise ValueError(
                f"payload of {payload_words} words exceeds this "
                f"session's {self._cursor.words_per_round} words/round "
                f"counter stride — size words_per_round for the widest "
                f"round up front")
        counter = self._cursor.next_round()
        chunk_words = _resolve_chunk_words(self.chunk_words, payload_words,
                                           self.cost)
        if self._plain_pending:
            # a plain run_round left its round published on the broker:
            # close it out non-destructively so this round's tag lands
            # on a fresh controller round
            resp = await self._admin.request("advance_round",
                                             {"session": self.sid})
            self._broker_round = int(resp["round"])
            self._plain_pending = False

        rnd = self._broker_round + len(self._pipe)
        slot = rnd % self._pipe_window
        failed = set(failed_nodes)
        machines = build_round_machines(
            values, self.topo, self.groups, self.initiators,
            mode=self.mode, weights=weights, cost=self.cost,
            symmetric_only=self.symmetric_only, scale_bits=self.scale_bits,
            provisioning_seed=self.provisioning_seed,
            learner_master=self.learner_master, counter=counter,
            subgroups=self.subgroups, failed=failed,
            initiator_fails=initiator_fails,
            crypto_cache=self._crypto_cache)

        async def acquire(node: int) -> WireClient:
            return await self._pipe_client(node, slot)

        async def release(node: int, _client: WireClient, crashed: bool):
            if crashed:
                c = self._pipe_clients.pop((node, slot), None)
                if c is not None:
                    await c.close()
                    self._closed_bytes += c.bytes_sent

        task = asyncio.ensure_future(_drive_round_machines(
            machines, acquire, release, self.sid,
            aggregation_timeout=self._wall_agg,
            timeout_scale=self.timeout_scale,
            compute_scale=self.compute_scale, chunk_words=chunk_words,
            payload_words=payload_words,
            prefetch_depth=self.prefetch_depth, stream=self.stream,
            round_tag=rnd))
        self._pipe.append(task)

    async def collect_round_pipelined(self) -> NetResult:
        """Wait for the OLDEST in-flight round, read its results, then
        ``advance_round`` — which delivers any already-buffered next-
        round transfers and un-parks its ops. Collected strictly in
        launch order, so the per-round MessageStats delta taken here
        contains exactly the finished round's ops (later rounds' ops
        are still parked) and the §5 closed forms hold round-by-round
        even while the chunk plane overlaps rounds on the wire."""
        if not self._pipe:
            raise RuntimeError("no pipelined round in flight")
        task = self._pipe.popleft()
        wall, crashed, streamed = await task
        raw = await self._admin.request("get_stats", {"session": self.sid})
        stats = {k: (raw[k] - self._prev_stats.get(k, 0)
                     if isinstance(raw.get(k), int) else raw[k])
                 for k in raw}
        self._prev_stats = {k: v for k, v in raw.items()
                            if isinstance(v, int)}
        final = await self._admin.request("peek_average",
                                          {"session": self.sid})
        resp = await self._admin.request("advance_round",
                                         {"session": self.sid})
        self._broker_round = int(resp["round"])
        self.rounds_done += 1
        self._plain_pending = False
        bytes_now = self._total_bytes() - self._prev_bytes
        self._prev_bytes += bytes_now
        return NetResult(
            average=None if final is None else final["average"],
            weight_avg=None if final is None else final.get("weight_avg"),
            wall_time=wall,
            stats=stats,
            bytes_sent=bytes_now,
            monitor_reposts=stats["monitor_reposts"],
            initiator_elections=stats["initiator_elections"],
            crashed_nodes=crashed,
            streamed_combines=streamed,
        )

    async def run_rounds_pipelined(self, rounds_values, *,
                                   window: int = 2,
                                   weights: Optional[np.ndarray] = None,
                                   failed_by_round: Optional[
                                       Mapping[int, Iterable[int]]] = None
                                   ) -> list:
        """R rounds with up to ``window`` overlapped on the wire —
        round r+1's uploads start while round r's tail drains. Returns
        one :class:`NetResult` per round, in round order; per-round
        stats deltas, bit-identity and counter bases are exactly those
        of the sequential :meth:`run_round` loop (asserted in
        tests/test_conformance.py's ``pipelined`` column)."""
        failed_by_round = dict(failed_by_round or {})
        results: list = []
        for r, values in enumerate(rounds_values):
            while len(self._pipe) >= max(1, int(
                    self._pipe_window or window)):
                results.append(await self.collect_round_pipelined())
            await self.start_round_pipelined(
                values, weights=weights,
                failed_nodes=set(failed_by_round.get(r, ())),
                window=window)
        while self._pipe:
            results.append(await self.collect_round_pipelined())
        return results

    async def run_round(self, values: np.ndarray, *,
                        weights: Optional[np.ndarray] = None,
                        failed_nodes: Iterable[int] = (),
                        initiator_fails: bool = False,
                        counter: Optional[int] = None) -> NetResult:
        """One aggregation round on the live session. Rounds after the
        first begin with ``reset_round``; the counter base comes from
        the session's :class:`RoundCursor` unless ``counter`` pins it
        (parity tests). Learner connections and key material are reused;
        a learner that crashed last round reconnects (crash-resume
        across the round boundary)."""
        values = np.asarray(values, np.float32)
        if values.shape[0] != self.n:
            raise ValueError(
                f"values has {values.shape[0]} rows for n={self.n}")
        V = values.shape[1]
        payload_words = V + 1 if weights is not None else V
        if self._cursor is None:
            self._cursor = RoundCursor(
                self._words_per_round or payload_words, self._counter0)
        if payload_words > self._cursor.words_per_round:
            # a payload wider than the per-round counter stride would
            # overlap the next round's pad words — silent keystream
            # reuse, the one invariant this class must never break
            raise ValueError(
                f"payload of {payload_words} words exceeds this "
                f"session's {self._cursor.words_per_round} words/round "
                f"counter stride — size words_per_round for the widest "
                f"round up front")
        if counter is None:
            counter = self._cursor.next_round()
        chunk_words = _resolve_chunk_words(self.chunk_words, payload_words,
                                           self.cost)
        if self._pipe:
            raise RuntimeError(
                "run_round while pipelined rounds are in flight — "
                "collect_round_pipelined them first")

        failed = set(failed_nodes)
        machines = build_round_machines(
            values, self.topo, self.groups, self.initiators,
            mode=self.mode, weights=weights, cost=self.cost,
            symmetric_only=self.symmetric_only, scale_bits=self.scale_bits,
            provisioning_seed=self.provisioning_seed,
            learner_master=self.learner_master, counter=counter,
            subgroups=self.subgroups, failed=failed,
            initiator_fails=initiator_fails,
            crypto_cache=self._crypto_cache)

        if self.rounds_done > 0:
            # new FL iteration on the same tenant: clear round state and
            # stale chunk buffers, keep keys/counters/connections warm.
            # The reset ROTATES every token (§15) — the admin client
            # adopts its own from the response; redistribute the fresh
            # per-node grant to the live learner connections
            await self._admin.request("reset_round", {"session": self.sid})
            for node, c in self._clients.items():
                c.set_token(self._node_token(node))
            for (node, _slot), c in self._pipe_clients.items():
                c.set_token(self._node_token(node))

        async def release(node: int, _client: WireClient, crashed: bool):
            if crashed:
                # the connection may hold half-sent frames / parked
                # polls — drop it so the node rejoins cleanly next round
                await self._drop_client(node)

        wall, crashed, streamed = await _drive_round_machines(
            machines, self._client, release, self.sid,
            aggregation_timeout=self._wall_agg,
            timeout_scale=self.timeout_scale,
            compute_scale=self.compute_scale, chunk_words=chunk_words,
            payload_words=payload_words,
            prefetch_depth=self.prefetch_depth, stream=self.stream)

        raw = await self._admin.request("get_stats", {"session": self.sid})
        stats = {k: (raw[k] - self._prev_stats.get(k, 0)
                     if isinstance(raw.get(k), int) else raw[k])
                 for k in raw}
        self._prev_stats = {k: v for k, v in raw.items()
                            if isinstance(v, int)}
        final = await self._admin.request("peek_average",
                                          {"session": self.sid})
        self.rounds_done += 1
        self._plain_pending = True
        bytes_now = self._total_bytes() - self._prev_bytes
        self._prev_bytes += bytes_now
        return NetResult(
            average=None if final is None else final["average"],
            weight_avg=None if final is None else final.get("weight_avg"),
            wall_time=wall,
            stats=stats,
            bytes_sent=bytes_now,
            monitor_reposts=stats["monitor_reposts"],
            initiator_elections=stats["initiator_elections"],
            crashed_nodes=crashed,
            streamed_combines=streamed,
        )

    async def close(self) -> None:
        while self._pipe:  # abandoned in-flight rounds die with us
            task = self._pipe.popleft()
            task.cancel()
            try:
                await task
            except (asyncio.CancelledError, Exception):  # noqa: BLE001
                pass
        for key in list(self._pipe_clients):
            c = self._pipe_clients.pop(key)
            await c.close()
            self._closed_bytes += c.bytes_sent
        for node in list(self._clients):
            await self._drop_client(node)
        if self._admin is not None:
            if self.sid is not None:
                try:
                    await self._admin.request("delete_session",
                                              {"session": self.sid})
                except Exception:  # noqa: BLE001
                    pass
            await self._admin.close()
            self._admin = None
        self.sid = None


async def run_federated_round_net(
    state: Any,
    local_fns: Mapping[int, Callable[[Any], np.ndarray]],
    apply_fn: Callable[[Any, np.ndarray], Any],
    addr: Addr,
    *,
    weights: Optional[np.ndarray] = None,
    counter: int = 0,
    failed_nodes: Iterable[int] = (),
    chunk_words: Optional[int] = None,
    **round_kw,
) -> Tuple[Any, NetResult]:
    """One FedAvg round over the wire plane (the paper's actual use
    case: learners chained, traffic encrypted, controller a broker).

    Each live learner runs its *real* local update — ``local_fns[node]``
    maps the shared model state to that node's f32[P] model delta (built
    by :func:`repro.train.federated.make_wire_federated`; injected as a
    callable so this module never imports JAX) — then the deltas travel
    the SAFE chain through the broker at ``addr``, chunk-streamed when
    longer than ``chunk_words``. The published (weighted) mean delta is
    merged via ``apply_fn`` and the new state returned.

    Local updates run in the default executor so a co-hosted broker (or
    other tenants on this loop) keeps serving while learners compute.
    Callers advance ``counter`` by at least P (+1 when weighted) words
    per round — the pad no-reuse invariant.

    ``failed_nodes`` never compute and never connect: the §5.3/5.4
    failover machinery publishes the survivors' mean, exactly as in the
    paper's dropped-org experiment.
    """
    failed = set(failed_nodes)
    nodes = sorted(local_fns)
    if nodes != list(range(1, len(nodes) + 1)):
        raise ValueError(f"local_fns must be keyed 1..n, got {nodes}")
    if not set(nodes) - failed:
        raise ValueError("no live learners: every node is in failed_nodes")
    values = await _collect_deltas(state, local_fns, failed, nodes)

    res = await run_safe_round_net(
        values, addr, weights=weights, counter=counter,
        failed_nodes=failed, chunk_words=chunk_words, **round_kw)
    if res.average is None:
        return state, res
    return apply_fn(state, res.average), res


async def _collect_deltas(state: Any, local_fns, failed: set,
                          nodes: list) -> np.ndarray:
    """Run each live learner's local update in the default executor and
    pack the deltas learner-major (shared by the single- and multi-round
    federated runners)."""
    loop = asyncio.get_running_loop()
    deltas: Dict[int, np.ndarray] = {}
    for node in nodes:
        if node in failed:
            continue
        out = await loop.run_in_executor(None, local_fns[node], state)
        deltas[node] = np.asarray(out, np.float32).ravel()
    sizes = {d.size for d in deltas.values()}
    if len(sizes) != 1:
        raise ValueError(f"learners produced mixed delta sizes {sizes}")
    values = np.zeros((len(nodes), sizes.pop()), np.float32)
    for node, d in deltas.items():
        values[node - 1] = d
    return values


async def run_federated_rounds_net(
    state: Any,
    local_fns: Mapping[int, Callable[[Any], np.ndarray]],
    apply_fn: Callable[[Any, np.ndarray], Any],
    addr: Addr,
    *,
    rounds: int,
    weights: Optional[np.ndarray] = None,
    counter0: int = 0,
    words_per_round: Optional[int] = None,
    failed_by_round: Optional[Mapping[int, Iterable[int]]] = None,
    pipeline: bool = False,
    window: int = 2,
    **session_kw,
) -> Tuple[Any, list]:
    """R federated rounds on ONE persistent broker session — the full
    §8 pipeline on the wire, amortized the way the paper amortizes
    Round 0.

    Where :func:`run_federated_round_net` rebuilds session, connections
    and key material every round, this keeps a
    :class:`PersistentNetSession` alive for all ``rounds``: one
    ``create_session``, one set of learner TCP connections, **no key
    derivation after Round 0** (``machines.key_derivations()`` stays
    flat), with ``reset_round`` + :class:`~repro.core.session.
    RoundCursor` counter bases between rounds (no pad reuse). Deltas
    chunk-stream through the chunk-granular combine by default.

    ``failed_by_round`` maps round index → nodes dead that round (they
    neither compute nor connect; §5.3/5.4 publish the survivors' mean,
    and the nodes rejoin the next round — crash-resume across the round
    boundary). ``session_kw`` forwards to
    :class:`PersistentNetSession` (``chunk_words``, ``prefetch_depth``,
    ``stream``, ``aggregation_timeout``, ...).

    ``pipeline=True`` overlaps up to ``window`` rounds on the wire
    (§11): round r+1's local updates compute — and its deltas upload —
    while round r's aggregation is still in flight. That makes the FL
    loop *staleness-1*: with the default ``window=2``, round r+1's
    deltas are computed from the state through round r−1 (round r has
    not been collected when they launch). Each round's published
    average is still the exact SAFE mean of the deltas that round
    actually shipped — the staleness is an FL-optimizer property
    (standard one-step asynchronous/pipelined SGD), not an aggregation
    approximation.

    Returns ``(final_state, [NetResult per round])``.
    """
    nodes = sorted(local_fns)
    if nodes != list(range(1, len(nodes) + 1)):
        raise ValueError(f"local_fns must be keyed 1..n, got {nodes}")
    failed_by_round = dict(failed_by_round or {})
    results: list = []
    sess = PersistentNetSession(
        addr, len(nodes), counter0=counter0,
        words_per_round=words_per_round, **session_kw)
    await sess.open()

    def fold(res: NetResult, state: Any) -> Any:
        results.append(res)
        return (state if res.average is None
                else apply_fn(state, res.average))

    try:
        for r in range(rounds):
            failed = set(failed_by_round.get(r, ()))
            if not set(nodes) - failed:
                raise ValueError(
                    f"round {r}: every node is in failed_by_round")
            if pipeline:
                while sess.pipeline_depth >= max(1, int(window)):
                    state = fold(await sess.collect_round_pipelined(),
                                 state)
                values = await _collect_deltas(state, local_fns, failed,
                                               nodes)
                await sess.start_round_pipelined(
                    values, weights=weights, failed_nodes=failed,
                    window=window)
            else:
                values = await _collect_deltas(state, local_fns, failed,
                                               nodes)
                state = fold(await sess.run_round(
                    values, weights=weights, failed_nodes=failed), state)
        while sess.pipeline_depth:
            state = fold(await sess.collect_round_pipelined(), state)
    finally:
        await sess.close()
    return state, results
