"""Wire-plane learner runtime: the SAFE state machines over asyncio.

Drives the *identical* generator coroutines from
:mod:`repro.core.machines` — the ones the discrete-event kernel runs in
virtual time — over a real TCP transport to :class:`~repro.net.broker.
SafeBroker`, mapping each yield onto awaits:

  ("compute", seconds)          -> optional scaled ``asyncio.sleep``
  ("call", op, kwargs, nbytes)  -> one request/response RPC
  ("wait", kind, kwargs, nbytes, timeout)
                                -> long-poll RPC; the broker parks the
                                   request until data or timeout

Because the machines, the ``Controller`` and the round construction
(:func:`~repro.core.machines.build_round_machines`) are shared with the
sim, the published average here is bit-identical to the sim's for the
same seeds/topology, and the ``MessageStats`` counters still satisfy
§5's closed forms (asserted in ``tests/test_net.py``).

Faults are injected at this layer via :mod:`repro.net.faults`
interceptors — latency, request drops (with at-most-once retry: a
dropped frame never reached the broker), and crash/churn schedules.
"""
from __future__ import annotations

import asyncio
import dataclasses
import time
from typing import Any, Dict, Iterable, Optional, Tuple

import numpy as np

from repro.core.costs import CostModel, EDGE
from repro.core.machines import LearnerGen, build_round_machines
from repro.net import wire
from repro.net.faults import DropPacket, Interceptor, LearnerCrashed
from repro.topology import RingTopology

Addr = Tuple[str, int]


class WireClient:
    """One connection to the broker; one outstanding request at a time
    (the learner state machines are strictly sequential)."""

    def __init__(self, host: str, port: int, node: int = 0,
                 interceptor: Optional[Interceptor] = None,
                 retry_backoff: float = 0.02):
        self.host = host
        self.port = port
        self.node = node
        self.interceptor = interceptor
        self.retry_backoff = retry_backoff
        self.bytes_sent = 0
        self.bytes_received = 0
        self.requests = 0
        self._reader: Optional[asyncio.StreamReader] = None
        self._writer: Optional[asyncio.StreamWriter] = None

    async def connect(self) -> "WireClient":
        self._reader, self._writer = await asyncio.open_connection(
            self.host, self.port)
        return self

    async def close(self) -> None:
        if self._writer is not None:
            self._writer.close()
            try:
                await self._writer.wait_closed()
            except Exception:  # noqa: BLE001
                pass
            self._writer = None
            self._reader = None

    async def request(self, op: str, kwargs: dict) -> Any:
        """One RPC. A DropPacket from the interceptor loses the frame
        *before* transmission; we back off and retry (safe: the broker
        never saw it). LearnerCrashed propagates to the runtime."""
        body = wire.encode_request(op, kwargs)
        framed = wire.encode_frame(body)
        while True:
            if self.interceptor is not None:
                try:
                    await self.interceptor.on_request(
                        self.node, op, len(framed))
                except DropPacket:
                    await asyncio.sleep(self.retry_backoff)
                    continue
            self._writer.write(framed)
            await self._writer.drain()
            self.bytes_sent += len(framed)
            self.requests += 1
            resp = await wire.read_frame(self._reader)
            if resp is None:
                raise wire.WireError("broker closed the connection")
            self.bytes_received += len(resp) + 4
            if self.interceptor is not None:
                await self.interceptor.on_response(
                    self.node, op, len(resp) + 4)
            return wire.decode_response(resp)


async def drive_learner(gen: LearnerGen, client: WireClient, session: int,
                        *, aggregation_timeout: float,
                        timeout_scale: float = 1.0,
                        compute_scale: float = 0.0) -> Any:
    """Run one state machine to completion over the wire.

    ``timeout`` mapping for ``wait`` yields: ``"aggregation"`` becomes
    the session's wall-clock aggregation timeout, numeric (virtual
    seconds) scale by ``timeout_scale``, ``None`` waits forever.
    ``compute_scale`` turns the machines' virtual compute costs into
    wall sleeps (0 = infinitely fast learners; the default, since the
    wire plane measures transport, not the cost model).
    """
    send_value = None
    while True:
        try:
            item = gen.send(send_value)
        except StopIteration as stop:
            return stop.value
        kind = item[0]
        if kind == "compute":
            if compute_scale > 0.0:
                await asyncio.sleep(item[1] * compute_scale)
            send_value = None
        elif kind == "call":
            _, op, kwargs, _nbytes = item
            send_value = await client.request(op, dict(kwargs, session=session))
        elif kind == "wait":
            _, wkind, kwargs, _nbytes, timeout = item
            if timeout == "aggregation":
                wall: Optional[float] = aggregation_timeout
            elif timeout is None:
                wall = None
            else:
                wall = float(timeout) * timeout_scale
            send_value = await client.request(
                wkind, dict(kwargs, session=session, timeout=wall))
        else:
            raise ValueError(f"unknown yield {item!r}")


@dataclasses.dataclass
class NetResult:
    """Wire-plane mirror of :class:`repro.core.protocol.SimResult` —
    ``stats`` is the broker's MessageStats as a dict (plus totals)."""

    average: Optional[np.ndarray]
    weight_avg: Optional[float]
    wall_time: float
    stats: Dict[str, int]
    bytes_sent: int
    monitor_reposts: int
    initiator_elections: int
    crashed_nodes: tuple = ()


async def run_safe_round_net(
    values: np.ndarray,
    addr: Addr,
    *,
    mode: str = "safe",
    subgroups: int = 1,
    failed_nodes: Iterable[int] = (),
    initiator_fails: bool = False,
    weights: Optional[np.ndarray] = None,
    cost: CostModel = EDGE,
    aggregation_timeout: Optional[float] = None,
    symmetric_only: bool = False,
    scale_bits: int = 16,
    provisioning_seed: int = 0xC0FFEE,
    learner_master: int = 0x5EED,
    counter: int = 0,
    interceptor: Optional[Interceptor] = None,
    timeout_scale: float = 1.0,
    compute_scale: float = 0.0,
) -> NetResult:
    """One full aggregation round over the wire — the transport twin of
    :func:`repro.core.protocol.run_safe_round` (same signature spirit,
    wall-clock timeouts). Builds the same topology, elects the same
    initiators, constructs the same machines, then runs one asyncio
    task + one TCP connection per live learner against the broker at
    ``addr``.

    ``failed_nodes`` are dead before the round (their clients never
    start — discovered by the broker's monitor, §5.3). ``mode`` must be
    'safe' or 'saf': INSEC needs a parsing, averaging controller, which
    the wire broker deliberately is not (the paper's point).
    """
    if mode not in ("safe", "saf"):
        raise ValueError(f"wire plane runs 'safe'/'saf', got {mode!r}")
    values = np.asarray(values, np.float32)
    n, _V = values.shape
    topo = RingTopology(n, subgroups)
    topo.validate_privacy()
    groups = topo.group_chains(node_base=1)
    initiators = {r + 1 for r in topo.elect_initiators()}
    failed = set(failed_nodes)

    machines = build_round_machines(
        values, topo, groups, initiators, mode=mode, weights=weights,
        cost=cost, symmetric_only=symmetric_only, scale_bits=scale_bits,
        provisioning_seed=provisioning_seed, learner_master=learner_master,
        counter=counter, subgroups=subgroups, failed=failed,
        initiator_fails=initiator_fails)

    admin = await WireClient(*addr).connect()
    sid = None
    try:
        created = await admin.request("create_session", {
            "groups": groups, "aggregation_timeout": aggregation_timeout})
        sid = created["session"]
        wall_agg = created["aggregation_timeout"]

        crashed = []

        async def one(node: int, gen: LearnerGen) -> Any:
            client = WireClient(*addr, node=node, interceptor=interceptor)
            await client.connect()
            try:
                return await drive_learner(
                    gen, client, sid, aggregation_timeout=wall_agg,
                    timeout_scale=timeout_scale, compute_scale=compute_scale)
            except LearnerCrashed:
                crashed.append(node)  # mid-round churn: learner just stops
                return None
            finally:
                admin.bytes_sent += client.bytes_sent
                await client.close()

        t0 = time.perf_counter()
        # return_exceptions: let every learner settle (each closes its
        # own connection in its finally) instead of abandoning running
        # tasks on the first error, then surface the first failure
        settled = await asyncio.gather(
            *(one(node, gen) for node, gen in machines.items()),
            return_exceptions=True)
        for r in settled:
            if isinstance(r, BaseException):
                raise r
        wall = time.perf_counter() - t0

        stats = await admin.request("get_stats", {"session": sid})
        final = await admin.request("peek_average", {"session": sid})
    finally:
        # free the tenant on the broker even when a learner errored —
        # a long-lived broker must not accumulate one Controller per
        # round (best-effort: the broker may already be gone)
        if sid is not None:
            try:
                await admin.request("delete_session", {"session": sid})
            except Exception:  # noqa: BLE001
                pass
        await admin.close()

    return NetResult(
        average=None if final is None else final["average"],
        weight_avg=None if final is None else final.get("weight_avg"),
        wall_time=wall,
        stats=stats,
        bytes_sent=admin.bytes_sent,
        monitor_reposts=stats["monitor_reposts"],
        initiator_elections=stats["initiator_elections"],
        crashed_nodes=tuple(crashed),
    )
