"""Wire-plane learner runtime: the SAFE state machines over asyncio.

Drives the *identical* generator coroutines from
:mod:`repro.core.machines` — the ones the discrete-event kernel runs in
virtual time — over a real TCP transport to :class:`~repro.net.broker.
SafeBroker`, mapping each yield onto awaits:

  ("compute", seconds)          -> optional scaled ``asyncio.sleep``
  ("call", op, kwargs, nbytes)  -> one request/response RPC
  ("wait", kind, kwargs, nbytes, timeout)
                                -> long-poll RPC; the broker parks the
                                   request until data or timeout

Because the machines, the ``Controller`` and the round construction
(:func:`~repro.core.machines.build_round_machines`) are shared with the
sim, the published average here is bit-identical to the sim's for the
same seeds/topology, and the ``MessageStats`` counters still satisfy
§5's closed forms (asserted in ``tests/test_net.py``).

Payloads larger than ``chunk_words`` stream over the chunked transfer
plane (docs/PROTOCOL.md §6) transparently: the runtime splits uploads
into ``post_chunk`` frames and pulls downloads chunk-by-chunk via
``get_chunk`` — with one request kept in flight ahead of the chunk
being processed, and the broker relaying chunks downstream before the
upload completes, so chain hops overlap the way the §8 pipelined
schedule overlaps segments. The state machines never see chunks: the
logical consume still happens (with ``elide_payload`` so the bulk bytes
travel exactly once) and the reassembled array is injected into its
response, keeping bits and §5 message counts identical to the
unchunked path.

Faults are injected at this layer via :mod:`repro.net.faults`
interceptors — latency, request drops (with at-most-once retry: a
dropped frame never reached the broker), and crash/churn schedules.

:func:`run_federated_round_net` is the training entry point: each
learner runs a real local FedAvg step (an injected callable — this
module stays JAX-free; :func:`repro.train.federated.make_wire_federated`
builds the callables) and ships its model delta through the broker.
"""
from __future__ import annotations

import asyncio
import dataclasses
import itertools
import time
from typing import Any, Callable, Dict, Iterable, Mapping, Optional, Tuple

import numpy as np

from repro.core.costs import CostModel, EDGE
from repro.core.machines import LearnerGen, build_round_machines
from repro.net import wire
from repro.net.faults import DropPacket, Interceptor, LearnerCrashed
from repro.topology import RingTopology

Addr = Tuple[str, int]

#: auto-chunk threshold: payloads above this many elements stream even
#: when the caller didn't ask for chunking (4·8M = 32 MiB of uint32 —
#: half of MAX_FRAME, so headers/retries never graze the frame cap).
AUTO_CHUNK_WORDS = 8 << 20

_xfer_ids = itertools.count(1)


class WireClient:
    """One connection to the broker; one outstanding request at a time
    (the learner state machines are strictly sequential). The chunk
    loops below briefly keep a second request in flight — that is safe
    on the same connection because the broker answers frames in order."""

    def __init__(self, host: str, port: int, node: int = 0,
                 interceptor: Optional[Interceptor] = None,
                 retry_backoff: float = 0.02):
        self.host = host
        self.port = port
        self.node = node
        self.interceptor = interceptor
        self.retry_backoff = retry_backoff
        self.bytes_sent = 0
        self.bytes_received = 0
        self.requests = 0
        self.chunk_frames = 0
        self._reader: Optional[asyncio.StreamReader] = None
        self._writer: Optional[asyncio.StreamWriter] = None

    async def connect(self) -> "WireClient":
        self._reader, self._writer = await asyncio.open_connection(
            self.host, self.port)
        return self

    async def close(self) -> None:
        if self._writer is not None:
            self._writer.close()
            try:
                await self._writer.wait_closed()
            except Exception:  # noqa: BLE001
                pass
            self._writer = None
            self._reader = None

    # -- low-level halves (chunk pipelining needs send/recv split) --------
    async def _send(self, op: str, kwargs: dict) -> None:
        """Fire one request frame, interceptor-gated (drops retry here —
        the frame never left, so resending is at-most-once)."""
        body = wire.encode_request(op, kwargs)
        framed = wire.encode_frame(body)
        while True:
            if self.interceptor is not None:
                try:
                    await self.interceptor.on_request(
                        self.node, op, len(framed))
                except DropPacket:
                    await asyncio.sleep(self.retry_backoff)
                    continue
            self._writer.write(framed)
            await self._writer.drain()
            self.bytes_sent += len(framed)
            self.requests += 1
            return

    async def _recv(self, op: str) -> Any:
        resp = await wire.read_frame(self._reader)
        if resp is None:
            raise wire.WireError("broker closed the connection")
        self.bytes_received += len(resp) + 4
        if self.interceptor is not None:
            await self.interceptor.on_response(self.node, op, len(resp) + 4)
        return wire.decode_response(resp)

    async def request(self, op: str, kwargs: dict) -> Any:
        """One RPC. A DropPacket from the interceptor loses the frame
        *before* transmission; we back off and retry (safe: the broker
        never saw it). LearnerCrashed propagates to the runtime."""
        await self._send(op, kwargs)
        return await self._recv(op)

    # -- chunked transfer plane (docs/PROTOCOL.md §6) ---------------------
    async def post_chunked(self, op: str, kwargs: dict, payload_field: str,
                           session: int, chunk_words: int) -> None:
        """Upload one logical post as a chunk stream. Keeps one frame in
        flight ahead of the previous response, so the broker can relay
        chunk k downstream while chunk k+1 is still on this socket.

        An upload the broker supersedes or drops mid-stream (the round
        reset under us, or another active transfer owns the slot) is
        swallowed, not raised: the state machine's own
        ``check_aggregate`` / timeout path observes that the post never
        landed and recovers through the §5.3/§5.4 machinery — exactly
        as it would for an unchunked post lost to a reset."""
        arr = np.ascontiguousarray(kwargs[payload_field]).ravel()
        total = wire.num_chunks(arr.size, chunk_words)
        meta = {k: v for k, v in kwargs.items() if k != payload_field}
        xfer = next(_xfer_ids)

        def frame(seq: int) -> dict:
            return dict(meta, session=session, op=op, xfer=xfer, seq=seq,
                        total=total, chunk_words=chunk_words,
                        payload=wire.chunk_slice(arr, seq, chunk_words))

        await self._send("post_chunk", frame(0))
        for seq in range(1, total):
            await self._send("post_chunk", frame(seq))
            self.chunk_frames += 1
            res = await self._recv("post_chunk")
            if res.get("superseded"):
                # drain the frame already in flight, then stop wasting
                # bytes — this upload lost its slot
                self.chunk_frames += 1
                await self._recv("post_chunk")
                return
        self.chunk_frames += 1
        await self._recv("post_chunk")

    async def get_chunked(self, kind: str, kwargs: dict, session: int,
                          chunk_words: int,
                          deadline: Optional[float]) -> Any:
        """Pull one logical array as a chunk stream, then issue the
        logical consume (``elide_payload=True``) and inject the
        reassembled array into its response. Returns the consume
        response, or ``{"status": "timeout"}`` when the deadline lapses
        mid-stream (matching the plain long-poll contract)."""
        loop = asyncio.get_running_loop()

        def remaining() -> Optional[float]:
            return None if deadline is None else deadline - loop.time()

        def chunk_req(seq: int) -> dict:
            return dict(kwargs, session=session, kind=kind, seq=seq,
                        words=chunk_words, timeout=remaining())

        asm: Optional[wire.ChunkAssembler] = None
        xid: Any = None
        tid: Any = None  # consume-guard timestamp of the current identity
        seq = 0
        outstanding = False  # a get_chunk frame in flight beyond `seq`
        while True:
            rem = remaining()
            if rem is not None and rem <= 0:
                if outstanding:
                    await self._recv("get_chunk")  # drain, then give up
                return {"status": "timeout"}
            if not outstanding:
                await self._send("get_chunk", chunk_req(seq))
            res = await self._recv("get_chunk")
            outstanding = False
            self.chunk_frames += 1
            if res.get("status") == "timeout":
                return res
            if (asm is None or res.get("xfer") != xid
                    or int(res["total"]) != asm.total):
                # first chunk — or the transfer identity changed under
                # us (the array was reposted / re-elected away):
                # restart assembly rather than mix two transfers
                asm = wire.ChunkAssembler(int(res["total"]))
                xid = res.get("xfer")
                tid = None
                seq = 0  # restart the ascending request cursor too
            if res.get("time") is not None:
                tid = res["time"]
            done = asm.add(int(res["seq"]), res["payload"])
            if not done:
                # prefetch the lowest missing chunk (requests go out in
                # ascending order, so advancing a cursor past what we
                # hold finds it in O(1) amortized): its request rides
                # ahead of this chunk's bookkeeping (and of the
                # broker-side wait)
                while seq in asm.chunks:
                    seq += 1
                await self._send("get_chunk", chunk_req(seq))
                outstanding = True
                continue
            # the logical consume, guarded by the streamed entry's
            # timestamp: the broker refuses to consume (and elide) any
            # OTHER posting — a reset racing us parks into the normal
            # timeout path instead of corrupting the round
            final = await self.request(kind, dict(
                kwargs, session=session, elide_payload=True,
                expect_time=tid, timeout=remaining()))
            if final.get("status") == "timeout":
                return final
            field = "aggregate" if kind == "get_aggregate" else "average"
            return dict(final, **{field: asm.assemble()})


async def drive_learner(gen: LearnerGen, client: WireClient, session: int,
                        *, aggregation_timeout: float,
                        timeout_scale: float = 1.0,
                        compute_scale: float = 0.0,
                        chunk_words: Optional[int] = None,
                        payload_words: Optional[int] = None) -> Any:
    """Run one state machine to completion over the wire.

    ``timeout`` mapping for ``wait`` yields: ``"aggregation"`` becomes
    the session's wall-clock aggregation timeout, numeric (virtual
    seconds) scale by ``timeout_scale``, ``None`` waits forever.
    ``compute_scale`` turns the machines' virtual compute costs into
    wall sleeps (0 = infinitely fast learners; the default, since the
    wire plane measures transport, not the cost model).

    With ``chunk_words`` set and ``payload_words`` (the round's vector
    length, weighted word included) exceeding it, array traffic takes
    the chunked plane; the machines are driven unchanged either way.
    """
    chunked = (chunk_words is not None and payload_words is not None
               and payload_words > chunk_words)
    loop = asyncio.get_running_loop()

    def wall_timeout(timeout) -> Optional[float]:
        if timeout == "aggregation":
            return aggregation_timeout
        if timeout is None:
            return None
        return float(timeout) * timeout_scale

    send_value = None
    while True:
        try:
            item = gen.send(send_value)
        except StopIteration as stop:
            return stop.value
        kind = item[0]
        if kind == "compute":
            if compute_scale > 0.0:
                await asyncio.sleep(item[1] * compute_scale)
            send_value = None
        elif kind == "call":
            _, op, kwargs, _nbytes = item
            payload_field = {"post_aggregate": "payload",
                             "post_average": "average"}.get(op)
            arr = kwargs.get(payload_field) if payload_field else None
            if (chunked and isinstance(arr, np.ndarray)
                    and arr.size > chunk_words):
                await client.post_chunked(op, kwargs, payload_field,
                                          session, chunk_words)
                send_value = None
            else:
                send_value = await client.request(
                    op, dict(kwargs, session=session))
        elif kind == "wait":
            _, wkind, kwargs, _nbytes, timeout = item
            wall = wall_timeout(timeout)
            if chunked and wkind in ("get_aggregate", "get_average"):
                deadline = None if wall is None else loop.time() + wall
                send_value = await client.get_chunked(
                    wkind, kwargs, session, chunk_words, deadline)
            else:
                send_value = await client.request(
                    wkind, dict(kwargs, session=session, timeout=wall))
        else:
            raise ValueError(f"unknown yield {item!r}")


@dataclasses.dataclass
class NetResult:
    """Wire-plane mirror of :class:`repro.core.protocol.SimResult` —
    ``stats`` is the broker's MessageStats as a dict (plus totals and
    the chunk-plane frame counters)."""

    average: Optional[np.ndarray]
    weight_avg: Optional[float]
    wall_time: float
    stats: Dict[str, int]
    bytes_sent: int
    monitor_reposts: int
    initiator_elections: int
    crashed_nodes: tuple = ()


async def run_safe_round_net(
    values: np.ndarray,
    addr: Addr,
    *,
    mode: str = "safe",
    subgroups: int = 1,
    failed_nodes: Iterable[int] = (),
    initiator_fails: bool = False,
    weights: Optional[np.ndarray] = None,
    cost: CostModel = EDGE,
    aggregation_timeout: Optional[float] = None,
    symmetric_only: bool = False,
    scale_bits: int = 16,
    provisioning_seed: int = 0xC0FFEE,
    learner_master: int = 0x5EED,
    counter: int = 0,
    interceptor: Optional[Interceptor] = None,
    timeout_scale: float = 1.0,
    compute_scale: float = 0.0,
    chunk_words: Optional[int] = None,
) -> NetResult:
    """One full aggregation round over the wire — the transport twin of
    :func:`repro.core.protocol.run_safe_round` (same signature spirit,
    wall-clock timeouts). Builds the same topology, elects the same
    initiators, constructs the same machines, then runs one asyncio
    task + one TCP connection per live learner against the broker at
    ``addr``.

    ``failed_nodes`` are dead before the round (their clients never
    start — discovered by the broker's monitor, §5.3). ``mode`` must be
    'safe' or 'saf': INSEC needs a parsing, averaging controller, which
    the wire broker deliberately is not (the paper's point).

    ``chunk_words`` enables the chunked transfer plane for payloads
    longer than that many elements; by default it switches on
    automatically once the payload could not safely fit one frame
    (AUTO_CHUNK_WORDS).
    """
    if mode not in ("safe", "saf"):
        raise ValueError(f"wire plane runs 'safe'/'saf', got {mode!r}")
    values = np.asarray(values, np.float32)
    n, V = values.shape
    payload_words = V + 1 if weights is not None else V
    if chunk_words is None and payload_words > AUTO_CHUNK_WORDS:
        chunk_words = wire.DEFAULT_CHUNK_WORDS
    topo = RingTopology(n, subgroups)
    topo.validate_privacy()
    groups = topo.group_chains(node_base=1)
    initiators = {r + 1 for r in topo.elect_initiators()}
    failed = set(failed_nodes)

    machines = build_round_machines(
        values, topo, groups, initiators, mode=mode, weights=weights,
        cost=cost, symmetric_only=symmetric_only, scale_bits=scale_bits,
        provisioning_seed=provisioning_seed, learner_master=learner_master,
        counter=counter, subgroups=subgroups, failed=failed,
        initiator_fails=initiator_fails)

    admin = await WireClient(*addr).connect()
    sid = None
    try:
        created = await admin.request("create_session", {
            "groups": groups, "aggregation_timeout": aggregation_timeout})
        sid = created["session"]
        wall_agg = created["aggregation_timeout"]

        crashed = []

        async def one(node: int, gen: LearnerGen) -> Any:
            client = WireClient(*addr, node=node, interceptor=interceptor)
            await client.connect()
            try:
                return await drive_learner(
                    gen, client, sid, aggregation_timeout=wall_agg,
                    timeout_scale=timeout_scale, compute_scale=compute_scale,
                    chunk_words=chunk_words, payload_words=payload_words)
            except LearnerCrashed:
                crashed.append(node)  # mid-round churn: learner just stops
                return None
            finally:
                admin.bytes_sent += client.bytes_sent
                await client.close()

        t0 = time.perf_counter()
        # return_exceptions: let every learner settle (each closes its
        # own connection in its finally) instead of abandoning running
        # tasks on the first error, then surface the first failure
        settled = await asyncio.gather(
            *(one(node, gen) for node, gen in machines.items()),
            return_exceptions=True)
        for r in settled:
            if isinstance(r, BaseException):
                raise r
        wall = time.perf_counter() - t0

        stats = await admin.request("get_stats", {"session": sid})
        final = await admin.request("peek_average", {"session": sid})
    finally:
        # free the tenant on the broker even when a learner errored —
        # a long-lived broker must not accumulate one Controller per
        # round (best-effort: the broker may already be gone)
        if sid is not None:
            try:
                await admin.request("delete_session", {"session": sid})
            except Exception:  # noqa: BLE001
                pass
        await admin.close()

    return NetResult(
        average=None if final is None else final["average"],
        weight_avg=None if final is None else final.get("weight_avg"),
        wall_time=wall,
        stats=stats,
        bytes_sent=admin.bytes_sent,
        monitor_reposts=stats["monitor_reposts"],
        initiator_elections=stats["initiator_elections"],
        crashed_nodes=tuple(crashed),
    )


async def run_federated_round_net(
    state: Any,
    local_fns: Mapping[int, Callable[[Any], np.ndarray]],
    apply_fn: Callable[[Any, np.ndarray], Any],
    addr: Addr,
    *,
    weights: Optional[np.ndarray] = None,
    counter: int = 0,
    failed_nodes: Iterable[int] = (),
    chunk_words: Optional[int] = None,
    **round_kw,
) -> Tuple[Any, NetResult]:
    """One FedAvg round over the wire plane (the paper's actual use
    case: learners chained, traffic encrypted, controller a broker).

    Each live learner runs its *real* local update — ``local_fns[node]``
    maps the shared model state to that node's f32[P] model delta (built
    by :func:`repro.train.federated.make_wire_federated`; injected as a
    callable so this module never imports JAX) — then the deltas travel
    the SAFE chain through the broker at ``addr``, chunk-streamed when
    longer than ``chunk_words``. The published (weighted) mean delta is
    merged via ``apply_fn`` and the new state returned.

    Local updates run in the default executor so a co-hosted broker (or
    other tenants on this loop) keeps serving while learners compute.
    Callers advance ``counter`` by at least P (+1 when weighted) words
    per round — the pad no-reuse invariant.

    ``failed_nodes`` never compute and never connect: the §5.3/5.4
    failover machinery publishes the survivors' mean, exactly as in the
    paper's dropped-org experiment.
    """
    failed = set(failed_nodes)
    nodes = sorted(local_fns)
    if nodes != list(range(1, len(nodes) + 1)):
        raise ValueError(f"local_fns must be keyed 1..n, got {nodes}")
    if not set(nodes) - failed:
        raise ValueError("no live learners: every node is in failed_nodes")
    loop = asyncio.get_running_loop()
    deltas: Dict[int, np.ndarray] = {}
    for node in nodes:
        if node in failed:
            continue
        out = await loop.run_in_executor(None, local_fns[node], state)
        deltas[node] = np.asarray(out, np.float32).ravel()
    sizes = {d.size for d in deltas.values()}
    if len(sizes) != 1:
        raise ValueError(f"learners produced mixed delta sizes {sizes}")
    values = np.zeros((len(nodes), sizes.pop()), np.float32)
    for node, d in deltas.items():
        values[node - 1] = d

    res = await run_safe_round_net(
        values, addr, weights=weights, counter=counter,
        failed_nodes=failed, chunk_words=chunk_words, **round_kw)
    if res.average is None:
        return state, res
    return apply_fn(state, res.average), res
