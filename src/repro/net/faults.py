"""Pluggable transport faults for the wire runtime.

Interceptors sit inside :class:`repro.net.client.WireClient` around each
request/response and perturb the transport the way the paper's
evaluation perturbs its testbed: latency (the deep-edge profiles of
§7), request loss, and learner crash/churn schedules (§5.3–5.4). They
never touch protocol state — failover is exercised end-to-end through
the *real* monitor/repost/re-election machinery on the broker.

Hook contract (all coroutines, called by the client):

  ``on_request(node, op, nbytes)``  before a request frame is sent.
    May sleep (latency), raise :class:`DropPacket` (the frame never
    leaves the host; the client backs off and retries — safe because
    the broker never saw it), or raise :class:`LearnerCrashed` (the
    learner runtime stops driving this node's state machine mid-round).
    Chunked transfers (docs/PROTOCOL.md §6) pass through the same hook
    one frame at a time (``op`` is ``post_chunk``/``get_chunk``), so a
    drop loses a single chunk (retried) and a churn schedule can kill a
    learner mid-upload — both exercised in tests/test_net.py.
  ``on_response(node, op, nbytes)`` after a response frame is read.
    May sleep. Drops are deliberately *not* supported here: the broker
    has already executed the (possibly consuming) op, so retrying would
    need request dedup — out of scope, and the paper's failure model
    (node crashes, not byzantine links) doesn't need it.

Fault draws use a seeded ``numpy`` RNG keyed by (seed, node), so within
one round runtime a learner's fault plan is reproducible regardless of
asyncio interleaving. One interceptor instance covers one tenant's
round: sharing an instance across concurrent tenants whose learners
reuse node ids would interleave draws from the shared per-node streams
in scheduler order — give each tenant its own instance (seeded per
tenant) when reproducibility across tenants matters, e.g. via the
factory form ``loadgen.run_protocol_load(interceptor=lambda t: ...)``.
"""
from __future__ import annotations

import asyncio
from typing import Dict, Iterable, Optional, Tuple

import numpy as np


class DropPacket(Exception):
    """Raised by an interceptor: this request frame is lost in transit."""


class LearnerCrashed(Exception):
    """Raised by an interceptor: this learner dies now (churn schedule)."""

    def __init__(self, node: int, after_ops: int):
        super().__init__(f"learner {node} crashed after {after_ops} ops")
        self.node = node
        self.after_ops = after_ops


class Interceptor:
    """Base: a transparent transport."""

    async def on_request(self, node: int, op: str, nbytes: int) -> None:
        return None

    async def on_response(self, node: int, op: str, nbytes: int) -> None:
        return None


class Chain(Interceptor):
    """Compose interceptors; hooks run in order."""

    def __init__(self, *parts: Interceptor):
        self.parts = parts

    async def on_request(self, node, op, nbytes):
        for p in self.parts:
            await p.on_request(node, op, nbytes)

    async def on_response(self, node, op, nbytes):
        for p in self.parts:
            await p.on_response(node, op, nbytes)


def _node_rng(seed: int, node: int) -> np.random.RandomState:
    return np.random.RandomState((seed * 1_000_003 + node) % 2**31)


class LatencyInterceptor(Interceptor):
    """Per-packet delay: ``floor + Exp(mean)`` seconds, independently on
    the request and response path (so one RPC pays two draws, like a
    real RTT). Deterministic per node for a given seed."""

    def __init__(self, mean: float = 0.002, floor: float = 0.0,
                 seed: int = 0):
        self.mean = mean
        self.floor = floor
        self.seed = seed
        self._rngs: Dict[int, np.random.RandomState] = {}

    def _draw(self, node: int) -> float:
        rng = self._rngs.get(node)
        if rng is None:
            rng = self._rngs[node] = _node_rng(self.seed, node)
        return self.floor + float(rng.exponential(self.mean))

    async def on_request(self, node, op, nbytes):
        await asyncio.sleep(self._draw(node))

    async def on_response(self, node, op, nbytes):
        await asyncio.sleep(self._draw(node))


class DropInterceptor(Interceptor):
    """Drop request frames with probability ``p`` (client retries after
    backoff). Only the request path — see module docstring."""

    def __init__(self, p: float = 0.05, seed: int = 0):
        if not 0.0 <= p < 1.0:
            raise ValueError(f"drop probability must be in [0, 1), got {p}")
        self.p = p
        self.seed = seed
        self._rngs: Dict[int, np.random.RandomState] = {}
        self.dropped = 0

    async def on_request(self, node, op, nbytes):
        rng = self._rngs.get(node)
        if rng is None:
            rng = self._rngs[node] = _node_rng(self.seed, node)
        if rng.uniform() < self.p:
            self.dropped += 1
            raise DropPacket(f"request {op} from node {node} dropped")


class ChurnInterceptor(Interceptor):
    """Crash schedule: node ``i`` dies just before issuing its
    ``crash_after[i] + 1``-th request (ops counted per node across its
    whole round, long-polls included). A crashed learner simply stops —
    its unconsumed postings and silent long-poll targets are what drive
    the broker's §5.3 repost / §5.4 re-election machinery."""

    def __init__(self, crash_after: Dict[int, int]):
        self.crash_after = dict(crash_after)
        self._ops: Dict[int, int] = {}
        self.crashed: set = set()

    async def on_request(self, node, op, nbytes):
        limit = self.crash_after.get(node)
        if limit is None:
            return
        done = self._ops.get(node, 0)
        if done >= limit:
            self.crashed.add(node)
            raise LearnerCrashed(node, done)
        self._ops[node] = done + 1


class HeavyTailLatencyInterceptor(Interceptor):
    """Per-packet delay ``floor + LogNormal(median, sigma)`` seconds —
    the heavy-tailed WAN regime (bufferbloat, cellular links) whose p99
    an exponential model badly understates. Parameterized by the
    *median* one-way delay: for ``X = median · exp(sigma·Z)`` with
    standard-normal Z, the declared analytic percentiles are

        p50 = median,   p(q) = median · exp(sigma · z_q)

    (z_90 ≈ 1.2816, z_99 ≈ 2.3263) — pinned within sampling tolerance
    by the property tests in tests/test_faults.py, so WAN benchmark
    rows annotate a distribution the code actually draws from.
    Deterministic per (seed, node), like :class:`LatencyInterceptor`.
    """

    #: standard-normal quantiles for the declared-percentile contract
    Z90 = 1.2816
    Z99 = 2.3263

    def __init__(self, median: float = 0.05, sigma: float = 0.8,
                 floor: float = 0.0, seed: int = 0):
        if median <= 0 or sigma <= 0:
            raise ValueError("median and sigma must be positive")
        self.median = median
        self.sigma = sigma
        self.floor = floor
        self.seed = seed
        self._rngs: Dict[int, np.random.RandomState] = {}

    def declared_percentile(self, q: float) -> float:
        """Analytic one-way-delay percentile (seconds), floor included.
        Only p50/p90/p99 are declared — a full inverse normal CDF is
        more precision than the contract needs."""
        z = {50.0: 0.0, 90.0: self.Z90, 99.0: self.Z99}.get(float(q))
        if z is None:
            raise ValueError(f"declared percentiles are 50/90/99, not {q}")
        return self.floor + self.median * float(np.exp(self.sigma * z))

    def _draw(self, node: int) -> float:
        rng = self._rngs.get(node)
        if rng is None:
            rng = self._rngs[node] = _node_rng(self.seed, node)
        return self.floor + float(
            self.median * np.exp(self.sigma * rng.standard_normal()))

    async def on_request(self, node, op, nbytes):
        await asyncio.sleep(self._draw(node))

    async def on_response(self, node, op, nbytes):
        await asyncio.sleep(self._draw(node))


#: WAN calibration profiles (ISSUE 8): named link regimes spanning the
#: paper-relevant 10–200 ms RTT range, with loss and tail shape. Each
#: value is metadata — ``make_wan_interceptor`` turns a profile into a
#: fresh interceptor chain (one per tenant; see module docstring), and
#: benchmark rows annotate these declared numbers next to measured
#: wall-clock (the PR 5 honesty convention). ``rtt_ms`` is the nominal
#: round-trip: one RPC pays two one-way draws of rtt/2 each.
WAN_PROFILES: Dict[str, dict] = {
    # clean metro fiber: low RTT, no loss, light exponential jitter
    "metro": {"kind": "exp", "rtt_ms": 10.0, "loss": 0.0},
    # cross-continent: moderate RTT, occasional loss
    "continental": {"kind": "exp", "rtt_ms": 50.0, "loss": 0.01},
    # intercontinental + bufferbloat: 200 ms RTT, lossy, lognormal tail
    # (sigma 0.8: declared p99 ≈ 6.4x the median one-way delay)
    "intercontinental_tail": {"kind": "lognormal", "rtt_ms": 200.0,
                              "loss": 0.02, "sigma": 0.8},
}


def make_wan_interceptor(profile: str, seed: int = 0) -> Interceptor:
    """Instantiate one WAN profile as an interceptor chain.

    ``exp`` profiles draw ``floor + Exp(mean)`` per direction with
    ``floor = mean = rtt/4`` (so the *mean* one-way delay is rtt/2 and
    the nominal RTT is paid per RPC on average); ``lognormal`` profiles
    put the one-way *median* at rtt/2 — the tail runs far beyond the
    nominal RTT, which is the point. Loss applies on the request path
    (client retries behind deterministic backoff).
    """
    meta = WAN_PROFILES.get(profile)
    if meta is None:
        raise ValueError(
            f"unknown WAN profile {profile!r} (have {sorted(WAN_PROFILES)})")
    one_way = meta["rtt_ms"] / 2e3  # seconds
    if meta["kind"] == "exp":
        lat: Interceptor = LatencyInterceptor(
            mean=one_way / 2, floor=one_way / 2, seed=seed)
    else:
        lat = HeavyTailLatencyInterceptor(
            median=one_way, sigma=meta["sigma"], seed=seed)
    if meta["loss"] > 0:
        return Chain(lat, DropInterceptor(p=meta["loss"], seed=seed + 1))
    return lat


def deep_edge_faults(seed: int = 0, mean_latency: float = 0.02,
                     drop_p: float = 0.02,
                     crash_after: Optional[Dict[int, int]] = None
                     ) -> Interceptor:
    """Convenience preset: lossy high-latency edge links plus an
    optional churn schedule — the §7 constrained-platform flavour."""
    parts: Tuple[Interceptor, ...] = (
        LatencyInterceptor(mean=mean_latency, seed=seed),
        DropInterceptor(p=drop_p, seed=seed + 1),
    )
    if crash_after:
        parts = parts + (ChurnInterceptor(crash_after),)
    return Chain(*parts)
