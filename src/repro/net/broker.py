"""Asyncio broker server: the SAFE controller behind the wire codec.

The paper's claim is that chain aggregation "reduces the controller of
the aggregation to a mere message broker" (§5, Appendix A). This module
is that broker as a real server: an asyncio TCP listener speaking
:mod:`repro.net.wire`, with

  * the *identical* :class:`repro.core.controller.Controller` per tenant
    session — the broker adds transport, long-poll scheduling and a
    wall clock, never protocol semantics (dispatch goes through the
    same ``call``/``probe``/``consume`` registry the discrete-event
    kernel uses);
  * long-poll waits: ``check_aggregate`` / ``get_aggregate`` /
    ``get_average`` park on a per-session condition until the probe is
    satisfiable or the client's timeout lapses (timeouts do **not**
    touch the message counters — exactly the sim kernel's accounting);
  * the external progress monitor (§5.3) as a background task ordering
    reposts on wall-clock timeouts;
  * optionally, an *engine plane*: ``submit_session``/``wait_session``
    ops that feed a :class:`repro.serve.agg_engine.AggregationEngine`,
    so many wire tenants batch through one compiled device program;
  * the *chunked transfer plane* (docs/PROTOCOL.md §6): arrays larger
    than one frame stream as ``post_chunk``/``get_chunk`` frames with
    per-chunk sequence numbers. The broker is store-and-forward at
    chunk granularity — a downstream learner can pull chunk k of a
    transfer whose chunk k+1 is still uploading, so chain hops overlap
    (the §8 pipelined schedule, at the wire). Chunk frames never touch
    ``MessageStats`` (one completed transfer = one logical message);
    they are tallied separately in ``get_stats``.

One TCP connection serves one client; requests on a connection are
processed in order (a parked long-poll blocks only its own connection),
which matches the one-outstanding-request HTTP clients of the paper's
deployment.
"""
from __future__ import annotations

import asyncio
import dataclasses
import itertools
import secrets
import ssl as _ssl
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.bon_controller import BON_CALL_OPS, BON_TIMED_OPS, \
    BON_WAIT_KINDS, BonController
from repro.core.controller import CALL_OPS, TIMED_OPS, WAIT_KINDS, \
    Controller, ParentController
from repro.net import wire
from repro.obs import MetricsRegistry, Tracer

#: Default per-session in-flight-chunk-bytes budget (ISSUE 7 admission
#: control): the sum of a session's buffered-but-not-yet-posted
#: transfer bytes past which new transfers are answered
#: ``{"status": "busy", "retry_after": t}`` instead of buffered. Sized
#: far above any legitimate round (4x MAX_FRAME) so only a genuinely
#: flooding tenant — many concurrent un-posted uploads — is shed, and
#: only sheds *itself* (the budget is per session). ``None`` disables.
DEFAULT_CHUNK_BUDGET_BYTES = 4 * wire.MAX_FRAME

#: Session ops whose kwargs name the acting learner (PROTOCOL.md §15):
#: a node-scoped token must match this field, so node A cannot post,
#: consume or elect as node B. ``get_key(node=...)`` names the key's
#: OWNER, not the caller (any chain neighbour fetches it) — absent here
#: by design. Chunk frames check the field of the logical op they carry.
IDENTITY_FIELDS = {
    "post_aggregate": "from_node",
    "post_average": "node",
    "check_aggregate": "node",
    "get_aggregate": "node",
    "should_initiate": "node",
    "register_key": "node",
}

#: Session ops only the session-scoped (admin) token may invoke:
#: destructive round/lifecycle control a single learner must not hold.
ADMIN_ONLY_OPS = frozenset({
    "reset_round", "advance_round", "delete_session",
})


def _auth_failed(op: str, reason: str) -> dict:
    """The counted-neutral rejection (PROTOCOL.md §15): an OK-framed
    response no Controller ever sees — uncounted, untimed, exactly like
    the admin-class ops, so the §5 closed forms cannot observe a denied
    request."""
    return {"status": "auth_failed", "op": op, "reason": reason}


class _Transfer:
    """One in-flight chunked upload (docs/PROTOCOL.md §6).

    Keyed on the session by the round and the *destination* of the
    eventual logical op — ``("agg", round, group, to_node)`` for
    post_aggregate, ``("avg", round, group)`` for post_average — so the
    receiving side can stream chunks out of a partially-arrived transfer
    (the §8-style pipelining: the broker relays chunk k downstream while
    chunk k+1 is still uploading), and so round r+1's transfers coexist
    with round r's while the tail drains (§11 cross-round pipelining).
    A transfer for a round ahead of the session's current one buffers
    and relays normally but its logical op is *deferred*: ``posted``
    stays False (with ``asm.complete`` True) until ``advance_round``
    delivers it — MessageStats only ever moves for the current round.
    """

    __slots__ = ("owner", "xfer", "op", "kwargs", "asm", "chunk_words",
                 "posted", "last_chunk_at", "created_at", "nbytes")

    def __init__(self, owner: int, xfer: int, op: str, kwargs: dict,
                 total: int, chunk_words: int, now: float):
        # transfer identity is (owner, xfer): xfer counters are only
        # unique per uploader process, so two orgs' streams must never
        # be merged on a bare xfer match
        self.owner = owner
        self.xfer = xfer
        self.op = op
        self.kwargs = kwargs      # logical-op kwargs minus the payload
        self.asm = wire.ChunkAssembler(total)
        self.chunk_words = chunk_words
        self.posted = False       # logical op executed (transfer complete)
        self.last_chunk_at = now  # staleness clock for slot ownership
        self.created_at = now     # trace span start (ISSUE 7)
        self.nbytes = 0           # buffered payload bytes (backlog series)

    def same_transfer(self, owner: int, xfer: int) -> bool:
        return self.owner == owner and self.xfer == xfer


class _Session:
    """One tenant: a Controller plus the broker-side wait machinery."""

    __slots__ = ("sid", "ctrl", "bon", "cond", "closed", "monitor_reposts",
                 "initiator_elections", "transfers", "chunk_frames_in",
                 "chunk_frames_out", "transfers_completed",
                 # cross-round pipelining (PROTOCOL.md §11)
                 "round", "chunk_frames_future",
                 # observability plane (ISSUE 7) — observes, never alters
                 "round_t0", "round_published", "rounds_completed",
                 "pending_bytes", "busy_rejections",
                 # transport hardening (PROTOCOL.md §15)
                 "token", "node_tokens", "token_nodes", "auth_failures",
                 # hierarchical chain-of-chains (PROTOCOL.md §15, §5.10)
                 "parent", "upstream", "org_average", "parent_global",
                 "uplink_errors")

    def __init__(self, sid: int, ctrl: Controller, now: float = 0.0,
                 bon: Optional[BonController] = None,
                 parent: Optional[ParentController] = None,
                 upstream: Optional[dict] = None):
        self.sid = sid
        self.ctrl = ctrl
        # BON tenant (PROTOCOL.md §14): the session speaks the baseline
        # protocol instead; SAFE ops still see a (quiescent) Controller
        self.bon = bon
        # hierarchical roles (PROTOCOL.md §15): a PARENT session folds
        # anonymized org averages (ParentController); a CHILD session
        # posts its own global (= org average) UP to `upstream` on
        # publication and withholds learners' get_average until the
        # parent's fold comes back down
        self.parent = parent
        self.upstream = upstream
        self.org_average: Optional[dict] = None   # child: own fold snapshot
        self.parent_global: Optional[dict] = None  # child: installed fold
        self.uplink_errors = 0
        # transport hardening (PROTOCOL.md §15): a session-scoped admin
        # token plus one token per enrolled node, minted at creation,
        # rotated wholesale by reset_round (stale rounds cannot replay)
        self.token = secrets.token_hex(16)
        self.node_tokens: Dict[int, str] = {
            n: secrets.token_hex(16)
            for chain in ctrl.groups.values() for n in chain}
        self.token_nodes: Dict[str, int] = {
            t: n for n, t in self.node_tokens.items()}
        self.auth_failures = 0
        self.cond = asyncio.Condition()
        self.closed = False
        self.monitor_reposts = 0
        self.initiator_elections = 0
        # chunked-transfer plane (never touches MessageStats)
        self.transfers: Dict[tuple, _Transfer] = {}
        self.chunk_frames_in = 0
        self.chunk_frames_out = 0
        self.transfers_completed = 0
        # cross-round pipelining (§11): the session's current round —
        # ops tagged with a later round park/buffer until advance_round
        # catches up; untagged ops always address the current round
        self.round = 0
        #: chunk frames accepted for a round AHEAD of the current one —
        #: the direct evidence that round r+1's bytes moved while round
        #: r was still open (asserted by the pipelining tests/bench)
        self.chunk_frames_future = 0
        # round lifecycle series: round_t0 restarts at create/reset, the
        # latency histogram observes it on global publication
        self.round_t0 = now
        self.round_published = False
        self.rounds_completed = 0
        # admission control: buffered-but-un-posted transfer bytes
        self.pending_bytes = 0
        self.busy_rejections = 0

    def rotate_tokens(self) -> dict:
        """Mint a fresh admin token and fresh per-node tokens (the
        reset_round rotation, PROTOCOL.md §15): every credential of the
        aborted round is dead, so a captured token cannot replay into
        the restarted round. Returns the wire-shaped grant."""
        self.token = secrets.token_hex(16)
        self.node_tokens = {n: secrets.token_hex(16)
                            for n in self.node_tokens}
        self.token_nodes = {t: n for n, t in self.node_tokens.items()}
        return {"token": self.token, "node_tokens": dict(self.node_tokens)}

    def forget_transfer(self, key: tuple) -> Optional[_Transfer]:
        """The single transfer-removal path: un-posted buffers leave the
        backlog accounting when they leave the table (posted buffers
        already left it at posting time)."""
        tr = self.transfers.pop(key, None)
        if tr is not None and not tr.posted:
            self.pending_bytes -= tr.nbytes
        return tr

    def drop_group_transfers(self, group: int) -> None:
        """Forget every (partial or posted) transfer of one group in the
        CURRENT round — the round restarted (§5.4), so its stale chunks
        must not be served. Buffers already accepted for later rounds
        survive the restart (cross-round pipelining, §11): the restart
        replays only the round that aborted."""
        for key in [k for k in self.transfers
                    if k[1] == self.round and k[2] == group]:
            self.forget_transfer(key)

    def clear_transfers(self) -> None:
        for key in list(self.transfers):
            self.forget_transfer(key)


async def _cond_wait(cond: asyncio.Condition, deadline: Optional[float]) -> bool:
    """One parked wait on ``cond`` (held). Returns False when the
    deadline lapsed, True when notified — callers re-check their
    predicate either way. The single place that owns the
    wait_for/Condition timeout interaction."""
    if deadline is None:
        await cond.wait()
        return True
    remaining = deadline - asyncio.get_running_loop().time()
    if remaining <= 0:
        return False
    try:
        await asyncio.wait_for(cond.wait(), remaining)
    except asyncio.TimeoutError:
        return False
    return True


async def _park(cond: asyncio.Condition, probe, deadline: Optional[float]):
    """The broker's long-poll skeleton, shared by every parked wait
    (protocol waits, chunk reads, engine-session waits): hold ``cond``,
    re-run ``probe`` on each wakeup, return its first non-None result —
    or None when the deadline lapses. The loop's load-bearing subtlety
    lives here once: after a lapsed deadline the probe runs one final
    time, so a notify racing the timeout is never a spurious timeout.
    ``probe`` executes under the condition lock; it may raise (session
    deleted) and may perform consuming side effects on success."""
    async with cond:
        timed_out = False
        while True:
            res = probe()
            if res is not None:
                return res
            if timed_out:
                return None
            timed_out = not await _cond_wait(cond, deadline)


class SafeBroker:
    """Wire-level SAFE broker (protocol plane + optional engine plane).

    Args:
      aggregation_timeout: default §5.4 round timeout (wall seconds) for
        sessions that don't specify their own.
      progress_timeout: §5.3 stuck-posting threshold (wall seconds).
      monitor_interval: progress-monitor tick period.
      engine: optional ``AggregationEngine``; enables ``submit_session``
        / ``wait_session``. The engine is stepped on the event loop (its
        ``step()`` is one compiled-program dispatch), with completion
        signalled through the engine's ``on_complete`` hook.
    """

    def __init__(self, aggregation_timeout: float = 30.0,
                 progress_timeout: float = 1.0,
                 monitor_interval: float = 0.25,
                 engine=None, engine_session_ttl: float = 300.0,
                 chunk_budget_bytes: Optional[int]
                 = DEFAULT_CHUNK_BUDGET_BYTES,
                 busy_retry_after: float = 0.05,
                 inflight_rounds: int = 2,
                 metrics: Optional[MetricsRegistry] = None,
                 tracer: Optional[Tracer] = None,
                 ssl_certfile: Optional[str] = None,
                 ssl_keyfile: Optional[str] = None):
        # optional TLS (PROTOCOL.md §15): kept as PATHS, not a built
        # SSLContext, so a sharded deployment can pickle them across the
        # worker-process spawn; the context is built in start()
        self.ssl_certfile = ssl_certfile
        self.ssl_keyfile = ssl_keyfile
        self.aggregation_timeout = aggregation_timeout
        self.progress_timeout = progress_timeout
        self.monitor_interval = monitor_interval
        self.engine_session_ttl = engine_session_ttl
        # cross-round pipelining window (PROTOCOL.md §11): chunk frames
        # tagged for rounds [current, current + inflight_rounds) are
        # accepted; frames beyond the window answer busy (the client's
        # ordinary backoff retries until advance_round opens it)
        self.inflight_rounds = max(1, int(inflight_rounds))
        # admission control (ISSUE 7, PROTOCOL.md §13): per-session
        # budget on buffered-but-un-posted chunk bytes; the suggested
        # client back-off rides the busy response
        self.chunk_budget_bytes = chunk_budget_bytes
        self.busy_retry_after = busy_retry_after
        # observability plane (ISSUE 7): a per-broker registry (each
        # shard worker process reports its own series) and a ring-buffer
        # tracer, disabled unless a caller opts in
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.tracer = tracer if tracer is not None else Tracer()
        self._m_rounds = self.metrics.counter("safe_rounds_completed_total")
        self._m_round_lat = self.metrics.histogram(
            "safe_round_latency_seconds")
        self._m_reposts = self.metrics.counter("safe_monitor_reposts_total")
        self._m_elections = self.metrics.counter(
            "safe_initiator_elections_total")
        self._m_busy = self.metrics.counter("safe_busy_responses_total")
        self._m_chunks_in = self.metrics.counter(
            "safe_chunk_frames_in_total")
        self._m_chunks_out = self.metrics.counter(
            "safe_chunk_frames_out_total")
        self._m_transfers = self.metrics.counter(
            "safe_transfers_completed_total")
        self._m_sessions_created = self.metrics.counter(
            "safe_sessions_created_total")
        self._m_redirects = self.metrics.counter("safe_redirects_total")
        self._m_active = self.metrics.gauge("safe_active_sessions")
        self._m_backlog = self.metrics.gauge("safe_chunk_backlog_bytes")
        self._sessions: Dict[int, _Session] = {}
        self._sids = itertools.count()
        self._server: Optional[asyncio.AbstractServer] = None
        self._extra_servers: list = []
        self._tasks: list = []
        self._conn_tasks: set = set()
        self._t0 = 0.0
        #: §5.3 monitor passes that hit a tenant exception (observability
        #: for the per-session guard in _monitor_loop)
        self.monitor_errors = 0
        #: engine steps that raised (the loop keeps serving; see
        #: _engine_loop's guard)
        self.engine_errors = 0
        # engine plane
        self.engine = engine
        self._engine_sessions: Dict[int, object] = {}
        # engine-plane chunked transfers (oversized submit values /
        # result fetches routed over the §6 transfer plane): staged
        # uploads keyed (owner, xfer); flattened result cache per sid
        self._engine_uploads: Dict[tuple, dict] = {}
        self._engine_flat: Dict[int, np.ndarray] = {}
        self.engine_chunk_frames_in = 0
        self.engine_chunk_frames_out = 0
        # sid -> completion wall time; entries older than
        # engine_session_ttl are pruned (abandoned submissions — a
        # tenant that crashed between submit_session and wait_session
        # must not pin its AggSession forever)
        self._engine_done: Dict[int, float] = {}
        self._engine_cond = asyncio.Condition()
        self._engine_wake = asyncio.Event()
        if engine is not None:
            # completion hook fires inside step() on the event-loop
            # thread; waiters are notified after the step returns.
            engine.on_complete = (
                lambda sess: self._engine_done.setdefault(
                    sess.sid, asyncio.get_running_loop().time()))

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    async def start(self, host: str = "127.0.0.1", port: int = 0,
                    *, reuse_port: bool = False) -> Tuple[str, int]:
        """Bind and serve; returns the (host, port) actually bound.

        ``reuse_port`` sets ``SO_REUSEPORT`` on the listener so several
        broker processes can share one port (the sharded runtime,
        repro.net.shard)."""
        loop = asyncio.get_running_loop()
        self._t0 = loop.time()
        self._server = await asyncio.start_server(
            self._handle, host, port, reuse_port=reuse_port or None,
            ssl=self._server_ssl())
        self._tasks.append(asyncio.ensure_future(self._monitor_loop()))
        if self.engine is not None:
            self._tasks.append(asyncio.ensure_future(self._engine_loop()))
        sock = self._server.sockets[0]
        addr = sock.getsockname()
        return addr[0], addr[1]

    async def add_listener(self, host: str, port: int,
                           *, reuse_port: bool = False) -> Tuple[str, int]:
        """Serve the same broker on an additional address — a sharded
        worker answers on its direct per-shard port AND the shared
        ``SO_REUSEPORT`` port. Closed with the broker on ``stop()``."""
        server = await asyncio.start_server(
            self._handle, host, port, reuse_port=reuse_port or None,
            ssl=self._server_ssl())
        self._extra_servers.append(server)
        addr = server.sockets[0].getsockname()
        return addr[0], addr[1]

    def _server_ssl(self) -> Optional[_ssl.SSLContext]:
        """Server-side TLS context from the configured cert/key paths —
        built lazily per listener (contexts are not picklable; the
        sharded workers each build their own)."""
        if self.ssl_certfile is None:
            return None
        ctx = _ssl.SSLContext(_ssl.PROTOCOL_TLS_SERVER)
        ctx.load_cert_chain(self.ssl_certfile, self.ssl_keyfile)
        return ctx

    async def stop(self) -> None:
        # stop accepting FIRST so no handler can slip in behind the
        # cancellation snapshot below
        if self._server is not None:
            self._server.close()
        for server in self._extra_servers:
            server.close()
        # cancel parked connection handlers too: a client long-polling
        # with timeout=None would otherwise leak (and on Python >= 3.12
        # make Server.wait_closed() block forever)
        pending = list(self._tasks) + list(self._conn_tasks)
        for t in pending:
            t.cancel()
        for t in pending:
            try:
                await t
            except (asyncio.CancelledError, Exception):
                pass
        # second sweep: a connection accepted just before close() only
        # registers once its handler task first runs, which may be
        # during the awaits above — the accept stream is closed, so
        # this drains in finitely many passes
        while self._conn_tasks:
            late = list(self._conn_tasks)
            self._conn_tasks.difference_update(late)
            for t in late:
                t.cancel()
            for t in late:
                try:
                    await t
                except (asyncio.CancelledError, Exception):
                    pass
        self._tasks.clear()
        if self._server is not None:
            await self._server.wait_closed()
            self._server = None
        for server in self._extra_servers:
            await server.wait_closed()
        self._extra_servers.clear()

    def now(self) -> float:
        """Broker wall clock (seconds since start) — the ``now`` every
        Controller call sees, mirroring the sim's virtual clock."""
        return asyncio.get_running_loop().time() - self._t0

    # ------------------------------------------------------------------
    # connection handling
    # ------------------------------------------------------------------
    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        self._conn_tasks.add(asyncio.current_task())
        try:
            while True:
                body = await wire.read_frame(reader)
                if body is None:
                    break
                try:
                    # zero-copy relay (PROTOCOL.md §12): array values
                    # decode as read-only views into the frame buffer —
                    # the broker stores and re-serves payloads, never
                    # does arithmetic on them (except §5.5 averaging of
                    # group averages, which allocates fresh output)
                    op, kwargs = wire.decode_request(body,
                                                     copy_arrays=False)
                    payload = await self._dispatch(op, kwargs)
                    out = wire.encode_response_parts(payload)
                except asyncio.CancelledError:
                    raise
                except wire.WireError as e:
                    out = [wire.encode_error(str(e))]
                except Exception as e:  # noqa: BLE001 — report, keep serving
                    out = [wire.encode_error(f"{type(e).__name__}: {e}")]
                try:
                    framed = wire.encode_frame_parts(out)
                except wire.WireError as e:
                    # response exceeded MAX_FRAME (e.g. a wait_session
                    # result with many large rounds): answer with the
                    # error instead of dying mid-connection
                    framed = [wire.encode_frame(wire.encode_error(str(e)))]
                # scatter-gather: relayed chunk payloads go to the
                # socket straight from the receive buffer they arrived
                # in — no per-frame copy on the hot path
                writer.writelines(framed)
                await writer.drain()
        except (asyncio.IncompleteReadError, ConnectionResetError,
                wire.WireDecodeError, asyncio.CancelledError):
            pass  # client went away / stream corrupt / shutdown
        finally:
            self._conn_tasks.discard(asyncio.current_task())
            writer.close()
            try:
                await writer.wait_closed()
            except Exception:  # noqa: BLE001
                pass

    def _session(self, kwargs: dict) -> _Session:
        sid = kwargs.pop("session", None)
        sess = self._sessions.get(sid)
        if sess is None:
            raise wire.WireError(f"unknown session {sid!r}")
        return sess

    def _check_auth(self, sess: _Session, op: str,
                    kwargs: dict) -> Optional[dict]:
        """Token gate for every session-addressed op (PROTOCOL.md §15).

        Returns None when the request may proceed, or the
        counted-neutral ``auth_failed`` response. Three rules:

        * every op must present the session's admin token or one of its
          per-node tokens (minted at create_session, rotated wholesale
          by reset_round — a stale round's credential never replays);
        * a node token must match the op's identity field
          (``IDENTITY_FIELDS``) — node A cannot post, consume or elect
          as node B. Chunk frames are checked against the logical op
          they carry;
        * round/lifecycle control (``ADMIN_ONLY_OPS``) takes the admin
          token only.

        The check runs before any Controller interaction and the denial
        is an ordinary OK-framed response: uncounted, untimed, invisible
        to MessageStats and the §5 closed forms.
        """
        token = kwargs.pop("token", None)
        if token is None:
            sess.auth_failures += 1
            return _auth_failed(op, "missing token")
        if token == sess.token:
            return None  # session-scoped (admin) token: any op
        node = sess.token_nodes.get(token)
        if node is None:
            sess.auth_failures += 1
            return _auth_failed(op, "unknown token")
        if op in ADMIN_ONLY_OPS:
            sess.auth_failures += 1
            return _auth_failed(op, f"{op} needs the session token")
        # chunk frames authenticate as the logical op they carry
        field = IDENTITY_FIELDS.get(op)
        if op == "post_chunk":
            field = IDENTITY_FIELDS.get(kwargs.get("op"))
        elif op == "get_chunk":
            field = IDENTITY_FIELDS.get(kwargs.get("kind"))
        if field is not None and field in kwargs \
                and int(kwargs[field]) != node:
            sess.auth_failures += 1
            return _auth_failed(
                op, f"token of node {node} cannot act as "
                    f"{field}={kwargs[field]}")
        return None

    def _shard_map(self) -> dict:
        """Shard topology for shard-aware clients (PROTOCOL.md §12).
        The single-process broker is its own sole shard; the sharded
        runtime (repro.net.shard) overrides this with the real map."""
        return {"shards": 1, "shard": 0, "ports": [], "shard_alive": [True]}

    # ------------------------------------------------------------------
    # observability plane (ISSUE 7, docs/PROTOCOL.md §13)
    # ------------------------------------------------------------------
    def _refresh_gauges(self) -> None:
        """Point-in-time gauges are computed at read time (the hot path
        never sums across sessions)."""
        self._m_backlog.set(
            sum(s.pending_bytes for s in self._sessions.values()))
        self._m_active.set(len(self._sessions))

    def _get_metrics(self, kwargs: dict) -> dict:
        """Live metrics snapshot (opcode ``get_metrics``, admin-class:
        uncounted, untimed — MessageStats and the §5 closed forms cannot
        see it). ``session`` (optional) narrows the per-session map; on
        a sharded broker a session-addressed request redirects to the
        owner like any other session op, a sessionless one is answered
        by whichever worker the socket reached (per-shard series)."""
        self._refresh_gauges()
        up = self.now()
        rate_base = max(up, 1e-9)
        only = kwargs.get("session")
        sessions = {}
        for sid, s in self._sessions.items():
            if only is not None and sid != only:
                continue
            sessions[sid] = {
                "rounds_completed": s.rounds_completed,
                "monitor_reposts": s.monitor_reposts,
                "initiator_elections": s.initiator_elections,
                "chunk_backlog_bytes": s.pending_bytes,
                "transfers_completed": s.transfers_completed,
                "busy_rejections": s.busy_rejections,
            }
        shard_map = self._shard_map()
        return {
            "uptime_s": up,
            "shard": shard_map.get("shard"),
            "shards": shard_map.get("shards"),
            "rounds_completed": self._m_rounds.value,
            "rounds_per_s": self._m_rounds.value / rate_base,
            "round_latency_p50_s": self._m_round_lat.percentile(50.0),
            "round_latency_p99_s": self._m_round_lat.percentile(99.0),
            "monitor_reposts": self._m_reposts.value,
            "initiator_elections": self._m_elections.value,
            "busy_rejections": self._m_busy.value,
            "redirects": self._m_redirects.value,
            "chunk_backlog_bytes": int(self._m_backlog.value),
            "active_sessions": len(self._sessions),
            "sessions": sessions,
            "series": self.metrics.snapshot(),
            "trace_spans": len(self.tracer),
        }

    async def start_metrics_http(self, host: str = "127.0.0.1",
                                 port: int = 0) -> Tuple[str, int]:
        """Optional plaintext HTTP exporter: ``GET /metrics`` answers
        the registry in Prometheus text exposition format (stdlib only
        — a hand-rolled HTTP/1.0 responder, one request per
        connection). Closed with the broker on ``stop()``."""
        server = await asyncio.start_server(
            self._handle_metrics_http, host, port)
        self._extra_servers.append(server)
        addr = server.sockets[0].getsockname()
        return addr[0], addr[1]

    async def _handle_metrics_http(self, reader: asyncio.StreamReader,
                                   writer: asyncio.StreamWriter) -> None:
        self._conn_tasks.add(asyncio.current_task())
        try:
            request_line = await reader.readline()
            parts = request_line.decode("latin-1", "replace").split()
            path = parts[1] if len(parts) >= 2 else "/"
            while True:  # drain headers until the blank line
                h = await reader.readline()
                if h in (b"\r\n", b"\n", b""):
                    break
            if path.split("?", 1)[0] == "/metrics":
                self._refresh_gauges()
                shard = self._shard_map().get("shard", 0)
                body = self.metrics.render_prometheus(
                    labels=f'shard="{shard}"').encode()
                status = "200 OK"
                ctype = "text/plain; version=0.0.4; charset=utf-8"
            else:
                body = b"try /metrics\n"
                status = "404 Not Found"
                ctype = "text/plain; charset=utf-8"
            writer.write((
                f"HTTP/1.0 {status}\r\n"
                f"Content-Type: {ctype}\r\n"
                f"Content-Length: {len(body)}\r\n"
                f"Connection: close\r\n\r\n").encode("latin-1") + body)
            await writer.drain()
        except (asyncio.IncompleteReadError, ConnectionResetError,
                asyncio.CancelledError):
            pass
        finally:
            self._conn_tasks.discard(asyncio.current_task())
            writer.close()
            try:
                await writer.wait_closed()
            except Exception:  # noqa: BLE001
                pass

    async def _dispatch(self, op: str, kwargs: dict):
        if op == "get_shard_map":
            return self._shard_map()
        if op == "get_metrics":
            # admin-class (PROTOCOL.md §13): never counted, never
            # timed, no Controller interaction — answered before the
            # session lookup so it needs no session to exist
            return self._get_metrics(kwargs)
        if op == "create_session":
            return self._create_session(kwargs)
        if op == "submit_session":
            return self._submit_session(kwargs)
        if op == "wait_session":
            return await self._wait_session(kwargs)
        # engine payloads beyond one frame ride the same chunk ops as
        # protocol arrays, but address the engine plane (no protocol
        # session): op/kind routes them before the session lookup
        if op == "post_chunk" and kwargs.get("op") == "submit_session":
            return self._post_engine_chunk(kwargs)
        if op == "get_chunk" and kwargs.get("kind") == "wait_session":
            return await self._get_engine_chunk(kwargs)

        sess = self._session(kwargs)
        denied = self._check_auth(sess, op, kwargs)
        if denied is not None:
            return denied
        if op == "post_org_average":
            return await self._post_org_average(sess, kwargs)
        if op == "get_org_average":
            return await self._get_org_average(sess, kwargs)
        if op == "post_chunk":
            return await self._post_chunk(sess, kwargs)
        if op == "get_chunk":
            return await self._get_chunk(sess, kwargs)
        if op == "delete_session":
            # tear the tenant down: unpark any stragglers, stop the
            # monitor from scanning it, free the Controller state
            self._sessions.pop(sess.sid, None)
            self._m_active.set(len(self._sessions))
            async with sess.cond:
                sess.closed = True
                sess.cond.notify_all()
            return None
        if op in BON_WAIT_KINDS:
            return await self._bon_long_poll(sess, op, kwargs)
        if op in BON_CALL_OPS:
            bon = self._require_bon(sess)
            if op in BON_TIMED_OPS:
                kwargs = dict(kwargs, now=self.now())
            async with sess.cond:
                res = bon.call(op, **kwargs)
                sess.cond.notify_all()
            return res
        if op in WAIT_KINDS:
            return await self._long_poll(sess, op, kwargs)
        if op in CALL_OPS:
            # cross-round pipelining (§11): a call tagged for a FUTURE
            # round parks until advance_round opens that round — the
            # controller only ever sees current-round ops, so the §5
            # closed forms hold per round boundary. A call tagged for a
            # PAST round is a straggler of a round that already closed:
            # executing it would poison the new round's state, so it is
            # dropped (None; should_initiate answers False).
            rnd = kwargs.pop("round", None)
            if rnd is not None:
                rnd = int(rnd)
                parked = await self._park_for_round(sess, rnd)
                if not parked:
                    return False if op == "should_initiate" else None
            if op == "post_aggregate":
                # transport-boundary hygiene: a posting addressed outside
                # the session's chain could never be consumed or reposted
                # around (order_repost indexes the chain) — reject it at
                # the RPC instead of letting it poison the monitor
                group = kwargs.get("group", 0)
                chain = sess.ctrl.groups.get(group)
                if chain is None:
                    raise wire.WireError(f"unknown group {group!r}")
                if kwargs.get("to_node") not in chain:
                    raise wire.WireError(
                        f"to_node {kwargs.get('to_node')!r} is not in "
                        f"group {group}'s chain")
            if op in TIMED_OPS:
                kwargs = dict(kwargs, now=self.now())
            async with sess.cond:
                res = sess.ctrl.call(op, **kwargs)
                if op == "should_initiate" and res:
                    sess.initiator_elections += 1
                    self._m_elections.inc()
                    # round restarted (§5.4): stale chunk buffers of the
                    # aborted round must not be served to the new chain
                    sess.drop_group_transfers(kwargs.get("group", 0))
                elif op == "post_average":
                    self._note_post_average(sess)
                sess.cond.notify_all()
            return res
        if op == "peek_average":
            if sess.parent is not None:
                # parent session: uncounted admin view — one org's
                # posted average with org=g, the cross-org fold without
                # (HierStats only moves on the counted hier ops)
                if kwargs.get("org") is not None:
                    return sess.parent.peek_org(int(kwargs["org"]))
                return sess.parent.try_get_org_average()
            if sess.bon is not None:
                avg = sess.bon.average
                return None if avg is None else {"average": avg}
            return sess.ctrl.try_get_average()
        if op == "get_stats":
            if sess.bon is not None:
                return sess.bon.stats_dict()
            stats = dataclasses.asdict(sess.ctrl.stats)
            stats["aggregation_total"] = sess.ctrl.stats.aggregation_total
            stats["key_exchange_total"] = sess.ctrl.stats.key_exchange_total
            stats["monitor_reposts"] = sess.monitor_reposts
            stats["initiator_elections"] = sess.initiator_elections
            stats["chunk_frames_in"] = sess.chunk_frames_in
            stats["chunk_frames_out"] = sess.chunk_frames_out
            stats["chunk_frames_future"] = sess.chunk_frames_future
            stats["transfers_completed"] = sess.transfers_completed
            stats["busy_rejections"] = sess.busy_rejections
            stats["round"] = sess.round
            stats["auth_failures"] = sess.auth_failures
            if sess.parent is not None:
                # parent level (§5.10): HierStats, never MessageStats —
                # the 2(c−f) closed form reads off these two counters
                stats["post_org_average"] = sess.parent.stats.post_org_average
                stats["get_org_average"] = sess.parent.stats.get_org_average
                stats["hierarchy_total"] = sess.parent.stats.hierarchy_total
                stats["crashed_orgs"] = list(sess.parent.crashed_orgs)
            if sess.upstream is not None:
                stats["uplink_errors"] = sess.uplink_errors
            return stats
        if op == "reset_round":
            # destructive restart of the SAME logical round: every
            # transfer dies, including any future-round buffers — a
            # pipelined driver uses advance_round instead
            async with sess.cond:
                sess.ctrl.reset_round()
                sess.clear_transfers()
                if sess.parent is not None:
                    sess.parent.reset_round()
                sess.org_average = None
                sess.parent_global = None
                # next round's latency clock starts at the reset
                sess.round_published = False
                sess.round_t0 = self.now()
                # §15: the aborted round's credentials die with it — a
                # replayed stale token cannot touch the new round. The
                # fresh grant rides the response; only the resetting
                # admin sees it and redistributes.
                grant = sess.rotate_tokens()
                sess.cond.notify_all()
            return grant
        if op == "advance_round":
            # non-destructive round boundary (§11): complete the current
            # round, open the next, keep round r+1's buffers — then
            # deliver any transfer that finished uploading while parked
            # (its logical op executes NOW, on the new round's clean
            # controller, which is what keeps per-round stats deltas and
            # the §5 closed forms exact under pipelining)
            async with sess.cond:
                if sess.closed:
                    raise wire.WireError(f"session {sess.sid} deleted")
                sess.ctrl.advance_round()
                sess.round += 1
                for key in [k for k in sess.transfers if k[1] < sess.round]:
                    sess.forget_transfer(key)
                if sess.parent is not None:
                    sess.parent.reset_round()
                sess.org_average = None
                sess.parent_global = None
                sess.round_published = False
                sess.round_t0 = self.now()
                for key in sorted(k for k in sess.transfers
                                  if k[1] == sess.round):
                    tr = sess.transfers[key]
                    if tr.asm.complete and not tr.posted:
                        self._deliver_transfer(sess, tr)
                sess.cond.notify_all()
            return {"round": sess.round}
        raise wire.WireError(f"unhandled op {op!r}")

    async def _park_for_round(self, sess: _Session, rnd: int) -> bool:
        """Hold a round-tagged call until the session's round catches up
        (woken by advance_round). True when the call may execute, False
        for a stale round. The deadline scales with the round gap: each
        in-flight round ahead of this op may legitimately consume a full
        aggregation timeout (churn recovery runs the stragglers' polls
        to expiry), and the op then deserves its own budget once its
        round opens — but a driver that dies without advancing must
        still not pin its learners' connections forever."""
        loop = asyncio.get_running_loop()
        gap = max(0, rnd - sess.round)
        deadline = loop.time() + (gap + 1) * sess.ctrl.aggregation_timeout

        def ready():
            if sess.closed:
                raise wire.WireError(f"session {sess.sid} deleted")
            return True if sess.round >= rnd else None

        ok = await _park(sess.cond, ready, deadline)
        if ok is None:
            raise wire.WireError(
                f"round {rnd} never opened (session at {sess.round})")
        return sess.round == rnd

    def _note_post_average(self, sess: _Session) -> None:
        """Round-lifecycle observation (holds ``sess.cond``): the first
        post_average after which the *global* average is published
        completes the session's round — count it and observe its
        latency. A pure peek (``try_get_average``): the protocol result
        is untouched."""
        if sess.round_published:
            return
        if sess.ctrl.try_get_average() is None:
            return
        sess.round_published = True
        sess.rounds_completed += 1
        self._m_rounds.inc()
        now = self.now()
        self._m_round_lat.observe(now - sess.round_t0)
        if self.tracer.enabled:
            self.tracer.record("round", sess.round_t0, now,
                               session=sess.sid,
                               round=sess.rounds_completed - 1)
        if sess.upstream is not None:
            # child role (§5.10): this session's global IS the org
            # average — snapshot it and ship it upward; learners'
            # get_average stays parked until the parent fold lands
            sess.org_average = dict(sess.ctrl.try_get_average())
            self._tasks.append(asyncio.ensure_future(self._uplink(sess)))

    # ------------------------------------------------------------------
    # hierarchical plane (docs/PROTOCOL.md §15, paper §5.10)
    # ------------------------------------------------------------------
    async def _post_org_average(self, sess: _Session, kwargs: dict):
        """Parent-side up-post: one child org's anonymized average lands
        in the ParentController (counted + timed in HierStats, never
        MessageStats). The fold publishes once every enrolled org posted
        — or earlier via the monitor's ``maybe_elide`` when whole orgs
        crashed."""
        if sess.parent is None:
            raise wire.WireError(
                f"session {sess.sid} is not a parent session")
        wavg = kwargs.get("weight_avg")
        async with sess.cond:
            sess.parent.post_org_average(
                int(kwargs.get("org", 0)),
                np.asarray(kwargs.get("average")),
                None if wavg is None else float(wavg),
                now=self.now())
            sess.cond.notify_all()
        return {"status": "ok"}

    async def _get_org_average(self, sess: _Session, kwargs: dict):
        """Parent-side down-fetch: long-poll the cross-org fold (counted
        in HierStats on consumption; a lapsed deadline counts nothing —
        the same park/probe/consume discipline as the §5 waits)."""
        if sess.parent is None:
            raise wire.WireError(
                f"session {sess.sid} is not a parent session")
        timeout = kwargs.pop("timeout", None)
        loop = asyncio.get_running_loop()
        deadline = None if timeout is None else loop.time() + float(timeout)

        def probe():
            if sess.closed:
                raise wire.WireError(f"session {sess.sid} deleted")
            if sess.parent.try_get_org_average() is None:
                return None
            res = sess.parent.get_org_average()
            sess.cond.notify_all()
            return res

        res = await _park(sess.cond, probe, deadline)
        return res if res is not None else {"status": "timeout"}

    async def _uplink(self, sess: _Session) -> None:
        """Child role (§5.10): ship the just-published org average UP to
        the parent session, long-poll the fold back DOWN, install it as
        what this broker's learners receive from ``get_average``. One
        anonymized vector crosses the org trust boundary per round —
        never an individual learner's aggregate."""
        up = sess.upstream
        org_avg = dict(sess.org_average)
        try:
            reader, writer = await asyncio.open_connection(
                up["host"], int(up["port"]))
        except OSError:
            sess.uplink_errors += 1
            return
        try:
            async def rpc(op: str, kw: dict):
                writer.write(wire.encode_frame(wire.encode_request(op, kw)))
                await writer.drain()
                body = await wire.read_frame(reader)
                if body is None:
                    raise wire.WireError("parent closed the uplink")
                return wire.decode_response(body)

            base = {"session": up["session"], "token": up["token"]}
            res = await rpc("post_org_average", dict(
                base, org=int(up["org"]), average=org_avg["average"],
                weight_avg=org_avg.get("weight_avg")))
            if isinstance(res, dict) and res.get("status") == "auth_failed":
                raise wire.WireError(
                    f"uplink rejected: {res.get('reason')}")
            glob = await rpc("get_org_average", dict(
                base, timeout=up.get("timeout")))
            if not isinstance(glob, dict) or "average" not in glob:
                raise wire.WireError(f"no parent fold: {glob!r}")
            async with sess.cond:
                sess.parent_global = {
                    "average": np.asarray(glob["average"]),
                    "weight_avg": glob.get("weight_avg"),
                    "time": float(glob.get("time", 0.0)),
                    "orgs": list(glob.get("orgs", [])),
                    "crashed_orgs": list(glob.get("crashed_orgs", [])),
                }
                sess.cond.notify_all()
        except (wire.WireError, OSError, asyncio.IncompleteReadError):
            sess.uplink_errors += 1
        finally:
            writer.close()
            try:
                await writer.wait_closed()
            except Exception:  # noqa: BLE001
                pass

    # ------------------------------------------------------------------
    # protocol plane
    # ------------------------------------------------------------------
    def _create_session(self, kwargs: dict) -> dict:
        raw_groups = kwargs.get("groups")
        if not isinstance(raw_groups, dict) or not raw_groups:
            raise wire.WireError("create_session needs a non-empty groups map")
        groups = {int(g): [int(x) for x in nodes]
                  for g, nodes in raw_groups.items()}
        for g, chain in groups.items():
            if not chain:
                # an empty chain can never post its group average, so
                # the session could never publish globally — every
                # learner would long-poll/elect forever
                raise wire.WireError(f"group {g} has an empty chain")
        timeout = kwargs.get("aggregation_timeout")
        if timeout is None:
            timeout = self.aggregation_timeout
        protocol = kwargs.get("protocol", "safe")
        bon = None
        if protocol == "bon":
            # BON tenant (additive kwarg, PROTOCOL.md §14): one flat
            # node set (the union of the groups map keeps the call
            # shape), its own threshold and dropout wait
            nodes = sorted({x for chain in groups.values() for x in chain})
            bon = BonController(
                nodes, threshold=kwargs.get("threshold"),
                roster_timeout=float(kwargs.get("roster_timeout", 1.0)),
                scale_bits=int(kwargs.get("scale_bits", 16)))
        elif protocol != "safe":
            raise wire.WireError(f"unknown protocol {protocol!r}")
        # hierarchical roles (additive kwargs, PROTOCOL.md §15). orgs=[..]
        # makes a PARENT session: it folds anonymized org averages with
        # the same arithmetic as §5.5 and elides whole crashed orgs on
        # its aggregation timeout (the SAFE ops still see a quiescent
        # Controller, mirroring the BON tenant shape).
        parent = None
        if kwargs.get("orgs") is not None:
            orgs = [int(o) for o in kwargs["orgs"]]
            if not orgs:
                raise wire.WireError("parent session needs a non-empty orgs list")
            parent = ParentController(
                orgs, aggregation_timeout=float(timeout))
        # upstream={host,port,session,org,token} makes a CHILD session:
        # on publishing its own global (= the org average) it posts that
        # one anonymized vector up and serves the parent's fold to its
        # learners once it arrives.
        upstream = kwargs.get("upstream")
        if upstream is not None:
            need = {"host", "port", "session", "org", "token"}
            if not isinstance(upstream, dict) or not need <= set(upstream):
                raise wire.WireError(
                    f"upstream needs the keys {sorted(need)}")
            upstream = dict(upstream)
        sid = next(self._sids)
        sess = _Session(
            sid, Controller(groups, aggregation_timeout=float(timeout)),
            now=self.now(), bon=bon, parent=parent, upstream=upstream)
        self._sessions[sid] = sess
        self._m_sessions_created.inc()
        self._m_active.set(len(self._sessions))
        return {"session": sid, "aggregation_timeout": float(timeout),
                "token": sess.token,
                "node_tokens": dict(sess.node_tokens)}

    async def _long_poll(self, sess: _Session, kind: str, kwargs: dict):
        """Park until the probe is satisfiable, then consume (counted),
        or answer {"status": "timeout"} (not counted — sim parity).

        ``elide_payload=True`` (set by chunk-aware clients that already
        streamed the array via ``get_chunk``) strips the bulk array from
        the response — the logical consume still happens and still
        counts, but the bytes travel only once. ``expect_time`` guards
        that consume: the probe only counts as satisfiable when the
        stored entry's timestamp matches, so a client can never consume
        (and discard, elided) a posting other than the one it streamed
        — a §5.4 reset racing the final consume parks it instead, and
        the ordinary timeout path takes over."""
        timeout = kwargs.pop("timeout", None)
        elide = bool(kwargs.pop("elide_payload", False))
        expect_time = kwargs.pop("expect_time", None)
        # §11: a wait tagged for a future round parks until advance_round
        # opens it (the controller holds nothing for that round yet) —
        # and its OWN timeout budget only starts then, because a full
        # predecessor round may legitimately stand between arrival and
        # eligibility. One tagged for a PAST round can never be
        # satisfied — its round's state is gone — so it answers the
        # ordinary timeout
        rnd = kwargs.pop("round", None)
        if rnd is not None and int(rnd) > sess.round:
            try:
                if not await self._park_for_round(sess, int(rnd)):
                    return {"status": "timeout"}
            except wire.WireError:
                if sess.closed:
                    raise
                return {"status": "timeout"}  # round never opened
        loop = asyncio.get_running_loop()
        deadline = None if timeout is None else loop.time() + float(timeout)

        def probe():
            if sess.closed:
                raise wire.WireError(f"session {sess.sid} deleted")
            if rnd is not None and sess.round != int(rnd):
                return None
            probed = sess.ctrl.probe(kind, **kwargs)
            if kind == "get_average" and sess.upstream is not None \
                    and sess.parent_global is None:
                # child session (§15/§5.10): the org's own fold never
                # reaches learners — distribution waits for the parent
                probed = None
            if probed is not None and expect_time is not None \
                    and float(probed.get("time", 0.0)) != float(expect_time):
                probed = None  # not the entry the client streamed
            if probed is None:
                return None
            res = sess.ctrl.consume(kind, **kwargs)
            if kind == "get_aggregate":
                # the posting is consumed — its chunk buffer (if it
                # streamed in) has nothing left to serve
                sess.forget_transfer(
                    ("agg", sess.round, kwargs.get("group", 0),
                     kwargs.get("node")))
                if elide:
                    res = dict(res, aggregate=None, chunked=True)
            elif kind == "get_average":
                if sess.upstream is not None:
                    # serve the parent fold (still ONE counted
                    # get_average per learner — the §5 per-org closed
                    # forms are untouched). Served inline: the chunked
                    # distribution path streams the org-level buffer,
                    # so elide is ignored on child sessions.
                    res = dict(res, **sess.parent_global)
                elif elide:
                    res = dict(res, average=None, chunked=True)
            # consuming get_aggregate resolves the poster's pending
            # check_aggregate — wake its waiter
            sess.cond.notify_all()
            return res

        res = await _park(sess.cond, probe, deadline)
        while (res is None and kind == "get_average"
               and sess.upstream is not None
               and sess.org_average is not None
               and sess.uplink_errors == 0):
            # child session whose OWN round already published: the only
            # thing pending is the parent fold (§15), and an uplink in
            # flight must not read as a stalled aggregation — answering
            # "timeout" here would push a finished org's learners into
            # a spurious §5.4 re-election. Re-park on the caller's own
            # cadence until the fold lands or the uplink dies.
            res = await _park(sess.cond, probe,
                              None if timeout is None
                              else loop.time() + float(timeout))
        return res if res is not None else {"status": "timeout"}

    # ------------------------------------------------------------------
    # BON baseline plane (docs/PROTOCOL.md §14)
    # ------------------------------------------------------------------
    @staticmethod
    def _require_bon(sess: _Session) -> BonController:
        if sess.bon is None:
            raise wire.WireError(
                f"session {sess.sid} is not a BON session")
        return sess.bon

    async def _bon_long_poll(self, sess: _Session, kind: str, kwargs: dict):
        """BON waits under the same park/probe/consume discipline as the
        SAFE long-polls: only consumption counts (in BonStats), a lapsed
        deadline answers {"status": "timeout"} and counts nothing."""
        bon = self._require_bon(sess)
        timeout = kwargs.pop("timeout", None)
        loop = asyncio.get_running_loop()
        deadline = None if timeout is None else loop.time() + float(timeout)

        def probe():
            if sess.closed:
                raise wire.WireError(f"session {sess.sid} deleted")
            if bon.probe(kind, **kwargs) is None:
                return None
            return bon.consume(kind, **kwargs)

        res = await _park(sess.cond, probe, deadline)
        return res if res is not None else {"status": "timeout"}

    # ------------------------------------------------------------------
    # chunked transfer plane (docs/PROTOCOL.md §6)
    # ------------------------------------------------------------------
    async def _post_chunk(self, sess: _Session, kwargs: dict):
        """One chunk of a chunked upload. On the final chunk the logical
        op (post_aggregate / post_average) executes with the assembled
        array — that is the only point MessageStats moves."""
        op = kwargs.get("op")
        if op not in ("post_aggregate", "post_average"):
            raise wire.WireError(f"post_chunk cannot carry {op!r}")
        group = int(kwargs.get("group", 0))
        chain = sess.ctrl.groups.get(group)
        if chain is None:
            raise wire.WireError(f"unknown group {group!r}")
        xfer = int(kwargs["xfer"])
        seq = int(kwargs["seq"])
        total = int(kwargs["total"])
        chunk_words = int(kwargs["chunk_words"])
        payload = kwargs.get("payload")
        if not isinstance(payload, np.ndarray) or payload.ndim != 1:
            raise wire.WireError("post_chunk payload must be a flat array")
        if op == "post_aggregate":
            to_node = kwargs.get("to_node")
            if to_node not in chain:
                # same transport-boundary hygiene as the unchunked RPC
                raise wire.WireError(
                    f"to_node {to_node!r} is not in group {group}'s chain")
            owner = int(kwargs.get("from_node"))
            base = {"from_node": owner, "to_node": to_node, "group": group}
        else:
            to_node = None
            owner = int(kwargs.get("node"))
            base = {"node": owner, "group": group,
                    "weight_avg": kwargs.get("weight_avg")}
        round_kw = kwargs.get("round")
        now = self.now()
        async with sess.cond:
            if sess.closed:
                # parity with the parked paths: a frame racing
                # delete_session must not execute on the torn-down
                # Controller and ack success
                raise wire.WireError(f"session {sess.sid} deleted")
            sess.chunk_frames_in += 1
            self._m_chunks_in.inc()
            # §11 round routing: untagged frames address the current
            # round; frames within the in-flight window buffer (and
            # relay) with their logical op deferred to advance_round;
            # frames past the window are shed with the ordinary busy
            # backoff; frames for a CLOSED round are superseded — that
            # round's slot will never be consumed
            rnd = sess.round if round_kw is None else int(round_kw)
            if rnd < sess.round:
                return {"seq": seq, "received": 0, "total": total,
                        "complete": False, "superseded": True,
                        "stale_round": True}
            if rnd >= sess.round + self.inflight_rounds:
                sess.busy_rejections += 1
                self._m_busy.inc()
                return {"status": "busy",
                        "retry_after": self.busy_retry_after}
            if rnd > sess.round:
                sess.chunk_frames_future += 1
            key = (("agg", rnd, group, to_node) if op == "post_aggregate"
                   else ("avg", rnd, group))
            tr = sess.transfers.get(key)
            if tr is not None and tr.same_transfer(owner, xfer) \
                    and tr.posted:
                # at-least-once repeat of a completed transfer (e.g. a
                # final chunk re-sent after a lost ack): idempotent ack,
                # never a fresh buffer — PROTOCOL.md §6 repeat rule
                return {"seq": seq, "received": tr.asm.total,
                        "total": tr.asm.total, "complete": True}
            if (tr is not None and tr.owner == owner
                    and xfer < tr.xfer):
                # stale frame of this uploader's own ABANDONED stream
                # (xfer ids are monotone per uploader; a streaming
                # combine restarts under a fresh xfer after an upstream
                # identity change): discard — it must never clobber the
                # newer stream's buffer
                return {"seq": seq, "received": 0, "total": total,
                        "complete": False, "superseded": True}
            if (tr is not None and not tr.same_transfer(owner, xfer)
                    and tr.owner != owner
                    and not tr.posted
                    and now - tr.last_chunk_at < self.progress_timeout):
                # the slot is owned by a DIFFERENT uploader's transfer
                # that is still actively receiving chunks: discard this
                # frame instead of replacing the buffer (last-writer-
                # wins would let two interleaved uploads clobber each
                # other forever). The losing uploader sees `superseded`
                # and falls back to the protocol's own reset/timeout
                # path. An uploader's own NEWER xfer is exempt: it
                # always replaces its older stream (uploaders are
                # sequential — a new xfer for the slot is a deliberate
                # restart, e.g. a partial combine abandoned after a
                # repost upstream).
                return {"seq": seq, "received": 0, "total": total,
                        "complete": False, "superseded": True}
            if tr is None or not tr.same_transfer(owner, xfer) or tr.posted:
                # admission control (ISSUE 7, PROTOCOL.md §13): a NEW
                # transfer that would push the session's un-posted
                # backlog past its budget is shed with a retry hint —
                # the budget is per session, so a flooding tenant
                # throttles itself, never its neighbors. Continuation
                # chunks of an admitted transfer are always accepted
                # (completing a transfer *drains* the backlog), and a
                # session with an empty backlog is always admitted —
                # both rules together make the budget deadlock-free.
                if (self.chunk_budget_bytes is not None
                        and sess.pending_bytes > 0
                        and sess.pending_bytes + payload.nbytes
                        > self.chunk_budget_bytes):
                    sess.busy_rejections += 1
                    self._m_busy.inc()
                    return {"status": "busy",
                            "retry_after": self.busy_retry_after}
                # a new transfer identity replaces a posted or gone-
                # stale buffer for this slot (repost retry, next round)
                sess.forget_transfer(key)
                tr = _Transfer(owner, xfer, op, base, total, chunk_words,
                               now)
                sess.transfers[key] = tr
            if tr.asm.total != total or tr.chunk_words != chunk_words:
                raise wire.WireError(
                    "chunk total/chunk_words mismatch within transfer "
                    f"{xfer}")
            tr.last_chunk_at = now
            fresh = seq not in tr.asm.chunks
            done = tr.asm.add(seq, payload)
            if fresh and not tr.posted:
                tr.nbytes += payload.nbytes
                sess.pending_bytes += payload.nbytes
            if done and not tr.posted and rnd == sess.round:
                # current round: the logical op executes NOW. A future-
                # round transfer stays buffered (posted=False,
                # asm.complete=True) until advance_round delivers it —
                # the uploader still sees complete=True below: its
                # upload obligation is met either way.
                self._deliver_transfer(sess, tr)
            elif self.tracer.enabled and not done:
                self.tracer.record("chunk", now, self.now(),
                                   session=sess.sid, op=op, owner=owner,
                                   xfer=xfer, seq=seq)
            sess.cond.notify_all()
        return {"seq": seq, "received": len(tr.asm.chunks), "total": total,
                "complete": tr.posted or tr.asm.complete}

    def _deliver_transfer(self, sess: _Session, tr: _Transfer) -> None:
        """Execute a completed transfer's logical op (holds
        ``sess.cond``) — the only point MessageStats moves for a chunked
        upload. Called from ``_post_chunk`` on a current-round final
        chunk, and from ``advance_round`` for transfers that completed
        while their round was still parked."""
        tr.posted = True
        # the buffer leaves the backlog accounting the moment the
        # logical op executes (it stays in the table only as the §6
        # idempotency record)
        sess.pending_bytes -= tr.nbytes
        sess.transfers_completed += 1
        self._m_transfers.inc()
        if self.tracer.enabled:
            self.tracer.record("transfer", tr.created_at, self.now(),
                               session=sess.sid, op=tr.op, owner=tr.owner,
                               xfer=tr.xfer, chunks=tr.asm.total)
        call_kw = dict(tr.kwargs, now=self.now())
        field = "payload" if tr.op == "post_aggregate" else "average"
        call_kw[field] = tr.asm.assemble()
        sess.ctrl.call(tr.op, **call_kw)
        if tr.op == "post_average":
            self._note_post_average(sess)
        # the posted buffer stays (for post_average too, even though
        # averages are served from controller state): it is the
        # idempotency record that lets a repeated final chunk be
        # re-acked instead of re-executing the op

    async def _get_chunk(self, sess: _Session, kwargs: dict):
        """Long-poll for one chunk of an inbound array.

        ``kind=get_aggregate`` serves from the live transfer buffer the
        moment chunk ``seq`` has arrived (store-and-forward pipelining —
        the upload need not be complete), falling back to slicing a
        completed unchunked posting. ``kind=get_average`` slices the
        published global average. Never counted in MessageStats; the
        client issues the logical consume (with ``elide_payload``) after
        the last chunk."""
        kind = kwargs.get("kind")
        if kind not in ("get_aggregate", "get_average"):
            raise wire.WireError(f"get_chunk cannot serve {kind!r}")
        group = int(kwargs.get("group", 0))
        node = kwargs.get("node")
        round_kw = kwargs.get("round")
        seq = int(kwargs["seq"])
        words = int(kwargs.get("words", wire.DEFAULT_CHUNK_WORDS))
        if words < 1:
            raise wire.WireError(f"words must be >= 1, got {words}")
        timeout = kwargs.get("timeout")
        loop = asyncio.get_running_loop()
        deadline = None if timeout is None else loop.time() + float(timeout)

        def slice_of(arr: np.ndarray, extra: dict) -> dict:
            arr = np.asarray(arr).ravel()
            total = wire.num_chunks(arr.size, words)
            if seq >= total:
                raise wire.WireError(f"chunk seq {seq} >= total {total}")
            return dict(extra, seq=seq, total=total, last=seq == total - 1,
                        payload=wire.chunk_slice(arr, seq, words))

        # Every response carries the transfer identity (`xfer`): the
        # uploader's id for buffered streams, the posting/publication
        # timestamp for slices of stored arrays. A reader seeing the
        # identity change mid-stream knows the underlying array was
        # replaced (repost after §5.3, re-election after §5.4) and must
        # restart assembly — mixing chunks of two transfers would hand
        # the state machine a corrupt ciphertext.
        def probe():
            # §11 round routing: a reader tagged for a round within the
            # window streams straight out of that round's live buffer —
            # this is the cross-round relay (round r+1 chunks flow hop
            # to hop while round r is still open). Controller-state
            # fallbacks (stored postings, the published average) only
            # exist for the CURRENT round, so future-round readers park
            # on the buffer alone until advance_round catches up.
            rnd = sess.round if round_kw is None else int(round_kw)
            if kind == "get_aggregate":
                tr = sess.transfers.get(("agg", rnd, group, node))
                if tr is not None and seq in tr.asm.chunks:
                    if tr.chunk_words != words:
                        raise wire.WireError(
                            f"transfer chunk size {tr.chunk_words} != "
                            f"requested {words}")
                    out = {"seq": seq, "total": tr.asm.total,
                           "last": seq == tr.asm.total - 1,
                           "from_node": tr.kwargs.get("from_node"),
                           # full identity (owner, xfer): bare xfer
                           # counters collide across uploader processes
                           "xfer": ("u", tr.owner, tr.xfer),
                           "payload": tr.asm.chunks[seq]}
                    if tr.posted:
                        # the consume-guard timestamp (`expect_time`)
                        # for the logical read that follows — on EVERY
                        # post-completion chunk, because out-of-order
                        # refetches mean the client's final received
                        # chunk need not be seq total-1. `posted` (the
                        # §5.3 contributor count) rides along so the
                        # streaming unmask can start publishing average
                        # slices before the final consume.
                        peek = sess.ctrl.probe("get_aggregate", node=node,
                                               group=group)
                        if peek is not None:
                            out["time"] = float(peek["time"])
                            out["posted"] = int(peek["posted"])
                    return out
                if rnd != sess.round:
                    return None  # future round: only the buffer serves
                peek = sess.ctrl.probe("get_aggregate", node=node,
                                       group=group)
                if peek is not None:
                    return slice_of(peek["aggregate"],
                                    {"from_node": peek["from_node"],
                                     "time": float(peek["time"]),
                                     "posted": int(peek["posted"]),
                                     "xfer": ("t", float(peek["time"]),
                                              peek["from_node"])})
                return None
            if rnd != sess.round:
                return None  # the average of a parked round: not yet
            peek = sess.ctrl.try_get_average()
            if peek is None:
                return None
            t = float(peek.get("time", 0.0))
            return slice_of(peek["average"], {"time": t, "xfer": ("avg", t)})

        def guarded():
            if sess.closed:
                raise wire.WireError(f"session {sess.sid} deleted")
            res = probe()
            if res is not None:
                sess.chunk_frames_out += 1
                self._m_chunks_out.inc()
            return res

        res = await _park(sess.cond, guarded, deadline)
        return res if res is not None else {"status": "timeout"}

    async def _monitor_loop(self) -> None:
        """External progress monitor (§5.3) on the wall clock: scan every
        session for postings stuck longer than ``progress_timeout`` and
        order reposts around the dead target."""
        while True:
            await asyncio.sleep(self.monitor_interval)
            now = self.now()
            if self.engine is not None:
                # expire abandoned engine sessions even when no new
                # submissions arrive to trigger the on-submit prune
                self._prune_engine_sessions()
            for sess in list(self._sessions.values()):
                # per-session guard: one tenant's bad state (e.g. a
                # posting addressed outside its chain) must not kill
                # the monitor task and silently disable §5.3 failover
                # for every other tenant
                try:
                    if sess.bon is not None:
                        # BON tenants: the roster settles by wall time
                        # when dropouts leave Round 2 short — nothing
                        # else wakes the parked roster waits
                        async with sess.cond:
                            if sess.bon.maybe_close_roster(now):
                                sess.cond.notify_all()
                        continue
                    if sess.parent is not None:
                        # parent level (§5.10): a whole child org that
                        # never posts is elided on the aggregation
                        # timeout, exactly like a dead learner
                        async with sess.cond:
                            if sess.parent.maybe_elide(now):
                                sess.cond.notify_all()
                    async with sess.cond:
                        for group in sess.ctrl.groups:
                            stuck = sess.ctrl.stuck_posting(
                                group, now, self.progress_timeout)
                            if stuck is None:
                                continue
                            poster, failed = stuck
                            if sess.ctrl.order_repost(
                                    group, poster, failed) is None:
                                # stalled: the chain finished but its
                                # consumer died — the §5.4 election
                                # recovers; no repost was ordered
                                continue
                            # the dead target's chunk buffer dies with
                            # its posting — the repost streams afresh
                            # (current round only: the monitor can only
                            # see current-round postings)
                            sess.forget_transfer(
                                ("agg", sess.round, group, failed))
                            sess.monitor_reposts += 1
                            self._m_reposts.inc()
                            sess.cond.notify_all()
                except asyncio.CancelledError:
                    raise
                except Exception:  # noqa: BLE001
                    self.monitor_errors += 1
                    continue

    # ------------------------------------------------------------------
    # engine plane
    # ------------------------------------------------------------------
    def _require_engine(self):
        if self.engine is None:
            raise wire.WireError("broker started without an engine")
        return self.engine

    def _prune_engine_sessions(self) -> None:
        """Drop completed-but-never-claimed sessions past the TTL (and
        with them their flattened-result cache and any staged chunk
        uploads abandoned mid-stream)."""
        now = asyncio.get_running_loop().time()
        cutoff = now - self.engine_session_ttl
        for sid, done_at in list(self._engine_done.items()):
            if done_at < cutoff:
                self._engine_done.pop(sid, None)
                self._engine_sessions.pop(sid, None)
                self._engine_flat.pop(sid, None)
        for key, ent in list(self._engine_uploads.items()):
            if ent["at"] < cutoff:
                del self._engine_uploads[key]

    def _submit_session(self, kwargs: dict) -> dict:
        engine = self._require_engine()
        self._prune_engine_sessions()
        values = np.asarray(kwargs["values"], np.float32)
        weights = kwargs.get("weights")
        alive = kwargs.get("alive")
        # validate at the RPC boundary what engine.submit doesn't (it
        # only checks values.shape): a wrong-length alive/weights array
        # would otherwise blow up inside a later step() and take the
        # engine loop down for every tenant
        for name, arr in (("alive", alive), ("weights", weights)):
            if arr is not None and np.asarray(arr).shape != (engine.n,):
                raise wire.WireError(
                    f"{name} must have shape ({engine.n},), got "
                    f"{np.asarray(arr).shape}")
        rounds = int(kwargs.get("rounds", 1))
        sess = engine.submit(
            values,
            rounds=rounds,
            provisioning_seed=int(kwargs.get("provisioning_seed", 0xC0FFEE)),
            learner_master=int(kwargs.get("learner_master", 0x5EED)),
            alive=None if alive is None else np.asarray(alive, np.float32),
            weights=None if weights is None else np.asarray(weights,
                                                            np.float32),
            rotate0=int(kwargs.get("rotate0", 0)))
        self._engine_sessions[sess.sid] = sess
        self._engine_wake.set()
        return {"sid": sess.sid}

    async def _wait_session(self, kwargs: dict):
        self._require_engine()
        sid = int(kwargs["sid"])
        sess = self._engine_sessions.get(sid)
        if sess is None:
            raise wire.WireError(f"unknown engine session {sid}")
        timeout = kwargs.get("timeout")
        elide = bool(kwargs.get("elide_results", False))
        loop = asyncio.get_running_loop()
        deadline = None if timeout is None else loop.time() + float(timeout)
        # completion is signalled by the engine's on_complete hook
        # (fires inside step(), before the post-step notify)
        done = await _park(
            self._engine_cond,
            lambda: (sid in self._engine_done or sess.done) or None,
            deadline)
        if done is None:
            return {"status": "timeout"}
        # NOT evicted here: if the response fails to frame/send, the
        # tenant can re-issue wait_session (idempotent read); eviction
        # happens via the engine_session_ttl prune after completion
        if elide:
            # chunk-aware client: it streamed (or will stream) the
            # results via get_chunk kind=wait_session — the completion
            # handshake travels without the bulk arrays
            return {"status": "done", "rounds": sess.rounds_done,
                    "results": None, "chunked": True}
        results = [np.asarray(r) for r in sess.results]
        if sum(int(r.size) for r in results) * 4 > wire.MAX_FRAME - 4096:
            raise wire.WireError(
                f"wait_session results for sid={sid} exceed one frame; "
                f"fetch them chunked (get_chunk kind=wait_session, then "
                f"wait_session with elide_results)")
        return {"status": "done", "rounds": sess.rounds_done,
                "results": results}

    def _post_engine_chunk(self, kwargs: dict):
        """One chunk of an oversized submit_session values upload. On
        the final chunk the reassembled flat f32 vector is reshaped to
        (engine.n, V) and submitted — the ack then carries the ``sid``.
        Repeats after completion re-ack the same sid (idempotent)."""
        engine = self._require_engine()
        owner = int(kwargs.get("node", 0))
        xfer = int(kwargs["xfer"])
        seq = int(kwargs["seq"])
        total = int(kwargs["total"])
        chunk_words = int(kwargs["chunk_words"])
        payload = kwargs.get("payload")
        if not isinstance(payload, np.ndarray) or payload.ndim != 1:
            raise wire.WireError("post_chunk payload must be a flat array")
        self.engine_chunk_frames_in += 1
        key = (owner, xfer)
        ent = self._engine_uploads.get(key)
        if ent is not None and ent["sid"] is not None:
            return {"seq": seq, "received": ent["asm"].total,
                    "total": ent["asm"].total, "complete": True,
                    "sid": ent["sid"]}
        if ent is None:
            meta = {k: v for k, v in kwargs.items()
                    if k not in ("payload", "op", "xfer", "seq", "total",
                                 "chunk_words", "node", "session")}
            ent = {"asm": wire.ChunkAssembler(total),
                   "chunk_words": chunk_words, "meta": meta, "sid": None,
                   "at": asyncio.get_running_loop().time()}
            self._engine_uploads[key] = ent
        if ent["asm"].total != total or ent["chunk_words"] != chunk_words:
            raise wire.WireError(
                f"chunk total/chunk_words mismatch within transfer {xfer}")
        ent["at"] = asyncio.get_running_loop().time()
        done = ent["asm"].add(seq, payload)
        res = {"seq": seq, "received": len(ent["asm"].chunks),
               "total": total, "complete": done}
        if done:
            flat = ent["asm"].assemble().astype(np.float32, copy=False)
            if flat.size % engine.n:
                raise wire.WireError(
                    f"submit values of {flat.size} words do not divide "
                    f"into {engine.n} learners")
            values = flat.reshape(engine.n, flat.size // engine.n)
            sub = self._submit_session(dict(ent["meta"], values=values))
            ent["sid"] = sub["sid"]
            res["sid"] = sub["sid"]
        return res

    async def _get_engine_chunk(self, kwargs: dict):
        """Long-poll for one chunk of a completed engine session's
        results, flattened round-major (rounds × V f32). Never counted;
        the client issues ``wait_session`` with ``elide_results`` for
        the completion handshake."""
        self._require_engine()
        sid = int(kwargs["sid"])
        seq = int(kwargs["seq"])
        words = int(kwargs.get("words", wire.DEFAULT_CHUNK_WORDS))
        if words < 1:
            raise wire.WireError(f"words must be >= 1, got {words}")
        sess = self._engine_sessions.get(sid)
        if sess is None:
            raise wire.WireError(f"unknown engine session {sid}")
        timeout = kwargs.get("timeout")
        loop = asyncio.get_running_loop()
        deadline = None if timeout is None else loop.time() + float(timeout)

        def probe():
            if not (sid in self._engine_done or sess.done):
                return None
            flat = self._engine_flat.get(sid)
            if flat is None:
                flat = (np.concatenate(
                    [np.asarray(r, np.float32).ravel()
                     for r in sess.results])
                    if sess.results else np.empty(0, np.float32))
                self._engine_flat[sid] = flat
            total = wire.num_chunks(flat.size, words)
            if seq >= total:
                raise wire.WireError(f"chunk seq {seq} >= total {total}")
            self.engine_chunk_frames_out += 1
            return {"seq": seq, "total": total, "last": seq == total - 1,
                    "rounds": sess.rounds_done,
                    "payload": wire.chunk_slice(flat, seq, words)}

        res = await _park(self._engine_cond, probe, deadline)
        return res if res is not None else {"status": "timeout"}

    async def _engine_loop(self) -> None:
        """Step the engine while work is queued. ``step()`` runs on the
        loop thread — one compiled-program dispatch per step — with a
        ``sleep(0)`` between steps so submissions/waiters interleave."""
        engine = self.engine
        while True:
            await self._engine_wake.wait()
            self._engine_wake.clear()
            while engine.queue or engine.active:
                try:
                    engine.step()
                except asyncio.CancelledError:
                    raise
                except Exception:  # noqa: BLE001 — keep the plane alive
                    # a poisoned step must not silently kill the loop
                    # for every tenant; back off so a persistently
                    # failing step can't busy-spin
                    self.engine_errors += 1
                    await asyncio.sleep(self.monitor_interval)
                async with self._engine_cond:
                    self._engine_cond.notify_all()
                await asyncio.sleep(0)
