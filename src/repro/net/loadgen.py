"""Load harness for the wire plane: S concurrent tenants, one broker.

Three load shapes, matching the broker's planes:

  * :func:`run_engine_load` — tenants submit whole aggregation sessions
    (``submit_session``/``wait_session``); the broker batches them
    through one :class:`~repro.serve.agg_engine.AggregationEngine`
    program per step. This is the ROADMAP's many-tenants story: wire
    concurrency in front, one compiled device program behind.
  * :func:`run_protocol_load` — tenants each run a *full* n-learner
    SAFE round over TCP (n connections, 4n RPCs, real long-polls), i.e.
    the paper's distributed system under concurrent sessions.
  * :func:`run_paper_scale` — ONE round at the paper's headline scale
    (n=36, §6.1: where SAFE beats Bonawitz-style masking by 70x/56x
    with/without failover), with the §5 closed-form message counts
    asserted inside the harness. ``benchmarks/paper_scale.py`` pairs it
    with the ``core/bon_protocol.py`` baseline at the same n
    (EXPERIMENTS.md §Paper-scale).

All report into the standard bench harness (``benchmarks/net_load.py``,
``benchmarks/paper_scale.py``).
"""
from __future__ import annotations

import asyncio
import dataclasses
import time
from typing import Iterable, List, Optional, Tuple

import numpy as np

from repro.net.broker import SafeBroker
from repro.net.client import (
    PersistentNetSession,
    WireClient,
    run_safe_round_net,
)

Addr = Tuple[str, int]


@dataclasses.dataclass
class LoadReport:
    plane: str
    tenants: int
    rounds: int          # total rounds completed across tenants
    wall_s: float
    rounds_per_s: float
    p50_s: float
    p99_s: float
    latencies_s: List[float]

    def row(self) -> dict:
        return {
            "plane": self.plane,
            "tenants": self.tenants,
            "rounds": self.rounds,
            "wall_s": self.wall_s,
            "rounds_per_s": self.rounds_per_s,
            "p50_s": self.p50_s,
            "p99_s": self.p99_s,
        }


def _report(plane: str, tenants: int, lats: List[float],
            wall: float) -> LoadReport:
    arr = np.asarray(lats, np.float64)
    return LoadReport(
        plane=plane, tenants=tenants, rounds=len(lats), wall_s=wall,
        rounds_per_s=len(lats) / wall if wall > 0 else float("inf"),
        p50_s=float(np.percentile(arr, 50)),
        p99_s=float(np.percentile(arr, 99)),
        latencies_s=lats)


async def run_engine_load(addr: Addr, *, tenants: int = 8,
                          rounds_per_tenant: int = 8, n: int = 8,
                          V: int = 1024, seed: int = 0,
                          warmup: bool = True,
                          timeout: float = 300.0,
                          chunk_words: Optional[int] = None) -> LoadReport:
    """Each tenant submits ``rounds_per_tenant`` single-round sessions
    back-to-back (closed-loop), measuring submit→published latency.

    ``chunk_words`` routes submit values and result fetches over the §6
    chunk plane — the path for engine payloads beyond one frame."""
    rng = np.random.RandomState(seed)
    tenant_vals = [rng.uniform(-1, 1, (n, V)).astype(np.float32)
                   for _ in range(tenants)]

    async def submit_and_wait(client, vals, t, r):
        sub_kw = {"values": vals, "rounds": 1,
                  "provisioning_seed": 0xC0FFEE + t,
                  "learner_master": 0x5EED + 17 * t,
                  "rotate0": r}
        if chunk_words is not None:
            sub = await client.submit_session_chunked(sub_kw, chunk_words)
            res = await client.wait_session_chunked(
                sub["sid"], timeout=timeout, chunk_words=chunk_words)
        else:
            sub = await client.request("submit_session", sub_kw)
            res = await client.request(
                "wait_session", {"sid": sub["sid"], "timeout": timeout})
        if res.get("status") != "done":
            raise RuntimeError(f"tenant {t} round {r}: {res}")
        return res

    if warmup:  # first submit compiles the engine program — keep it
        client = await WireClient(*addr).connect()
        try:
            await submit_and_wait(client, tenant_vals[0], 0, 0)
        finally:
            await client.close()

    async def tenant(t: int) -> List[float]:
        client = await WireClient(*addr, node=t).connect()
        lats = []
        try:
            for r in range(rounds_per_tenant):
                t0 = time.perf_counter()
                res = await submit_and_wait(client, tenant_vals[t], t, r)
                lats.append(time.perf_counter() - t0)
                exp = tenant_vals[t].mean(0)
                got = res["results"][0]
                if np.abs(got - exp).max() > 1e-2:
                    raise RuntimeError(f"tenant {t} got a wrong average")
        finally:
            await client.close()
        return lats

    t0 = time.perf_counter()
    per_tenant = await asyncio.gather(*(tenant(t) for t in range(tenants)))
    wall = time.perf_counter() - t0
    lats = [x for lat in per_tenant for x in lat]
    return _report("engine", tenants, lats, wall)


def _check_round(t: int, r: int, res, vals: np.ndarray) -> None:
    """Shared per-round sanity check for the protocol-load shapes."""
    if res.crashed_nodes:
        # churn plan fired: the published mean is over a subset whose
        # membership depends on *when* each crash landed (before vs.
        # after reposting) — value correctness under churn is pinned by
        # tests/test_net.py, not the loadgen
        return
    if res.average is None:
        raise RuntimeError(f"tenant {t} round {r}: no average")
    exp = vals.mean(0)
    if np.abs(res.average - exp).max() > 1e-2:
        raise RuntimeError(f"tenant {t} round {r}: wrong average")


async def run_protocol_load(addr: Addr, *, tenants: int = 4,
                            rounds_per_tenant: int = 3, n: int = 8,
                            V: int = 256, seed: int = 0,
                            interceptor=None,
                            chunk_words: Optional[int] = None,
                            prefetch_depth: Optional[int] = None,
                            persistent: bool = False) -> LoadReport:
    """Each tenant runs full n-learner SAFE rounds concurrently with
    every other tenant — one broker session per round by default, or
    (``persistent=True``) all of a tenant's rounds on ONE
    :class:`~repro.net.client.PersistentNetSession` (shared keys,
    connections and counter space — the amortized path the streaming
    benchmark compares against the rebuild path).

    ``interceptor`` is either a shared Interceptor instance or a
    callable ``tenant_index -> Interceptor`` — use the factory form for
    reproducible per-tenant fault plans (tenants reuse node ids, so a
    shared instance's per-node RNG streams interleave in scheduler
    order; see repro.net.faults).
    """
    rng = np.random.RandomState(seed)
    tenant_vals = [rng.uniform(-1, 1, (n, V)).astype(np.float32)
                   for _ in range(tenants)]

    async def tenant(t: int) -> List[float]:
        ic = interceptor(t) if callable(interceptor) else interceptor
        lats = []
        if persistent:
            sess = PersistentNetSession(
                addr, n, provisioning_seed=0xC0FFEE + t,
                learner_master=0x5EED + 17 * t, interceptor=ic,
                chunk_words=chunk_words, prefetch_depth=prefetch_depth,
                words_per_round=V + 1)
            await sess.open()
            try:
                for r in range(rounds_per_tenant):
                    t0 = time.perf_counter()
                    res = await sess.run_round(tenant_vals[t])
                    lats.append(time.perf_counter() - t0)
                    _check_round(t, r, res, tenant_vals[t])
            finally:
                await sess.close()
            return lats
        for r in range(rounds_per_tenant):
            t0 = time.perf_counter()
            res = await run_safe_round_net(
                tenant_vals[t], addr,
                provisioning_seed=0xC0FFEE + t,
                learner_master=0x5EED + 17 * t,
                counter=r * (V + 1),
                interceptor=ic, chunk_words=chunk_words,
                prefetch_depth=prefetch_depth)
            lats.append(time.perf_counter() - t0)
            _check_round(t, r, res, tenant_vals[t])
        return lats

    t0 = time.perf_counter()
    per_tenant = await asyncio.gather(*(tenant(t) for t in range(tenants)))
    wall = time.perf_counter() - t0
    lats = [x for lat in per_tenant for x in lat]
    return _report("protocol", tenants, lats, wall)


async def run_paper_scale(
    *,
    n: int = 36,
    V: int = 256,
    failures: Iterable[int] = (),
    seed: int = 0,
    chunk_words: Optional[int] = None,
    prefetch_depth: Optional[int] = None,
    stream: bool = True,
    weights: Optional[np.ndarray] = None,
    progress_timeout: float = 0.3,
    monitor_interval: float = 0.1,
    aggregation_timeout: float = 60.0,
) -> dict:
    """One SAFE round over real TCP at paper scale, closed forms checked.

    Starts a fresh broker, runs ``run_safe_round_net`` with n learners
    (``failures`` dead before the round — the paper's §6.1 failover
    experiment takes out nodes 4–6 after key exchange), and asserts:

      * MessageStats == §5 closed form 4(n−f) + 2f (4n when f=0);
      * one §5.3 monitor repost per dead node;
      * the published average equals the survivors' clear-text mean.

    Returns a flat row for the bench harness (wall seconds, messages,
    bytes, chunk-plane frame counts). ``chunk_words`` prices the
    chunk-streaming path at the same scale.
    """
    rng = np.random.RandomState(seed)
    vals = rng.uniform(-1, 1, (n, V)).astype(np.float32)
    failed = sorted(set(failures))
    broker = SafeBroker(progress_timeout=progress_timeout,
                        monitor_interval=monitor_interval,
                        aggregation_timeout=aggregation_timeout)
    addr = await broker.start()
    try:
        res = await run_safe_round_net(
            vals, addr, failed_nodes=failed, weights=weights,
            chunk_words=chunk_words, prefetch_depth=prefetch_depth,
            stream=stream)
    finally:
        await broker.stop()

    f = len(failed)
    expected = 4 * (n - f) + 2 * f
    got = res.stats["aggregation_total"]
    if got != expected:
        raise AssertionError(
            f"n={n} f={f}: {got} aggregation messages, §5 closed form "
            f"says {expected}")
    if res.monitor_reposts != f:
        raise AssertionError(
            f"{res.monitor_reposts} monitor reposts for {f} dead nodes")
    mask = np.ones(n, bool)
    for node in failed:
        mask[node - 1] = False
    if weights is None:
        exp_avg = vals[mask].mean(0)
    else:
        w = np.asarray(weights, np.float64)[mask]
        exp_avg = (vals[mask] * w[:, None]).sum(0) / w.sum()
    if np.abs(res.average - exp_avg).max() > 1e-2:
        raise AssertionError("published average off the survivors' mean")
    return {
        "n": n,
        "V": V,
        "failures": f,
        "messages": got,
        "expected_messages": expected,
        "monitor_reposts": res.monitor_reposts,
        "wall_s": res.wall_time,
        "bytes_sent": res.bytes_sent,
        "chunk_frames_in": res.stats["chunk_frames_in"],
        "chunk_frames_out": res.stats["chunk_frames_out"],
        "transfers_completed": res.stats["transfers_completed"],
        "streamed_combines": res.streamed_combines,
    }
