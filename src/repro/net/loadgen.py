"""Load harness for the wire plane: S concurrent tenants, one broker.

Three load shapes, matching the broker's planes:

  * :func:`run_engine_load` — tenants submit whole aggregation sessions
    (``submit_session``/``wait_session``); the broker batches them
    through one :class:`~repro.serve.agg_engine.AggregationEngine`
    program per step. This is the ROADMAP's many-tenants story: wire
    concurrency in front, one compiled device program behind.
  * :func:`run_protocol_load` — tenants each run a *full* n-learner
    SAFE round over TCP (n connections, 4n RPCs, real long-polls), i.e.
    the paper's distributed system under concurrent sessions.
  * :func:`run_paper_scale` — ONE round at the paper's headline scale
    (n=36, §6.1: where SAFE beats Bonawitz-style masking by 70x/56x
    with/without failover) and beyond it (n=128/512, ISSUE 6), with
    the §5 closed-form message counts AND sim↔wire bit-identity
    asserted inside the harness — optionally against a sharded broker
    fleet, and optionally under mid-round churn instead of pre-round
    death. ``benchmarks/paper_scale.py`` pairs it with the
    ``core/bon_protocol.py`` baseline at the same n
    (EXPERIMENTS.md §Paper-scale).

:func:`run_slo_load` closes the observability loop (ISSUE 7): heavy-
tailed multi-tenant profiles driven against a live ``get_metrics``
poller, with the SLOs — p99 round latency, zero dropped sessions,
bounded chunk backlog — evaluated in-harness into a pass/fail the CI
smoke gate asserts (``benchmarks/slo.py``).

For scale-out measurements ``run_protocol_load`` can spread its tenants
over spawned worker processes (``client_procs``) so a sharded broker
(``repro.net.shard``) is measured against a client that can actually
saturate it; :func:`ensure_fd_headroom` lifts RLIMIT_NOFILE for the
thousands of sockets an n=512 round opens.

All report into the standard bench harness (``benchmarks/net_load.py``,
``benchmarks/paper_scale.py``).
"""
from __future__ import annotations

import asyncio
import dataclasses
import multiprocessing
import time
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.net.broker import SafeBroker
from repro.net.client import (
    PersistentNetSession,
    WireClient,
    run_bon_round_net,
    run_safe_round_net,
)
from repro.net.shard import ShardedBroker

Addr = Tuple[str, int]


def ensure_fd_headroom(need: int) -> None:
    """Raise the soft RLIMIT_NOFILE toward the hard limit if ``need``
    descriptors would not fit; fail with a clear message otherwise.

    Paper-scale runs open O(n) learner connections on each side of the
    broker — at n=512 that is thousands of sockets, and the default
    soft limit of 1024 dies mid-round with a cryptic EMFILE."""
    try:
        import resource
    except ImportError:  # non-POSIX: nothing to tune, let the OS decide
        return
    soft, hard = resource.getrlimit(resource.RLIMIT_NOFILE)
    if soft >= need:
        return
    want = min(max(need, soft), hard if hard > 0 else need)
    try:
        resource.setrlimit(resource.RLIMIT_NOFILE, (want, hard))
        soft = want
    except (ValueError, OSError):
        pass
    if soft < need:
        raise RuntimeError(
            f"RLIMIT_NOFILE soft limit {soft} < {need} descriptors this "
            f"run needs (hard limit {hard}); raise it with `ulimit -n`")


@dataclasses.dataclass
class LoadReport:
    plane: str
    tenants: int
    rounds: int          # total rounds completed across tenants
    wall_s: float
    rounds_per_s: float
    p50_s: float
    p99_s: float
    latencies_s: List[float]

    def row(self) -> dict:
        return {
            "plane": self.plane,
            "tenants": self.tenants,
            "rounds": self.rounds,
            "wall_s": self.wall_s,
            "rounds_per_s": self.rounds_per_s,
            "p50_s": self.p50_s,
            "p99_s": self.p99_s,
        }


def _report(plane: str, tenants: int, lats: List[float],
            wall: float) -> LoadReport:
    arr = np.asarray(lats, np.float64)
    return LoadReport(
        plane=plane, tenants=tenants, rounds=len(lats), wall_s=wall,
        rounds_per_s=len(lats) / wall if wall > 0 else float("inf"),
        p50_s=float(np.percentile(arr, 50)),
        p99_s=float(np.percentile(arr, 99)),
        latencies_s=lats)


@dataclasses.dataclass
class SLOReport:
    """One SLO-gated load run (ISSUE 7): client-observed latencies plus
    the broker's own metrics plane, with the service-level objectives
    evaluated in-harness so a regression fails the bench, not just
    drifts a JSON number."""

    profile: str
    tenants: int
    heavy_tenants: int
    rounds: int
    wall_s: float
    rounds_per_s: float
    p50_s: float
    p99_s: float
    dropped_sessions: int
    busy_rejections: int      # broker-side admissions refused (total)
    shed_tenants: int         # tenants busy'd >= once that still finished
    backlog_peak_bytes: int   # max chunk_backlog_bytes seen while polling
    metrics_samples: int      # live get_metrics polls during the run
    broker_rounds_completed: int
    slo_p99_s: float
    slo_backlog_bytes: int
    passed: bool
    error: Optional[str] = None
    wan_profile: str = "none"

    def row(self) -> dict:
        return {k: getattr(self, k) for k in (
            "profile", "tenants", "heavy_tenants", "rounds", "wall_s",
            "rounds_per_s", "p50_s", "p99_s", "dropped_sessions",
            "busy_rejections", "shed_tenants", "backlog_peak_bytes",
            "metrics_samples", "broker_rounds_completed", "slo_p99_s",
            "slo_backlog_bytes", "passed", "wan_profile")}


async def run_slo_load(
    *,
    profile: str = "steady",
    tenants: int = 4,
    rounds_per_tenant: int = 3,
    n: int = 6,
    V: int = 256,
    heavy_tenants: int = 1,
    heavy_factor: int = 8,
    heavy_subgroups: int = 2,
    chunk_words: Optional[int] = None,
    heavy_chunk_words: Optional[int] = None,
    chunk_budget_bytes: Optional[int] = "default",  # sentinel, see below
    seed: int = 0,
    shards: int = 1,
    slo_p99_s: float = 60.0,
    slo_backlog_bytes: Optional[int] = None,
    metrics_poll_s: float = 0.02,
    bit_identical: bool = True,
    progress_timeout: float = 2.0,
    monitor_interval: float = 0.5,
    aggregation_timeout: float = 120.0,
    wan_profile: Optional[str] = None,
    wan_seed: int = 0,
    timeout_scale: float = 1.0,
) -> SLOReport:
    """Heavy-tailed multi-tenant load with asserted SLOs (ISSUE 7).

    Starts its own broker (sharded when ``shards > 1``), drives
    ``tenants`` concurrent tenants — each running full n-learner SAFE
    rounds — and polls the live ``get_metrics`` plane the whole time.
    Three traffic profiles:

      * ``"steady"`` — every tenant ships the same V-word vector; the
        uniform baseline (no admission pressure expected).
      * ``"heavy_tail"`` — the first ``heavy_tenants`` tenants ship
        ``heavy_factor``× larger vectors over the chunk plane while the
        rest stay small: the many-small/few-huge shape real federations
        have, under the default (ample) chunk budget.
      * ``"busy_shed"`` — heavy tail against a deliberately small
        per-session chunk budget (one chunk), so the flooding tenants'
        concurrent transfers get ``busy``-shed and must retry-after
        their way through (the §13 admission loop) while small tenants
        never see a rejection.

    Heavy tenants run ``heavy_subgroups`` parallel §5.5 group chains
    (default 2 — the minimum n is then 6, two rings of 3 for the
    privacy bound): the two chains post concurrently into ONE session,
    which is what makes admission pressure *deterministic* — with a
    single chain the SAFE hops are strictly sequential and the backlog
    drains between transfers, so nothing would ever be refused.

    SLOs evaluated into ``passed``: client-observed p99 round latency
    ``<= slo_p99_s``; **zero** dropped sessions (every tenant finished
    every round with the §5 closed-form message count and — when
    ``bit_identical`` — an average ``np.array_equal`` to the sim's);
    peak chunk backlog ``<= slo_backlog_bytes`` (default: 2× tenants ×
    (budget + one full payload) — an admitted transfer's continuations
    may legitimately overrun the budget, §13, so "bounded" means
    bounded by that, not by the budget alone). A tenant that was
    busy'd at least once and still
    finished all its rounds counts into ``shed_tenants`` — the
    shed-and-recovered signal CI gates on.

    ``wan_profile`` (a ``repro.net.faults.WAN_PROFILES`` name) runs
    every tenant behind that WAN emulation — each tenant gets its own
    interceptor seeded ``wan_seed + tenant`` so fault draws are
    reproducible per tenant, not interleaved in scheduler order. This
    is the SLO *calibration* path (ISSUE 9): a declared p99 under a 50
    ms-RTT profile is only honest if the harness can actually hold it,
    so ``benchmarks/slo.py`` carries a ``wan_continental`` row whose
    ``slo_p99_s`` is derived from RTT × the §5 chain depth. Pair with
    ``timeout_scale``/``progress_timeout`` generous enough that a slow
    WAN hop does not read as a dead node.
    """
    from repro.core.protocol import run_safe_round
    from repro.net.broker import DEFAULT_CHUNK_BUDGET_BYTES
    from repro.net.faults import make_wan_interceptor

    if profile not in ("steady", "heavy_tail", "busy_shed"):
        raise ValueError(f"unknown SLO profile {profile!r}")
    heavy = set(range(heavy_tenants)) if profile != "steady" else set()
    heavy_V = V * heavy_factor
    if heavy_chunk_words is None:
        # chunk the heavy tenants' traffic so the transfer plane (and
        # its budget) is actually exercised: ~16 chunks per payload
        heavy_chunk_words = max(1, heavy_V // 16)
    if chunk_budget_bytes == "default":
        if profile == "busy_shed":
            # ONE chunk of budget: the first in-flight transfer claims
            # the whole session (its continuations are always admitted —
            # §13 keeps streams deadlock-free, and an empty backlog
            # always admits), so the OTHER group chain's first chunk is
            # refused until it drains — guaranteed shedding
            chunk_budget_bytes = heavy_chunk_words * 4
        else:
            chunk_budget_bytes = DEFAULT_CHUNK_BUDGET_BYTES
    budget = (DEFAULT_CHUNK_BUDGET_BYTES if chunk_budget_bytes is None
              else int(chunk_budget_bytes))
    if slo_backlog_bytes is None:
        # "bounded" per §13 means: at most ~one over-budget transfer's
        # continuations per concurrently-admitted chain per session
        # (continuations are never refused), plus the budget itself —
        # NOT that backlog never exceeds the budget
        max_payload = 4 * ((heavy_V if heavy else V) + 1)
        slo_backlog_bytes = 2 * tenants * (budget + max_payload)

    rng = np.random.RandomState(seed)
    tenant_vals = [
        rng.uniform(-1, 1, (n, heavy_V if t in heavy else V))
        .astype(np.float32) for t in range(tenants)]
    ensure_fd_headroom(4 * n * tenants + 128)

    broker_kw = dict(progress_timeout=progress_timeout,
                     monitor_interval=monitor_interval,
                     aggregation_timeout=aggregation_timeout,
                     chunk_budget_bytes=chunk_budget_bytes)
    if shards > 1:
        broker = ShardedBroker(shards, **broker_kw)
    else:
        broker = SafeBroker(**broker_kw)
    addr = await broker.start()
    metric_ports = (list(broker.shard_ports) if shards > 1
                    else [addr[1]])

    peak = {"backlog": 0, "samples": 0}
    stop_polling = asyncio.Event()

    async def poll_metrics() -> None:
        clients = [await WireClient(addr[0], p).connect()
                   for p in metric_ports]
        try:
            while not stop_polling.is_set():
                backlog = 0
                for c in clients:
                    m = await c.request("get_metrics", {})
                    backlog += int(m["chunk_backlog_bytes"])
                peak["backlog"] = max(peak["backlog"], backlog)
                peak["samples"] += 1
                await asyncio.sleep(metrics_poll_s)
        finally:
            for c in clients:
                await c.close()

    async def tenant(t: int) -> Tuple[List[float], int]:
        vals = tenant_vals[t]
        tV = vals.shape[1]
        cw = heavy_chunk_words if t in heavy else chunk_words
        sg = heavy_subgroups if t in heavy else 1
        lats: List[float] = []
        busy = 0
        icpt = (make_wan_interceptor(wan_profile, seed=wan_seed + t)
                if wan_profile else None)
        for r in range(rounds_per_tenant):
            t0 = time.perf_counter()
            # stream=False pins chunked tenants to the buffered chunk
            # plane: these profiles exist to put admission control
            # under chunk-frame pressure, and the ISSUE 9 small-payload
            # fast path (auto stream=None) would otherwise skip the
            # chunk plane wholesale for frame-sized payloads
            res = await run_safe_round_net(
                vals, addr, subgroups=sg,
                provisioning_seed=0xC0FFEE + t,
                learner_master=0x5EED + 17 * t, counter=r * (tV + 1),
                chunk_words=cw,
                stream=False if cw is not None else None,
                interceptor=icpt, timeout_scale=timeout_scale)
            lats.append(time.perf_counter() - t0)
            busy += int(res.stats.get("busy_rejections", 0))
            got = res.stats["aggregation_total"]
            expected = 4 * n + (sg if sg > 1 else 0)  # §5/§5.5 forms
            if got != expected:
                raise RuntimeError(
                    f"tenant {t} round {r}: {got} aggregation messages, "
                    f"§5 closed form says {expected}")
            _check_round(t, r, res, vals)
            if bit_identical:
                sim = run_safe_round(
                    vals, subgroups=sg, provisioning_seed=0xC0FFEE + t,
                    learner_master=0x5EED + 17 * t, counter=r * (tV + 1))
                if not np.array_equal(sim.average, res.average):
                    raise RuntimeError(
                        f"tenant {t} round {r}: wire average not "
                        f"bit-identical to the sim")
        return lats, busy

    poller = asyncio.create_task(poll_metrics())
    error: Optional[str] = None
    dropped = 0
    shed = 0
    lats: List[float] = []
    busy_total = 0
    broker_rounds = 0
    try:
        t0 = time.perf_counter()
        settled = await asyncio.gather(
            *(tenant(t) for t in range(tenants)), return_exceptions=True)
        wall = time.perf_counter() - t0
        for t, res in enumerate(settled):
            if isinstance(res, BaseException):
                dropped += 1
                if error is None:
                    error = f"tenant {t}: {type(res).__name__}: {res}"
                continue
            t_lats, t_busy = res
            lats.extend(t_lats)
            busy_total += t_busy
            if t_busy > 0:
                shed += 1  # busy'd at least once, still finished
        # one deterministic post-run snapshot (the poller races rounds)
        mc = await WireClient(*addr).connect()
        try:
            if shards > 1:
                for p in metric_ports:
                    await mc.redirect(p)
                    m = await mc.request("get_metrics", {})
                    broker_rounds += int(m["rounds_completed"])
            else:
                m = await mc.request("get_metrics", {})
                broker_rounds = int(m["rounds_completed"])
        finally:
            await mc.close()
    finally:
        stop_polling.set()
        try:
            await poller
        except Exception:  # noqa: BLE001 — a poll race never fails a run
            pass
        await broker.stop()

    if profile == "steady" and busy_total:
        error = error or (f"steady profile saw {busy_total} busy "
                          f"rejections under the default budget")
    arr = np.asarray(lats or [0.0], np.float64)
    p99 = float(np.percentile(arr, 99))
    passed = (error is None and dropped == 0 and p99 <= slo_p99_s
              and peak["backlog"] <= slo_backlog_bytes)
    return SLOReport(
        profile=profile, tenants=tenants, heavy_tenants=len(heavy),
        rounds=len(lats), wall_s=wall,
        rounds_per_s=len(lats) / wall if wall > 0 else float("inf"),
        p50_s=float(np.percentile(arr, 50)), p99_s=p99,
        dropped_sessions=dropped, busy_rejections=busy_total,
        shed_tenants=shed, backlog_peak_bytes=peak["backlog"],
        metrics_samples=peak["samples"],
        broker_rounds_completed=broker_rounds,
        slo_p99_s=slo_p99_s, slo_backlog_bytes=int(slo_backlog_bytes),
        passed=bool(passed), error=error,
        wan_profile=wan_profile or "none")


async def run_engine_load(addr: Addr, *, tenants: int = 8,
                          rounds_per_tenant: int = 8, n: int = 8,
                          V: int = 1024, seed: int = 0,
                          warmup: bool = True,
                          timeout: float = 300.0,
                          chunk_words: Optional[int] = None) -> LoadReport:
    """Each tenant submits ``rounds_per_tenant`` single-round sessions
    back-to-back (closed-loop), measuring submit→published latency.

    ``chunk_words`` routes submit values and result fetches over the §6
    chunk plane — the path for engine payloads beyond one frame."""
    rng = np.random.RandomState(seed)
    tenant_vals = [rng.uniform(-1, 1, (n, V)).astype(np.float32)
                   for _ in range(tenants)]

    async def submit_and_wait(client, vals, t, r):
        sub_kw = {"values": vals, "rounds": 1,
                  "provisioning_seed": 0xC0FFEE + t,
                  "learner_master": 0x5EED + 17 * t,
                  "rotate0": r}
        if chunk_words is not None:
            sub = await client.submit_session_chunked(sub_kw, chunk_words)
            res = await client.wait_session_chunked(
                sub["sid"], timeout=timeout, chunk_words=chunk_words)
        else:
            sub = await client.request("submit_session", sub_kw)
            res = await client.request(
                "wait_session", {"sid": sub["sid"], "timeout": timeout})
        if res.get("status") != "done":
            raise RuntimeError(f"tenant {t} round {r}: {res}")
        return res

    if warmup:  # first submit compiles the engine program — keep it
        client = await WireClient(*addr).connect()
        try:
            await submit_and_wait(client, tenant_vals[0], 0, 0)
        finally:
            await client.close()

    async def tenant(t: int) -> List[float]:
        client = await WireClient(*addr, node=t).connect()
        lats = []
        try:
            for r in range(rounds_per_tenant):
                t0 = time.perf_counter()
                res = await submit_and_wait(client, tenant_vals[t], t, r)
                lats.append(time.perf_counter() - t0)
                exp = tenant_vals[t].mean(0)
                got = res["results"][0]
                if np.abs(got - exp).max() > 1e-2:
                    raise RuntimeError(f"tenant {t} got a wrong average")
        finally:
            await client.close()
        return lats

    t0 = time.perf_counter()
    per_tenant = await asyncio.gather(*(tenant(t) for t in range(tenants)))
    wall = time.perf_counter() - t0
    lats = [x for lat in per_tenant for x in lat]
    return _report("engine", tenants, lats, wall)


def _check_round(t: int, r: int, res, vals: np.ndarray) -> None:
    """Shared per-round sanity check for the protocol-load shapes."""
    if res.crashed_nodes:
        # churn plan fired: the published mean is over a subset whose
        # membership depends on *when* each crash landed (before vs.
        # after reposting) — value correctness under churn is pinned by
        # tests/test_net.py, not the loadgen
        return
    if res.average is None:
        raise RuntimeError(f"tenant {t} round {r}: no average")
    exp = vals.mean(0)
    if np.abs(res.average - exp).max() > 1e-2:
        raise RuntimeError(f"tenant {t} round {r}: wrong average")


async def _drive_tenants(addr: Addr, tenant_indices: Sequence[int], *,
                         rounds_per_tenant: int, n: int, V: int,
                         seed: int, interceptor=None,
                         chunk_words: Optional[int] = None,
                         prefetch_depth: Optional[int] = None,
                         persistent: bool = False) -> List[float]:
    """Drive a subset of tenants against a running broker; the shared
    core of :func:`run_protocol_load` for both the in-process and the
    multi-process (``client_procs``) paths. Tenant values are
    regenerated from ``seed`` in *global* tenant order, so any process
    driving tenant ``t`` sees the same f32 matrix."""
    max_t = max(tenant_indices) + 1 if tenant_indices else 0
    rng = np.random.RandomState(seed)
    tenant_vals = [rng.uniform(-1, 1, (n, V)).astype(np.float32)
                   for _ in range(max_t)]

    async def tenant(t: int) -> List[float]:
        ic = interceptor(t) if callable(interceptor) else interceptor
        lats = []
        if persistent:
            sess = PersistentNetSession(
                addr, n, provisioning_seed=0xC0FFEE + t,
                learner_master=0x5EED + 17 * t, interceptor=ic,
                chunk_words=chunk_words, prefetch_depth=prefetch_depth,
                words_per_round=V + 1)
            await sess.open()
            try:
                for r in range(rounds_per_tenant):
                    t0 = time.perf_counter()
                    res = await sess.run_round(tenant_vals[t])
                    lats.append(time.perf_counter() - t0)
                    _check_round(t, r, res, tenant_vals[t])
            finally:
                await sess.close()
            return lats
        for r in range(rounds_per_tenant):
            t0 = time.perf_counter()
            res = await run_safe_round_net(
                tenant_vals[t], addr,
                provisioning_seed=0xC0FFEE + t,
                learner_master=0x5EED + 17 * t,
                counter=r * (V + 1),
                interceptor=ic, chunk_words=chunk_words,
                prefetch_depth=prefetch_depth)
            lats.append(time.perf_counter() - t0)
            _check_round(t, r, res, tenant_vals[t])
        return lats

    per_tenant = await asyncio.gather(*(tenant(t) for t in tenant_indices))
    return [x for lat in per_tenant for x in lat]


def _client_worker_main(addr: Addr, tenant_indices, drive_kw: dict,
                        conn, start_ev) -> None:
    """Spawn target for one client-load worker process: signal ready,
    wait for the synchronized start (so spawn + import time stays out
    of the measured wall), drive the tenant subset, ship latencies."""
    try:
        conn.send("ready")
        start_ev.wait()
        lats = asyncio.run(
            _drive_tenants(addr, list(tenant_indices), **drive_kw))
        conn.send(("ok", lats))
    except BaseException as e:  # noqa: BLE001 — parent re-raises
        conn.send(("error", f"{type(e).__name__}: {e}"))
    finally:
        conn.close()


async def run_protocol_load(addr: Addr, *, tenants: int = 4,
                            rounds_per_tenant: int = 3, n: int = 8,
                            V: int = 256, seed: int = 0,
                            interceptor=None,
                            chunk_words: Optional[int] = None,
                            prefetch_depth: Optional[int] = None,
                            persistent: bool = False,
                            client_procs: Optional[int] = None
                            ) -> LoadReport:
    """Each tenant runs full n-learner SAFE rounds concurrently with
    every other tenant — one broker session per round by default, or
    (``persistent=True``) all of a tenant's rounds on ONE
    :class:`~repro.net.client.PersistentNetSession` (shared keys,
    connections and counter space — the amortized path the streaming
    benchmark compares against the rebuild path).

    ``interceptor`` is either a shared Interceptor instance or a
    callable ``tenant_index -> Interceptor`` — use the factory form for
    reproducible per-tenant fault plans (tenants reuse node ids, so a
    shared instance's per-node RNG streams interleave in scheduler
    order; see repro.net.faults).

    ``client_procs`` spreads the tenants over that many *worker
    processes* (spawned, start-synchronized so setup stays out of the
    wall measurement). With a sharded broker the single client event
    loop is otherwise the new bottleneck — one process cannot drive
    more load than one shard can serve, and the scaling curve would
    measure the client. Interceptors don't cross process boundaries
    (they are live objects with RNG state), so the two are exclusive.
    """
    drive_kw = dict(rounds_per_tenant=rounds_per_tenant, n=n, V=V,
                    seed=seed, chunk_words=chunk_words,
                    prefetch_depth=prefetch_depth, persistent=persistent)
    if not client_procs or client_procs <= 1:
        t0 = time.perf_counter()
        lats = await _drive_tenants(addr, range(tenants),
                                    interceptor=interceptor, **drive_kw)
        wall = time.perf_counter() - t0
        return _report("protocol", tenants, lats, wall)

    if interceptor is not None:
        raise ValueError("interceptor is not supported with client_procs "
                         "(live fault plans don't cross processes)")
    procs = min(client_procs, tenants)
    slices = [list(range(w, tenants, procs)) for w in range(procs)]
    ctx = multiprocessing.get_context("spawn")
    start_ev = ctx.Event()
    workers, pipes = [], []
    loop = asyncio.get_running_loop()
    try:
        for idx in slices:
            parent, child = ctx.Pipe()
            proc = ctx.Process(
                target=_client_worker_main,
                args=(addr, idx, drive_kw, child, start_ev), daemon=True)
            proc.start()
            child.close()
            workers.append(proc)
            pipes.append(parent)
        for pipe in pipes:  # all interpreters up before the clock starts
            if not await loop.run_in_executor(None, pipe.poll, 120.0):
                raise RuntimeError("client worker failed to report ready")
            msg = await loop.run_in_executor(None, pipe.recv)
            if msg != "ready":
                raise RuntimeError(f"client worker: {msg}")
        t0 = time.perf_counter()
        start_ev.set()
        lats: List[float] = []
        for pipe in pipes:
            status, payload = await loop.run_in_executor(None, pipe.recv)
            if status != "ok":
                raise RuntimeError(f"client worker failed: {payload}")
            lats.extend(payload)
        wall = time.perf_counter() - t0
    finally:
        for proc in workers:
            await loop.run_in_executor(None, proc.join, 10.0)
        for proc in workers:
            if proc.is_alive():
                proc.terminate()
        for pipe in pipes:
            pipe.close()
    return _report("protocol", tenants, lats, wall)


async def run_paper_scale(
    *,
    n: int = 36,
    V: int = 256,
    failures: Iterable[int] = (),
    churn: Optional[Dict[int, int]] = None,
    seed: int = 0,
    shards: int = 1,
    chunk_words: Optional[int] = None,
    prefetch_depth: Optional[int] = None,
    stream: Optional[bool] = True,
    weights: Optional[np.ndarray] = None,
    bit_identical: bool = True,
    interceptor=None,
    timeout_scale: float = 1.0,
    progress_timeout: float = 0.3,
    monitor_interval: float = 0.1,
    aggregation_timeout: float = 60.0,
) -> dict:
    """One SAFE round over real TCP at paper scale, closed forms checked.

    Starts a fresh broker, runs ``run_safe_round_net`` with n learners
    (``failures`` dead before the round — the paper's §6.1 failover
    experiment takes out nodes 4–6 after key exchange), and asserts:

      * MessageStats == §5 closed form 4(n−f) + 2f (4n when f=0);
      * one §5.3 monitor repost per dead node;
      * the published average equals the survivors' clear-text mean;
      * (``bit_identical``) the wire average is ``np.array_equal`` to
        the discrete-event simulation's for the same inputs — the
        sim↔wire discipline at n=128+, not just test-sized n.

    ``churn`` maps node id → op count after which the node crashes
    *mid-round* (repro.net.faults.ChurnInterceptor) — failover under
    live churn instead of pre-round death. Message totals under churn
    depend on when each crash lands relative to reposting, so the
    closed form is reported but only bounded (≥ the all-crash-early
    form), while bit-identity vs. the sim with the same nodes dead
    still holds exactly. ``shards`` > 1 runs the round against a
    :class:`~repro.net.shard.ShardedBroker` fleet — same assertions,
    sharded runtime (redirect + direct-dial paths under load).
    ``interceptor`` layers extra transport faults (e.g. a WAN profile
    from ``repro.net.faults.make_wan_interceptor``) under any churn
    schedule; pair it with ``timeout_scale`` and generous
    ``progress_timeout`` so a slow WAN hop doesn't read as a dead node.

    Returns a flat row for the bench harness (wall seconds, messages,
    bytes, chunk-plane frame counts). ``chunk_words`` prices the
    chunk-streaming path at the same scale.
    """
    from repro.net.faults import Chain, ChurnInterceptor

    rng = np.random.RandomState(seed)
    vals = rng.uniform(-1, 1, (n, V)).astype(np.float32)
    failed = sorted(set(failures))
    churn = dict(churn or {})
    if failed and churn:
        raise ValueError("pick failures= (pre-round) or churn= "
                         "(mid-round), not both")
    # each live learner holds a control + possibly an aux chunk
    # connection, broker mirrors both; headroom for pipes/listeners
    ensure_fd_headroom(4 * n + 128)
    if churn:
        churn_icpt = ChurnInterceptor(churn)
        interceptor = (Chain(interceptor, churn_icpt) if interceptor
                       else churn_icpt)
    broker_kw = dict(progress_timeout=progress_timeout,
                     monitor_interval=monitor_interval,
                     aggregation_timeout=aggregation_timeout)
    if shards > 1:
        broker = ShardedBroker(shards, **broker_kw)
    else:
        broker = SafeBroker(**broker_kw)
    addr = await broker.start()
    try:
        res = await run_safe_round_net(
            vals, addr, failed_nodes=failed, weights=weights,
            interceptor=interceptor, timeout_scale=timeout_scale,
            chunk_words=chunk_words,
            prefetch_depth=prefetch_depth, stream=stream)
    finally:
        await broker.stop()

    dead = sorted(churn) if churn else failed
    f = len(dead)
    expected = 4 * (n - f) + 2 * f
    got = res.stats["aggregation_total"]
    if churn:
        if res.crashed_nodes != tuple(sorted(churn)):
            raise AssertionError(
                f"churn plan {sorted(churn)} but crashed nodes "
                f"{res.crashed_nodes}")
        if got < expected:
            raise AssertionError(
                f"n={n} churn f={f}: {got} aggregation messages below "
                f"the §5 floor {expected}")
    else:
        if got != expected:
            raise AssertionError(
                f"n={n} f={f}: {got} aggregation messages, §5 closed "
                f"form says {expected}")
        if res.monitor_reposts != f:
            raise AssertionError(
                f"{res.monitor_reposts} monitor reposts for {f} dead "
                f"nodes")
    mask = np.ones(n, bool)
    for node in dead:
        mask[node - 1] = False
    if weights is None:
        exp_avg = vals[mask].mean(0)
    else:
        w = np.asarray(weights, np.float64)[mask]
        exp_avg = (vals[mask] * w[:, None]).sum(0) / w.sum()
    if np.abs(res.average - exp_avg).max() > 1e-2:
        raise AssertionError("published average off the survivors' mean")
    if bit_identical:
        from repro.core.protocol import run_safe_round

        sim = run_safe_round(vals, failed_nodes=dead, weights=weights)
        if not np.array_equal(sim.average, res.average):
            raise AssertionError(
                f"n={n} f={f} shards={shards}: wire average is not "
                f"bit-identical to the simulation")
    return {
        "n": n,
        "V": V,
        "failures": f,
        "churn": bool(churn),
        "shards": shards,
        "messages": got,
        "expected_messages": expected,
        "monitor_reposts": res.monitor_reposts,
        "wall_s": res.wall_time,
        "bytes_sent": res.bytes_sent,
        "chunk_frames_in": res.stats["chunk_frames_in"],
        "chunk_frames_out": res.stats["chunk_frames_out"],
        "transfers_completed": res.stats["transfers_completed"],
        "streamed_combines": res.streamed_combines,
        "bit_identical": bool(bit_identical),
    }


async def run_bon_scale(
    *,
    n: int = 36,
    V: int = 256,
    failures: Iterable[int] = (),
    churn: Optional[Dict[int, int]] = None,
    seed: int = 0,
    threshold: Optional[int] = None,
    interceptor=None,
    bit_identical: bool = True,
    roster_timeout: float = 0.5,
    monitor_interval: float = 0.1,
    aggregation_timeout: float = 60.0,
    timeout_scale: float = 1.0,
) -> dict:
    """One BON baseline round over real TCP, closed form checked.

    The Bonawitz-style twin of :func:`run_paper_scale` (ISSUE 8): starts
    a fresh broker, drives ``run_bon_round_net`` with n learners (every
    node connects — BON dropouts fail *after* Rounds 0–1, unlike SAFE's
    pre-round deaths), and asserts:

      * BonStats == the closed form ``2n + 2n(n−1) + ℓ(n+2)`` with
        ``ℓ = n − f`` (docs/PROTOCOL.md §14) — exact even under
        ``churn``, because a BON crash schedule of ``2n`` ops lands
        precisely on the R1/R2 boundary, the point where the sim's
        ``failed_nodes`` semantics place dropouts;
      * the published average equals the survivors' clear-text mean;
      * (``bit_identical``) the wire average is ``np.array_equal`` to
        ``run_bon_round``'s for the same inputs and dropout set.

    ``failures`` marks nodes that stop cooperatively after Round 1;
    ``churn`` maps node → op budget for
    :class:`~repro.net.faults.ChurnInterceptor` (pass ``2n`` per victim
    for the sim-equivalent point). ``interceptor`` layers WAN faults
    (``repro.net.faults.make_wan_interceptor``) on clean runs. Returns
    a flat bench row like ``run_paper_scale``'s.
    """
    from repro.core.bon_protocol import run_bon_round
    from repro.net.faults import Chain, ChurnInterceptor

    rng = np.random.RandomState(seed)
    vals = rng.uniform(-1, 1, (n, V)).astype(np.float32)
    failed = sorted(set(failures))
    churn = dict(churn or {})
    if failed and churn:
        raise ValueError("pick failures= (post-R1) or churn= "
                         "(op schedule), not both")
    ensure_fd_headroom(4 * n + 128)
    icpt = interceptor
    if churn:
        churn_icpt = ChurnInterceptor(churn)
        icpt = Chain(icpt, churn_icpt) if icpt else churn_icpt
    broker = SafeBroker(monitor_interval=monitor_interval,
                        aggregation_timeout=aggregation_timeout)
    addr = await broker.start()
    try:
        res = await run_bon_round_net(
            vals, addr, failed_nodes=failed, threshold=threshold,
            seed=seed, roster_timeout=roster_timeout,
            interceptor=icpt, timeout_scale=timeout_scale)
    finally:
        await broker.stop()

    dead = sorted(set(res.crashed_nodes) | set(failed))
    f = len(dead)
    if churn and sorted(churn) != dead:
        raise AssertionError(
            f"churn plan {sorted(churn)} but crashed nodes {dead}")
    if res.messages != res.expected_messages:
        raise AssertionError(
            f"BON n={n} f={f}: {res.messages} messages, closed form "
            f"says {res.expected_messages}")
    mask = np.ones(n, bool)
    for node in dead:
        mask[node - 1] = False
    exp_avg = vals[mask].mean(0)
    if np.abs(res.average - exp_avg).max() > 1e-2:
        raise AssertionError("BON average off the survivors' mean")
    if bit_identical:
        sim = run_bon_round(vals, failed_nodes=dead, threshold=threshold,
                            seed=seed)
        if not np.array_equal(sim.average, res.average):
            raise AssertionError(
                f"BON n={n} f={f}: wire average is not bit-identical "
                f"to the simulation")
    return {
        "protocol": "bon",
        "n": n,
        "V": V,
        "failures": f,
        "churn": bool(churn),
        "messages": res.messages,
        "expected_messages": res.expected_messages,
        "wall_s": res.wall_time,
        "bytes_sent": res.bytes_sent,
        "shares_reconstructed": res.stats.get("shares_reconstructed", 0),
        "bit_identical": bool(bit_identical),
    }


async def run_hierarchical_scale(
    *,
    n: int = 36,
    orgs: int = 3,
    V: int = 256,
    failed_orgs: Iterable[int] = (),
    failed_nodes: Iterable[int] = (),
    initiator_fails: bool = False,
    seed: int = 0,
    bit_identical: bool = True,
    progress_timeout: float = 1.0,
    monitor_interval: float = 0.2,
    aggregation_timeout: float = 60.0,
    parent_timeout: Optional[float] = None,
) -> dict:
    """One §5.10 chain-of-chains round over real TCP, both levels'
    closed forms checked (docs/PROTOCOL.md §15).

    Starts a parent broker and a child broker (all ``orgs`` child
    sessions on the latter — one broker per org is the deployment
    picture, one broker hosting them all is the same wire path), runs
    :func:`~repro.net.client.run_hierarchical_round_net`, and asserts:

      * per surviving org ``g`` with ``f_g`` dead learners:
        ``MessageStats == 4(n_g − f_g) + 2 f_g + 1`` (the §5 form for a
        single-group session from a ``subgroups=orgs`` build, ``+1`` for
        the org's one global publish) and one monitor repost per dead
        learner;
      * parent level: ``hierarchy_total == 2(c − f)`` for ``c = orgs``
        and ``f`` whole-org crashes — one ``post_org_average`` up and
        one ``get_org_average`` down per surviving org, nothing per
        crashed org (elided like a dead learner);
      * crashed orgs come back in ``crashed_orgs`` exactly as planned;
      * (``bit_identical``) the parent average is ``np.array_equal`` to
        ``run_hierarchical_round_sim``'s for the same inputs — and, on
        a fully clean round, to the flat ``run_safe_round(subgroups=
        orgs)``'s, the §5.10 anonymization-changes-nothing claim.

    The default monitor cadence is gentler than ``run_paper_scale``'s
    (1.0 s progress window): ``orgs`` chains long-poll concurrently on
    one client event loop, and at n=128 a live-but-unscheduled learner
    must not read as dead or the §5.3 monitor walks its posting onward
    and the exact per-org form no longer holds.

    Returns a flat row for the bench harness.
    """
    from repro.core.protocol import run_hierarchical_round_sim, run_safe_round
    from repro.net.client import run_hierarchical_round_net
    from repro.topology import RingTopology

    rng = np.random.RandomState(seed)
    vals = rng.uniform(-1, 1, (n, V)).astype(np.float32)
    failed = sorted(set(failed_nodes))
    dead_orgs = sorted(set(failed_orgs))
    chains = RingTopology(n, orgs).group_chains(node_base=1)
    ensure_fd_headroom(4 * n + 128)

    if parent_timeout is None:
        # with a planned whole-org crash the parent must give up on the
        # missing org; without one it should never elide
        parent_timeout = 2.0 if dead_orgs else aggregation_timeout
    broker_kw = dict(progress_timeout=progress_timeout,
                     monitor_interval=monitor_interval,
                     aggregation_timeout=aggregation_timeout)
    parent = SafeBroker(**broker_kw)
    child = SafeBroker(**broker_kw)
    paddr = await parent.start()
    caddr = await child.start()
    try:
        res = await run_hierarchical_round_net(
            vals, paddr, {g: caddr for g in range(orgs)},
            failed_orgs=dead_orgs, failed_nodes=failed,
            initiator_fails=initiator_fails,
            aggregation_timeout=aggregation_timeout,
            parent_timeout=parent_timeout)
    finally:
        await parent.stop()
        await child.stop()

    f_orgs = len(dead_orgs)
    live = [g for g in range(orgs) if g not in dead_orgs]
    per_org = {}
    for g in live:
        n_g = len(chains[g])
        f_g = sum(1 for node in failed if node in chains[g])
        expected = 4 * (n_g - f_g) + 2 * f_g + 1
        got = res.org_results[g].stats["aggregation_total"]
        if not initiator_fails and got != expected:
            raise AssertionError(
                f"org {g} (n_g={n_g}, f_g={f_g}): {got} aggregation "
                f"messages, §5.10 per-org form says {expected}")
        if not initiator_fails and res.org_results[g].monitor_reposts != f_g:
            raise AssertionError(
                f"org {g}: {res.org_results[g].monitor_reposts} monitor "
                f"reposts for {f_g} dead learners")
        per_org[g] = got
    hier_total = res.parent_stats["hierarchy_total"]
    if hier_total != 2 * (orgs - f_orgs):
        raise AssertionError(
            f"parent level: {hier_total} hierarchy messages, closed "
            f"form says {2 * (orgs - f_orgs)} for c={orgs} f={f_orgs}")
    if res.elided_orgs != tuple(dead_orgs):
        raise AssertionError(
            f"planned org crashes {dead_orgs} but parent elided "
            f"{res.elided_orgs}")
    if bit_identical:
        sim = run_hierarchical_round_sim(
            vals, orgs=orgs, failed_orgs=dead_orgs, failed_nodes=failed,
            initiator_fails=initiator_fails,
            aggregation_timeout=3.0 if initiator_fails else 8.0)
        if not np.array_equal(sim.average, res.average):
            raise AssertionError(
                f"n={n} orgs={orgs}: hierarchical wire average is not "
                f"bit-identical to the simulation")
        if not dead_orgs and not failed and not initiator_fails:
            flat = run_safe_round(vals, subgroups=orgs)
            if not np.array_equal(flat.average, res.average):
                raise AssertionError(
                    f"n={n} orgs={orgs}: clean hierarchical average is "
                    f"not bit-identical to the flat subgroup round")
    return {
        "protocol": "hierarchical",
        "n": n,
        "orgs": orgs,
        "V": V,
        "failed_orgs": f_orgs,
        "failed_nodes": len(failed),
        "org_messages": {str(g): per_org[g] for g in live},
        "hierarchy_messages": hier_total,
        "expected_hierarchy_messages": 2 * (orgs - f_orgs),
        "elided_orgs": list(res.elided_orgs),
        "wall_s": res.wall_time,
        "bit_identical": bool(bit_identical),
    }


async def run_shard_failover_load(
    *,
    tenants: int = 3,
    rounds_per_tenant: int = 2,
    n: int = 4,
    V: int = 32,
    shards: int = 2,
    kill_shard: int = 0,
    kill_after_round: int = 0,
    seed: int = 0,
    progress_timeout: float = 0.4,
    monitor_interval: float = 0.1,
    aggregation_timeout: float = 30.0,
) -> dict:
    """Kill a shard worker mid-run; tenants recover onto the survivors.

    Starts a :class:`~repro.net.shard.ShardedBroker` behind its
    dispatcher (``use_reuseport=False`` — deterministic across
    platforms). Each tenant opens a
    :class:`~repro.net.client.PersistentNetSession` (so its session is
    PINNED to whatever shard the dispatcher's round-robin landed it on)
    and runs rounds; once every tenant has finished round
    ``kill_after_round``, worker ``kill_shard`` is terminated. Tenants
    whose session lives on the dead shard see
    :class:`~repro.net.client.ShardDeadError` on their next round — the
    deterministic §12 surface, not a hang — abandon the stranded
    session, and replay the round as a fresh one-shot session through
    the shared dispatcher address (which routes ``create_session`` to
    LIVE shards only) with the SAME seeds and counter base the
    persistent session would have used (``r * (V+1)``), so the
    recovered average is bit-identical to the uninterrupted
    simulation's.

    Asserts every round of every tenant (including each replayed one)
    matches the §5 closed form and the sim bit-for-bit, and that at
    least one tenant actually exercised the recovery path (with
    ``tenants >= shards`` the round-robin guarantees the dead shard
    owned at least one session). Returns a flat row for the bench/test
    harness.
    """
    from repro.core.protocol import run_safe_round
    from repro.net.client import ShardDeadError

    rng = np.random.RandomState(seed)
    tenant_vals = [rng.uniform(-1, 1, (n, V)).astype(np.float32)
                   for _ in range(tenants)]
    ensure_fd_headroom(4 * n * tenants + 128)

    broker = ShardedBroker(shards, use_reuseport=False,
                           progress_timeout=progress_timeout,
                           monitor_interval=monitor_interval,
                           aggregation_timeout=aggregation_timeout)
    addr = await broker.start()
    killed = asyncio.Event()
    barrier_done = [asyncio.Event() for _ in range(tenants)]
    recoveries = [0] * tenants

    async def kill_worker() -> None:
        for ev in barrier_done:
            await ev.wait()
        loop = asyncio.get_running_loop()
        proc = broker._procs[kill_shard]
        proc.terminate()
        await loop.run_in_executor(None, proc.join, 10.0)
        killed.set()

    def check(t: int, r: int, res, vals) -> None:
        got = res.stats["aggregation_total"]
        if got != 4 * n:
            raise RuntimeError(
                f"tenant {t} round {r}: {got} aggregation messages, "
                f"§5 closed form says {4 * n}")
        sim = run_safe_round(
            vals, provisioning_seed=0xC0FFEE + t,
            learner_master=0x5EED + 17 * t, counter=r * (V + 1))
        if not np.array_equal(sim.average, res.average):
            raise RuntimeError(
                f"tenant {t} round {r}: round not bit-identical to "
                f"the sim")

    async def tenant(t: int) -> None:
        vals = tenant_vals[t]
        sess = PersistentNetSession(
            addr, n, provisioning_seed=0xC0FFEE + t,
            learner_master=0x5EED + 17 * t, words_per_round=V + 1)
        await sess.open()
        stranded = False
        try:
            for r in range(rounds_per_tenant):
                if not stranded:
                    try:
                        res = await sess.run_round(vals)
                    except ShardDeadError:
                        # session stranded on the killed worker: abandon
                        # it and replay this round (and the rest) as
                        # one-shot sessions via the dispatcher, which
                        # only routes creates to live shards
                        stranded = True
                        recoveries[t] += 1
                if stranded:
                    res = await run_safe_round_net(
                        vals, addr,
                        provisioning_seed=0xC0FFEE + t,
                        learner_master=0x5EED + 17 * t,
                        counter=r * (V + 1))
                check(t, r, res, vals)
                if r == kill_after_round:
                    barrier_done[t].set()
                    await killed.wait()
        finally:
            try:
                await sess.close()
            except (ShardDeadError, OSError):
                pass  # the stranded session's shard is gone with it

    try:
        await asyncio.gather(kill_worker(),
                             *(tenant(t) for t in range(tenants)))
        dead = broker.dead_shards()
    finally:
        await broker.stop()

    if kill_shard not in dead:
        raise AssertionError(f"killed shard {kill_shard} not reported "
                             f"dead (dead set: {sorted(dead)})")
    total_recoveries = sum(recoveries)
    if rounds_per_tenant > kill_after_round + 1 and total_recoveries == 0:
        raise AssertionError(
            "no tenant hit the dead shard after the kill — the recovery "
            "path went unexercised (dispatcher routing drifted?)")
    return {
        "protocol": "shard_failover",
        "tenants": tenants,
        "rounds_per_tenant": rounds_per_tenant,
        "n": n,
        "shards": shards,
        "killed_shard": kill_shard,
        "recoveries": total_recoveries,
        "rounds_completed": tenants * rounds_per_tenant,
        "bit_identical": True,
    }
