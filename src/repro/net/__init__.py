"""Wire plane: the SAFE control plane over a real async transport.

`repro.net` runs the *same* learner state machines
(``core/machines.py``) and the *same* broker semantics
(``core/controller.Controller``) as the discrete-event simulation, but
over actual sockets: a binary wire codec (``wire``), an asyncio broker
server with long-poll scheduling and the §5.3 progress monitor
(``broker``), a learner runtime mapping generator yields onto awaits
(``client``), pluggable transport faults (``faults``), and a
multi-tenant load harness (``loadgen``). ``shard`` scales the broker
out: N worker processes behind one ``SO_REUSEPORT`` port, sessions
consistently hashed to shards by session id (PROTOCOL.md §12).

Numpy-only by design (no JAX import) so a broker or learner can run on
hosts without an accelerator stack; the engine plane takes an already-
constructed ``serve.AggregationEngine`` by injection.
"""
from repro.net.broker import DEFAULT_CHUNK_BUDGET_BYTES, SafeBroker
from repro.net.client import (
    BonNetResult,
    HierNetResult,
    NetResult,
    PersistentNetSession,
    ShardDeadError,
    WireClient,
    auto_chunk_words,
    backoff_delay,
    drive_learner,
    run_bon_round_net,
    run_federated_round_net,
    run_federated_rounds_net,
    run_hierarchical_round_net,
    run_safe_round_net,
)
from repro.net.faults import (
    WAN_PROFILES,
    Chain,
    ChurnInterceptor,
    DropInterceptor,
    DropPacket,
    HeavyTailLatencyInterceptor,
    Interceptor,
    LatencyInterceptor,
    LearnerCrashed,
    deep_edge_faults,
    make_wan_interceptor,
)
from repro.net.shard import ShardBroker, ShardedBroker, shard_of
from repro.net.loadgen import (
    LoadReport,
    SLOReport,
    run_bon_scale,
    run_engine_load,
    run_hierarchical_scale,
    run_paper_scale,
    run_protocol_load,
    run_shard_failover_load,
    run_slo_load,
)

__all__ = [
    "SafeBroker",
    "DEFAULT_CHUNK_BUDGET_BYTES",
    "auto_chunk_words",
    "backoff_delay",
    "ShardBroker",
    "ShardedBroker",
    "shard_of",
    "WireClient",
    "NetResult",
    "BonNetResult",
    "HierNetResult",
    "ShardDeadError",
    "PersistentNetSession",
    "drive_learner",
    "run_safe_round_net",
    "run_bon_round_net",
    "run_hierarchical_round_net",
    "run_federated_round_net",
    "run_federated_rounds_net",
    "Interceptor",
    "Chain",
    "LatencyInterceptor",
    "DropInterceptor",
    "ChurnInterceptor",
    "HeavyTailLatencyInterceptor",
    "DropPacket",
    "LearnerCrashed",
    "deep_edge_faults",
    "WAN_PROFILES",
    "make_wan_interceptor",
    "LoadReport",
    "SLOReport",
    "run_engine_load",
    "run_protocol_load",
    "run_paper_scale",
    "run_bon_scale",
    "run_hierarchical_scale",
    "run_shard_failover_load",
    "run_slo_load",
]
