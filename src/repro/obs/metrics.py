"""Dependency-free metrics primitives for the wire plane (ISSUE 7).

Three series types, all safe to update from the asyncio hot path
without locks — the broker's event loop is single-threaded, every
update is a couple of int/float ops, and nothing here ever awaits:

  * :class:`Counter` — monotonic; ``inc`` only.
  * :class:`Gauge` — settable point-in-time value.
  * :class:`Histogram` — fixed log-spaced buckets with cumulative
    counts; p50/p99 (any percentile) extracted by walking the
    cumulative distribution and interpolating inside the bucket.
    Fixed buckets keep ``observe`` O(len(buckets)) with zero
    allocation — no reservoir, no quantile sketch, no numpy on the
    hot path.

:class:`MetricsRegistry` is the per-broker namespace: get-or-create by
name, a picklable :meth:`~MetricsRegistry.snapshot` for the wire
``get_metrics`` op, and :meth:`~MetricsRegistry.render_prometheus` for
the optional plaintext HTTP exporter (Prometheus text exposition
format, stdlib only).

Observability observes: nothing in this module touches frames,
payloads, or the §5 message counters.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "DEFAULT_LATENCY_BUCKETS",
]

# Upper bounds (seconds) for round/transfer latency histograms:
# ~log-spaced from 1ms to 60s, +Inf implicit. Chosen to resolve both
# localhost microbenchmark rounds (single-digit ms) and WAN-profile
# rounds under LatencyInterceptor (seconds).
DEFAULT_LATENCY_BUCKETS: Tuple[float, ...] = (
    0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
    1.0, 2.5, 5.0, 10.0, 30.0, 60.0,
)


class Counter:
    """Monotonic counter. ``inc`` only — resets don't exist (rates are
    the consumer's job, deltas are :class:`~repro.net.client
    .PersistentNetSession`-style subtraction)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """Point-in-time value (active sessions, backlog bytes)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def dec(self, n: float = 1.0) -> None:
        self.value -= n


class Histogram:
    """Fixed-bucket histogram with percentile extraction.

    ``bounds`` are inclusive upper bounds; an implicit +Inf bucket
    catches the tail. ``counts[i]`` is the number of observations with
    ``v <= bounds[i]`` (non-cumulative storage; cumulated on read).
    """

    __slots__ = ("name", "bounds", "counts", "count", "sum")

    def __init__(self, name: str,
                 bounds: Sequence[float] = DEFAULT_LATENCY_BUCKETS):
        self.name = name
        self.bounds = tuple(float(b) for b in bounds)
        if list(self.bounds) != sorted(self.bounds):
            raise ValueError(f"histogram bounds must be sorted: {bounds}")
        self.counts = [0] * (len(self.bounds) + 1)  # +1: the +Inf bucket
        self.count = 0
        self.sum = 0.0

    def observe(self, v: float) -> None:
        self.count += 1
        self.sum += v
        for i, b in enumerate(self.bounds):
            if v <= b:
                self.counts[i] += 1
                return
        self.counts[-1] += 1

    def percentile(self, p: float) -> float:
        """Approximate percentile (0..100) by linear interpolation
        inside the containing bucket. Empty histogram -> 0.0; tail
        (+Inf) bucket -> the largest finite bound (a floor, reported
        rather than inventing an upper edge)."""
        if self.count == 0:
            return 0.0
        rank = (p / 100.0) * self.count
        seen = 0
        lo = 0.0
        for i, b in enumerate(self.bounds):
            c = self.counts[i]
            if seen + c >= rank and c > 0:
                frac = (rank - seen) / c
                return lo + frac * (b - lo)
            seen += c
            lo = b
        return self.bounds[-1] if self.bounds else 0.0

    def to_dict(self) -> dict:
        return {
            "count": self.count,
            "sum": self.sum,
            "p50": self.percentile(50.0),
            "p99": self.percentile(99.0),
            "buckets": [[b, c] for b, c in zip(self.bounds, self.counts)]
                       + [[float("inf"), self.counts[-1]]],
        }


class MetricsRegistry:
    """Per-broker namespace of series, get-or-create by name.

    One registry per :class:`~repro.net.broker.SafeBroker` (so each
    ``ShardBroker`` worker process reports its own shard's series —
    ``get_metrics`` without a ``session`` kwarg is answered by
    whichever worker the socket reaches, and the response names its
    shard).
    """

    def __init__(self):
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge(name)
        return g

    def histogram(self, name: str,
                  bounds: Optional[Sequence[float]] = None) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = Histogram(
                name, bounds if bounds is not None
                else DEFAULT_LATENCY_BUCKETS)
        return h

    def snapshot(self) -> dict:
        """Wire-safe snapshot: plain dicts of plain scalars/lists —
        exactly what the codec's value tags can carry."""
        return {
            "counters": {n: c.value for n, c in self._counters.items()},
            "gauges": {n: float(g.value) for n, g in self._gauges.items()},
            "histograms": {n: h.to_dict()
                           for n, h in self._histograms.items()},
        }

    def render_prometheus(self, prefix: str = "",
                          labels: str = "") -> str:
        """Prometheus text exposition format (0.0.4), stdlib only.

        ``labels`` is a pre-rendered label body like
        ``shard="2"`` applied to every series.
        """
        lab = "{%s}" % labels if labels else ""
        lines: List[str] = []
        for n, c in sorted(self._counters.items()):
            lines.append(f"# TYPE {prefix}{n} counter")
            lines.append(f"{prefix}{n}{lab} {c.value}")
        for n, g in sorted(self._gauges.items()):
            lines.append(f"# TYPE {prefix}{n} gauge")
            lines.append(f"{prefix}{n}{lab} {float(g.value)}")
        for n, h in sorted(self._histograms.items()):
            lines.append(f"# TYPE {prefix}{n} histogram")
            cum = 0
            for b, c in zip(h.bounds, h.counts):
                cum += c
                le = f'le="{b}"'
                body = f"{labels},{le}" if labels else le
                lines.append(f"{prefix}{n}_bucket{{{body}}} {cum}")
            cum += h.counts[-1]
            le = 'le="+Inf"'
            body = f"{labels},{le}" if labels else le
            lines.append(f"{prefix}{n}_bucket{{{body}}} {cum}")
            lines.append(f"{prefix}{n}_sum{lab} {h.sum}")
            lines.append(f"{prefix}{n}_count{lab} {h.count}")
        return "\n".join(lines) + "\n"
