"""Observability plane (ISSUE 7): dependency-free metrics + tracing.

``repro.obs`` deliberately imports nothing from ``repro.net`` or
``repro.core`` — it is the leaf layer both instrument. See
ARCHITECTURE.md §Observability.
"""
from repro.obs.metrics import (
    DEFAULT_LATENCY_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
)
from repro.obs.trace import Span, Tracer

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "DEFAULT_LATENCY_BUCKETS",
    "Span",
    "Tracer",
]
