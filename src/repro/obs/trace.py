"""Span tracing for round/transfer/chunk lifecycle events (ISSUE 7).

A :class:`Tracer` is a fixed-capacity ring buffer of :class:`Span`
records — **off by default** so the zero-copy hot path pays exactly one
``if tracer.enabled`` branch per site. Spans carry only small scalars
(names, node/session ids, chunk sequence numbers, monotonic
timestamps): never payload arrays, never buffer references — so the
tracer cannot pin the zero-copy frame views the broker relays
(PROTOCOL.md §12) or alter their lifetime.

The ring buffer bounds memory by construction: a long-lived broker
under heavy load keeps the most recent ``capacity`` spans and silently
drops the oldest (``dropped`` counts them, so an exporter can tell a
quiet broker from a wrapped one).
"""
from __future__ import annotations

from collections import deque
from typing import Deque, List, Optional

__all__ = ["Span", "Tracer"]


class Span:
    """One lifecycle event: ``[t0, t1]`` on the broker's monotonic
    clock (``SafeBroker.now()``), plus small scalar attributes."""

    __slots__ = ("name", "t0", "t1", "attrs")

    def __init__(self, name: str, t0: float, t1: float, attrs: dict):
        self.name = name
        self.t0 = t0
        self.t1 = t1
        self.attrs = attrs

    @property
    def duration(self) -> float:
        return self.t1 - self.t0

    def to_dict(self) -> dict:
        return {"name": self.name, "t0": self.t0, "t1": self.t1,
                "duration": self.duration, **self.attrs}


class Tracer:
    """Ring-buffer span recorder, disabled unless asked for.

    ``record`` is the only hot-path entry point; callers guard it with
    ``if tracer.enabled`` so a disabled tracer costs one attribute
    load. Attributes must be small scalars (ints/floats/short strings)
    — the tracer asserts nothing at runtime to stay off the hot path,
    the contract is documented here and enforced by the test suite.
    """

    def __init__(self, capacity: int = 2048, enabled: bool = False):
        if capacity <= 0:
            raise ValueError("tracer capacity must be positive")
        self.capacity = capacity
        self.enabled = enabled
        self.dropped = 0
        self._spans: Deque[Span] = deque()

    def record(self, name: str, t0: float, t1: float, **attrs) -> None:
        if not self.enabled:
            return
        if len(self._spans) >= self.capacity:
            self._spans.popleft()
            self.dropped += 1
        self._spans.append(Span(name, t0, t1, attrs))

    def spans(self, name: Optional[str] = None) -> List[Span]:
        if name is None:
            return list(self._spans)
        return [s for s in self._spans if s.name == name]

    def export(self) -> List[dict]:
        """Wire-safe export: plain dicts of plain scalars."""
        return [s.to_dict() for s in self._spans]

    def clear(self) -> None:
        self._spans.clear()
        self.dropped = 0

    def __len__(self) -> int:
        return len(self._spans)
