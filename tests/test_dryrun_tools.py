"""Dry-run tooling: HLO collective parser, shape-bytes, spec sanitizer,
hierarchical controller — pure-host units."""
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.dryrun import _shape_bytes, parse_collectives
from repro.models.sharding import sanitize_spec


class TestShapeBytes:
    def test_simple(self):
        assert _shape_bytes("bf16[16,1024]{1,0}") == 16 * 1024 * 2
        assert _shape_bytes("f32[8]{0}") == 32
        assert _shape_bytes("u32[2,2]{1,0}") == 16

    def test_tuple_sums(self):
        assert _shape_bytes("(bf16[4]{0}, f32[4]{0})") == 8 + 16

    def test_unknown_dtype_ignored(self):
        assert _shape_bytes("token[]") == 0


SAMPLE_HLO = """
HloModule test

%main (p0: f32[64]) -> f32[64] {
  %p0 = f32[64]{0} parameter(0)
  %cp = f32[64]{0} collective-permute(%p0), source_target_pairs={{0,1}}
  %ag = f32[128]{0} all-gather(%cp), dimensions={0}
  ROOT %ar = f32[64]{0} all-reduce(%p0), to_apply=%add
}

%while_body.1 (p: f32[32]) -> f32[32] {
  %p = f32[32]{0} parameter(0)
  ROOT %a2a = f32[32]{0} all-to-all(%p), dimensions={0}
}
"""


class TestParseCollectives:
    def test_counts_and_bytes(self):
        res = parse_collectives(SAMPLE_HLO, loop_multiplier=1)
        assert res["bytes"]["collective-permute"] == 64 * 4
        assert res["bytes"]["all-gather"] == 128 * 4
        assert res["bytes"]["all-reduce"] == 64 * 4
        assert res["bytes"]["all-to-all"] == 32 * 4

    def test_loop_multiplier_applies_to_while_bodies(self):
        r1 = parse_collectives(SAMPLE_HLO, loop_multiplier=1)
        r10 = parse_collectives(SAMPLE_HLO, loop_multiplier=10)
        # only the all-to-all inside %while_body scales
        assert r10["bytes"]["all-to-all"] == 10 * r1["bytes"]["all-to-all"]
        assert r10["bytes"]["all-gather"] == r1["bytes"]["all-gather"]


class TestSanitizeSpec:
    def test_drops_non_divisible(self):
        spec = sanitize_spec(P("model", None), (151655, 896), {"model": 16})
        assert spec == P(None, None)

    def test_keeps_divisible(self):
        spec = sanitize_spec(P("model", None), (256, 8), {"model": 16})
        assert spec == P("model", None)

    def test_tuple_axes(self):
        spec = sanitize_spec(P(("pod", "data"), None), (64, 8),
                             {"pod": 2, "data": 16})
        assert spec == P(("pod", "data"), None)
        spec = sanitize_spec(P(("pod", "data"), None), (33, 8),
                             {"pod": 2, "data": 16})
        assert spec == P(None, None)

    def test_pads_short_spec(self):
        spec = sanitize_spec(P("model"), (32, 4, 4), {"model": 16})
        assert spec == P("model", None, None)


class TestHierarchicalController:
    def test_parent_averages_children(self):
        from repro.core.controller import Controller, HierarchicalController
        import numpy as np
        kids = []
        for base in (0.0, 2.0):
            c = Controller({0: [1, 2, 3]})
            c.post_average(1, np.full(4, base + 1.0), group=0)
            kids.append(c)
        parent = HierarchicalController(kids)
        res = parent.collect()
        np.testing.assert_allclose(res["average"], np.full(4, 2.0))
        assert parent.up_messages == 2

    def test_incomplete_child_rejected(self):
        from repro.core.controller import Controller, HierarchicalController
        parent = HierarchicalController([Controller({0: [1, 2, 3]})])
        with pytest.raises(AssertionError):
            parent.collect()
