"""Protocol conformance matrix (ISSUE 8).

One table-driven suite pinning the wire-plane contract for BOTH
protocols across fault patterns and transport shapes:

    protocol  ∈ {safe, bon}
    fault     ∈ {clean, f1 (one dead), fq (n/4 dead), churn (mid-round)}
    transport ∈ {buffered, streamed, persistent, pipelined}

Every cell asserts the §5 / §14 closed-form message count (exact, or
the documented floor under SAFE mid-round churn) AND bit-identity of
the wire average against the discrete-event simulation for the same
inputs — the sim↔wire discipline as a conformance matrix rather than a
scatter of individual regressions.

``pipelined`` is ``persistent`` with §11 cross-round overlap: window-2
rounds launch before their predecessor publishes, so the cells pin the
hardest compositions — §5.3 crash recovery and §5.4 re-election running
in round r while round r+1 is already in flight behind it — and the
per-round stats deltas must STILL meet the exact closed forms (the
broker parks round r+1's counted ops until ``advance_round``, so the
deltas between advances are per-round exact even mid-overlap).

Two cells degrade by design, with the degradation itself asserted:

  * BON × streamed — the chunk plane is not wired to ``bon_*`` ops
    (docs/PROTOCOL.md §14): BON runs buffered and its stats must show
    no streamed/chunk activity at all.
  * BON × persistent — BON re-runs key agreement every round (§2 point
    1; the cost SAFE's persistent sessions amortize), so "persistent"
    BON is two independent rounds, each paying the full n advertise
    ops, while SAFE's second round derives zero new keys.

Smoke-sized (n=8) so the matrix is tier-1; each test carries the
test_net.py SIGALRM deadline so a hung broker aborts instead of
stalling the suite.
"""
import asyncio
import signal

import numpy as np
import pytest

from repro.core import machines
from repro.core.bon_protocol import bon_expected_messages, run_bon_round
from repro.core.protocol import run_safe_round
from repro.net import (
    ChurnInterceptor,
    PersistentNetSession,
    SafeBroker,
    run_bon_round_net,
    run_safe_round_net,
)

N = 8
V = 16
DEADLINE_S = 90

#: fault pattern → nodes dead for the closed form (churn schedules are
#: built per protocol: op budgets differ between SAFE and BON rounds)
FAULTS = {
    "clean": (),
    "f1": (3,),
    "fq": (3, 6),  # n/4 dead — the paper's heavy-dropout flavour
    "churn": (5,),
}

#: deterministic input seed per cell (str hashes are per-process salted)
SEEDS = {
    ("safe", "clean", "buffered"): 100, ("safe", "clean", "streamed"): 101,
    ("safe", "f1", "buffered"): 102, ("safe", "f1", "streamed"): 103,
    ("safe", "fq", "buffered"): 104, ("safe", "fq", "streamed"): 105,
    ("safe", "churn", "buffered"): 106, ("safe", "churn", "streamed"): 107,
    ("bon", "clean", "buffered"): 110, ("bon", "f1", "buffered"): 111,
    ("bon", "fq", "buffered"): 112, ("bon", "churn", "buffered"): 113,
}


@pytest.fixture(autouse=True)
def _hard_deadline():
    def _expired(signum, frame):
        raise TimeoutError(f"conformance test exceeded {DEADLINE_S}s")

    old = signal.signal(signal.SIGALRM, _expired)
    signal.alarm(DEADLINE_S)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)


def _vals(seed):
    return np.random.RandomState(seed).uniform(
        -1, 1, (N, V)).astype(np.float32)


def _safe_expected(f: int) -> int:
    return 4 * (N - f) + 2 * f if f else 4 * N


async def _with_broker(coro_fn, **broker_kw):
    broker = SafeBroker(**dict(
        dict(progress_timeout=0.4, monitor_interval=0.1,
             aggregation_timeout=30.0), **broker_kw))
    addr = await broker.start()
    try:
        return await coro_fn(addr)
    finally:
        await broker.stop()


class TestSafeMatrix:
    @pytest.mark.parametrize("fault", ["clean", "f1", "fq", "churn"])
    @pytest.mark.parametrize("transport", ["buffered", "streamed"])
    def test_cell(self, fault, transport):
        dead = FAULTS[fault]
        vals = _vals(SEEDS[("safe", fault, transport)])
        kw = dict(stream=False) if transport == "buffered" else dict(
            stream=True, chunk_words=V // 2)
        if fault == "churn":
            # node 5 dies after ONE op — keys are pre-provisioned
            # (zero RPCs), so the budget must land before the node can
            # consume or post an aggregate; §5.3 recovery then reposts
            # around it and the result matches the sim with node 5 dead
            kw["interceptor"] = ChurnInterceptor({5: 1})
        else:
            kw["failed_nodes"] = dead

        # churn recovery completes within ~3x progress_timeout; a tight
        # aggregation deadline keeps the stragglers' final long-polls
        # from pinning the wall clock at the default 30 s
        broker_kw = dict(aggregation_timeout=3.0) if fault == "churn" else {}
        res = asyncio.run(_with_broker(
            lambda addr: run_safe_round_net(vals, addr, **kw), **broker_kw))

        sim = run_safe_round(vals, failed_nodes=list(dead))
        assert res.crashed_nodes == (dead if fault == "churn" else ())
        assert np.array_equal(sim.average, res.average)  # bit-identical
        expected = _safe_expected(len(dead))
        got = res.stats["aggregation_total"]
        if fault == "churn":
            # mid-round crash timing makes the total depend on when the
            # crash lands relative to reposting: floor-bounded (the
            # all-crash-early form), not exact — same contract as
            # loadgen.run_paper_scale
            assert got >= expected, (got, expected)
        else:
            assert got == expected, (got, expected)
        if transport == "streamed" and fault == "clean":
            assert res.streamed_combines == N - 1

    @pytest.mark.parametrize("fault", ["clean", "f1", "fq", "churn"])
    def test_persistent_cell(self, fault):
        """Three rounds on ONE live session: round 0 clean (derives all
        key material), round 1 under the fault, round 2 clean again.
        key_derivations() must be flat outside failover — round 1 may
        derive exactly the 2 skip-pad keys per dead node that §5.3
        recovery requires, and round 2 derives ZERO (everything,
        including the skip pads, is cached). Each round still meets its
        closed form and matches the sim at its counter base."""
        dead = FAULTS[fault]
        vals0, vals1, vals2 = _vals(70), _vals(71), _vals(72)
        churn = ChurnInterceptor({}) if fault == "churn" else None

        async def go(addr):
            sess = PersistentNetSession(
                addr, N, interceptor=churn,
                aggregation_timeout=3.0 if churn else None)
            await sess.open()
            try:
                r0 = await sess.run_round(vals0)
                d0 = machines.key_derivations()
                if churn is not None:
                    # arm the schedule only now: node 5 gets ONE more op
                    # in round 1 — enough to re-enter the round, not
                    # enough to consume or post — so the crash lands
                    # mid-round-1 on the SAME live session
                    churn.crash_after[5] = churn._ops.get(5, 0) + 1
                r1 = await sess.run_round(
                    vals1, failed_nodes=() if churn else dead)
                d1 = machines.key_derivations()
                if churn is not None:
                    churn.crash_after.pop(5)  # node 5 rejoins
                r2 = await sess.run_round(vals2)
                d2 = machines.key_derivations()
                return r0, r1, r2, d1 - d0, d2 - d1
            finally:
                await sess.close()

        r0, r1, r2, derivs_r1, derivs_r2 = asyncio.run(_with_broker(go))
        # flat outside failover; failover derives only the skip pads
        if fault == "churn":
            assert derivs_r1 <= 2 * len(dead)
        else:
            assert derivs_r1 == 2 * len(dead)
        assert derivs_r2 == 0
        assert np.array_equal(run_safe_round(vals0).average, r0.average)
        assert r0.stats["aggregation_total"] == 4 * N
        sim1 = run_safe_round(vals1, failed_nodes=list(dead), counter=V)
        assert np.array_equal(sim1.average, r1.average)
        if fault == "churn":
            assert r1.crashed_nodes == dead
            assert r1.stats["aggregation_total"] >= _safe_expected(len(dead))
        else:
            assert r1.stats["aggregation_total"] == _safe_expected(len(dead))
        sim2 = run_safe_round(vals2, counter=2 * V)
        assert np.array_equal(sim2.average, r2.average)
        assert r2.stats["aggregation_total"] == 4 * N

    @pytest.mark.parametrize("fault", ["clean", "f1", "fq", "churn"])
    def test_pipelined_cell(self, fault):
        """§11 pipelined transport: four rounds on ONE session with
        window-2 overlap — round r+1 launches before round r publishes
        — and each round must STILL meet its exact closed form, its
        counter base, and bit-identity with the sim, with
        key_derivations() flat outside failover.

        churn: node 5 crashes mid-round-1 while round 2 is ALREADY in
        flight behind it (launched with node 5 declared dead — a
        crashed learner does not rejoin instantly), so §5.3 recovery
        and the pipelined round coexist on the broker; round 3 (node 5
        rejoined) overlaps round 2's tail."""
        dead = FAULTS[fault]
        vals = [_vals(120 + i) for i in range(4)]
        churn = ChurnInterceptor({}) if fault == "churn" else None

        async def go(addr):
            sess = PersistentNetSession(
                addr, N, interceptor=churn,
                aggregation_timeout=3.0 if churn else None)
            await sess.open()
            try:
                if churn is not None:
                    # round 0 runs alone (arming the crash needs node
                    # 5's op counter quiescent), then rounds 1..3
                    # pipeline through the fault
                    await sess.start_round_pipelined(vals[0])
                    r0 = await sess.collect_round_pipelined()
                    d0 = machines.key_derivations()
                    churn.crash_after[5] = churn._ops.get(5, 0) + 1
                    await sess.start_round_pipelined(vals[1])
                    await sess.start_round_pipelined(
                        vals[2], failed_nodes=(5,))
                    r1 = await sess.collect_round_pipelined()
                    d1 = machines.key_derivations()
                    churn.crash_after.pop(5)
                    await sess.start_round_pipelined(vals[3])
                    r2 = await sess.collect_round_pipelined()
                    r3 = await sess.collect_round_pipelined()
                    d2 = machines.key_derivations()
                else:
                    await sess.start_round_pipelined(vals[0])
                    await sess.start_round_pipelined(
                        vals[1], failed_nodes=dead)
                    r0 = await sess.collect_round_pipelined()
                    d0 = machines.key_derivations()
                    await sess.start_round_pipelined(vals[2])
                    r1 = await sess.collect_round_pipelined()
                    d1 = machines.key_derivations()
                    await sess.start_round_pipelined(vals[3])
                    r2 = await sess.collect_round_pipelined()
                    r3 = await sess.collect_round_pipelined()
                    d2 = machines.key_derivations()
                return (r0, r1, r2, r3), d1 - d0, d2 - d1
            finally:
                await sess.close()

        rs, derivs_fault, derivs_after = asyncio.run(_with_broker(go))
        dead_by_round = ([(), dead, (5,), ()] if fault == "churn"
                         else [(), dead, (), ()])
        for i, (r, dd) in enumerate(zip(rs, dead_by_round)):
            sim = run_safe_round(vals[i], failed_nodes=list(dd),
                                 counter=i * V)
            assert np.array_equal(sim.average, r.average), f"round {i}"
            expected = _safe_expected(len(dd))
            got = r.stats["aggregation_total"]
            if fault == "churn" and i == 1:
                # mid-round crash timing: floor-bounded, as everywhere
                # (recovery may legitimately run a §5.4 election)
                assert got >= expected, (i, got, expected)
                assert r.crashed_nodes == (5,)
            else:
                assert got == expected, (i, got, expected)
                assert r.crashed_nodes == ()
                assert r.initiator_elections == 0
        # flat outside failover, even with rounds overlapped: the fault
        # window derives only the skip pads, and nothing thereafter —
        # round 2 (and churn's declared-dead round) reuses them cached
        if fault == "churn":
            assert derivs_fault <= 2 * len(dead)
        else:
            assert derivs_fault == 2 * len(dead)
        assert derivs_after == 0

    def test_pipelined_reelection_between_rounds(self):
        """§5.4 between overlapped rounds: round 1's initiator posts
        once then crashes (Fig. 5) while round 2 is already launched
        behind it. Re-election converges round 1 to the survivors'
        average, and round 2 — initiator back, running on the same
        session — still meets the exact 4n form at its counter base.

        Runs under the harness's AGGRESSIVE §5.3 monitor cadence
        (progress 0.4 s / interval 0.1 s, the `_with_broker` default) —
        the regression guard for the §5.3 × §5.4 repost-walk bug: the
        monitor used to walk the last survivor's stuck posting around
        live-but-consumed nodes during the election stall until a
        spurious "self" verdict dropped its contribution from the
        published average with crashed_nodes=(). Post-fix the recovery
        is cadence-invariant: the monitor stalls the unconsumable
        posting (0 reposts), the §5.4 election restarts the round, and
        the restarted chain orders exactly ONE repost around the dead
        initiator — so reposts, elections, and average bits must all
        equal the sim's, at this cadence and the default alike."""
        vals = [_vals(130 + i) for i in range(3)]

        async def go(addr):
            async with PersistentNetSession(
                    addr, N, aggregation_timeout=3.0) as sess:
                await sess.start_round_pipelined(vals[0])
                r0 = await sess.collect_round_pipelined()
                await sess.start_round_pipelined(vals[1],
                                                 initiator_fails=True)
                await sess.start_round_pipelined(vals[2])
                r1 = await sess.collect_round_pipelined()
                r2 = await sess.collect_round_pipelined()
                return r0, r1, r2

        r0, r1, r2 = asyncio.run(_with_broker(go))
        assert np.array_equal(run_safe_round(vals[0]).average, r0.average)
        assert r0.stats["aggregation_total"] == 4 * N
        sim1 = run_safe_round(vals[1], initiator_fails=True,
                              aggregation_timeout=3.0, counter=V)
        # cadence-invariant recovery: exactly one election, exactly one
        # repost (the restarted chain around the dead initiator), no
        # survivor stranded, and the average bit-identical to the sim —
        # regardless of which survivor wins the real-time election race
        # (the contributor SET is deterministic, so the bits are too)
        assert sim1.monitor_reposts == 1
        assert r1.initiator_elections == sim1.initiator_elections == 1
        assert r1.monitor_reposts == sim1.monitor_reposts == 1
        assert r1.crashed_nodes == ()
        assert np.array_equal(sim1.average, r1.average)
        np.testing.assert_allclose(r1.average, vals[1][1:].mean(0),
                                   atol=1e-3)
        sim2 = run_safe_round(vals[2], counter=2 * V)
        assert np.array_equal(sim2.average, r2.average)
        assert r2.stats["aggregation_total"] == 4 * N
        assert r2.initiator_elections == 0


class TestBonMatrix:
    def _run(self, vals, **kw):
        return asyncio.run(_with_broker(
            lambda addr: run_bon_round_net(vals, addr, **kw)))

    @pytest.mark.parametrize("fault", ["clean", "f1", "fq", "churn"])
    def test_buffered_cell(self, fault):
        dead = FAULTS[fault]
        vals = _vals(SEEDS[("bon", fault, "buffered")])
        kw = {}
        if fault == "churn":
            # a 2n op budget lands the crash exactly on the R1/R2
            # boundary — the point where the sim's failed_nodes
            # semantics place dropouts, so the count stays EXACT
            kw["interceptor"] = ChurnInterceptor({5: 2 * N})
        else:
            kw["failed_nodes"] = dead

        res = self._run(vals, **kw)

        sim = run_bon_round(vals, failed_nodes=list(dead))
        assert res.crashed_nodes == (dead if fault == "churn" else ())
        assert np.array_equal(sim.average, res.average)  # bit-identical
        expected = bon_expected_messages(N, len(dead))
        assert res.messages == expected
        assert res.expected_messages == expected
        assert sim.messages == expected

    def test_streamed_cell_degrades_to_buffered(self):
        """The chunk plane is not wired to ``bon_*`` ops (§14): a BON
        round under any transport shape is buffered, and its stats must
        show zero streamed/chunk activity."""
        vals = _vals(80)
        res = self._run(vals)
        assert res.stats["protocol"] == "bon"
        # BonStats has no chunk/stream fields — nothing chunk-shaped may
        # appear; the counted ops are exactly the 8 bon_* opcodes
        for key in res.stats:
            assert "chunk" not in key and "stream" not in key, key
        assert res.messages == bon_expected_messages(N)
        assert np.array_equal(run_bon_round(vals).average, res.average)

    def test_persistent_cell_pays_keyagree_per_round(self):
        """BON's "persistent" shape is two independent rounds: every
        round re-runs the full key agreement (n advertises, 2n(n−1)
        share messages) — the per-round cost SAFE's persistent sessions
        amortize to zero (TestSafeMatrix.test_persistent_cell)."""
        vals0, vals1 = _vals(81), _vals(82)
        r0 = self._run(vals0, seed=5)
        r1 = self._run(vals1, seed=6)
        for r, vals, seed in ((r0, vals0, 5), (r1, vals1, 6)):
            assert r.stats["bon_advertise"] == N
            assert r.stats["bon_post_share"] == N * (N - 1)
            assert r.messages == bon_expected_messages(N)
            assert np.array_equal(
                run_bon_round(vals, seed=seed).average, r.average)


class TestHierarchicalMatrix:
    """§5.10 chain-of-chains column (docs/PROTOCOL.md §15): N=8 as two
    child orgs of 4, each running its full SAFE chain on a real child
    broker and posting its group average to a real parent broker.

        fault ∈ {clean, learner_crash (one dead inside a child),
                 org_crash (a whole child org offline),
                 aggressive_cadence (child initiator crashes mid-round
                 under the harness's aggressive §5.3 monitor cadence —
                 the §5.3×§5.4 regression surface, hierarchical twin)}

    Every cell asserts BOTH levels' closed forms — per surviving org
    ``4(n_g − f_g) + 2 f_g + 1`` and parent ``hierarchy_total ==
    2(c − f)`` — and bit-identity of the parent average against
    ``run_hierarchical_round_sim`` for the same inputs (clean also
    against the flat ``run_safe_round(subgroups=2)``: anonymizing the
    org boundary must not change a single bit)."""

    ORGS = 2
    N_G = N // 2

    def _round(self, vals, *, child_agg=30.0, parent_timeout=30.0, **kw):
        from repro.net import run_hierarchical_round_net

        async def go():
            parent = SafeBroker(aggregation_timeout=30.0,
                                progress_timeout=0.4, monitor_interval=0.1)
            child = SafeBroker(aggregation_timeout=child_agg,
                               progress_timeout=0.4, monitor_interval=0.1)
            paddr = await parent.start()
            caddr = await child.start()
            try:
                return await run_hierarchical_round_net(
                    vals, paddr, {g: caddr for g in range(self.ORGS)},
                    aggregation_timeout=child_agg,
                    parent_timeout=parent_timeout, **kw)
            finally:
                await parent.stop()
                await child.stop()

        return asyncio.run(go())

    def _check_org_forms(self, res, dead_nodes=(), skip_orgs=()):
        from repro.topology import RingTopology

        chains = RingTopology(N, self.ORGS).group_chains(node_base=1)
        for g, r in res.org_results.items():
            if g in skip_orgs:
                continue
            f_g = sum(1 for d in dead_nodes if d in chains[g])
            expected = 4 * (self.N_G - f_g) + 2 * f_g + 1
            assert r.stats["aggregation_total"] == expected, (g, r.stats)
            assert r.monitor_reposts == f_g, (g, r.monitor_reposts)

    def test_clean_cell(self):
        from repro.core.protocol import run_hierarchical_round_sim

        vals = _vals(140)
        res = self._round(vals)
        sim = run_hierarchical_round_sim(vals, orgs=self.ORGS)
        flat = run_safe_round(vals, subgroups=self.ORGS)
        self._check_org_forms(res)
        assert res.parent_stats["hierarchy_total"] == 2 * self.ORGS
        assert res.elided_orgs == ()
        for g in range(self.ORGS):
            assert np.array_equal(res.org_averages[g], sim.org_averages[g])
        assert np.array_equal(res.average, sim.average)
        assert np.array_equal(res.average, flat.average)

    def test_learner_crash_cell(self):
        """One dead learner inside org 0: the child chain fails over
        exactly as a flat §5.3 round would (4(n_g−1)+2+1, one repost),
        the OTHER org never notices, and the parent still hears from
        both orgs."""
        from repro.core.protocol import run_hierarchical_round_sim

        vals = _vals(141)
        res = self._round(vals, failed_nodes=(3,))
        sim = run_hierarchical_round_sim(vals, orgs=self.ORGS,
                                         failed_nodes=(3,))
        self._check_org_forms(res, dead_nodes=(3,))
        assert res.parent_stats["hierarchy_total"] == 2 * self.ORGS
        assert res.elided_orgs == ()
        assert np.array_equal(res.average, sim.average)

    def test_org_crash_cell(self):
        """A whole child org offline: the parent elides it like a dead
        learner — no messages from it, ``hierarchy_total == 2(c−1)``,
        and the parent average folds the survivors only."""
        from repro.core.protocol import run_hierarchical_round_sim

        vals = _vals(142)
        res = self._round(vals, failed_orgs=(1,), parent_timeout=1.5)
        sim = run_hierarchical_round_sim(vals, orgs=self.ORGS,
                                         failed_orgs=(1,))
        self._check_org_forms(res, skip_orgs=(1,))
        assert res.elided_orgs == sim.elided_orgs == (1,)
        assert res.parent_stats["crashed_orgs"] == [1]
        assert res.parent_stats["hierarchy_total"] == 2 * (self.ORGS - 1)
        assert 1 not in res.org_results
        assert np.array_equal(res.average, sim.average)

    def test_aggressive_cadence_cell(self):
        """Child org 0's initiator posts once then crashes (Fig. 5)
        under the aggressive monitor cadence — the hierarchical twin of
        ``TestSafeMatrix.test_pipelined_reelection_between_rounds``.
        Post-fix the recovery is cadence-invariant: ONE §5.4 election,
        ONE repost, and the org average the child posts upward is
        bit-identical to the sim's — so the parent average is too. A
        regression to the §5.3×§5.4 repost-walk bug would silently
        drop a survivor from the GLOBAL cross-org average here."""
        from repro.core.protocol import run_hierarchical_round_sim

        vals = _vals(143)
        res = self._round(vals, initiator_fails=True, child_agg=3.0)
        sim = run_hierarchical_round_sim(vals, orgs=self.ORGS,
                                         initiator_fails=True,
                                         aggregation_timeout=3.0)
        r0, s0 = res.org_results[0], sim.org_results[0]
        assert r0.initiator_elections == s0.initiator_elections == 1
        assert r0.monitor_reposts == s0.monitor_reposts == 1
        # org 1 is untouched by org 0's re-election
        self._check_org_forms(res, skip_orgs=(0,))
        assert res.parent_stats["hierarchy_total"] == 2 * self.ORGS
        assert np.array_equal(res.org_averages[0], sim.org_averages[0])
        assert np.array_equal(res.average, sim.average)
