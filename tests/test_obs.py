"""Unit tests for the observability primitives (``repro.obs``, ISSUE 7).

These are pure-python tests for the metrics/tracing building blocks;
their integration with the wire plane (``get_metrics``, the HTTP
exporter, admission control) is covered by
``test_net.py::TestObservability``.
"""
import math

import pytest

from repro.obs import (
    DEFAULT_LATENCY_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    Span,
    Tracer,
)


class TestCounterGauge:
    def test_counter_monotonic(self):
        c = Counter("x")
        assert c.value == 0
        c.inc()
        c.inc(5)
        assert c.value == 6
        assert not hasattr(c, "set")  # monotonic by construction

    def test_gauge_set_inc_dec(self):
        g = Gauge("y")
        g.set(3.5)
        g.inc()
        g.dec(0.5)
        assert g.value == 4.0


class TestHistogram:
    def test_percentiles_on_known_distribution(self):
        # 100 observations spread uniformly over (0, 1] against bounds
        # at every 0.1: p50 lands ~0.5, p99 ~0.99 (within one bucket)
        h = Histogram("lat", bounds=[i / 10 for i in range(1, 11)])
        for i in range(1, 101):
            h.observe(i / 100)
        assert h.count == 100
        assert h.sum == pytest.approx(50.5)
        assert h.percentile(50) == pytest.approx(0.5, abs=0.1)
        assert h.percentile(99) == pytest.approx(0.99, abs=0.1)
        assert h.percentile(50) <= h.percentile(99)

    def test_empty_and_tail(self):
        h = Histogram("lat", bounds=[0.1, 1.0])
        assert h.percentile(50) == 0.0  # empty -> 0, not NaN
        h.observe(100.0)  # +Inf bucket
        assert h.counts[-1] == 1
        # tail percentile floors at the largest finite bound
        assert h.percentile(99) == 1.0

    def test_unsorted_bounds_rejected(self):
        with pytest.raises(ValueError):
            Histogram("bad", bounds=[1.0, 0.1])

    def test_to_dict_schema(self):
        h = Histogram("lat")
        h.observe(0.003)
        d = h.to_dict()
        assert set(d) == {"count", "sum", "p50", "p99", "buckets"}
        assert d["count"] == 1 and d["sum"] == pytest.approx(0.003)
        # one [bound, count] pair per finite bound plus the +Inf tail
        assert len(d["buckets"]) == len(DEFAULT_LATENCY_BUCKETS) + 1
        assert d["buckets"][-1][0] == math.inf
        assert sum(c for _, c in d["buckets"]) == 1


class TestMetricsRegistry:
    def test_get_or_create_returns_same_series(self):
        r = MetricsRegistry()
        assert r.counter("a") is r.counter("a")
        assert r.gauge("b") is r.gauge("b")
        assert r.histogram("c") is r.histogram("c")

    def test_snapshot_wire_safe(self):
        r = MetricsRegistry()
        r.counter("hits").inc(3)
        r.gauge("depth").set(2)
        r.histogram("lat", bounds=[0.1, 1.0]).observe(0.05)
        s = r.snapshot()
        assert s["counters"] == {"hits": 3}
        assert s["gauges"] == {"depth": 2.0}
        assert isinstance(s["gauges"]["depth"], float)
        assert s["histograms"]["lat"]["count"] == 1

    def test_render_prometheus(self):
        r = MetricsRegistry()
        r.counter("hits").inc(3)
        r.histogram("lat", bounds=[0.1, 1.0]).observe(0.05)
        r.histogram("lat").observe(50.0)  # +Inf tail
        text = r.render_prometheus(labels='shard="2"')
        assert "# TYPE hits counter" in text
        assert 'hits{shard="2"} 3' in text
        # bucket counts are cumulative and end at +Inf == count
        assert 'lat_bucket{shard="2",le="0.1"} 1' in text
        assert 'lat_bucket{shard="2",le="1.0"} 1' in text
        assert 'lat_bucket{shard="2",le="+Inf"} 2' in text
        assert 'lat_count{shard="2"} 2' in text
        assert text.endswith("\n")

    def test_render_prometheus_unlabelled(self):
        r = MetricsRegistry()
        r.counter("hits").inc()
        text = r.render_prometheus()
        assert "hits 1" in text


class TestTracer:
    def test_disabled_by_default_records_nothing(self):
        t = Tracer()
        assert not t.enabled
        t.record("round", 0.0, 1.0, session=7)
        assert len(t) == 0 and t.export() == []

    def test_enabled_records_spans(self):
        t = Tracer(enabled=True)
        t.record("round", 1.0, 3.5, session=7, n=4)
        (s,) = t.spans("round")
        assert isinstance(s, Span)
        assert s.duration == 2.5
        assert s.to_dict() == {"name": "round", "t0": 1.0, "t1": 3.5,
                               "duration": 2.5, "session": 7, "n": 4}
        assert t.spans("other") == []

    def test_ring_capacity_bounds_memory(self):
        t = Tracer(capacity=4, enabled=True)
        for i in range(10):
            t.record("chunk", i, i + 1, seq=i)
        assert len(t) == 4
        assert t.dropped == 6  # wrapped, and says so
        assert [s.attrs["seq"] for s in t.spans()] == [6, 7, 8, 9]
        t.clear()
        assert len(t) == 0 and t.dropped == 0

    def test_bad_capacity_rejected(self):
        with pytest.raises(ValueError):
            Tracer(capacity=0)
