"""Shamir t-of-n secret sharing (BON's recovery substrate)."""
import random

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.shamir import P, reconstruct, share


@given(st.integers(0, 2**64 - 1), st.integers(2, 8), st.integers(0, 6),
       st.integers(0, 2**31))
@settings(max_examples=40, deadline=None)
def test_reconstruct_from_any_t_shares(secret, t, extra, seed):
    n = t + extra
    rng = random.Random(seed)
    shares = share(secret, t, n, rng)
    picked = rng.sample(shares, t)
    assert reconstruct(picked) == secret


def test_fewer_than_t_shares_reveal_nothing():
    """With t-1 shares every candidate secret remains consistent — check a
    few candidates reconstruct plausibly (information-theoretic hiding)."""
    rng = random.Random(0)
    secret = 123456789
    shares = share(secret, 3, 5, rng)
    partial = shares[:2]
    # t-1 shares + ANY forged third point yields SOME value; two different
    # forgeries yield different "secrets" -> the partial set determines nothing
    a = reconstruct(partial + [(5, 1)])
    b = reconstruct(partial + [(5, 2)])
    assert a != b


def test_duplicate_indices_rejected():
    rng = random.Random(0)
    shares = share(42, 2, 4, rng)
    with pytest.raises(ValueError):
        reconstruct([shares[0], shares[0]])


def test_secret_out_of_range_rejected():
    with pytest.raises(ValueError):
        share(P, 2, 3, random.Random(0))
