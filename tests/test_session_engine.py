"""Multi-session aggregation engine: bit-identity vs. standalone runs,
slot admission/eviction churn, multi-round counter/rotation advance, and
weighted sessions. Runs on an 8-host-device mesh in a subprocess."""
from helpers import run_multidevice

ENGINE_CODE = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core import ChainConfig, SecureAggregator
from repro.serve import AggregationEngine

mesh = jax.make_mesh((8,), ("data",))
n, V, S = 8, 37, 4
rng = np.random.RandomState(0)
cfg = ChainConfig(num_learners=n, mode="safe")
eng = AggregationEngine(mesh, cfg, slots=S, payload_words=V)

# 6 sessions through 4 slots (forces queueing + eviction churn); session
# 0 runs 3 rounds (counter/rotation advance); session 2 has dead ranks
# including the default initiator (rank 0).
sessions = []
for s in range(6):
    sv = rng.uniform(-2, 2, (n, V)).astype(np.float32)
    alive = np.ones(n, np.float32)
    if s == 2:
        alive[[0, 5]] = 0.0
    sessions.append(eng.submit(sv, rounds=3 if s == 0 else 1,
                               provisioning_seed=0xC0FFEE + s,
                               learner_master=0x5EED + 17 * s,
                               alive=alive, rotate0=s))
eng.run_until_done()
assert all(sess.done for sess in sessions), "sessions left unfinished"
assert eng.rounds_completed == 8, eng.rounds_completed

# --- acceptance: batched output bit-identical to standalone runs -------
for s, sess in enumerate(sessions):
    single = SecureAggregator(cfg, 0xC0FFEE + s, 0x5EED + 17 * s)
    for r in range(sess.rounds):
        ctr, rot = r * V, s + r  # what AggSession reserved/rotated
        def per_rank(v, a, ctr=ctr, rot=rot):
            return single.aggregate(v.reshape(-1), ctr, alive=a, rotate=rot)
        f = jax.shard_map(per_rank, mesh=mesh, in_specs=(P("data"), P()),
                          out_specs=P(), axis_names=frozenset({"data"}),
                          check_vma=False)
        with jax.set_mesh(mesh):
            ref = np.asarray(jax.jit(f)(jnp.asarray(sess.values),
                                        jnp.asarray(sess.alive)))
        assert np.array_equal(ref, sess.results[r]), (
            f"session {s} round {r} not bit-identical")

# --- value sanity: published mean == survivor mean ---------------------
for sess in sessions:
    mask = sess.alive > 0
    exp = sess.values[mask].mean(0)
    assert np.abs(sess.results[0] - exp).max() < 1e-3
print("ENGINE_BITIDENTICAL_OK")

# --- weighted sessions -------------------------------------------------
wcfg = ChainConfig(num_learners=n, mode="safe", weighted=True)
weng = AggregationEngine(mesh, wcfg, slots=2, payload_words=V)
w = rng.uniform(1, 10, (n,)).astype(np.float32)
sv = rng.uniform(-2, 2, (n, V)).astype(np.float32)
wsess = weng.submit(sv, weights=w)
weng.run_until_done()
exp = np.average(sv, 0, weights=w)
assert np.abs(wsess.results[0] - exp).max() < 1e-3
print("ENGINE_WEIGHTED_OK")
"""


def test_engine_bit_identity_and_churn():
    out = run_multidevice(ENGINE_CODE, devices=8)
    assert "ENGINE_BITIDENTICAL_OK" in out
    assert "ENGINE_WEIGHTED_OK" in out
