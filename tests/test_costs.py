"""CostModel calibration (ISSUE 8): fit round-trip + §6.1 ratio pins.

``CostModel.fit`` turns measured (features, seconds) samples into a
calibrated model with a residual report — benchmarks/bon_wire.py uses
it to re-derive the paper's §6.1 BON/SAFE ratio from this host's
measured per-op latencies. Here: the solver recovers known constants
exactly from noise-free samples, clips unphysical negatives, inherits
unfitted fields from its base, and the stock EDGE model's re-derived
§6.1 ratios stay pinned (a constant-table edit that silently moves the
headline reproduction number fails here, not in a benchmark row).
"""
import dataclasses

import numpy as np
import pytest

from repro.core.bon_protocol import bon_expected_messages, run_bon_round
from repro.core.costs import DEEP_EDGE, EDGE, CostModel
from repro.core.protocol import run_safe_round

N, V = 36, 256
FAILED = (4, 5, 6)


class TestFit:
    def test_noise_free_round_trip(self):
        true = {"t_msg": 0.004, "t_byte": 3e-7, "t_share": 5e-5}

        def t(feats):
            return sum(true[k] * v for k, v in feats.items())

        samples = []
        for nb in (64, 1024, 65536):
            samples.append(({"t_msg": 1.0, "t_byte": float(nb)},
                            t({"t_msg": 1.0, "t_byte": float(nb)})))
        for k in (5, 9, 36):
            samples.append(({"t_share": float(k)},
                            t({"t_share": float(k)})))
        fitted, resid = CostModel.fit(samples)
        for k, v in true.items():
            assert getattr(fitted, k) == pytest.approx(v, rel=1e-9), k
        assert resid["rms"] == pytest.approx(0.0, abs=1e-12)
        assert resid["r2"] == pytest.approx(1.0)
        assert resid["n_samples"] == len(samples)

    def test_unfitted_fields_inherit_base(self):
        fitted, _ = CostModel.fit(
            [({"t_msg": 1.0}, 0.01)], base=DEEP_EDGE, name="x")
        assert fitted.name == "x"
        assert fitted.t_msg == pytest.approx(0.01)
        # everything not in the samples keeps the base's value
        for f in dataclasses.fields(CostModel):
            if f.name in ("name", "t_msg"):
                continue
            assert getattr(fitted, f.name) == getattr(DEEP_EDGE, f.name), \
                f.name

    def test_negative_coefficients_clip_to_zero(self):
        # two colinear-ish samples forcing one coefficient negative:
        # a (1,1) feature pair cheaper than the t_msg-only sample
        samples = [
            ({"t_msg": 1.0}, 0.010),
            ({"t_msg": 1.0}, 0.010),
            ({"t_msg": 1.0, "t_share": 1.0}, 0.002),
        ]
        fitted, _ = CostModel.fit(samples)
        assert fitted.t_share == 0.0  # not negative

    def test_rejects_unknown_constants_and_empty(self):
        with pytest.raises(ValueError, match="unknown cost constants"):
            CostModel.fit([({"t_warp_drive": 1.0}, 1.0)])
        with pytest.raises(ValueError, match="at least one sample"):
            CostModel.fit([])

    def test_fit_is_usable_by_the_simulations(self):
        """A fitted model drops straight into both protocol sims."""
        fitted, _ = CostModel.fit(
            [({"t_msg": 1.0, "t_byte": 256.0}, 2e-4),
             ({"t_msg": 1.0, "t_byte": 65536.0}, 5e-4),
             ({"t_share": 9.0}, 1e-4)],
            base=EDGE, name="host")
        vals = np.random.RandomState(1).uniform(
            -1, 1, (8, 32)).astype(np.float32)
        s = run_safe_round(vals, cost=fitted)
        b = run_bon_round(vals, cost=fitted)
        assert s.virtual_time > 0 and b.virtual_time > s.virtual_time
        assert np.array_equal(s.average, run_safe_round(vals).average)


class TestRatio61Pins:
    """The re-derived §6.1 comparison on the stock models, pinned.

    Regression ranges, not paper-exact values: the EDGE constants are
    calibrated to the paper's *order of magnitude* (costs.py docstring)
    and the ratio moves smoothly with them. The message ratio is exact
    arithmetic and pinned exactly.
    """

    @pytest.fixture(scope="class")
    def rounds(self):
        rng = np.random.RandomState(0)
        vals = rng.uniform(-1, 1, (N, V)).astype(np.float32)
        return {
            "safe": run_safe_round(vals),
            "safe_f": run_safe_round(vals, failed_nodes=list(FAILED)),
            "bon": run_bon_round(vals),
            "bon_f": run_bon_round(vals, failed_nodes=list(FAILED)),
        }

    def test_clean_time_ratio_range(self, rounds):
        ratio = rounds["bon"].virtual_time / rounds["safe"].virtual_time
        assert 18.0 < ratio < 28.0, ratio

    def test_failover_time_ratio_range(self, rounds):
        # conservative by construction: BON's dropout wait is excluded
        # (global_timeout=0) while SAFE still pays its §5.3 discovery
        # timeouts — a lower bound on the paper's advantage
        ratio = (rounds["bon_f"].virtual_time
                 / rounds["safe_f"].virtual_time)
        assert 1.3 < ratio < 4.0, ratio

    def test_message_ratio_exact(self, rounds):
        assert rounds["bon"].messages == bon_expected_messages(N)
        assert rounds["safe"].stats.aggregation_total == 4 * N
        assert rounds["bon"].messages / rounds[
            "safe"].stats.aggregation_total == pytest.approx(27.5)

    def test_failover_messages_closed_form(self, rounds):
        f = len(FAILED)
        assert rounds["bon_f"].messages == bon_expected_messages(N, f)
        assert (rounds["safe_f"].stats.aggregation_total
                == 4 * (N - f) + 2 * f)
