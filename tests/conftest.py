import os
import sys

# make `from helpers import run_multidevice` work regardless of rootdir
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    # container without hypothesis: register the deterministic fallback
    import _hypothesis_fallback

    sys.modules["hypothesis"] = _hypothesis_fallback
    sys.modules["hypothesis.strategies"] = _hypothesis_fallback.strategies

# Do NOT set XLA device-count flags here: the main test process must see
# exactly one device (multi-device tests spawn subprocesses — helpers.py).
