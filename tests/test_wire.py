"""Wire codec: encode/decode identity for every controller op payload
(property-based via hypothesis or its deterministic fallback stub),
including empty groups, empty payloads and max-size vectors, plus frame
hardening (truncation, bad version, unknown opcode/tag, oversize)."""
import struct

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.net import wire


def _eq(a, b) -> bool:
    """Deep equality where numpy arrays compare exactly (dtype + bits)."""
    if isinstance(a, np.ndarray) or isinstance(b, np.ndarray):
        return (isinstance(a, np.ndarray) and isinstance(b, np.ndarray)
                and a.dtype == b.dtype and a.shape == b.shape
                and np.array_equal(a, b))
    if isinstance(a, dict) and isinstance(b, dict):
        return a.keys() == b.keys() and all(_eq(a[k], b[k]) for k in a)
    if isinstance(a, (list, tuple)) and isinstance(b, (list, tuple)):
        return len(a) == len(b) and all(_eq(x, y) for x, y in zip(a, b))
    return a == b


def _roundtrip_request(op, kwargs):
    out = wire.decode_request(wire.encode_request(op, kwargs))
    assert out[0] == op
    assert _eq(out[1], kwargs), (op, kwargs, out[1])


def _roundtrip_response(payload):
    got = wire.decode_response(wire.encode_response(payload))
    assert _eq(got, payload), (payload, got)


def _u32(xs) -> np.ndarray:
    return np.asarray(xs, dtype=np.uint32)


class TestOpRoundtrips:
    """Every controller op's request payload survives the wire."""

    @given(st.integers(1, 64), st.integers(1, 64), st.integers(0, 7),
           st.lists(st.integers(0, 2**32 - 1), min_size=0, max_size=64))
    @settings(max_examples=30, deadline=None)
    def test_post_aggregate(self, frm, to, group, payload):
        _roundtrip_request("post_aggregate", dict(
            session=0, from_node=frm, to_node=to, group=group,
            payload=_u32(payload)))

    @given(st.integers(1, 64), st.integers(0, 7),
           st.floats(0.0, 100.0, allow_nan=False))
    @settings(max_examples=20, deadline=None)
    def test_wait_ops(self, node, group, timeout):
        for op in ("check_aggregate", "get_aggregate"):
            _roundtrip_request(op, dict(session=1, node=node, group=group,
                                        timeout=timeout))
            _roundtrip_request(op, dict(session=1, node=node, group=group,
                                        timeout=None))
        _roundtrip_request("get_average", dict(session=1, timeout=timeout))

    @given(st.lists(st.floats(-1e6, 1e6, allow_nan=False, width=32),
                    min_size=0, max_size=64),
           st.floats(0.0, 1e6, allow_nan=False))
    @settings(max_examples=30, deadline=None)
    def test_post_average(self, avg, wavg):
        arr = np.asarray(avg, np.float32)
        _roundtrip_request("post_average", dict(
            session=2, node=1, group=0, average=arr, weight_avg=wavg))
        _roundtrip_request("post_average", dict(
            session=2, node=1, group=0, average=arr.astype(np.float64),
            weight_avg=None))

    @given(st.integers(1, 64), st.integers(0, 7))
    @settings(max_examples=15, deadline=None)
    def test_should_initiate(self, node, group):
        _roundtrip_request("should_initiate",
                           dict(session=0, node=node, group=group))

    @given(st.integers(1, 64), st.lists(st.integers(0, 255), max_size=64))
    @settings(max_examples=15, deadline=None)
    def test_key_exchange(self, node, blob):
        _roundtrip_request("register_key",
                           dict(session=0, node=node, key_blob=bytes(blob)))
        _roundtrip_request("get_key", dict(session=0, node=node))

    @given(st.integers(1, 6), st.integers(0, 5))
    @settings(max_examples=15, deadline=None)
    def test_create_session_groups(self, ngroups, empty_groups):
        """Int-keyed groups maps, including sessions with empty groups."""
        groups = {g: list(range(g * 10 + 1, g * 10 + 4))
                  for g in range(ngroups)}
        for g in range(ngroups, ngroups + empty_groups):
            groups[g] = []  # empty group: encodable, broker-side validated
        _roundtrip_request("create_session",
                           dict(groups=groups, aggregation_timeout=12.5))

    def test_engine_plane_ops(self):
        vals = np.arange(32, dtype=np.float32).reshape(8, 4)
        _roundtrip_request("submit_session", dict(
            values=vals, rounds=3, provisioning_seed=0xC0FFEE,
            learner_master=0x5EED, rotate0=1, weights=None, alive=None))
        _roundtrip_request("wait_session", dict(sid=7, timeout=30.0))
        _roundtrip_response({"status": "done", "rounds": 2,
                             "results": [vals.mean(0), vals.mean(0) * 2]})

    def test_empty_and_max_size_vectors(self):
        """Boundary payloads: zero-length and MAX-frame-scale vectors."""
        _roundtrip_request("post_aggregate", dict(
            session=0, from_node=1, to_node=2, group=0,
            payload=np.empty((0,), np.uint32)))
        big = np.arange(1 << 20, dtype=np.uint32)  # 4 MiB of ring words
        _roundtrip_request("post_aggregate", dict(
            session=0, from_node=1, to_node=2, group=0, payload=big))
        _roundtrip_response({"aggregate": big, "from_node": 3, "posted": 8,
                             "time": 0.25})

    def test_response_statuses(self):
        for payload in (None, True, False, {"status": "timeout"},
                        {"status": "repost", "to_node": 4},
                        {"status": "self", "posted": 1},
                        {"average": np.zeros(3, np.float32),
                         "weight_avg": None, "time": 1.0}):
            _roundtrip_response(payload)


class TestValueTree:
    @given(st.lists(st.integers(-2**63, 2**63 - 1), max_size=16))
    @settings(max_examples=25, deadline=None)
    def test_int_lists(self, xs):
        assert wire.decode_value(wire.encode_value(xs)) == xs

    @given(st.floats(-1e300, 1e300, allow_nan=False))
    @settings(max_examples=25, deadline=None)
    def test_floats_exact(self, x):
        out = wire.decode_value(wire.encode_value(x))
        assert struct.pack(">d", out) == struct.pack(">d", x)  # bitwise

    def test_nested(self):
        v = {"a": [1, {"b": None, 3: True}], 2: b"\x00\xff",
             "arr": np.ones((2, 3), np.int64), "s": "π ≠ 3"}
        assert _eq(wire.decode_value(wire.encode_value(v)), v)

    def test_preserves_array_dtype(self):
        for dt in (np.uint32, np.float32, np.float64, np.int32, np.int64,
                   np.uint8):
            arr = np.zeros(4, dt)
            out = wire.decode_value(wire.encode_value(arr))
            assert out.dtype == np.dtype(dt).newbyteorder("<")

    def test_decoded_arrays_writable(self):
        out = wire.decode_value(
            wire.encode_value(np.arange(8, dtype=np.uint32)))
        out += 1  # state machines do arithmetic on received payloads


class TestZeroCopy:
    """ISSUE 6 zero-copy relay guards: the codec must be correct when
    decoding from a ``memoryview`` *slice* of a larger buffer (the
    broker parses frames in place out of its receive buffer — offsets
    are never zero in practice), and the parts encoders must emit
    exactly the bytes of the contiguous encoders."""

    @staticmethod
    def _embed(body: bytes, pad_front: int, pad_back: int) -> memoryview:
        """A view into a larger buffer, starting at a non-zero offset."""
        buf = bytearray(b"\xAA" * pad_front + body + b"\x55" * pad_back)
        return memoryview(buf)[pad_front:pad_front + len(body)]

    @given(st.integers(1, 64), st.integers(1, 64),
           st.lists(st.integers(0, 2**32 - 1), min_size=0, max_size=64),
           st.integers(1, 37), st.integers(0, 9))
    @settings(max_examples=30, deadline=None)
    def test_request_roundtrip_from_offset_view(self, frm, to, payload,
                                                pad_front, pad_back):
        kwargs = dict(session=3, from_node=frm, to_node=to, group=0,
                      payload=_u32(payload))
        body = wire.encode_request("post_aggregate", kwargs)
        view = self._embed(body, pad_front, pad_back)
        op, got = wire.decode_request(view)
        assert op == "post_aggregate"
        assert _eq(got, kwargs)
        # and the broker's no-copy flavour still yields the exact bits
        op, got = wire.decode_request(view, copy_arrays=False)
        assert _eq(got["payload"], kwargs["payload"])

    @given(st.lists(st.floats(-1e6, 1e6, allow_nan=False, width=32),
                    min_size=0, max_size=64),
           st.integers(1, 37), st.integers(0, 9))
    @settings(max_examples=30, deadline=None)
    def test_response_roundtrip_from_offset_view(self, avg, pad_front,
                                                 pad_back):
        payload = {"average": np.asarray(avg, np.float32),
                   "weight_avg": None, "time": 0.5}
        body = wire.encode_response(payload)
        got = wire.decode_response(self._embed(body, pad_front, pad_back))
        assert _eq(got, payload)

    def test_no_copy_decode_views_into_frame(self):
        """copy_arrays=False returns read-only views aliasing the frame
        buffer — the zero-copy contract the broker relay relies on."""
        arr = np.arange(1024, dtype=np.uint32)
        body = wire.encode_request("post_aggregate", dict(
            session=0, from_node=1, to_node=2, group=0, payload=arr))
        buf = bytearray(b"\x00" * 13 + body)  # non-zero start offset
        view = memoryview(buf)[13:]
        _, got = wire.decode_request(view, copy_arrays=False)
        out = got["payload"]
        assert not out.flags.writeable  # a view, not private storage
        assert np.array_equal(out, arr)
        # mutate the underlying buffer through one of out's words:
        # the decoded array must alias it (no hidden copy)
        base = np.frombuffer(buf, dtype=np.uint8)
        probe_off = buf.index(arr[500].tobytes())
        base[probe_off] ^= 0xFF
        assert out[500] != arr[500]

    def test_parts_encoders_match_contiguous(self):
        """encode_*_parts joined == the bytes-returning encoders, for
        small (coalesced) and large (segmented) arrays alike."""
        for payload in (np.arange(4, dtype=np.uint32),
                        np.arange(1 << 16, dtype=np.uint32)):
            kwargs = dict(session=1, from_node=2, to_node=3, group=0,
                          payload=payload)
            parts = wire.encode_request_parts("post_aggregate", kwargs)
            flat = wire.encode_request("post_aggregate", kwargs)
            assert b"".join(bytes(p) for p in parts) == flat
            assert wire.parts_nbytes(parts) == len(flat)
            resp = {"aggregate": payload, "from_node": 2, "posted": 1,
                    "time": 0.0}
            rparts = wire.encode_response_parts(resp)
            rflat = wire.encode_response(resp)
            assert b"".join(bytes(p) for p in rparts) == rflat
            framed = wire.encode_frame_parts(rparts)
            assert b"".join(bytes(p) for p in framed) == \
                wire.encode_frame(rflat)

    def test_large_array_segment_is_a_view(self):
        """Arrays past the coalescing threshold ride as views of the
        caller's buffer — encoding a big payload must not duplicate it."""
        arr = np.arange(1 << 16, dtype=np.uint32)
        parts = wire.encode_request_parts("post_aggregate", dict(
            session=0, from_node=1, to_node=2, group=0, payload=arr))
        aliased = [p for p in parts if isinstance(p, memoryview)
                   and p.nbytes == arr.nbytes]
        assert aliased, "large payload was copied into the frame"
        src = np.frombuffer(aliased[0], dtype=np.uint32)
        assert np.shares_memory(src, arr)

    def test_frame_parts_oversize_rejected(self):
        big = [b"\x00" * (wire.MAX_FRAME + 1)]
        with pytest.raises(wire.WireError):
            wire.encode_frame_parts(big)


class TestHardening:
    def test_truncated_frame(self):
        body = wire.encode_request("get_average", {"session": 0})
        with pytest.raises(wire.WireDecodeError):
            wire.decode_request(body[:-3])

    def test_trailing_bytes(self):
        body = wire.encode_request("get_average", {"session": 0})
        with pytest.raises(wire.WireDecodeError):
            wire.decode_request(body + b"\x00")

    def test_bad_version(self):
        body = wire.encode_request("get_average", {"session": 0})
        bad = bytes([wire.WIRE_VERSION + 1]) + body[1:]
        with pytest.raises(wire.WireDecodeError):
            wire.decode_request(bad)

    def test_unknown_opcode(self):
        bad = struct.pack(">BB", wire.WIRE_VERSION, 255) + wire.encode_value({})
        with pytest.raises(wire.WireDecodeError):
            wire.decode_request(bad)

    def test_huge_shape_claim_rejected(self):
        """Array dims whose product would overflow/absurdly exceed the
        frame must fail as WireDecodeError, not a numpy ValueError."""
        bad = (bytes([9, 0, 2])  # tag=array, dtype=u4, ndim=2
               + struct.pack(">I", 2**32 - 1) * 2)  # 2 huge dims, no data
        with pytest.raises(wire.WireDecodeError):
            wire.decode_value(bad)

    def test_unknown_tag(self):
        with pytest.raises(wire.WireDecodeError):
            wire.decode_value(b"\xfa")

    def test_unknown_op_name(self):
        with pytest.raises(wire.WireError):
            wire.encode_request("drop_tables", {})

    def test_oversize_frame_rejected(self):
        with pytest.raises(wire.WireError):
            wire.encode_frame(b"\x00" * (wire.MAX_FRAME + 1))

    def test_error_response_raises(self):
        with pytest.raises(wire.WireError, match="boom"):
            wire.decode_response(wire.encode_error("boom"))

    def test_unencodable_type(self):
        with pytest.raises(wire.WireError):
            wire.encode_value(object())
