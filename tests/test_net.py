"""Wire plane end-to-end: the SAFE state machines over a real asyncio
transport. Acceptance (ISSUE 2): for the same seeds/topology the
published average over the wire is bit-identical to the discrete-event
sim, and MessageStats matches §5's closed forms for n ∈ {4, 8} with and
without an injected failure. Plus: faults (latency/drop/churn),
re-election, the engine plane, the broker's counter hygiene, and the
chunked transfer plane of docs/PROTOCOL.md §6 (boundary sizes,
single-chunk fallback, reordered/duplicate chunks, drops mid-stream,
crash mid-upload).

Every test runs under a hard SIGALRM deadline (autouse fixture) so a
hung broker or lost long-poll aborts the test instead of stalling the
whole tier-1 run.
"""
import asyncio
import signal

import numpy as np
import pytest
from helpers import run_multidevice

from repro.core.protocol import run_safe_round
from repro.net import (
    Chain,
    ChurnInterceptor,
    DropInterceptor,
    LatencyInterceptor,
    SafeBroker,
    run_safe_round_net,
)

#: per-test wall deadline (seconds). The slowest in-process paths below
#: are the re-election tests (~1x aggregation_timeout + a second round);
#: 90 s leaves an order of magnitude of headroom without letting a hang
#: stall tier-1. Tests that spawn a jax subprocess (fresh import +
#: 8-device compile) get the larger budget, aligned with
#: helpers.run_multidevice's own timeout.
NET_TEST_DEADLINE_S = 90
SUBPROCESS_DEADLINE_S = 900
_SUBPROCESS_TESTS = {"test_engine_plane_over_wire"}


@pytest.fixture(autouse=True)
def _hard_deadline(request):
    """Per-test timeout: a hung broker/long-poll raises instead of
    hanging pytest (no pytest-timeout in the container)."""
    deadline = (SUBPROCESS_DEADLINE_S
                if request.node.name in _SUBPROCESS_TESTS
                else NET_TEST_DEADLINE_S)

    def _expired(signum, frame):
        raise TimeoutError(
            f"net test exceeded {deadline}s hard deadline")

    old = signal.signal(signal.SIGALRM, _expired)
    signal.alarm(deadline)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)


def _vals(n, V, seed=0):
    return np.random.RandomState(seed).uniform(-1, 1, (n, V)).astype(np.float32)


def _wire_round(values, *, broker_kw=None, **round_kw):
    """Start a fresh broker, run one round over TCP, tear down."""

    async def go():
        broker = SafeBroker(**dict(
            dict(progress_timeout=0.4, monitor_interval=0.1,
                 aggregation_timeout=30.0), **(broker_kw or {})))
        addr = await broker.start()
        try:
            return await run_safe_round_net(values, addr, **round_kw)
        finally:
            await broker.stop()

    return asyncio.run(go())


class TestSimEquivalence:
    """Same seeds, same topology ⇒ same bits, same message counts."""

    @pytest.mark.parametrize("n", [4, 8])
    def test_bit_identical_no_failure(self, n):
        vals = _vals(n, 16, seed=n)
        sim = run_safe_round(vals)
        net = _wire_round(vals)
        assert np.array_equal(sim.average, net.average)  # bit-identical
        assert net.stats["aggregation_total"] == 4 * n
        assert sim.stats.aggregation_total == 4 * n
        # per-op counters agree too
        for op in ("post_aggregate", "check_aggregate", "get_aggregate",
                   "post_average", "get_average", "should_initiate"):
            assert net.stats[op] == getattr(sim.stats, op), op

    @pytest.mark.parametrize("n", [4, 8])
    def test_bit_identical_with_failure(self, n):
        """One dead learner: §5.3 closed form 4(n−f) + 2f, f=1."""
        vals = _vals(n, 16, seed=10 + n)
        sim = run_safe_round(vals, failed_nodes=[3])
        net = _wire_round(vals, failed_nodes=[3])
        assert np.array_equal(sim.average, net.average)
        expected = 4 * (n - 1) + 2
        assert sim.stats.aggregation_total == expected
        assert net.stats["aggregation_total"] == expected
        assert net.monitor_reposts == 1
        mask = np.ones(n, bool)
        mask[2] = False
        np.testing.assert_allclose(net.average, vals[mask].mean(0), atol=1e-3)

    def test_adjacent_failures(self):
        vals = _vals(8, 8, seed=3)
        sim = run_safe_round(vals, failed_nodes=[4, 5])
        net = _wire_round(vals, failed_nodes=[4, 5])
        assert np.array_equal(sim.average, net.average)
        assert net.stats["aggregation_total"] == 4 * 6 + 2 * 2
        assert net.monitor_reposts == 2

    def test_subgroups_closed_form(self):
        """§5.5: 4n + g messages, average of group averages."""
        vals = _vals(8, 8, seed=4)
        sim = run_safe_round(vals, subgroups=2)
        net = _wire_round(vals, subgroups=2)
        assert np.array_equal(sim.average, net.average)
        assert net.stats["aggregation_total"] == 4 * 8 + 2
        assert sim.stats.aggregation_total == 4 * 8 + 2

    def test_weighted_bit_identical(self):
        vals = _vals(6, 8, seed=5)
        w = np.array([1000, 200, 3000, 500, 800, 1500], np.float32)
        sim = run_safe_round(vals, weights=w)
        net = _wire_round(vals, weights=w)
        assert np.array_equal(sim.average, net.average)
        assert float(sim.weight_avg) == float(net.weight_avg)

    def test_saf_mode(self):
        vals = _vals(5, 8, seed=6)
        sim = run_safe_round(vals, mode="saf")
        net = _wire_round(vals, mode="saf")
        assert np.array_equal(sim.average, net.average)


class TestFaults:
    def test_latency_and_drops_do_not_change_the_answer(self):
        """Transport faults perturb timing, never semantics: the codec +
        retry path must keep the bits and the §5.2 count intact (drops
        happen before the broker sees the frame, so no double count)."""
        vals = _vals(8, 16, seed=7)
        sim = run_safe_round(vals)
        drop = DropInterceptor(p=0.1, seed=3)
        net = _wire_round(vals, interceptor=Chain(
            LatencyInterceptor(mean=0.002, seed=7), drop))
        assert np.array_equal(sim.average, net.average)
        assert net.stats["aggregation_total"] == 4 * 8
        assert drop.dropped > 0  # the fault plan actually fired

    def test_churn_crash_lost_aggregate_reelects(self):
        """A learner crashes *between* consuming the running aggregate
        and reposting it (the worst §5.4 case: the aggregate is lost).
        The round times out, a survivor is re-elected, and the retry
        publishes the survivors' average — bit-identical to a sim where
        that node was dead all along (ring addition commutes)."""
        vals = _vals(8, 16, seed=8)
        churn = ChurnInterceptor({5: 1})  # dies before its post_aggregate
        net = _wire_round(
            vals, interceptor=churn,
            broker_kw=dict(aggregation_timeout=2.0))
        sim = run_safe_round(vals, failed_nodes=[5])
        assert net.crashed_nodes == (5,)
        assert net.initiator_elections >= 1
        assert np.array_equal(sim.average, net.average)

    def test_initiator_crash_reelects(self):
        """Fig. 5: initiator posts once then crashes; §5.4 re-election
        over the wire converges to the survivors' average."""
        vals = _vals(8, 8, seed=9)
        sim = run_safe_round(vals, initiator_fails=True,
                             aggregation_timeout=2.0)
        net = _wire_round(vals, initiator_fails=True,
                          broker_kw=dict(aggregation_timeout=2.0))
        assert net.initiator_elections >= 1
        assert np.array_equal(sim.average, net.average)
        np.testing.assert_allclose(net.average, vals[1:].mean(0), atol=1e-3)


class TestBrokerHygiene:
    def test_unknown_session_is_an_error_not_a_crash(self):
        from repro.net import WireClient, wire as _w

        async def go():
            broker = SafeBroker()
            addr = await broker.start()
            try:
                c = await WireClient(*addr).connect()
                with pytest.raises(_w.WireError, match="unknown session"):
                    await c.request("get_stats", {"session": 999})
                # unserviceable sessions refused at the boundary
                with pytest.raises(_w.WireError, match="empty chain"):
                    await c.request("create_session",
                                    {"groups": {0: [1, 2, 3], 1: []}})
                # connection still serves after the errors
                made = await c.request("create_session",
                                       {"groups": {0: [1, 2, 3]}})
                assert made["session"] == 0
                await c.close()
            finally:
                await broker.stop()

        asyncio.run(go())

    def test_completed_rounds_free_their_sessions(self):
        """run_safe_round_net deletes its broker session: a long-lived
        broker must not accumulate one Controller per finished round."""
        from repro.net import WireClient, wire as _w

        async def go():
            broker = SafeBroker(progress_timeout=0.4, monitor_interval=0.1)
            addr = await broker.start()
            try:
                await run_safe_round_net(_vals(4, 4), addr)
                assert broker._sessions == {}  # torn down server-side
                c = await WireClient(*addr).connect()
                with pytest.raises(_w.WireError, match="unknown session"):
                    await c.request("get_stats", {"session": 0})
                await c.close()
            finally:
                await broker.stop()

        asyncio.run(go())

    def test_stop_unparks_forever_long_polls(self):
        """broker.stop() must cancel connection handlers parked on a
        timeout=None long-poll instead of leaking (or hanging
        wait_closed on newer Pythons)."""
        from repro.net import WireClient

        async def go():
            broker = SafeBroker()
            addr = await broker.start()
            c = await WireClient(*addr).connect()
            await c.request("create_session", {"groups": {0: [1, 2, 3]}})
            poll = asyncio.ensure_future(c.request(
                "get_average", {"session": 0, "timeout": None}))
            await asyncio.sleep(0.2)  # let it park on the broker
            assert not poll.done()
            await broker.stop()  # must return promptly
            with pytest.raises(Exception):
                await asyncio.wait_for(poll, 5.0)  # conn dropped cleanly
            await c.close()

        asyncio.run(go())

    def test_stray_to_node_rejected_and_monitor_survives(self):
        """A posting addressed outside the chain is refused at the RPC
        boundary (it could never be consumed or reposted around), so it
        can't poison the §5.3 monitor for other tenants."""
        from repro.net import WireClient, wire as _w

        async def go():
            broker = SafeBroker(progress_timeout=0.2, monitor_interval=0.05)
            addr = await broker.start()
            try:
                c = await WireClient(*addr).connect()
                await c.request("create_session", {"groups": {0: [1, 2, 3]}})
                with pytest.raises(_w.WireError, match="not in"):
                    await c.request("post_aggregate", {
                        "session": 0, "from_node": 1, "to_node": 99,
                        "group": 0,
                        "payload": np.zeros(4, np.uint32)})
                with pytest.raises(_w.WireError, match="unknown group"):
                    await c.request("post_aggregate", {
                        "session": 0, "from_node": 1, "to_node": 2,
                        "group": 7,
                        "payload": np.zeros(4, np.uint32)})
                await c.close()
                # monitor still alive and clean; a full round still works
                res = await run_safe_round_net(_vals(4, 4), addr)
                assert res.stats["aggregation_total"] == 4 * 4
                assert broker.monitor_errors == 0
            finally:
                await broker.stop()

        asyncio.run(go())

    def test_wire_round_rejects_insec(self):
        with pytest.raises(ValueError):
            _wire_round(_vals(4, 4), mode="insec")

    def test_two_sessions_are_isolated(self):
        """Two tenants on one broker: independent controllers, stats,
        and averages (the multi-session story at the wire level)."""
        vals_a, vals_b = _vals(4, 8, seed=11), _vals(4, 8, seed=12)

        async def go():
            broker = SafeBroker(progress_timeout=0.4, monitor_interval=0.1)
            addr = await broker.start()
            try:
                a, b = await asyncio.gather(
                    run_safe_round_net(vals_a, addr),
                    run_safe_round_net(vals_b, addr, learner_master=0x9999))
            finally:
                await broker.stop()
            return a, b

        a, b = asyncio.run(go())
        sim_a = run_safe_round(vals_a)
        sim_b = run_safe_round(vals_b, learner_master=0x9999)
        assert np.array_equal(a.average, sim_a.average)
        assert np.array_equal(b.average, sim_b.average)
        assert a.stats["aggregation_total"] == 4 * 4
        assert b.stats["aggregation_total"] == 4 * 4


class TestChunkedTransfer:
    """docs/PROTOCOL.md §6: multi-frame array streaming. Chunking is
    transport — bits, §5 message counts and failover semantics must be
    indistinguishable from the unchunked path."""

    def test_multi_chunk_bit_identical_and_counts(self):
        """V=103 over 16-word chunks (7 per transfer, ragged tail)."""
        vals = _vals(6, 103, seed=21)
        sim = run_safe_round(vals)
        # stream=False pins the buffered chunk plane: with the default
        # auto policy a payload this small skips chunking wholesale
        # (ISSUE 9 small-n fast path, TestAutoStreamThreshold)
        net = _wire_round(vals, chunk_words=16, stream=False)
        assert np.array_equal(sim.average, net.average)
        assert net.stats["aggregation_total"] == 4 * 6
        assert net.stats["transfers_completed"] == 7  # 6 hops + average
        assert net.stats["chunk_frames_in"] == 7 * 7

    def test_exact_chunk_boundary(self):
        """V an exact multiple of chunk_words: no empty trailing chunk."""
        vals = _vals(4, 64, seed=22)
        sim = run_safe_round(vals)
        net = _wire_round(vals, chunk_words=16, stream=False)
        assert np.array_equal(sim.average, net.average)
        assert net.stats["chunk_frames_in"] == 5 * 4  # exactly 64/16 each

    def test_single_chunk_fallback(self):
        """Payload fits one chunk: the plain ops carry it, zero chunk
        frames on the wire."""
        vals = _vals(4, 8, seed=23)
        sim = run_safe_round(vals)
        net = _wire_round(vals, chunk_words=16)
        assert np.array_equal(sim.average, net.average)
        assert net.stats["chunk_frames_in"] == 0
        assert net.stats["chunk_frames_out"] == 0

    def test_chunked_weighted_and_dead_node(self):
        """A dead learner's chunked transfer is reposted around (§5.3)
        and the weighted closed form 4(n−f)+2f still holds."""
        vals = _vals(8, 48, seed=24)
        w = np.arange(1, 9, dtype=np.float32) * 100
        sim = run_safe_round(vals, failed_nodes=[3], weights=w)
        net = _wire_round(vals, failed_nodes=[3], weights=w, chunk_words=16,
                          stream=False)
        assert np.array_equal(sim.average, net.average)
        assert float(sim.weight_avg) == float(net.weight_avg)
        assert net.stats["aggregation_total"] == 4 * 7 + 2
        assert net.monitor_reposts == 1

    def test_dropped_chunks_retry_clean(self):
        """Drops hit individual chunk frames (they never reached the
        broker — at-most-once retry), bits and counts survive."""
        vals = _vals(8, 48, seed=25)
        sim = run_safe_round(vals)
        drop = DropInterceptor(p=0.1, seed=9)
        net = _wire_round(vals, chunk_words=16, stream=False,
                          interceptor=Chain(
                              LatencyInterceptor(mean=0.001, seed=9), drop))
        assert np.array_equal(sim.average, net.average)
        assert net.stats["aggregation_total"] == 4 * 8
        assert drop.dropped > 0

    def test_crash_mid_upload_reelects(self):
        """Buffered path (stream=False): a learner dies partway through
        streaming its aggregate AFTER consuming its predecessor's
        posting (the buffered pipeline consumes before it re-posts): no
        stuck posting exists, so §5.3 cannot fire — the round times
        out, §5.4 re-elects, and the survivors' retry publishes,
        bit-identical to a sim where that node was dead all along."""
        vals = _vals(8, 48, seed=26)
        # node 5 (non-initiator): 3 get_chunk + 1 get_aggregate frames,
        # then dies before its 2nd post_chunk — one chunk buffered
        churn = ChurnInterceptor({5: 5})
        net = _wire_round(vals, chunk_words=16, interceptor=churn,
                          stream=False,
                          broker_kw=dict(aggregation_timeout=2.0))
        sim = run_safe_round(vals, failed_nodes=[5])
        assert net.crashed_nodes == (5,)
        assert net.initiator_elections >= 1
        assert np.array_equal(sim.average, net.average)

    def test_crash_mid_streamed_combine_reposts_around(self):
        """Streaming path: the combine defers the logical consume to
        after the upload, so a learner crashing mid-hop leaves its
        predecessor's posting unconsumed — the §5.3 monitor reposts
        around the dead node (no full §5.4 round restart needed), its
        half-combined upload goes stale and is replaced, and the
        survivors' average is bit-identical to a sim where that node
        was dead all along."""
        vals = _vals(8, 48, seed=26)
        churn = ChurnInterceptor({5: 5})  # dies mid-streamed-combine
        net = _wire_round(vals, chunk_words=16, interceptor=churn,
                          stream=True,
                          broker_kw=dict(aggregation_timeout=2.0))
        sim = run_safe_round(vals, failed_nodes=[5])
        assert net.crashed_nodes == (5,)
        assert net.monitor_reposts >= 1
        assert np.array_equal(sim.average, net.average)

    def test_reordered_duplicate_chunks_and_streaming(self):
        """Raw frames: chunks arrive out of order with a duplicate; the
        logical post fires exactly once, on completion; a chunk is
        downloadable *before* the upload completes (store-and-forward
        pipelining); the elided consume counts once."""
        from repro.net import WireClient

        payload = np.arange(40, dtype=np.uint32)
        cw = 16  # chunks [0:16] [16:32] [32:40], total 3

        def frame(seq):
            return {"session": 0, "op": "post_aggregate", "xfer": 77,
                    "seq": seq, "total": 3, "chunk_words": cw,
                    "from_node": 1, "to_node": 2, "group": 0,
                    "payload": payload[seq * cw:(seq + 1) * cw]}

        async def go():
            broker = SafeBroker()
            addr = await broker.start()
            try:
                c = await WireClient(*addr).connect()
                await c.request("create_session", {"groups": {0: [1, 2]}})
                r = await c.request("post_chunk", frame(2))  # tail first
                assert not r["complete"] and r["received"] == 1
                # streaming: the buffered chunk serves before completion
                g = await c.request("get_chunk", {
                    "session": 0, "kind": "get_aggregate", "node": 2,
                    "group": 0, "seq": 2, "words": cw, "timeout": 5.0})
                assert g["last"] and g["from_node"] == 1
                assert np.array_equal(g["payload"], payload[32:])
                st = await c.request("get_stats", {"session": 0})
                assert st["post_aggregate"] == 0  # not a message yet
                await c.request("post_chunk", frame(0))
                r = await c.request("post_chunk", frame(0))  # duplicate
                assert r["received"] == 2  # idempotent overwrite
                r = await c.request("post_chunk", frame(1))
                assert r["complete"]
                # at-least-once repeat AFTER completion (final ack lost):
                # idempotent re-ack, no fresh buffer, no second posting
                r = await c.request("post_chunk", frame(1))
                assert r["complete"] and r["received"] == 3
                st = await c.request("get_stats", {"session": 0})
                assert st["post_aggregate"] == 1
                assert st["transfers_completed"] == 1
                parts = [(await c.request("get_chunk", {
                    "session": 0, "kind": "get_aggregate", "node": 2,
                    "group": 0, "seq": s, "words": cw,
                    "timeout": 5.0}))["payload"] for s in range(3)]
                res = await c.request("get_aggregate", {
                    "session": 0, "node": 2, "group": 0,
                    "elide_payload": True, "timeout": 5.0})
                assert res["chunked"] is True and res["aggregate"] is None
                assert np.array_equal(np.concatenate(parts), payload)
                st = await c.request("get_stats", {"session": 0})
                assert st["get_aggregate"] == 1
                # post_average idempotency: the posted buffer is the
                # repeat record — a re-sent final chunk re-acks, never
                # re-executes the op (PROTOCOL.md §6 repeat rule)
                avg_frame = {"session": 0, "op": "post_average",
                             "xfer": 5, "seq": 0, "total": 1,
                             "chunk_words": cw, "node": 1, "group": 0,
                             "payload": np.zeros(8, np.float32)}
                r = await c.request("post_chunk", dict(avg_frame))
                assert r["complete"]
                r = await c.request("post_chunk", dict(avg_frame))
                assert r["complete"]
                st = await c.request("get_stats", {"session": 0})
                assert st["post_average"] == 1
                await c.close()
            finally:
                await broker.stop()

        asyncio.run(go())


class TestStreamingCombine:
    """The chunk-granular §5.1.2 combine (ISSUE 4 tentpole): chunk k is
    decrypted/added/re-encrypted and shipped downstream while chunk k+1
    is in flight. Streaming is transport scheduling — bits, §5 message
    counts and failover semantics must be indistinguishable from the
    reassemble-then-combine path (and from the sim)."""

    @pytest.mark.parametrize("n", [4, 8])
    def test_streamed_bit_identical_and_counts(self, n):
        vals = _vals(n, 103, seed=30 + n)
        sim = run_safe_round(vals)
        net = _wire_round(vals, chunk_words=16, stream=True)
        assert np.array_equal(sim.average, net.average)
        assert net.stats["aggregation_total"] == 4 * n
        # every non-initiator hop ran the fused streaming combine
        assert net.streamed_combines == n - 1
        for op in ("post_aggregate", "check_aggregate", "get_aggregate",
                   "post_average", "get_average", "should_initiate"):
            assert net.stats[op] == getattr(sim.stats, op), op

    def test_streamed_equals_buffered(self):
        """stream=True vs stream=False: identical bits, counts, and
        chunk-frame tallies (streaming reorders frames, never adds)."""
        vals = _vals(6, 103, seed=31)
        on = _wire_round(vals, chunk_words=16, stream=True)
        off = _wire_round(vals, chunk_words=16, stream=False)
        assert np.array_equal(on.average, off.average)
        assert on.stats["aggregation_total"] == off.stats["aggregation_total"]
        assert on.stats["chunk_frames_in"] == off.stats["chunk_frames_in"]
        assert on.streamed_combines == 5 and off.streamed_combines == 0

    def test_streamed_weighted_with_failure_closed_form(self):
        vals = _vals(8, 48, seed=32)
        w = np.arange(1, 9, dtype=np.float32) * 100
        sim = run_safe_round(vals, failed_nodes=[3], weights=w)
        net = _wire_round(vals, failed_nodes=[3], weights=w, chunk_words=16,
                          stream=True)
        assert np.array_equal(sim.average, net.average)
        assert float(sim.weight_avg) == float(net.weight_avg)
        assert net.stats["aggregation_total"] == 4 * 7 + 2
        assert net.monitor_reposts == 1

    def test_streamed_under_faults(self):
        """Latency + drops against the streaming path: chunk frames are
        retried at-most-once, identities keep assembly straight."""
        vals = _vals(8, 48, seed=33)
        sim = run_safe_round(vals)
        drop = DropInterceptor(p=0.08, seed=11)
        net = _wire_round(vals, chunk_words=16, stream=True,
                          interceptor=Chain(
                              LatencyInterceptor(mean=0.001, seed=11),
                              drop))
        assert np.array_equal(sim.average, net.average)
        assert net.stats["aggregation_total"] == 4 * 8
        assert drop.dropped > 0

    @pytest.mark.parametrize("depth", [1, 4])
    def test_prefetch_depth_is_transport_only(self, depth):
        """Any prefetch depth yields the same bits and counts — depth
        moves wall-clock, never semantics (the ablation that picked
        wire.DEFAULT_PREFETCH_DEPTH lives in benchmarks/streaming.py)."""
        vals = _vals(6, 103, seed=34)
        sim = run_safe_round(vals)
        net = _wire_round(vals, chunk_words=16, prefetch_depth=depth,
                          stream=True)
        assert np.array_equal(sim.average, net.average)
        assert net.stats["aggregation_total"] == 4 * 6
        assert net.streamed_combines == 5


class TestPersistentSessions:
    """One broker session, R rounds (ISSUE 4): reset_round + RoundCursor
    counter bases between rounds, key material and connections reused —
    no key re-derivation after Round 0, per-round §5 closed forms, and
    crash-resume across round boundaries."""

    def test_five_rounds_bit_identical_no_rederivation(self):
        from repro.core import machines
        from repro.net import PersistentNetSession

        n, V, R = 4, 103, 5
        rng = np.random.RandomState(40)
        rounds = [rng.uniform(-1, 1, (n, V)).astype(np.float32)
                  for _ in range(R)]

        async def go():
            broker = SafeBroker(progress_timeout=0.4, monitor_interval=0.1,
                                aggregation_timeout=30.0)
            addr = await broker.start()
            try:
                sess = PersistentNetSession(addr, n, chunk_words=16,
                                            stream=True)
                await sess.open()
                try:
                    d0 = machines.key_derivations()
                    out = []
                    derivs = []
                    for vals in rounds:
                        out.append(await sess.run_round(vals))
                        derivs.append(machines.key_derivations() - d0)
                    assert len(broker._sessions) == 1  # ONE tenant alive
                finally:
                    await sess.close()
                assert broker._sessions == {}  # torn down on close
                return out, derivs
            finally:
                await broker.stop()

        out, derivs = asyncio.run(go())
        # Round 0 derived everything; rounds 1..R-1 derived NOTHING
        assert derivs[0] > 0
        assert all(d == derivs[0] for d in derivs[1:]), derivs
        V_words = rounds[0].shape[1]
        for r, res in enumerate(out):
            sim = run_safe_round(rounds[r], counter=r * V_words)
            assert np.array_equal(sim.average, res.average), f"round {r}"
            # per-round stats delta still satisfies the closed form
            assert res.stats["aggregation_total"] == 4 * n, (r, res.stats)
            assert res.initiator_elections == 0
            assert res.streamed_combines == n - 1

    def test_undersized_counter_stride_is_refused(self):
        """A payload wider than the session's words/round stride would
        overlap the next round's pad words — silent keystream reuse.
        The session must refuse the round up front, even when
        words_per_round was pinned explicitly."""
        from repro.net import PersistentNetSession

        n, V = 4, 32

        async def go():
            broker = SafeBroker()
            addr = await broker.start()
            try:
                # stride sized for unweighted rounds; a weighted round
                # needs V+1 words
                sess = PersistentNetSession(addr, n, words_per_round=V)
                await sess.open()
                try:
                    with pytest.raises(ValueError, match="stride"):
                        await sess.run_round(
                            _vals(n, V), weights=np.ones(n, np.float32))
                    # a correctly-sized round still runs afterwards
                    res = await sess.run_round(_vals(n, V, seed=60))
                    assert res.average is not None
                finally:
                    await sess.close()
            finally:
                await broker.stop()

        asyncio.run(go())

    def test_crash_resume_across_round_boundary(self):
        """Node 5 churn-crashes mid-round-0 (partial streamed combine),
        resumes in round 1: round 0 publishes the survivors' mean
        (§5.3/§5.4 recovery), round 1 is clean — full average, clean
        closed form — over the SAME session and fresh counter space."""
        from repro.net import PersistentNetSession

        n, V = 8, 48
        rng = np.random.RandomState(41)
        vals0 = rng.uniform(-1, 1, (n, V)).astype(np.float32)
        vals1 = rng.uniform(-1, 1, (n, V)).astype(np.float32)
        churn = ChurnInterceptor({5: 5})

        async def go():
            broker = SafeBroker(progress_timeout=0.4, monitor_interval=0.1,
                                aggregation_timeout=2.0)
            addr = await broker.start()
            try:
                sess = PersistentNetSession(addr, n, chunk_words=16,
                                            stream=True, interceptor=churn)
                await sess.open()
                try:
                    r0 = await sess.run_round(vals0)
                    # the org comes back online for the next round
                    churn.crash_after.pop(5)
                    r1 = await sess.run_round(vals1)
                finally:
                    await sess.close()
                return r0, r1
            finally:
                await broker.stop()

        r0, r1 = asyncio.run(go())
        assert r0.crashed_nodes == (5,)
        sim0 = run_safe_round(vals0, failed_nodes=[5])
        assert np.array_equal(sim0.average, r0.average)
        # round 1: node 5 resumed — full clean round on the same session
        assert r1.crashed_nodes == ()
        sim1 = run_safe_round(vals1, counter=V)
        assert np.array_equal(sim1.average, r1.average)
        assert r1.stats["aggregation_total"] == 4 * n

    def test_reset_mid_stream_cannot_corrupt(self):
        """Races against a partially-combined transfer buffer, raw
        frames: (a) reset_round mid-upload — the leftover chunks must
        not complete into a posting; (b) the uploader's own NEWER xfer
        replaces its abandoned stream; (c) a stale frame of the OLD
        xfer after the replacement is discarded, never merged."""
        from repro.net import WireClient

        payload = np.arange(48, dtype=np.uint32)
        cw = 16  # 3 chunks

        def frame(xfer, seq, arr=payload):
            return {"session": 0, "op": "post_aggregate", "xfer": xfer,
                    "seq": seq, "total": 3, "chunk_words": cw,
                    "from_node": 1, "to_node": 2, "group": 0,
                    "payload": arr[seq * cw:(seq + 1) * cw]}

        async def go():
            broker = SafeBroker()
            addr = await broker.start()
            try:
                c = await WireClient(*addr).connect()
                await c.request("create_session", {"groups": {0: [1, 2]}})
                # (a) two chunks up, then the round resets
                await c.request("post_chunk", frame(7, 0))
                await c.request("post_chunk", frame(7, 2))
                await c.request("reset_round", {"session": 0})
                r = await c.request("post_chunk", frame(7, 1))
                # the buffer restarted from scratch: one chunk, no post
                assert not r["complete"] and r["received"] == 1
                st = await c.request("get_stats", {"session": 0})
                assert st["post_aggregate"] == 0
                # (b) the uploader restarts under a newer xfer: replaces
                # its own half-dead stream even though it is "active"
                fresh = np.arange(100, 148, dtype=np.uint32)
                r = await c.request("post_chunk", frame(8, 0, fresh))
                assert r["received"] == 1
                # (c) stale duplicate of the OLD stream: discarded
                r = await c.request("post_chunk", frame(7, 2))
                assert r.get("superseded") and not r["complete"]
                r = await c.request("post_chunk", frame(8, 1, fresh))
                r = await c.request("post_chunk", frame(8, 2, fresh))
                assert r["complete"]
                st = await c.request("get_stats", {"session": 0})
                assert st["post_aggregate"] == 1
                # the posting holds the NEW stream's bytes, untouched by
                # the stale frame
                got = await c.request("get_aggregate", {
                    "session": 0, "node": 2, "group": 0, "timeout": 5.0})
                assert np.array_equal(got["aggregate"], fresh)
                await c.close()
            finally:
                await broker.stop()

        asyncio.run(go())

    def test_federated_rounds_one_session(self):
        """run_federated_rounds_net (ISSUE 4 acceptance): R=5 FedAvg
        rounds on ONE session — no key re-derivation after Round 0, a
        mid-training dead round recovered via §5.3, state evolution
        matching the closed-form FedAvg recursion."""
        from repro.core import machines
        from repro.net import run_federated_rounds_net

        n, P, R = 4, 103, 5
        rng = np.random.RandomState(42)
        grads = {node: rng.uniform(-1, 1, P).astype(np.float32)
                 for node in range(1, n + 1)}
        # each learner's "local update": a deterministic function of the
        # shared state, so every round's expected mean is computable
        local_fns = {node: (lambda s, g=grads[node]: g - 0.1 * s)
                     for node in range(1, n + 1)}

        def apply_fn(state, avg):
            return state + avg

        async def go():
            broker = SafeBroker(progress_timeout=0.4, monitor_interval=0.1,
                                aggregation_timeout=30.0)
            addr = await broker.start()
            try:
                # reference: key derivations ONE round costs
                d0 = machines.key_derivations()
                await run_federated_rounds_net(
                    np.zeros(P, np.float32), local_fns, apply_fn, addr,
                    rounds=1, chunk_words=16)
                d_single = machines.key_derivations() - d0
                d1 = machines.key_derivations()
                state, results = await run_federated_rounds_net(
                    np.zeros(P, np.float32), local_fns, apply_fn, addr,
                    rounds=R, chunk_words=16,
                    failed_by_round={2: [3]})
                d_multi = machines.key_derivations() - d1
                return state, results, d_single, d_multi
            finally:
                await broker.stop()

        state, results, d_single, d_multi = asyncio.run(go())
        assert len(results) == R
        # expected evolution, recomputed in the clear
        exp = np.zeros(P, np.float32)
        for r in range(R):
            live = [nd for nd in range(1, n + 1) if not (r == 2 and nd == 3)]
            deltas = np.stack([grads[nd] - 0.1 * exp for nd in live])
            avg = np.asarray(results[r].average)
            np.testing.assert_allclose(avg, deltas.mean(0), atol=2e-3)
            exp = exp + avg  # apply the PUBLISHED average (bit-exact path)
        np.testing.assert_array_equal(state, exp)
        # round 2 ran 4(n-1)+2 messages (one dead org), others 4n
        for r, res in enumerate(results):
            expect = 4 * (n - 1) + 2 if r == 2 else 4 * n
            assert res.stats["aggregation_total"] == expect, (r, res.stats)
        # R rounds derive exactly what ONE round derives, plus the two
        # genuinely NEW pair keys of round 2's §5.3 repost (poster 2 and
        # receiver 4 each derive the never-before-used 2→4 hop pad) —
        # nothing already derived in Round 0 is ever derived again
        assert d_single > 0
        assert d_multi == d_single + 2


class TestCrossRoundPipelining:
    """§11 cross-round pipelining (ISSUE 9 tentpole): transfers and
    chunk relay namespaced by (session, round). The broker accepts —
    and relays — round r+1's chunk streams while round r's tail drains,
    parks round-tagged logical ops until ``advance_round`` opens the
    round, and delivers deferred transfers at the boundary, so the
    per-round MessageStats deltas keep the §5 closed forms and every
    round stays bit-identical to its sim twin."""

    def test_future_round_chunks_accepted_before_current_publishes(self):
        """THE §11 acceptance property, raw frames: a round-1 chunk is
        accepted and downloadable while round 0 is still incomplete
        (nothing published, nothing posted), with the logical op
        deferred to advance_round; stale-round stragglers are shed, and
        frames past the in-flight window get the busy backoff."""
        from repro.net import WireClient

        arr = np.arange(48, dtype=np.uint32)
        cw = 16  # 3 chunks

        def frame(seq, rnd):
            return {"session": 0, "op": "post_aggregate", "xfer": 5,
                    "seq": seq, "total": 3, "chunk_words": cw,
                    "from_node": 1, "to_node": 2, "group": 0,
                    "round": rnd, "payload": arr[seq * cw:(seq + 1) * cw]}

        async def go():
            broker = SafeBroker()
            addr = await broker.start()
            try:
                c = await WireClient(*addr).connect()
                await c.request("create_session", {"groups": {0: [1, 2]}})
                # round 0 is open and has seen NOTHING — post a chunk
                # addressed to round 1
                r = await c.request("post_chunk", frame(0, rnd=1))
                assert r["received"] == 1 and not r.get("superseded")
                assert r.get("status") != "busy"
                # the round-1 chunk is downloadable NOW: store-and-
                # forward relay across the round boundary
                got = await c.request("get_chunk", {
                    "session": 0, "kind": "get_aggregate", "node": 2,
                    "group": 0, "round": 1, "seq": 0, "words": cw,
                    "timeout": 5.0})
                assert np.array_equal(got["payload"], arr[:cw])
                # ...while round 0 remains untouched: no logical op, no
                # average, round counter still 0
                st = await c.request("get_stats", {"session": 0})
                assert st["round"] == 0
                assert st["post_aggregate"] == 0
                assert st["chunk_frames_future"] == 1
                assert (await c.request("peek_average",
                                        {"session": 0})) is None
                # completing the round-1 transfer STILL defers the op
                await c.request("post_chunk", frame(1, rnd=1))
                r = await c.request("post_chunk", frame(2, rnd=1))
                assert r["complete"]
                st = await c.request("get_stats", {"session": 0})
                assert st["post_aggregate"] == 0
                # advance_round opens round 1 and delivers the transfer
                adv = await c.request("advance_round", {"session": 0})
                assert adv["round"] == 1
                st = await c.request("get_stats", {"session": 0})
                assert st["round"] == 1
                assert st["post_aggregate"] == 1
                got = await c.request("get_aggregate", {
                    "session": 0, "node": 2, "group": 0, "round": 1,
                    "timeout": 5.0})
                assert np.array_equal(got["aggregate"], arr)
                # a straggler frame for the CLOSED round 0 is shed
                r = await c.request("post_chunk", frame(0, rnd=0))
                assert r.get("superseded") and r.get("stale_round")
                # a frame past the window (rounds {1, 2} in flight) is
                # refused with the §13 busy backoff, never buffered —
                # raw send/recv because WireClient.request would honour
                # the backoff and retry forever
                await c._send("post_chunk", frame(0, rnd=3))
                r = await c._recv("post_chunk")
                assert r.get("status") == "busy"
                st = await c.request("get_stats", {"session": 0})
                assert st["busy_rejections"] == 1
                await c.close()
            finally:
                await broker.stop()

        asyncio.run(go())

    def test_pipelined_rounds_bit_identical_closed_forms(self):
        """R rounds with window 2 on one session, streaming combine on:
        every round bit-identical to its independent sim twin, per-round
        4n closed form exact, and — the point — chunk frames of round
        r+1 observed on the broker while round r was still current."""
        from repro.net import PersistentNetSession

        n, V, R = 4, 103, 4
        rng = np.random.RandomState(90)
        rounds = [rng.uniform(-1, 1, (n, V)).astype(np.float32)
                  for _ in range(R)]

        async def go():
            broker = SafeBroker(progress_timeout=0.4, monitor_interval=0.1,
                                aggregation_timeout=30.0)
            addr = await broker.start()
            try:
                sess = PersistentNetSession(addr, n, chunk_words=16,
                                            stream=True)
                await sess.open()
                try:
                    out = await sess.run_rounds_pipelined(rounds)
                    raw = await sess._admin.request(
                        "get_stats", {"session": sess.sid})
                finally:
                    await sess.close()
                return out, raw
            finally:
                await broker.stop()

        out, raw = asyncio.run(go())
        assert len(out) == R
        for r, res in enumerate(out):
            sim = run_safe_round(rounds[r], counter=r * V)
            assert np.array_equal(sim.average, res.average), f"round {r}"
            assert res.stats["aggregation_total"] == 4 * n, (r, res.stats)
            assert res.initiator_elections == 0
            assert res.monitor_reposts == 0
        # cross-round overlap actually happened on the wire: the broker
        # accepted round r+1 chunk frames while round r was current
        assert raw["chunk_frames_future"] > 0
        assert raw["round"] == R

    def test_pipelined_unchunked_parks_and_stays_exact(self):
        """No chunk plane at all (V below every threshold): round r+1's
        ops simply park at the broker until the boundary — zero overlap,
        identical correctness. The degenerate end of §11."""
        from repro.net import PersistentNetSession

        n, V, R = 4, 16, 3
        rng = np.random.RandomState(91)
        rounds = [rng.uniform(-1, 1, (n, V)).astype(np.float32)
                  for _ in range(R)]

        async def go():
            broker = SafeBroker(progress_timeout=0.4, monitor_interval=0.1,
                                aggregation_timeout=30.0)
            addr = await broker.start()
            try:
                async with PersistentNetSession(addr, n) as sess:
                    return await sess.run_rounds_pipelined(rounds)
            finally:
                await broker.stop()

        out = asyncio.run(go())
        for r, res in enumerate(out):
            sim = run_safe_round(rounds[r], counter=r * V)
            assert np.array_equal(sim.average, res.average), f"round {r}"
            assert res.stats["aggregation_total"] == 4 * n, (r, res.stats)

    def test_federated_pipeline_staleness_one(self):
        """run_federated_rounds_net(pipeline=True): with window 2,
        round r's deltas are computed from the state through round r−2
        (staleness-1 pipelined FL). The whole evolution is recomputed in
        the clear and must match — including the exact fold of the
        published (bit-exact) averages into the final state."""
        from repro.net import run_federated_rounds_net

        n, P, R = 4, 103, 4
        rng = np.random.RandomState(43)
        grads = {node: rng.uniform(-1, 1, P).astype(np.float32)
                 for node in range(1, n + 1)}
        local_fns = {node: (lambda s, g=grads[node]: g - 0.1 * s)
                     for node in range(1, n + 1)}

        def apply_fn(state, avg):
            return state + avg

        async def go():
            broker = SafeBroker(progress_timeout=0.4, monitor_interval=0.1,
                                aggregation_timeout=30.0)
            addr = await broker.start()
            try:
                return await run_federated_rounds_net(
                    np.zeros(P, np.float32), local_fns, apply_fn, addr,
                    rounds=R, chunk_words=16, pipeline=True)
            finally:
                await broker.stop()

        state, results = asyncio.run(go())
        assert len(results) == R
        # the launch/collect schedule of window 2: rounds 0 and 1 launch
        # from the initial state; round r>=2 launches after round r-2
        # folded — so round r's deltas use the state through round r-2
        folded = np.zeros(P, np.float32)
        exp_states = [np.zeros(P, np.float32)]
        for r in range(R):
            used = exp_states[max(0, r - 1)]
            deltas = np.stack([grads[nd] - 0.1 * used
                               for nd in range(1, n + 1)])
            avg = np.asarray(results[r].average)
            np.testing.assert_allclose(avg, deltas.mean(0), atol=2e-3)
            folded = folded + avg  # the PUBLISHED average, bit-exact
            exp_states.append(folded.copy())
        np.testing.assert_array_equal(state, folded)
        for r, res in enumerate(results):
            assert res.stats["aggregation_total"] == 4 * n, (r, res.stats)


class TestAutoStreamThreshold:
    """ISSUE 6/9 small-n regression fix: ``stream=None`` (the default)
    skips the chunk plane wholesale below ``wire.MIN_STREAM_WORDS``,
    where per-chunk round-trips and the get_chunk/consume handshake
    dominate and there is nothing to overlap — a payload that small
    rides one frame anyway. Either path is bit-identical."""

    def test_small_payload_auto_skips_chunk_plane(self):
        from repro.net import wire

        V = 103
        assert V < wire.MIN_STREAM_WORDS
        vals = _vals(4, V, seed=60)
        net = _wire_round(vals, chunk_words=16)  # stream unspecified
        assert net.streamed_combines == 0
        # not just buffered: zero chunk frames — the payload took the
        # single-frame plain ops (the ISSUE 9 small-n fast path)
        assert net.stats["chunk_frames_in"] == 0
        assert net.stats["chunk_frames_out"] == 0
        assert np.array_equal(run_safe_round(vals).average, net.average)

    def test_threshold_payload_auto_streams(self):
        from repro.net import wire

        V = wire.MIN_STREAM_WORDS  # exactly at the threshold: streams
        vals = _vals(4, V, seed=61)
        net = _wire_round(vals, chunk_words=4096)
        assert net.streamed_combines == 4 - 1
        assert np.array_equal(run_safe_round(vals).average, net.average)

    def test_force_flags_override_auto(self):
        from repro.net import wire

        vals = _vals(4, 103, seed=62)
        on = _wire_round(vals, chunk_words=16, stream=True)
        assert on.streamed_combines == 3  # forced despite tiny payload
        big = _vals(4, wire.MIN_STREAM_WORDS, seed=63)
        off = _wire_round(big, chunk_words=4096, stream=False)
        assert off.streamed_combines == 0  # disabled despite large


class TestShardRouting:
    """ISSUE 6 sharded broker: sessions consistently hashed to worker
    processes by session id (``shard_of``), misdirected ops answered
    with the §12 redirect, rounds bit-identical to the sim through
    every entry path (shared SO_REUSEPORT port, direct ports, the
    dispatcher fallback)."""

    BROKER_KW = dict(progress_timeout=0.4, monitor_interval=0.1,
                     aggregation_timeout=30.0)

    def test_shard_hash_stable_across_processes(self):
        """The routing table is a pure function of the session id: a
        fresh interpreter computes the identical mapping (workers never
        exchange routing state — this IS the consistency guarantee)."""
        import json
        import os
        import subprocess
        import sys

        from repro.net import shard_of

        local = [shard_of(s, 4) for s in range(64)]
        code = ("import json; from repro.net.shard import shard_of; "
                "print(json.dumps([shard_of(s, 4) for s in range(64)]))")
        env = dict(os.environ)
        src = os.path.join(os.path.dirname(__file__), "..", "src")
        env["PYTHONPATH"] = os.path.abspath(src)
        out = subprocess.run([sys.executable, "-c", code], env=env,
                             capture_output=True, text=True, timeout=60)
        assert out.returncode == 0, out.stderr
        assert json.loads(out.stdout) == local
        # owner allocation invariant: sid % shards == allocating shard
        assert all(shard_of(s, 4) == s % 4 for s in range(64))

    def test_sessions_pinned_to_one_shard(self):
        """Every op of a session is served by the worker that allocated
        it: the owner answers on its direct port, every OTHER worker
        answers the same op with a redirect naming the owner (it holds
        no state for the session)."""
        from repro.net import ShardedBroker, WireClient, shard_of

        async def go():
            sb = ShardedBroker(3, **self.BROKER_KW)
            addr = await sb.start()
            try:
                for k in range(3):
                    c = await WireClient(
                        addr[0], sb.shard_ports[k]).connect()
                    try:
                        created = await c.request("create_session", {
                            "groups": {0: [1, 2, 3]},
                            "aggregation_timeout": 5.0})
                        sid = created["session"]
                        # the allocator owns what it allocated, and
                        # advertises itself in the response
                        assert shard_of(sid, 3) == k
                        assert created["shard"] == k
                        assert created["port"] == sb.shard_ports[k]
                        for other in range(3):
                            if other == k:
                                continue
                            c2 = await WireClient(
                                addr[0], sb.shard_ports[other]).connect()
                            try:
                                # raw send/recv: observe the redirect
                                # itself (request() would follow it)
                                await c2._send("get_stats",
                                               {"session": sid})
                                res = await c2._recv("get_stats")
                                assert res["status"] == "redirect"
                                assert res["shard"] == k
                                assert res["port"] == sb.shard_ports[k]
                            finally:
                                await c2.close()
                        st = await c.request("get_stats", {"session": sid})
                        assert st["aggregation_total"] == 0
                        await c.request("delete_session", {"session": sid})
                    finally:
                        await c.close()
            finally:
                await sb.stop()

        asyncio.run(go())

    def test_wrong_shard_dial_completes_round_bit_identically(self):
        """Every learner dials the WRONG worker's direct port; the §12
        redirect settles each onto the owner after one bounce and the
        round completes — same bits as the sim, same §5 closed form."""
        from repro.core.machines import build_round_machines
        from repro.net import ShardedBroker, WireClient, shard_of
        from repro.net.client import drive_learner
        from repro.topology import RingTopology

        n, V = 4, 16
        vals = _vals(n, V, seed=50)

        async def go():
            sb = ShardedBroker(2, **self.BROKER_KW)
            addr = await sb.start()
            try:
                topo = RingTopology(n, 1)
                groups = topo.group_chains(node_base=1)
                initiators = {r + 1 for r in topo.elect_initiators()}
                machines = build_round_machines(
                    vals, topo, groups, initiators)
                admin = await WireClient(
                    addr[0], sb.shard_ports[0]).connect()
                try:
                    created = await admin.request("create_session", {
                        "groups": groups, "aggregation_timeout": 30.0})
                    sid = created["session"]
                    owner = shard_of(sid, 2)
                    wrong = sb.shard_ports[1 - owner]

                    async def drive(node, gen):
                        c = await WireClient(
                            addr[0], wrong, node=node,
                            token=admin.node_tokens[node]).connect()
                        try:
                            await drive_learner(
                                gen, c, sid,
                                aggregation_timeout=created[
                                    "aggregation_timeout"])
                            # the redirect moved this client's socket to
                            # the owning worker's direct port
                            assert c.port == sb.shard_ports[owner]
                        finally:
                            await c.close()

                    await asyncio.gather(
                        *(drive(nd, gen)
                          for nd, gen in machines.items()))
                    stats = await admin.request(
                        "get_stats", {"session": sid})
                    final = await admin.request(
                        "peek_average", {"session": sid})
                    await admin.request(
                        "delete_session", {"session": sid})
                    return stats, final
                finally:
                    await admin.close()
            finally:
                await sb.stop()

        stats, final = asyncio.run(go())
        sim = run_safe_round(vals)
        assert np.array_equal(sim.average, final["average"])
        assert stats["aggregation_total"] == 4 * n

    def test_rounds_via_shared_port_and_dispatcher(self):
        """Full rounds through both shared-port flavours: SO_REUSEPORT
        (kernel spreads first contacts) and the accept-and-hand-off
        dispatcher (``use_reuseport=False``) — bit-identical, closed
        form intact, and consecutive rounds land on distinct shards
        (the sid stride walks the workers)."""
        from repro.net import ShardedBroker

        vals = _vals(6, 16, seed=51)
        sim = run_safe_round(vals)

        async def go(use_reuseport):
            sb = ShardedBroker(2, use_reuseport=use_reuseport,
                               **self.BROKER_KW)
            addr = await sb.start()
            try:
                return [await run_safe_round_net(vals, addr)
                        for _ in range(2)]
            finally:
                await sb.stop()

        for use_reuseport in (True, False):
            for net in asyncio.run(go(use_reuseport)):
                assert np.array_equal(sim.average, net.average)
                assert net.stats["aggregation_total"] == 4 * 6

    def test_get_shard_map_single_broker(self):
        """The op is additive (§9): an UNsharded broker answers it too,
        reporting a one-shard world — clients need no capability probe."""

        async def go():
            broker = SafeBroker(**self.BROKER_KW)
            addr = await broker.start()
            try:
                from repro.net import WireClient

                c = await WireClient(*addr).connect()
                try:
                    return await c.request("get_shard_map", {})
                finally:
                    await c.close()
            finally:
                await broker.stop()

        m = asyncio.run(go())
        assert m == {"shards": 1, "shard": 0, "ports": [],
                     "shard_alive": [True]}


class _FakeEngineSession:
    def __init__(self, sid, values, rounds):
        self.sid = sid
        self.values = values
        self.rounds = rounds
        self.results = []
        self.rounds_done = 0

    @property
    def done(self):
        return self.rounds_done >= self.rounds


class _FakeEngine:
    """In-process numpy stand-in for serve.AggregationEngine exposing
    exactly the surface the broker drives (submit/step/queue/active/
    on_complete, n, V) — lets the engine-plane chunk routing be tested
    without jax or a device mesh."""

    def __init__(self, n, V):
        self.n, self.V = n, V
        self.queue = []
        self._sids = iter(range(1 << 30))
        self.on_complete = None

    @property
    def active(self):
        return 0

    def submit(self, values, *, rounds=1, **kw):
        if values.shape != (self.n, self.V):
            raise ValueError(f"values shape {values.shape} != "
                             f"({self.n}, {self.V})")
        sess = _FakeEngineSession(next(self._sids), values, rounds)
        self.queue.append(sess)
        return sess

    def step(self):
        if not self.queue:
            return 0
        sess = self.queue.pop(0)
        while not sess.done:
            sess.results.append(sess.values.mean(0))
            sess.rounds_done += 1
        if self.on_complete is not None:
            self.on_complete(sess)
        return 1


class TestEngineChunked:
    """ISSUE 4 satellite: oversized engine payloads route over the §6
    chunk plane instead of being refused at submit time."""

    def test_chunked_submit_and_wait_roundtrip(self):
        async def go():
            n, V = 4, 1000
            broker = SafeBroker(engine=_FakeEngine(n, V))
            addr = await broker.start()
            try:
                from repro.net import WireClient

                c = await WireClient(*addr).connect()
                vals = _vals(n, V, seed=50)
                sub = await c.submit_session_chunked(
                    {"values": vals, "rounds": 3}, chunk_words=256)
                res = await c.wait_session_chunked(
                    sub["sid"], timeout=30.0, chunk_words=512)
                assert res["status"] == "done" and res["rounds"] == 3
                for r in res["results"]:
                    assert np.array_equal(r, vals.mean(0))
                # idempotent re-fetch until the TTL prune
                again = await c.wait_session_chunked(
                    sub["sid"], timeout=5.0, chunk_words=512)
                assert again["status"] == "done"
                assert np.array_equal(again["results"][0], vals.mean(0))
                assert broker.engine_chunk_frames_in > 0
                assert broker.engine_chunk_frames_out > 0
                await c.close()
            finally:
                await broker.stop()

        asyncio.run(go())

    def test_oversized_plain_wait_refused_with_guidance(self, monkeypatch):
        """A result set beyond one frame is no longer refused at submit:
        submission succeeds, the UNCHUNKED wait errors with a pointer to
        the chunked fetch, and the chunked fetch delivers."""
        from repro.net import WireClient, wire as _w

        async def go():
            n, V = 4, 1000
            broker = SafeBroker(engine=_FakeEngine(n, V))
            addr = await broker.start()
            try:
                c = await WireClient(*addr).connect()
                vals = _vals(n, V, seed=51)
                # rounds*V*4 = 80 KB > the shrunken 64 KiB frame cap
                monkeypatch.setattr(_w, "MAX_FRAME", 1 << 16)
                sub = await c.request("submit_session",
                                      {"values": vals, "rounds": 20})
                with pytest.raises(_w.WireError, match="chunked"):
                    await c.request("wait_session",
                                    {"sid": sub["sid"], "timeout": 30.0})
                res = await c.wait_session_chunked(
                    sub["sid"], timeout=30.0, chunk_words=1024)
                assert res["status"] == "done" and res["rounds"] == 20
                assert np.array_equal(res["results"][19], vals.mean(0))
                await c.close()
            finally:
                await broker.stop()

        asyncio.run(go())

    def test_chunked_submit_repeat_final_chunk_idempotent(self):
        """A re-sent final submit chunk re-acks the SAME sid — never a
        second engine session (PROTOCOL.md §6 repeat rule, engine
        flavour)."""
        from repro.net import WireClient

        async def go():
            n, V = 2, 64
            eng = _FakeEngine(n, V)
            broker = SafeBroker(engine=eng)
            addr = await broker.start()
            try:
                c = await WireClient(*addr).connect()
                vals = _vals(n, V, seed=52)
                flat = vals.ravel()

                def frame(seq):
                    return {"op": "submit_session", "node": 9, "xfer": 3,
                            "seq": seq, "total": 2, "chunk_words": 64,
                            "rounds": 1,
                            "payload": flat[seq * 64:(seq + 1) * 64]}

                r0 = await c.request("post_chunk", frame(0))
                assert not r0["complete"]
                r1 = await c.request("post_chunk", frame(1))
                assert r1["complete"]
                r1b = await c.request("post_chunk", frame(1))  # repeat
                assert r1b["complete"] and r1b["sid"] == r1["sid"]
                assert len(broker._engine_sessions) == 1
                await c.close()
            finally:
                await broker.stop()

        asyncio.run(go())


ENGINE_WIRE_CODE = """
import asyncio, numpy as np, jax
from repro.core.types import ChainConfig
from repro.serve import AggregationEngine
from repro.net import SafeBroker, WireClient

mesh = jax.make_mesh((8,), ("data",))
n, V, S = 8, 32, 4
cfg = ChainConfig(num_learners=n, mode="safe")
engine = AggregationEngine(mesh, cfg, slots=S, payload_words=V)
rng = np.random.RandomState(0)

async def go():
    broker = SafeBroker(engine=engine)
    addr = await broker.start()
    try:
        clients = [await WireClient(*addr, node=t).connect()
                   for t in range(S)]
        tenant_vals = [rng.uniform(-1, 1, (n, V)).astype(np.float32)
                       for _ in range(S)]
        sids = []
        for t, c in enumerate(clients):
            sub = await c.request("submit_session", {
                "values": tenant_vals[t], "rounds": 2,
                "provisioning_seed": 0xC0FFEE + t,
                "learner_master": 0x5EED + t})
            sids.append(sub["sid"])
        for t, c in enumerate(clients):
            res = await c.request("wait_session",
                                  {"sid": sids[t], "timeout": 300.0})
            assert res["status"] == "done", res
            assert res["rounds"] == 2
            exp = tenant_vals[t].mean(0)
            for r in res["results"]:
                assert np.abs(r - exp).max() < 1e-3
        # wait_session is an idempotent read until the TTL prune: a
        # client whose first response was lost can re-fetch its results
        again = await clients[0].request("wait_session",
                                         {"sid": sids[0], "timeout": 1.0})
        assert again["status"] == "done" and again["rounds"] == 2
        # abandoned submissions (never waited on) are pruned after the
        # TTL instead of pinning their AggSession forever
        broker.engine_session_ttl = 0.0
        sub = await clients[0].request("submit_session", {
            "values": tenant_vals[0], "rounds": 1})
        abandoned = sub["sid"]
        # never waited on: the engine completes it, then the monitor's
        # TTL prune (ttl=0) must drop it without any further submits
        for _ in range(200):
            if (abandoned not in broker._engine_sessions
                    and abandoned not in broker._engine_done):
                break
            await asyncio.sleep(0.1)
        assert abandoned not in broker._engine_sessions, "abandoned session not pruned"
        broker.engine_session_ttl = 300.0  # new sessions must survive
        sub2 = await clients[0].request("submit_session", {
            "values": tenant_vals[0], "rounds": 1})
        res = await clients[0].request("wait_session",
                                       {"sid": sub2["sid"], "timeout": 300.0})
        assert res["status"] == "done"
        for c in clients:
            await c.close()
    finally:
        await broker.stop()

asyncio.run(go())
print("ENGINE_WIRE_OK")
"""


def test_engine_plane_over_wire():
    """S wire tenants batch through one AggregationEngine behind the
    broker (submit_session/wait_session), results correct per tenant."""
    out = run_multidevice(ENGINE_WIRE_CODE, devices=8)
    assert "ENGINE_WIRE_OK" in out


class TestObservability:
    """ISSUE 7: the metrics plane (PROTOCOL.md §13) — live snapshots,
    admission control, shard-death visibility, adaptive chunking and
    the deterministic backoff helper."""

    BROKER_KW = dict(progress_timeout=2.0, monitor_interval=0.5,
                     aggregation_timeout=30.0)

    def test_metrics_monotonic_and_uncounted(self):
        """Counters rise monotonically round over round; polling
        ``get_metrics`` mid-stream never perturbs the §5 closed form
        (admin-class: uncounted, untimed)."""
        from repro.net import PersistentNetSession, WireClient

        n, V = 4, 64
        vals = _vals(n, V, seed=70)

        async def go():
            broker = SafeBroker(**self.BROKER_KW)
            addr = await broker.start()
            try:
                mc = await WireClient(*addr).connect()
                sess = PersistentNetSession(addr, n, words_per_round=V)
                await sess.open()
                try:
                    m0 = await mc.request("get_metrics", {})
                    r1 = await sess.run_round(vals)
                    m1 = await mc.request("get_metrics", {})
                    for _ in range(5):  # free polls between rounds
                        await mc.request("get_metrics", {})
                    r2 = await sess.run_round(vals)
                    m2 = await mc.request("get_metrics",
                                          {"session": sess.sid})
                    return r1, r2, m0, m1, m2, sess.sid
                finally:
                    await sess.close()
                    await mc.close()
            finally:
                await broker.stop()

        r1, r2, m0, m1, m2, sid = asyncio.run(go())
        # snapshots are invisible to MessageStats: exact closed form
        assert r1.stats["aggregation_total"] == 4 * n
        assert r2.stats["aggregation_total"] == 4 * n
        assert np.array_equal(r1.average,
                              run_safe_round(vals).average)
        assert (m0["rounds_completed"], m1["rounds_completed"],
                m2["rounds_completed"]) == (0, 1, 2)
        hists = [m["series"]["histograms"]["safe_round_latency_seconds"]
                 for m in (m0, m1, m2)]
        assert [h["count"] for h in hists] == [0, 1, 2]
        assert 0.0 < m2["round_latency_p50_s"] <= m2["round_latency_p99_s"]
        for key in ("safe_rounds_completed_total",
                    "safe_chunk_frames_in_total"):
            series = [m["series"]["counters"][key] for m in (m0, m1, m2)]
            assert series == sorted(series), (key, series)
        # per-session view for the (still-open) tenant, narrowed by sid
        assert list(m2["sessions"]) == [sid]
        assert m2["sessions"][sid]["rounds_completed"] == 2
        assert m2["sessions"][sid]["chunk_backlog_bytes"] == 0
        assert m2["active_sessions"] == 1
        assert m2["rounds_per_s"] > 0

    def test_metrics_snapshot_schema(self):
        """The wire snapshot keeps a stable shape — dashboards and the
        SLO harness key into it."""
        from repro.net import WireClient

        async def go():
            broker = SafeBroker(**self.BROKER_KW)
            addr = await broker.start()
            try:
                await run_safe_round_net(_vals(4, 16, seed=71), addr)
                c = await WireClient(*addr).connect()
                try:
                    return await c.request("get_metrics", {})
                finally:
                    await c.close()
            finally:
                await broker.stop()

        m = asyncio.run(go())
        required = {
            "uptime_s", "shard", "shards", "rounds_completed",
            "rounds_per_s", "round_latency_p50_s", "round_latency_p99_s",
            "monitor_reposts", "initiator_elections", "busy_rejections",
            "redirects", "chunk_backlog_bytes", "active_sessions",
            "sessions", "series", "trace_spans"}
        assert required <= set(m), required - set(m)
        s = m["series"]
        assert set(s) == {"counters", "gauges", "histograms"}
        assert all(isinstance(v, int) for v in s["counters"].values())
        assert all(isinstance(v, float) for v in s["gauges"].values())
        h = s["histograms"]["safe_round_latency_seconds"]
        assert set(h) == {"count", "sum", "p50", "p99", "buckets"}
        # buckets are [bound, count] pairs ending at +Inf
        assert h["buckets"][-1][0] == float("inf")
        assert sum(b[1] for b in h["buckets"]) == h["count"] == 1
        # the transient round's session is gone again
        assert m["active_sessions"] == 0 and m["sessions"] == {}

    def test_flooding_tenant_busy_shed_bit_identical(self):
        """Admission control: a one-chunk budget forces the second
        parallel §5.5 group chain into busy/retry-after; the client's
        backoff loop replays it and the round still completes with the
        exact ``4n + g`` closed form, bit-identical to the sim."""
        n, V, chunk = 6, 2048, 128
        vals = _vals(n, V, seed=72)
        sim = run_safe_round(vals, subgroups=2)
        net = _wire_round(
            vals, subgroups=2, chunk_words=chunk, stream=False,
            broker_kw=dict(chunk_budget_bytes=chunk * 4,
                           progress_timeout=2.0, monitor_interval=0.5))
        assert np.array_equal(sim.average, net.average)
        assert net.stats["aggregation_total"] == 4 * n + 2
        assert net.stats["busy_rejections"] > 0

    def test_busy_never_triggers_with_ample_budget(self):
        """The default budget never sheds a well-behaved tenant — the
        steady-profile SLO baseline in miniature."""
        vals = _vals(6, 2048, seed=73)
        net = _wire_round(vals, subgroups=2, chunk_words=128, stream=False)
        assert net.stats["aggregation_total"] == 4 * 6 + 2
        assert net.stats["busy_rejections"] == 0

    def test_http_metrics_exporter(self):
        """GET /metrics answers Prometheus text; other paths 404."""

        async def go():
            broker = SafeBroker(**self.BROKER_KW)
            addr = await broker.start()
            haddr = await broker.start_metrics_http()
            try:
                await run_safe_round_net(_vals(4, 16, seed=74), addr)

                async def get(path):
                    r, w = await asyncio.open_connection(*haddr)
                    w.write(f"GET {path} HTTP/1.0\r\n\r\n".encode())
                    await w.drain()
                    body = (await r.read()).decode()
                    w.close()
                    return body

                ok = await get("/metrics")
                missing = await get("/nope")
                return ok, missing
            finally:
                await broker.stop()

        ok, missing = asyncio.run(go())
        assert ok.startswith("HTTP/1.0 200")
        assert 'safe_rounds_completed_total{shard="0"} 1' in ok
        assert 'safe_round_latency_seconds_bucket{shard="0",le="+Inf"} 1' in ok
        assert "# TYPE safe_round_latency_seconds histogram" in ok
        assert missing.startswith("HTTP/1.0 404")

    def test_shard_death_visible_and_survivors_serve(self):
        """Killing a worker marks it dead in ``get_shard_map``, fails
        its sessions fast with a clear error, and leaves sessionless
        traffic flowing to the survivors."""
        from repro.net import ShardedBroker, WireClient, wire as _w

        async def go():
            sb = ShardedBroker(2, use_reuseport=False, **self.BROKER_KW)
            addr = await sb.start()
            try:
                loop = asyncio.get_running_loop()
                sb._procs[0].terminate()
                await loop.run_in_executor(None, sb._procs[0].join, 10.0)
                assert not sb._procs[0].is_alive()
                c = await WireClient(*addr).connect()
                try:
                    m = await c.request("get_shard_map", {})
                    assert m["shard_alive"] == [False, True]
                    assert m["shard_deaths"] == 1
                    # session ops owned by the dead shard fail fast
                    # with a diagnosis, not a hang (sid 0 -> shard 0)
                    try:
                        await c.request("get_stats", {"session": 0})
                        raise AssertionError("expected WireError")
                    except _w.WireError as e:
                        assert "dead" in str(e)
                finally:
                    await c.close()
                # new sessions land on the live shard and run clean
                res = await run_safe_round_net(_vals(4, 8, seed=75), addr)
                assert res.stats["aggregation_total"] == 4 * 4
                assert sb.shard_deaths == 1
                return True
            finally:
                await sb.stop()

        assert asyncio.run(go())

    def test_backoff_delay_deterministic_and_capped(self):
        from repro.net import backoff_delay

        seq = [backoff_delay(a, base=0.02, seed=3) for a in range(12)]
        assert seq == [backoff_delay(a, base=0.02, seed=3)
                       for a in range(12)]  # replayable
        for a, d in enumerate(seq):
            hi = min(0.5, 0.02 * 2 ** a)
            assert 0.5 * hi <= d < hi  # jittered into [0.5, 1.0)*hi
        # capped, and huge attempt counts do not overflow the shift
        assert backoff_delay(10_000, base=0.02, seed=1) <= 0.5
        # co-tenants (different seeds) desynchronize
        assert any(backoff_delay(a, base=0.02, seed=1)
                   != backoff_delay(a, base=0.02, seed=2)
                   for a in range(4))

    def test_auto_chunk_words_quantized(self):
        from repro.net import auto_chunk_words, wire as _w

        for pw in (1, 1000, _w.MIN_STREAM_WORDS, 100_000, 1 << 20,
                   1 << 23, 1 << 26):
            aw = auto_chunk_words(pw)
            assert aw % _w.MIN_STREAM_WORDS == 0
            assert _w.MIN_STREAM_WORDS <= aw <= _w.DEFAULT_CHUNK_WORDS
        # small payloads come back whole (no chunk overhead)
        assert auto_chunk_words(1024) >= 1024
        # the legacy None path (> AUTO_CHUNK_WORDS) is preserved
        from repro.net.client import AUTO_CHUNK_WORDS, _resolve_chunk_words
        assert auto_chunk_words(1 << 26) == _w.DEFAULT_CHUNK_WORDS
        assert (_resolve_chunk_words(None, AUTO_CHUNK_WORDS + 1)
                == _w.DEFAULT_CHUNK_WORDS)
        assert _resolve_chunk_words(None, 64) is None
        assert _resolve_chunk_words(256, 1 << 26) == 256


class TestAuth:
    """PROTOCOL.md §15 session tokens: every sessioned op must present
    the opaque token minted at ``create_session``; denials come back as
    counted-neutral ``auth_failed`` responses (never in MessageStats,
    never timed), and ``reset_round`` rotates the whole grant."""

    BROKER_KW = dict(progress_timeout=0.4, monitor_interval=0.1,
                     aggregation_timeout=30.0)

    def test_tokenless_and_wrong_token_rejected(self):
        from repro.net import WireClient

        async def go():
            broker = SafeBroker(**self.BROKER_KW)
            addr = await broker.start()
            admin = await WireClient(*addr).connect()
            anon = await WireClient(*addr).connect()  # never gets a token
            try:
                grant = await admin.request(
                    "create_session", {"groups": {0: [1, 2, 3]}})
                sid = grant["session"]
                assert grant["token"]
                assert set(grant["node_tokens"]) == {1, 2, 3}
                assert len(set(grant["node_tokens"].values())) == 3

                # token-less op: rejected, with the op echoed back
                r_missing = await anon.request("get_stats",
                                               {"session": sid})
                # made-up token: rejected
                r_unknown = await anon.request(
                    "get_stats", {"session": sid, "token": "f" * 32})
                # node 1's token cannot act as node 2 (identity check)
                r_imperson = await anon.request(
                    "post_aggregate",
                    {"session": sid, "token": grant["node_tokens"][1],
                     "from_node": 2})
                # node tokens cannot run admin-only ops
                r_admin_op = await anon.request(
                    "reset_round",
                    {"session": sid, "token": grant["node_tokens"][1]})
                # ...while its own identity is fine at the auth layer
                # (short long-poll timeout: nothing is addressed to
                # node 2, the point is it gets PAST auth)
                r_self = await anon.request(
                    "check_aggregate",
                    {"session": sid, "token": grant["node_tokens"][2],
                     "node": 2, "timeout": 0.2})
                stats = await admin.request("get_stats", {"session": sid})
            finally:
                await anon.close()
                await admin.close()
                await broker.stop()
            return r_missing, r_unknown, r_imperson, r_admin_op, r_self, stats

        (r_missing, r_unknown, r_imperson, r_admin_op, r_self,
         stats) = asyncio.run(go())
        for r, why in ((r_missing, "missing"), (r_unknown, "unknown"),
                       (r_imperson, "node 1"), (r_admin_op, "admin")):
            assert r["status"] == "auth_failed", (why, r)
        assert r_missing["op"] == "get_stats"
        assert r_self.get("status") != "auth_failed", r_self
        # counted-neutral: four denials, zero protocol messages
        assert stats["auth_failures"] == 4
        assert stats["aggregation_total"] == 0

    def test_reset_round_rotates_tokens(self):
        """A captured token is worthless after ``reset_round``: the
        whole grant (admin + per-node) is re-minted, replaying the stale
        one is an ``auth_failed``, and the fresh grant works."""
        from repro.net import WireClient

        async def go():
            broker = SafeBroker(**self.BROKER_KW)
            addr = await broker.start()
            admin = await WireClient(*addr).connect()
            try:
                grant = await admin.request(
                    "create_session", {"groups": {0: [1, 2]}})
                sid = grant["session"]
                stale = grant["token"]
                stale_node = grant["node_tokens"][1]
                # WireClient adopts the rotated grant from the response
                grant2 = await admin.request("reset_round",
                                             {"session": sid})
                assert grant2["token"] != stale
                assert grant2["node_tokens"][1] != stale_node
                assert admin.token == grant2["token"]
                r_stale = await admin.request(
                    "get_stats", {"session": sid, "token": stale})
                r_stale_node = await admin.request(
                    "should_initiate",
                    {"session": sid, "token": stale_node, "node": 1})
                r_fresh = await admin.request("get_stats",
                                              {"session": sid})
            finally:
                await admin.close()
                await broker.stop()
            return r_stale, r_stale_node, r_fresh

        r_stale, r_stale_node, r_fresh = asyncio.run(go())
        assert r_stale["status"] == "auth_failed"
        assert r_stale_node["status"] == "auth_failed"
        assert r_fresh.get("status") != "auth_failed"
        assert r_fresh["auth_failures"] == 2

    def test_full_round_under_auth_is_unchanged(self):
        """The token plumbing is invisible to an honest round: same
        closed form, same bits as the sim (the §15 counted-neutral
        rule, asserted end-to-end)."""
        vals = _vals(4, 16, seed=91)
        sim = run_safe_round(vals)
        net = _wire_round(vals)
        assert np.array_equal(sim.average, net.average)
        assert net.stats["aggregation_total"] == 4 * 4
        assert net.stats["auth_failures"] == 0


class TestTLS:
    """Optional TLS on the broker listener (PROTOCOL.md §15): same
    protocol, same bits, over an encrypted transport."""

    def _certs(self, tmp_path):
        import shutil
        import subprocess

        openssl = shutil.which("openssl")
        if openssl is None:
            pytest.skip("openssl not available for self-signed certs")
        cert, key = tmp_path / "cert.pem", tmp_path / "key.pem"
        subprocess.run(
            [openssl, "req", "-x509", "-newkey", "rsa:2048",
             "-keyout", str(key), "-out", str(cert), "-days", "1",
             "-nodes", "-subj", "/CN=localhost"],
            check=True, capture_output=True)
        return str(cert), str(key)

    def test_tls_round_bit_identical(self, tmp_path):
        import ssl

        cert, key = self._certs(tmp_path)
        vals = _vals(4, 16, seed=92)

        async def go():
            broker = SafeBroker(progress_timeout=0.4, monitor_interval=0.1,
                                aggregation_timeout=30.0,
                                ssl_certfile=cert, ssl_keyfile=key)
            addr = await broker.start()
            ctx = ssl.SSLContext(ssl.PROTOCOL_TLS_CLIENT)
            ctx.check_hostname = False
            ctx.verify_mode = ssl.CERT_NONE  # self-signed test cert
            try:
                return await run_safe_round_net(vals, addr, ssl=ctx)
            finally:
                await broker.stop()

        res = asyncio.run(go())
        sim = run_safe_round(vals)
        assert np.array_equal(sim.average, res.average)
        assert res.stats["aggregation_total"] == 4 * 4

    def test_plaintext_client_cannot_speak_to_tls_broker(self, tmp_path):
        from repro.net import ShardDeadError, WireClient, wire as _w

        cert, key = self._certs(tmp_path)

        async def go():
            broker = SafeBroker(progress_timeout=0.4, monitor_interval=0.1,
                                aggregation_timeout=30.0,
                                ssl_certfile=cert, ssl_keyfile=key)
            addr = await broker.start()
            try:
                c = await WireClient(*addr).connect()  # no ssl context
                try:
                    await asyncio.wait_for(
                        c.request("get_metrics", {}), timeout=5.0)
                    return None
                except (ShardDeadError, _w.WireError, ConnectionError,
                        OSError, asyncio.TimeoutError, EOFError) as e:
                    return e
                finally:
                    await c.close()
            finally:
                await broker.stop()

        assert asyncio.run(go()) is not None


class TestHierarchicalWire:
    """§5.10 chain-of-chains across two real brokers (parent + child
    host): per-level closed forms and sim↔wire bit-identity. The full
    fault matrix lives in tests/test_conformance.py."""

    def _round(self, vals, orgs, **kw):
        from repro.net import run_hierarchical_round_net

        parent_timeout = kw.pop("parent_timeout", 30.0)
        child_agg = kw.pop("aggregation_timeout", 30.0)

        async def go():
            parent = SafeBroker(aggregation_timeout=30.0,
                                progress_timeout=0.4, monitor_interval=0.1)
            child = SafeBroker(aggregation_timeout=child_agg,
                               progress_timeout=0.4, monitor_interval=0.1)
            paddr = await parent.start()
            caddr = await child.start()
            try:
                return await run_hierarchical_round_net(
                    vals, paddr, {g: caddr for g in range(orgs)},
                    aggregation_timeout=child_agg,
                    parent_timeout=parent_timeout, **kw)
            finally:
                await parent.stop()
                await child.stop()

        return asyncio.run(go())

    def test_clean_two_orgs_matches_sim_and_flat(self):
        from repro.core.protocol import run_hierarchical_round_sim

        vals = _vals(8, 16, seed=93)
        res = self._round(vals, 2)
        sim = run_hierarchical_round_sim(vals, orgs=2)
        flat = run_safe_round(vals, subgroups=2)
        for g in (0, 1):
            assert res.org_results[g].stats["aggregation_total"] == 4 * 4 + 1
            assert np.array_equal(res.org_averages[g],
                                  sim.org_averages[g])
        assert res.parent_stats["hierarchy_total"] == 2 * 2
        assert res.parent_stats["post_org_average"] == 2
        assert res.parent_stats["get_org_average"] == 2
        assert res.elided_orgs == ()
        assert np.array_equal(res.average, sim.average)
        assert np.array_equal(res.average, flat.average)

    def test_whole_org_elided_like_a_dead_learner(self):
        from repro.core.protocol import run_hierarchical_round_sim

        vals = _vals(8, 16, seed=94)
        res = self._round(vals, 2, failed_orgs=(1,), parent_timeout=1.5)
        sim = run_hierarchical_round_sim(vals, orgs=2, failed_orgs=(1,))
        assert res.elided_orgs == (1,)
        assert res.parent_stats["crashed_orgs"] == [1]
        assert res.parent_stats["hierarchy_total"] == 2 * 1
        assert np.array_equal(res.average, sim.average)
        # the surviving org ran its full chain untouched
        assert res.org_results[0].stats["aggregation_total"] == 4 * 4 + 1


class TestShardFailover:
    """§12 dead-shard recovery end-to-end: kill a worker mid-tenant,
    the stranded tenant sees a deterministic ``ShardDeadError``, and
    the replayed round (fresh session on a live shard, same seeds and
    counter) is bit-identical to the sim. The harness itself asserts
    the closed forms and bit-identity per round."""

    def test_kill_worker_mid_tenant_recovers_bit_identical(self):
        from repro.net import run_shard_failover_load

        row = asyncio.run(run_shard_failover_load(
            tenants=3, rounds_per_tenant=2, n=4, V=32, shards=2))
        # the dispatcher round-robins 3 sessions over 2 shards, so the
        # killed shard owned >= 1: the recovery path MUST have fired
        assert row["recoveries"] >= 1
        assert row["rounds_completed"] == 6
        assert row["killed_shard"] == 0
