"""Wire plane end-to-end: the SAFE state machines over a real asyncio
transport. Acceptance (ISSUE 2): for the same seeds/topology the
published average over the wire is bit-identical to the discrete-event
sim, and MessageStats matches §5's closed forms for n ∈ {4, 8} with and
without an injected failure. Plus: faults (latency/drop/churn),
re-election, the engine plane, the broker's counter hygiene, and the
chunked transfer plane of docs/PROTOCOL.md §6 (boundary sizes,
single-chunk fallback, reordered/duplicate chunks, drops mid-stream,
crash mid-upload).

Every test runs under a hard SIGALRM deadline (autouse fixture) so a
hung broker or lost long-poll aborts the test instead of stalling the
whole tier-1 run.
"""
import asyncio
import signal

import numpy as np
import pytest
from helpers import run_multidevice

from repro.core.protocol import run_safe_round
from repro.net import (
    Chain,
    ChurnInterceptor,
    DropInterceptor,
    LatencyInterceptor,
    SafeBroker,
    run_safe_round_net,
)

#: per-test wall deadline (seconds). The slowest in-process paths below
#: are the re-election tests (~1x aggregation_timeout + a second round);
#: 90 s leaves an order of magnitude of headroom without letting a hang
#: stall tier-1. Tests that spawn a jax subprocess (fresh import +
#: 8-device compile) get the larger budget, aligned with
#: helpers.run_multidevice's own timeout.
NET_TEST_DEADLINE_S = 90
SUBPROCESS_DEADLINE_S = 900
_SUBPROCESS_TESTS = {"test_engine_plane_over_wire"}


@pytest.fixture(autouse=True)
def _hard_deadline(request):
    """Per-test timeout: a hung broker/long-poll raises instead of
    hanging pytest (no pytest-timeout in the container)."""
    deadline = (SUBPROCESS_DEADLINE_S
                if request.node.name in _SUBPROCESS_TESTS
                else NET_TEST_DEADLINE_S)

    def _expired(signum, frame):
        raise TimeoutError(
            f"net test exceeded {deadline}s hard deadline")

    old = signal.signal(signal.SIGALRM, _expired)
    signal.alarm(deadline)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)


def _vals(n, V, seed=0):
    return np.random.RandomState(seed).uniform(-1, 1, (n, V)).astype(np.float32)


def _wire_round(values, *, broker_kw=None, **round_kw):
    """Start a fresh broker, run one round over TCP, tear down."""

    async def go():
        broker = SafeBroker(**dict(
            dict(progress_timeout=0.4, monitor_interval=0.1,
                 aggregation_timeout=30.0), **(broker_kw or {})))
        addr = await broker.start()
        try:
            return await run_safe_round_net(values, addr, **round_kw)
        finally:
            await broker.stop()

    return asyncio.run(go())


class TestSimEquivalence:
    """Same seeds, same topology ⇒ same bits, same message counts."""

    @pytest.mark.parametrize("n", [4, 8])
    def test_bit_identical_no_failure(self, n):
        vals = _vals(n, 16, seed=n)
        sim = run_safe_round(vals)
        net = _wire_round(vals)
        assert np.array_equal(sim.average, net.average)  # bit-identical
        assert net.stats["aggregation_total"] == 4 * n
        assert sim.stats.aggregation_total == 4 * n
        # per-op counters agree too
        for op in ("post_aggregate", "check_aggregate", "get_aggregate",
                   "post_average", "get_average", "should_initiate"):
            assert net.stats[op] == getattr(sim.stats, op), op

    @pytest.mark.parametrize("n", [4, 8])
    def test_bit_identical_with_failure(self, n):
        """One dead learner: §5.3 closed form 4(n−f) + 2f, f=1."""
        vals = _vals(n, 16, seed=10 + n)
        sim = run_safe_round(vals, failed_nodes=[3])
        net = _wire_round(vals, failed_nodes=[3])
        assert np.array_equal(sim.average, net.average)
        expected = 4 * (n - 1) + 2
        assert sim.stats.aggregation_total == expected
        assert net.stats["aggregation_total"] == expected
        assert net.monitor_reposts == 1
        mask = np.ones(n, bool)
        mask[2] = False
        np.testing.assert_allclose(net.average, vals[mask].mean(0), atol=1e-3)

    def test_adjacent_failures(self):
        vals = _vals(8, 8, seed=3)
        sim = run_safe_round(vals, failed_nodes=[4, 5])
        net = _wire_round(vals, failed_nodes=[4, 5])
        assert np.array_equal(sim.average, net.average)
        assert net.stats["aggregation_total"] == 4 * 6 + 2 * 2
        assert net.monitor_reposts == 2

    def test_subgroups_closed_form(self):
        """§5.5: 4n + g messages, average of group averages."""
        vals = _vals(8, 8, seed=4)
        sim = run_safe_round(vals, subgroups=2)
        net = _wire_round(vals, subgroups=2)
        assert np.array_equal(sim.average, net.average)
        assert net.stats["aggregation_total"] == 4 * 8 + 2
        assert sim.stats.aggregation_total == 4 * 8 + 2

    def test_weighted_bit_identical(self):
        vals = _vals(6, 8, seed=5)
        w = np.array([1000, 200, 3000, 500, 800, 1500], np.float32)
        sim = run_safe_round(vals, weights=w)
        net = _wire_round(vals, weights=w)
        assert np.array_equal(sim.average, net.average)
        assert float(sim.weight_avg) == float(net.weight_avg)

    def test_saf_mode(self):
        vals = _vals(5, 8, seed=6)
        sim = run_safe_round(vals, mode="saf")
        net = _wire_round(vals, mode="saf")
        assert np.array_equal(sim.average, net.average)


class TestFaults:
    def test_latency_and_drops_do_not_change_the_answer(self):
        """Transport faults perturb timing, never semantics: the codec +
        retry path must keep the bits and the §5.2 count intact (drops
        happen before the broker sees the frame, so no double count)."""
        vals = _vals(8, 16, seed=7)
        sim = run_safe_round(vals)
        drop = DropInterceptor(p=0.1, seed=3)
        net = _wire_round(vals, interceptor=Chain(
            LatencyInterceptor(mean=0.002, seed=7), drop))
        assert np.array_equal(sim.average, net.average)
        assert net.stats["aggregation_total"] == 4 * 8
        assert drop.dropped > 0  # the fault plan actually fired

    def test_churn_crash_lost_aggregate_reelects(self):
        """A learner crashes *between* consuming the running aggregate
        and reposting it (the worst §5.4 case: the aggregate is lost).
        The round times out, a survivor is re-elected, and the retry
        publishes the survivors' average — bit-identical to a sim where
        that node was dead all along (ring addition commutes)."""
        vals = _vals(8, 16, seed=8)
        churn = ChurnInterceptor({5: 1})  # dies before its post_aggregate
        net = _wire_round(
            vals, interceptor=churn,
            broker_kw=dict(aggregation_timeout=2.0))
        sim = run_safe_round(vals, failed_nodes=[5])
        assert net.crashed_nodes == (5,)
        assert net.initiator_elections >= 1
        assert np.array_equal(sim.average, net.average)

    def test_initiator_crash_reelects(self):
        """Fig. 5: initiator posts once then crashes; §5.4 re-election
        over the wire converges to the survivors' average."""
        vals = _vals(8, 8, seed=9)
        sim = run_safe_round(vals, initiator_fails=True,
                             aggregation_timeout=2.0)
        net = _wire_round(vals, initiator_fails=True,
                          broker_kw=dict(aggregation_timeout=2.0))
        assert net.initiator_elections >= 1
        assert np.array_equal(sim.average, net.average)
        np.testing.assert_allclose(net.average, vals[1:].mean(0), atol=1e-3)


class TestBrokerHygiene:
    def test_unknown_session_is_an_error_not_a_crash(self):
        from repro.net import WireClient, wire as _w

        async def go():
            broker = SafeBroker()
            addr = await broker.start()
            try:
                c = await WireClient(*addr).connect()
                with pytest.raises(_w.WireError, match="unknown session"):
                    await c.request("get_stats", {"session": 999})
                # unserviceable sessions refused at the boundary
                with pytest.raises(_w.WireError, match="empty chain"):
                    await c.request("create_session",
                                    {"groups": {0: [1, 2, 3], 1: []}})
                # connection still serves after the errors
                made = await c.request("create_session",
                                       {"groups": {0: [1, 2, 3]}})
                assert made["session"] == 0
                await c.close()
            finally:
                await broker.stop()

        asyncio.run(go())

    def test_completed_rounds_free_their_sessions(self):
        """run_safe_round_net deletes its broker session: a long-lived
        broker must not accumulate one Controller per finished round."""
        from repro.net import WireClient, wire as _w

        async def go():
            broker = SafeBroker(progress_timeout=0.4, monitor_interval=0.1)
            addr = await broker.start()
            try:
                await run_safe_round_net(_vals(4, 4), addr)
                assert broker._sessions == {}  # torn down server-side
                c = await WireClient(*addr).connect()
                with pytest.raises(_w.WireError, match="unknown session"):
                    await c.request("get_stats", {"session": 0})
                await c.close()
            finally:
                await broker.stop()

        asyncio.run(go())

    def test_stop_unparks_forever_long_polls(self):
        """broker.stop() must cancel connection handlers parked on a
        timeout=None long-poll instead of leaking (or hanging
        wait_closed on newer Pythons)."""
        from repro.net import WireClient

        async def go():
            broker = SafeBroker()
            addr = await broker.start()
            c = await WireClient(*addr).connect()
            await c.request("create_session", {"groups": {0: [1, 2, 3]}})
            poll = asyncio.ensure_future(c.request(
                "get_average", {"session": 0, "timeout": None}))
            await asyncio.sleep(0.2)  # let it park on the broker
            assert not poll.done()
            await broker.stop()  # must return promptly
            with pytest.raises(Exception):
                await asyncio.wait_for(poll, 5.0)  # conn dropped cleanly
            await c.close()

        asyncio.run(go())

    def test_stray_to_node_rejected_and_monitor_survives(self):
        """A posting addressed outside the chain is refused at the RPC
        boundary (it could never be consumed or reposted around), so it
        can't poison the §5.3 monitor for other tenants."""
        from repro.net import WireClient, wire as _w

        async def go():
            broker = SafeBroker(progress_timeout=0.2, monitor_interval=0.05)
            addr = await broker.start()
            try:
                c = await WireClient(*addr).connect()
                await c.request("create_session", {"groups": {0: [1, 2, 3]}})
                with pytest.raises(_w.WireError, match="not in"):
                    await c.request("post_aggregate", {
                        "session": 0, "from_node": 1, "to_node": 99,
                        "group": 0,
                        "payload": np.zeros(4, np.uint32)})
                with pytest.raises(_w.WireError, match="unknown group"):
                    await c.request("post_aggregate", {
                        "session": 0, "from_node": 1, "to_node": 2,
                        "group": 7,
                        "payload": np.zeros(4, np.uint32)})
                await c.close()
                # monitor still alive and clean; a full round still works
                res = await run_safe_round_net(_vals(4, 4), addr)
                assert res.stats["aggregation_total"] == 4 * 4
                assert broker.monitor_errors == 0
            finally:
                await broker.stop()

        asyncio.run(go())

    def test_wire_round_rejects_insec(self):
        with pytest.raises(ValueError):
            _wire_round(_vals(4, 4), mode="insec")

    def test_two_sessions_are_isolated(self):
        """Two tenants on one broker: independent controllers, stats,
        and averages (the multi-session story at the wire level)."""
        vals_a, vals_b = _vals(4, 8, seed=11), _vals(4, 8, seed=12)

        async def go():
            broker = SafeBroker(progress_timeout=0.4, monitor_interval=0.1)
            addr = await broker.start()
            try:
                a, b = await asyncio.gather(
                    run_safe_round_net(vals_a, addr),
                    run_safe_round_net(vals_b, addr, learner_master=0x9999))
            finally:
                await broker.stop()
            return a, b

        a, b = asyncio.run(go())
        sim_a = run_safe_round(vals_a)
        sim_b = run_safe_round(vals_b, learner_master=0x9999)
        assert np.array_equal(a.average, sim_a.average)
        assert np.array_equal(b.average, sim_b.average)
        assert a.stats["aggregation_total"] == 4 * 4
        assert b.stats["aggregation_total"] == 4 * 4


class TestChunkedTransfer:
    """docs/PROTOCOL.md §6: multi-frame array streaming. Chunking is
    transport — bits, §5 message counts and failover semantics must be
    indistinguishable from the unchunked path."""

    def test_multi_chunk_bit_identical_and_counts(self):
        """V=103 over 16-word chunks (7 per transfer, ragged tail)."""
        vals = _vals(6, 103, seed=21)
        sim = run_safe_round(vals)
        net = _wire_round(vals, chunk_words=16)
        assert np.array_equal(sim.average, net.average)
        assert net.stats["aggregation_total"] == 4 * 6
        assert net.stats["transfers_completed"] == 7  # 6 hops + average
        assert net.stats["chunk_frames_in"] == 7 * 7

    def test_exact_chunk_boundary(self):
        """V an exact multiple of chunk_words: no empty trailing chunk."""
        vals = _vals(4, 64, seed=22)
        sim = run_safe_round(vals)
        net = _wire_round(vals, chunk_words=16)
        assert np.array_equal(sim.average, net.average)
        assert net.stats["chunk_frames_in"] == 5 * 4  # exactly 64/16 each

    def test_single_chunk_fallback(self):
        """Payload fits one chunk: the plain ops carry it, zero chunk
        frames on the wire."""
        vals = _vals(4, 8, seed=23)
        sim = run_safe_round(vals)
        net = _wire_round(vals, chunk_words=16)
        assert np.array_equal(sim.average, net.average)
        assert net.stats["chunk_frames_in"] == 0
        assert net.stats["chunk_frames_out"] == 0

    def test_chunked_weighted_and_dead_node(self):
        """A dead learner's chunked transfer is reposted around (§5.3)
        and the weighted closed form 4(n−f)+2f still holds."""
        vals = _vals(8, 48, seed=24)
        w = np.arange(1, 9, dtype=np.float32) * 100
        sim = run_safe_round(vals, failed_nodes=[3], weights=w)
        net = _wire_round(vals, failed_nodes=[3], weights=w, chunk_words=16)
        assert np.array_equal(sim.average, net.average)
        assert float(sim.weight_avg) == float(net.weight_avg)
        assert net.stats["aggregation_total"] == 4 * 7 + 2
        assert net.monitor_reposts == 1

    def test_dropped_chunks_retry_clean(self):
        """Drops hit individual chunk frames (they never reached the
        broker — at-most-once retry), bits and counts survive."""
        vals = _vals(8, 48, seed=25)
        sim = run_safe_round(vals)
        drop = DropInterceptor(p=0.1, seed=9)
        net = _wire_round(vals, chunk_words=16, interceptor=Chain(
            LatencyInterceptor(mean=0.001, seed=9), drop))
        assert np.array_equal(sim.average, net.average)
        assert net.stats["aggregation_total"] == 4 * 8
        assert drop.dropped > 0

    def test_crash_mid_upload_reelects(self):
        """A learner dies partway through streaming its aggregate (some
        chunks uploaded, transfer never completes): no posting exists,
        so §5.3 cannot fire — the round times out, §5.4 re-elects, and
        the survivors' retry publishes, bit-identical to a sim where
        that node was dead all along."""
        vals = _vals(8, 48, seed=26)
        # node 5 (non-initiator): 3 get_chunk + 1 get_aggregate frames,
        # then dies before its 2nd post_chunk — one chunk buffered
        churn = ChurnInterceptor({5: 5})
        net = _wire_round(vals, chunk_words=16, interceptor=churn,
                          broker_kw=dict(aggregation_timeout=2.0))
        sim = run_safe_round(vals, failed_nodes=[5])
        assert net.crashed_nodes == (5,)
        assert net.initiator_elections >= 1
        assert np.array_equal(sim.average, net.average)

    def test_reordered_duplicate_chunks_and_streaming(self):
        """Raw frames: chunks arrive out of order with a duplicate; the
        logical post fires exactly once, on completion; a chunk is
        downloadable *before* the upload completes (store-and-forward
        pipelining); the elided consume counts once."""
        from repro.net import WireClient

        payload = np.arange(40, dtype=np.uint32)
        cw = 16  # chunks [0:16] [16:32] [32:40], total 3

        def frame(seq):
            return {"session": 0, "op": "post_aggregate", "xfer": 77,
                    "seq": seq, "total": 3, "chunk_words": cw,
                    "from_node": 1, "to_node": 2, "group": 0,
                    "payload": payload[seq * cw:(seq + 1) * cw]}

        async def go():
            broker = SafeBroker()
            addr = await broker.start()
            try:
                c = await WireClient(*addr).connect()
                await c.request("create_session", {"groups": {0: [1, 2]}})
                r = await c.request("post_chunk", frame(2))  # tail first
                assert not r["complete"] and r["received"] == 1
                # streaming: the buffered chunk serves before completion
                g = await c.request("get_chunk", {
                    "session": 0, "kind": "get_aggregate", "node": 2,
                    "group": 0, "seq": 2, "words": cw, "timeout": 5.0})
                assert g["last"] and g["from_node"] == 1
                assert np.array_equal(g["payload"], payload[32:])
                st = await c.request("get_stats", {"session": 0})
                assert st["post_aggregate"] == 0  # not a message yet
                await c.request("post_chunk", frame(0))
                r = await c.request("post_chunk", frame(0))  # duplicate
                assert r["received"] == 2  # idempotent overwrite
                r = await c.request("post_chunk", frame(1))
                assert r["complete"]
                # at-least-once repeat AFTER completion (final ack lost):
                # idempotent re-ack, no fresh buffer, no second posting
                r = await c.request("post_chunk", frame(1))
                assert r["complete"] and r["received"] == 3
                st = await c.request("get_stats", {"session": 0})
                assert st["post_aggregate"] == 1
                assert st["transfers_completed"] == 1
                parts = [(await c.request("get_chunk", {
                    "session": 0, "kind": "get_aggregate", "node": 2,
                    "group": 0, "seq": s, "words": cw,
                    "timeout": 5.0}))["payload"] for s in range(3)]
                res = await c.request("get_aggregate", {
                    "session": 0, "node": 2, "group": 0,
                    "elide_payload": True, "timeout": 5.0})
                assert res["chunked"] is True and res["aggregate"] is None
                assert np.array_equal(np.concatenate(parts), payload)
                st = await c.request("get_stats", {"session": 0})
                assert st["get_aggregate"] == 1
                # post_average idempotency: the posted buffer is the
                # repeat record — a re-sent final chunk re-acks, never
                # re-executes the op (PROTOCOL.md §6 repeat rule)
                avg_frame = {"session": 0, "op": "post_average",
                             "xfer": 5, "seq": 0, "total": 1,
                             "chunk_words": cw, "node": 1, "group": 0,
                             "payload": np.zeros(8, np.float32)}
                r = await c.request("post_chunk", dict(avg_frame))
                assert r["complete"]
                r = await c.request("post_chunk", dict(avg_frame))
                assert r["complete"]
                st = await c.request("get_stats", {"session": 0})
                assert st["post_average"] == 1
                await c.close()
            finally:
                await broker.stop()

        asyncio.run(go())


ENGINE_WIRE_CODE = """
import asyncio, numpy as np, jax
from repro.core.types import ChainConfig
from repro.serve import AggregationEngine
from repro.net import SafeBroker, WireClient

mesh = jax.make_mesh((8,), ("data",))
n, V, S = 8, 32, 4
cfg = ChainConfig(num_learners=n, mode="safe")
engine = AggregationEngine(mesh, cfg, slots=S, payload_words=V)
rng = np.random.RandomState(0)

async def go():
    broker = SafeBroker(engine=engine)
    addr = await broker.start()
    try:
        clients = [await WireClient(*addr, node=t).connect()
                   for t in range(S)]
        tenant_vals = [rng.uniform(-1, 1, (n, V)).astype(np.float32)
                       for _ in range(S)]
        sids = []
        for t, c in enumerate(clients):
            sub = await c.request("submit_session", {
                "values": tenant_vals[t], "rounds": 2,
                "provisioning_seed": 0xC0FFEE + t,
                "learner_master": 0x5EED + t})
            sids.append(sub["sid"])
        for t, c in enumerate(clients):
            res = await c.request("wait_session",
                                  {"sid": sids[t], "timeout": 300.0})
            assert res["status"] == "done", res
            assert res["rounds"] == 2
            exp = tenant_vals[t].mean(0)
            for r in res["results"]:
                assert np.abs(r - exp).max() < 1e-3
        # wait_session is an idempotent read until the TTL prune: a
        # client whose first response was lost can re-fetch its results
        again = await clients[0].request("wait_session",
                                         {"sid": sids[0], "timeout": 1.0})
        assert again["status"] == "done" and again["rounds"] == 2
        # abandoned submissions (never waited on) are pruned after the
        # TTL instead of pinning their AggSession forever
        broker.engine_session_ttl = 0.0
        sub = await clients[0].request("submit_session", {
            "values": tenant_vals[0], "rounds": 1})
        abandoned = sub["sid"]
        # never waited on: the engine completes it, then the monitor's
        # TTL prune (ttl=0) must drop it without any further submits
        for _ in range(200):
            if (abandoned not in broker._engine_sessions
                    and abandoned not in broker._engine_done):
                break
            await asyncio.sleep(0.1)
        assert abandoned not in broker._engine_sessions, "abandoned session not pruned"
        broker.engine_session_ttl = 300.0  # new sessions must survive
        sub2 = await clients[0].request("submit_session", {
            "values": tenant_vals[0], "rounds": 1})
        res = await clients[0].request("wait_session",
                                       {"sid": sub2["sid"], "timeout": 300.0})
        assert res["status"] == "done"
        for c in clients:
            await c.close()
    finally:
        await broker.stop()

asyncio.run(go())
print("ENGINE_WIRE_OK")
"""


def test_engine_plane_over_wire():
    """S wire tenants batch through one AggregationEngine behind the
    broker (submit_session/wait_session), results correct per tenant."""
    out = run_multidevice(ENGINE_WIRE_CODE, devices=8)
    assert "ENGINE_WIRE_OK" in out
