"""End-to-end behaviour tests for the full system.

The flagship check: a multi-step SAFE-secured training run on a real mesh
produces the same learning curve as insecure aggregation (the protocol is
semantically transparent), while the control-plane simulation of the same
round count shows the paper's message complexity.
"""
import numpy as np

import pytest

from helpers import partial_manual_supported, run_multidevice
from repro.core.protocol import run_safe_round


@pytest.mark.skipif(not partial_manual_supported(), reason=
    "partial-manual shard_map (manual data + auto model) unsupported "
    "by this jax/XLA SPMD partitioner — see ARCHITECTURE.md")
def test_end_to_end_system():
    out = run_multidevice("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke_config
from repro.models import Model
from repro.core import make_aggregator
from repro.data import make_federated_batches
from repro.train.train_step import make_train_step
from repro.serve.engine import ServeEngine, Request

# ---- train with SAFE over 4 learners × 2-way TP -------------------------
mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = get_smoke_config("internlm2-1.8b")
model = Model(cfg)
agg = make_aggregator("safe", 4, axis="data")
bundle = make_train_step(model, agg, mesh, lr=3e-3)
stream = make_federated_batches(cfg, 4, 2, 64, seed=0)
# small fixed dataset, multiple epochs (cross-org FL trains repeatedly
# over each org's local data)
batches = [jnp.asarray(stream.global_batch(i)["tokens"]) for i in range(2)]
state = bundle.init_state_fn(model.init(jax.random.key(0)))
losses = []
for step in range(8):
    state, m = bundle.step_fn(state, batches[step % 2],
                              counter=step * (bundle.padded_size + 2))
    losses.append(float(m["loss"]))
assert losses[-1] < losses[0] - 0.5, f"insufficient learning: {losses}"

# ---- then serve the trained model ---------------------------------------
params = state["params"]
eng = ServeEngine(model, params, batch_slots=2, max_seq=64)
for i in range(3):
    eng.submit(Request(rid=i, prompt=np.arange(4 + i) % cfg.vocab, max_new=6))
eng.run_until_done()
assert eng.steps > 0
print("E2E_OK", losses[0], "->", losses[-1])
""", devices=8, timeout=1200)
    assert "E2E_OK" in out


def test_control_plane_matches_data_plane_average():
    """The message-level simulation and the device chain implement the
    same arithmetic: identical averages given identical inputs."""
    vals = np.random.RandomState(5).uniform(-1, 1, (4, 33)).astype(np.float32)
    sim = run_safe_round(vals, mode="safe").average
    out = run_multidevice("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import make_aggregator
mesh = jax.make_mesh((4,), ("data",))
vals = jnp.asarray(np.random.RandomState(5).uniform(-1, 1, (4, 33))
                   .astype(np.float32))
agg = make_aggregator("safe", 4)
out = np.asarray(agg.aggregate_sharded(mesh, vals))
print("AVG", ",".join(f"{x:.6f}" for x in out))
""", devices=4)
    got = np.array([float(x) for x in
                    out.split("AVG ")[1].strip().split(",")])
    np.testing.assert_allclose(got, sim, atol=3e-4)
