"""Minimal deterministic stand-in for the ``hypothesis`` package.

The container this repo tests on does not ship hypothesis and installing
packages is off-limits, so conftest.py registers this module under
``sys.modules['hypothesis']`` when the real package is missing. It
implements exactly the surface the test-suite uses — ``given``,
``settings``, ``assume`` and the ``integers`` / ``floats`` / ``lists``
strategies — as seeded random sampling: each decorated test runs
``max_examples`` times with examples drawn from a RNG seeded by the test
name, so failures reproduce across runs. It does none of hypothesis's
shrinking or database work; with the real package installed this module
is never imported.
"""
from __future__ import annotations

import functools
import inspect
import types
import zlib

import numpy as np

_DEFAULT_MAX_EXAMPLES = 25


class _Unsatisfied(Exception):
    """Raised by assume() to discard the current example."""


def assume(condition) -> bool:
    if not condition:
        raise _Unsatisfied()
    return True


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: np.random.RandomState):
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    span = int(max_value) - int(min_value)

    def draw(rng):
        # randint caps at int64 ranges; compose for the full-u64 strategies
        if span >= 2**62:
            lo = rng.randint(0, 2**31)
            hi = rng.randint(0, span // 2**31 + 1)
            return int(min_value) + min(lo + hi * 2**31, span)
        return int(min_value) + int(rng.randint(0, span + 1))

    return _Strategy(draw)


def floats(min_value: float, max_value: float, allow_nan: bool = False,
           width: int = 64) -> _Strategy:
    def draw(rng):
        x = rng.uniform(min_value, max_value)
        return float(np.float32(x)) if width == 32 else float(x)

    return _Strategy(draw)


def lists(elements: _Strategy, min_size: int = 0, max_size: int = 10) -> _Strategy:
    def draw(rng):
        k = int(rng.randint(min_size, max_size + 1))
        return [elements.draw(rng) for _ in range(k)]

    return _Strategy(draw)


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return deco


def given(*strats: _Strategy):
    def deco(fn):
        n_examples = getattr(fn, "_fallback_max_examples", _DEFAULT_MAX_EXAMPLES)

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):  # args = (self,) for method tests
            rng = np.random.RandomState(zlib.adler32(fn.__qualname__.encode()))
            ran = 0
            attempts = 0
            while ran < n_examples and attempts < n_examples * 50:
                attempts += 1
                drawn = [s.draw(rng) for s in strats]
                try:
                    fn(*args, *drawn, **kwargs)
                except _Unsatisfied:
                    continue
                ran += 1
            if ran == 0:
                raise RuntimeError(
                    f"{fn.__qualname__}: every generated example was "
                    "rejected by assume()")

        # pytest must not see the drawn parameters as fixtures: expose only
        # the leading params given does not supply (i.e. ``self``).
        params = list(inspect.signature(fn).parameters.values())
        keep = params[: len(params) - len(strats)]
        wrapper.__signature__ = inspect.Signature(keep)
        if hasattr(wrapper, "__wrapped__"):
            del wrapper.__wrapped__  # wraps() sets it; it re-exposes fn's sig
        return wrapper

    return deco


strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = integers
strategies.floats = floats
strategies.lists = lists
