"""Examples smoke: every script under examples/ must actually run.

Each example is executed as a real subprocess (``SAFE_SMOKE=1`` shrinks
round/step counts; 8 host devices so the sharded paths engage), exactly
the way the README tells a user to run it. A quickstart that bit-rots
is a broken front door — this is the regression net for it.
"""
import glob
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
EXAMPLES = sorted(glob.glob(os.path.join(REPO, "examples", "*.py")))

#: every example must be enumerated here — a new example is a new smoke
#: case by construction (the glob) and this set catches silent renames.
EXPECTED = {
    "failover_demo.py",
    "federated_training.py",
    "kernels_demo.py",
    "quickstart.py",
    "serving.py",
}


def test_every_example_is_smoked():
    assert {os.path.basename(p) for p in EXAMPLES} >= EXPECTED, (
        "an example disappeared — update tests/test_examples.py if the "
        "rename is intentional")


@pytest.mark.parametrize("path", EXAMPLES,
                         ids=[os.path.basename(p) for p in EXAMPLES])
def test_example_runs(path):
    env = dict(os.environ)
    env["SAFE_SMOKE"] = "1"
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run([sys.executable, path], capture_output=True,
                          text=True, timeout=900, env=env)
    assert proc.returncode == 0, (
        f"{os.path.basename(path)} failed (rc={proc.returncode}):\n"
        f"--- stdout ---\n{proc.stdout[-3000:]}\n"
        f"--- stderr ---\n{proc.stderr[-3000:]}")
