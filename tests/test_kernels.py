"""Pallas kernels vs pure-jnp oracles — integer kernels, so exact equality.

Sweeps shapes (including non-tile-aligned), counter bases, block sizes,
and key material. Runs in interpret mode on CPU (the kernels' TPU path is
identical modulo the Mosaic lowering).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.ops import (bon_mask, chain_combine,
                               chain_combine_batched, mask_add)
from repro.kernels.ref import (bon_mask_ref, chain_combine_batched_ref,
                               chain_combine_ref, mask_add_ref)
from repro.kernels.threefry_mask_add import mask_add as raw_mask_add

SHAPES = [1, 5, 127, 128, 129, 1000, 8192, 100_001]


@pytest.mark.parametrize("V", SHAPES)
def test_mask_add_shapes(V):
    rng = np.random.RandomState(V)
    x = jnp.asarray(rng.uniform(-100, 100, V).astype(np.float32))
    key = jnp.asarray(rng.randint(0, 2**32, 2, dtype=np.uint64).astype(np.uint32))
    got = mask_add(x, key, 42)
    want = mask_add_ref(x, key, 42)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("V", [3, 256, 4097])
@pytest.mark.parametrize("base", [0, 1, 2**31, 2**32 - 5])
def test_mask_add_counter_bases(V, base):
    x = jnp.asarray(np.random.RandomState(7).uniform(-1, 1, V).astype(np.float32))
    key = jnp.array([11, 13], jnp.uint32)
    np.testing.assert_array_equal(
        np.asarray(mask_add(x, key, base)),
        np.asarray(mask_add_ref(x, key, base)))


@pytest.mark.parametrize("block_rows", [8, 16, 64])
def test_mask_add_block_shapes(block_rows):
    V = 3000
    x = jnp.asarray(np.random.RandomState(1).uniform(-10, 10, V).astype(np.float32))
    key = jnp.array([5, 6], jnp.uint32)
    got = raw_mask_add(x, key, 0, block_rows=block_rows, interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(mask_add_ref(x, key, 0)))


@pytest.mark.parametrize("scale_bits", [8, 16, 24])
def test_mask_add_scale_bits(scale_bits):
    V = 500
    x = jnp.asarray(np.random.RandomState(2).uniform(-3, 3, V).astype(np.float32))
    key = jnp.array([1, 2], jnp.uint32)
    np.testing.assert_array_equal(
        np.asarray(mask_add(x, key, 0, scale_bits=scale_bits)),
        np.asarray(mask_add_ref(x, key, 0, scale_bits=scale_bits)))


@pytest.mark.parametrize("V", [7, 640, 9000])
def test_chain_combine(V):
    rng = np.random.RandomState(V)
    cipher = jnp.asarray(rng.randint(0, 2**32, V, dtype=np.uint64).astype(np.uint32))
    x = jnp.asarray(rng.uniform(-50, 50, V).astype(np.float32))
    kin = jnp.array([11, 22], jnp.uint32)
    kout = jnp.array([33, 44], jnp.uint32)
    np.testing.assert_array_equal(
        np.asarray(chain_combine(cipher, x, kin, kout, 9)),
        np.asarray(chain_combine_ref(cipher, x, kin, kout, 9)))


@pytest.mark.parametrize("S,V", [(1, 128), (3, 1000), (8, 257)])
def test_chain_combine_batched(S, V):
    """Session-batched kernel == oracle (exact, per-session keys/counters
    delivered via scalar prefetch)."""
    rng = np.random.RandomState(S * 1000 + V)
    cipher = jnp.asarray(rng.randint(0, 2**32, (S, V), dtype=np.uint64)
                         .astype(np.uint32))
    x = jnp.asarray(rng.uniform(-50, 50, (S, V)).astype(np.float32))
    kin = jnp.asarray(rng.randint(0, 2**32, (S, 2), dtype=np.uint64)
                      .astype(np.uint32))
    kout = jnp.asarray(rng.randint(0, 2**32, (S, 2), dtype=np.uint64)
                       .astype(np.uint32))
    bases = jnp.asarray(rng.randint(0, 2**32, (S,), dtype=np.uint64)
                        .astype(np.uint32))
    np.testing.assert_array_equal(
        np.asarray(chain_combine_batched(cipher, x, kin, kout, bases)),
        np.asarray(chain_combine_batched_ref(cipher, x, kin, kout, bases)))


def test_chain_combine_batched_matches_single_calls():
    """Row s of the batched kernel is bit-identical to a standalone
    chain_combine under session s's keys — the engine's independence
    invariant at the kernel level."""
    rng = np.random.RandomState(42)
    S, V = 4, 513
    cipher = jnp.asarray(rng.randint(0, 2**32, (S, V), dtype=np.uint64)
                         .astype(np.uint32))
    x = jnp.asarray(rng.uniform(-5, 5, (S, V)).astype(np.float32))
    kin = jnp.asarray(rng.randint(0, 2**32, (S, 2), dtype=np.uint64)
                      .astype(np.uint32))
    kout = jnp.asarray(rng.randint(0, 2**32, (S, 2), dtype=np.uint64)
                       .astype(np.uint32))
    bases = jnp.asarray(np.arange(S).astype(np.uint32) * 1000)
    batched = np.asarray(chain_combine_batched(cipher, x, kin, kout, bases))
    for s in range(S):
        np.testing.assert_array_equal(
            batched[s],
            np.asarray(chain_combine(cipher[s], x[s], kin[s], kout[s],
                                     bases[s])))


def test_chain_combine_roundtrip_semantics():
    """A full 4-hop kernel chain equals the sum of the inputs (masks and
    pads cancel) — the kernel-level version of the protocol test."""
    from repro.crypto.fixedpoint import FixedPointCodec
    from repro.crypto.prf import derive_pair_key, keystream_pair_lanes
    V, n = 1000, 4
    rng = np.random.RandomState(0)
    vals = [jnp.asarray(rng.uniform(-5, 5, V).astype(np.float32))
            for _ in range(n)]
    seed = jnp.array([9, 9], jnp.uint32)
    keys = [derive_pair_key(seed, i, (i + 1) % n) for i in range(n)]
    rkey = jnp.array([77, 88], jnp.uint32)
    R = keystream_pair_lanes(rkey, V, 0)
    cipher = mask_add(vals[0], keys[0], 0) + R  # initiator: enc + R
    for i in range(1, n):
        cipher = chain_combine(cipher, vals[i], keys[i - 1], keys[i], 0)
    codec = FixedPointCodec(16)
    total = codec.decode((cipher - keystream_pair_lanes(keys[n - 1], V, 0)) - R)
    np.testing.assert_allclose(np.asarray(total),
                               np.asarray(sum(vals)), atol=n / 2**16 + 1e-4)


@pytest.mark.parametrize("m", [1, 2, 8, 15])
def test_bon_mask(m):
    V = 2000
    rng = np.random.RandomState(m)
    x = jnp.asarray(rng.uniform(-50, 50, V).astype(np.float32))
    keys = jnp.asarray(rng.randint(0, 2**32, (m, 2), dtype=np.uint64)
                       .astype(np.uint32))
    signs = jnp.asarray(rng.choice([-1, 1], m).astype(np.int32))
    np.testing.assert_array_equal(
        np.asarray(bon_mask(x, keys, signs, 5)),
        np.asarray(bon_mask_ref(x, keys, signs, 5)))


def test_bon_pairwise_cancellation():
    """Opposite-sign pads cancel: bon_mask(x,+k) + bon_mask(y,-k) ==
    encode(x)+encode(y)."""
    from repro.crypto.fixedpoint import FixedPointCodec
    V = 512
    rng = np.random.RandomState(3)
    x = jnp.asarray(rng.uniform(-5, 5, V).astype(np.float32))
    y = jnp.asarray(rng.uniform(-5, 5, V).astype(np.float32))
    k = jnp.array([[123, 456]], jnp.uint32)
    a = bon_mask(x, k, jnp.array([1], jnp.int32), 0)
    b = bon_mask(y, k, jnp.array([-1], jnp.int32), 0)
    codec = FixedPointCodec(16)
    np.testing.assert_array_equal(
        np.asarray(a + b), np.asarray(codec.encode(x) + codec.encode(y)))


@given(st.integers(1, 4096), st.integers(0, 2**32 - 1), st.integers(0, 2**16))
@settings(max_examples=15, deadline=None)
def test_mask_add_property(V, k0, base):
    x = jnp.asarray(np.random.RandomState(V % 100).uniform(-10, 10, V)
                    .astype(np.float32))
    key = jnp.array([k0, k0 ^ 0xDEADBEEF], jnp.uint32)
    np.testing.assert_array_equal(
        np.asarray(mask_add(x, key, base)),
        np.asarray(mask_add_ref(x, key, base)))
