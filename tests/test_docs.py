"""Doc-sync: docs/PROTOCOL.md's tables must match the code registries.

The protocol book is the authoritative spec of the wire layer; these
tests parse its markdown tables and compare them — entry by entry, both
directions — against the registries in ``repro.net.wire``
(``OPCODE``/``VALUE_TAGS``/``ARRAY_DTYPES``) and
``repro.core.controller`` (``CALL_OPS``/``WAIT_KINDS``/``TIMED_OPS``/
``MessageStats`` plus ``HIER_OPS``/``HIER_TIMED_OPS``/``HierStats`` —
the §15 hierarchical parent plane) and ``repro.core.bon_controller``
(``BON_OPS``/``BON_TIMED_OPS``/``BonStats`` — the §14 baseline
plane). Adding an
opcode without documenting it, or editing the doc without changing the
code, fails tier-1.
"""
import dataclasses
import os
import re

import pytest

from repro.core.bon_controller import BON_OPS, BON_TIMED_OPS, BonStats
from repro.core.controller import (
    CALL_OPS, HIER_OPS, HIER_TIMED_OPS, HierStats, MessageStats, TIMED_OPS,
    WAIT_KINDS,
)
from repro.net import wire

DOC = os.path.join(os.path.dirname(__file__), "..", "docs", "PROTOCOL.md")


def _tables(text):
    """Every markdown table as (header_cells, [row_cells...])."""
    tables = []
    current = None
    for line in text.splitlines():
        line = line.strip()
        if line.startswith("|"):
            # markdown escapes a literal pipe inside a cell as \|
            line = line.replace("\\|", "\x00")
            cells = [c.strip().replace("\x00", "|")
                     for c in line.strip("|").split("|")]
            if all(re.fullmatch(r":?-+:?", c) for c in cells):
                continue  # separator row
            if current is None:
                current = (cells, [])
                tables.append(current)
            else:
                current[1].append(cells)
        else:
            current = None
    return tables


@pytest.fixture(scope="module")
def doc():
    with open(DOC) as f:
        return f.read()


@pytest.fixture(scope="module")
def tables(doc):
    by_header = {}
    for header, rows in _tables(doc):
        by_header[tuple(h.lower() for h in header[:2])] = (header, rows)
    return by_header


def _table(tables, first_two):
    assert first_two in tables, (
        f"PROTOCOL.md lost its {first_two} table (found: "
        f"{sorted(tables)})")
    return tables[first_two]


class TestOpcodeTable:
    def _rows(self, tables):
        header, rows = _table(tables, ("code", "op"))
        assert [h.lower() for h in header] == [
            "code", "op", "class", "counted", "timed"]
        return [dict(zip(["code", "op", "cls", "counted", "timed"], r))
                for r in rows]

    def test_codes_match_registry(self, tables):
        documented = {r["op"]: int(r["code"]) for r in self._rows(tables)}
        assert documented == wire.OPCODE, (
            "PROTOCOL.md §7 opcode table != wire.OPCODE — update BOTH "
            "the registry and the book")

    def test_classes_match_registries(self, tables):
        by_cls = {}
        for r in self._rows(tables):
            by_cls.setdefault(r["cls"], set()).add(r["op"])
        assert by_cls["call"] == set(CALL_OPS)
        assert by_cls["wait"] == set(WAIT_KINDS)
        assert by_cls["chunk"] == {"post_chunk", "get_chunk"}
        assert by_cls["engine"] == {"submit_session", "wait_session"}
        assert by_cls["bon"] == set(BON_OPS)
        assert by_cls["hier"] == set(HIER_OPS)
        assert by_cls["admin"] == (set(wire.OPS) - set(CALL_OPS)
                                   - set(WAIT_KINDS) - set(BON_OPS)
                                   - set(HIER_OPS)
                                   - by_cls["chunk"] - by_cls["engine"])

    def test_counted_column_is_messagestats(self, tables):
        counted = {r["op"] for r in self._rows(tables)
                   if r["counted"] == "yes"}
        # the §5 accounting: counted SAFE ops are exactly the
        # MessageStats fields (the controller's client ops), the §14
        # baseline's counted ops are exactly the BonStats fields, and
        # the §15 parent hop's are exactly the HierStats fields — the
        # three tallies never mix but every counted op lives in one
        fields = ({f.name for f in dataclasses.fields(MessageStats)}
                  | {f.name for f in dataclasses.fields(BonStats)}
                  | {f.name for f in dataclasses.fields(HierStats)})
        assert counted == fields
        assert counted == (set(CALL_OPS) | set(WAIT_KINDS) | set(BON_OPS)
                           | set(HIER_OPS))

    def test_timed_column_matches(self, tables):
        timed = {r["op"] for r in self._rows(tables) if r["timed"] == "yes"}
        assert timed == (set(TIMED_OPS) | set(BON_TIMED_OPS)
                         | set(HIER_TIMED_OPS))


class TestValueTagTable:
    def test_tags_match(self, tables):
        _, rows = _table(tables, ("tag", "name"))
        documented = {r[1]: int(r[0]) for r in rows}
        assert documented == wire.VALUE_TAGS, (
            "PROTOCOL.md §4 tag table != wire.VALUE_TAGS")


class TestDtypeTable:
    def test_dtypes_match(self, tables):
        _, rows = _table(tables, ("code", "dtype"))
        documented = {int(r[0]): r[1] for r in rows}
        assert documented == {c: dt.str for c, dt in
                              wire.ARRAY_DTYPES.items()}, (
            "PROTOCOL.md §5 dtype table != wire.ARRAY_DTYPES")


class TestScalars:
    def test_wire_version_pinned(self, doc):
        assert f"`WIRE_VERSION` (currently {wire.WIRE_VERSION})" in doc, (
            "PROTOCOL.md §9 must state the current WIRE_VERSION")

    def test_max_frame_pinned(self, doc):
        assert f"`MAX_FRAME` is {wire.MAX_FRAME >> 20} MiB" in doc, (
            "PROTOCOL.md §2 must state MAX_FRAME")
