"""Control-plane protocol simulation: correctness, §5 message formulas,
failover, subgroups, weighted averaging, privacy of the broker view."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.costs import DEEP_EDGE, EDGE
from repro.core.protocol import LearnerCrypto, run_safe_round
from repro.core.bon_protocol import run_bon_round


def _vals(n, V, seed=0):
    return np.random.RandomState(seed).uniform(-1, 1, (n, V)).astype(np.float32)


class TestBasicRound:
    def test_safe_average_exact(self):
        vals = _vals(8, 5)
        res = run_safe_round(vals, mode="safe")
        np.testing.assert_allclose(res.average, vals.mean(0), atol=1e-3)

    def test_message_count_4n(self):
        """§5.2: the basic algorithm is exactly 4n messages."""
        for n in (3, 5, 10, 17):
            res = run_safe_round(_vals(n, 3))
            assert res.stats.aggregation_total == 4 * n
            # 1 post_aggregate, 1 check, 1 get_aggregate per node;
            # initiator posts average, others get it
            assert res.stats.post_aggregate == n
            assert res.stats.check_aggregate == n
            assert res.stats.get_aggregate == n
            assert res.stats.post_average == 1
            assert res.stats.get_average == n - 1

    def test_insec_2n_messages(self):
        res = run_safe_round(_vals(6, 3), mode="insec")
        assert res.stats.aggregation_total == 2 * 6

    def test_saf_equals_safe_value(self):
        vals = _vals(7, 4)
        a = run_safe_round(vals, mode="safe").average
        b = run_safe_round(vals, mode="saf").average
        np.testing.assert_allclose(a, b, atol=1e-4)

    def test_min_three_learners(self):
        with pytest.raises(ValueError):
            run_safe_round(_vals(2, 3))
        with pytest.raises(ValueError):
            run_safe_round(_vals(8, 3), subgroups=4)  # groups of 2

    @given(st.integers(3, 12), st.integers(1, 20), st.integers(0, 1000))
    @settings(max_examples=15, deadline=None)
    def test_property_average(self, n, V, seed):
        vals = _vals(n, V, seed)
        res = run_safe_round(vals)
        np.testing.assert_allclose(res.average, vals.mean(0), atol=2e-3)


class TestFailover:
    def test_progress_failover_value_and_messages(self):
        """§5.3: f dead nodes -> average over survivors, 2 extra messages
        per failure, count = 4·(survivors) + 2f."""
        vals = _vals(9, 4)
        res = run_safe_round(vals, mode="safe", failed_nodes=[4, 6])
        mask = np.ones(9, bool)
        mask[[3, 5]] = False
        np.testing.assert_allclose(res.average, vals[mask].mean(0), atol=1e-3)
        assert res.monitor_reposts == 2
        assert res.stats.aggregation_total == 4 * 7 + 2 * 2

    def test_adjacent_failures(self):
        vals = _vals(8, 3)
        res = run_safe_round(vals, mode="safe", failed_nodes=[4, 5, 6])
        mask = np.ones(8, bool)
        mask[[3, 4, 5]] = False
        np.testing.assert_allclose(res.average, vals[mask].mean(0), atol=1e-3)

    def test_drop_to_three_survivors(self):
        """n−f ≥ 3 boundary (§5.3)."""
        vals = _vals(6, 3)
        res = run_safe_round(vals, failed_nodes=[2, 3, 4])
        mask = np.array([1, 0, 0, 0, 1, 1], bool)
        np.testing.assert_allclose(res.average, vals[mask].mean(0), atol=1e-3)

    def test_initiator_failover(self):
        """§5.4: initiator crash -> re-election, average over the rest,
        messages bounded by (i+1)(4n+2f+in)."""
        vals = _vals(10, 3)
        res = run_safe_round(vals, mode="safe", initiator_fails=True,
                             aggregation_timeout=2.0)
        np.testing.assert_allclose(res.average, vals[1:].mean(0), atol=1e-3)
        assert res.initiator_elections == 1
        n, i, f = 10, 1, 0
        assert res.stats.aggregation_total <= (i + 1) * (4 * n + 2 * f + i * n)


class TestSubgroups:
    def test_average_of_group_averages(self):
        vals = _vals(12, 4)
        res = run_safe_round(vals, subgroups=3)
        exp = np.mean([vals[0:4].mean(0), vals[4:8].mean(0),
                       vals[8:12].mean(0)], axis=0)
        np.testing.assert_allclose(res.average, exp, atol=1e-3)

    def test_message_count_4n_plus_g(self):
        """§5.5: one extra get_average per subgroup initiator."""
        res = run_safe_round(_vals(12, 2), subgroups=3)
        assert res.stats.aggregation_total == 4 * 12 + 3

    def test_parallel_groups_faster(self):
        """Subgrouping shortens the serial chain (paper §7.3 evaluates
        this on the deep-edge platform, where per-hop latency dominates
        — Figs. 19-20 show ~4.5s -> ~2s with 4 groups)."""
        vals = _vals(12, 64)
        t1 = run_safe_round(vals, subgroups=1, cost=DEEP_EDGE,
                            symmetric_only=True).virtual_time
        t4 = run_safe_round(vals, subgroups=4, cost=DEEP_EDGE,
                            symmetric_only=True).virtual_time
        assert t4 < 0.7 * t1, (t1, t4)


class TestWeighted:
    def test_weighted_average(self):
        """§5.6: Σwx/Σw without revealing individual weights."""
        vals = _vals(6, 5)
        w = np.array([1000, 200, 3000, 500, 800, 1500], np.float32)
        res = run_safe_round(vals, weights=w)
        np.testing.assert_allclose(res.average,
                                   np.average(vals, 0, weights=w), atol=1e-3)

    def test_no_extra_messages(self):
        base = run_safe_round(_vals(6, 5)).stats.aggregation_total
        w = np.ones(6, np.float32) * 7
        withw = run_safe_round(_vals(6, 5), weights=w).stats.aggregation_total
        assert base == withw


class TestPrivacy:
    def test_broker_never_sees_plaintext(self):
        """Every payload the controller stores must differ from the raw
        encoding (it is masked by R and/or the hop pad)."""
        from repro.core.controller import Controller
        from repro.crypto.np_impl import NpFixedPoint
        vals = _vals(5, 8)
        seen = []
        orig = Controller.post_aggregate

        def spy(self, from_node, to_node, payload, group=0, now=0.0):
            seen.append(np.array(payload))
            return orig(self, from_node, to_node, payload, group, now)

        Controller.post_aggregate = spy
        try:
            run_safe_round(vals, mode="safe")
        finally:
            Controller.post_aggregate = orig
        codec = NpFixedPoint(16)
        encodings = [codec.encode(v) for v in vals]
        partial_sums = []
        acc = np.zeros(8, np.uint32)
        old = np.seterr(over="ignore")
        for e in encodings:
            acc = acc + e
            partial_sums.append(acc.copy())
        np.seterr(**old)
        for payload in seen:
            for plain in encodings + partial_sums:
                assert not np.array_equal(payload, plain), \
                    "controller observed an unmasked (partial) aggregate"

    def test_saf_leaks_nothing_because_of_initiator_mask(self):
        """Even without hop encryption, the single mask R hides partial
        sums from the broker (SAF mode)."""
        from repro.core.controller import Controller
        vals = _vals(4, 6)
        seen = []
        orig = Controller.post_aggregate

        def spy(self, from_node, to_node, payload, group=0, now=0.0):
            seen.append(np.array(payload))
            return orig(self, from_node, to_node, payload, group, now)

        Controller.post_aggregate = spy
        try:
            run_safe_round(vals, mode="saf")
        finally:
            Controller.post_aggregate = orig
        from repro.crypto.np_impl import NpFixedPoint
        codec = NpFixedPoint(16)
        for payload, v in zip(seen, vals):
            assert not np.array_equal(payload, codec.encode(v))


class TestBON:
    def test_bon_average(self):
        vals = _vals(8, 6)
        res = run_bon_round(vals)
        np.testing.assert_allclose(res.average, vals.mean(0), atol=1e-3)

    def test_bon_dropout_recovery(self):
        vals = _vals(9, 4)
        res = run_bon_round(vals, failed_nodes=[3, 7])
        mask = np.ones(9, bool)
        mask[[2, 6]] = False
        np.testing.assert_allclose(res.average, vals[mask].mean(0), atol=1e-3)
        assert res.shares_reconstructed > 0

    def test_bon_quadratic_messages(self):
        """BON share traffic grows with n² (the paper's core complaint)."""
        m10 = run_bon_round(_vals(10, 2)).bytes_sent
        m20 = run_bon_round(_vals(20, 2)).bytes_sent
        assert m20 > 2.5 * m10  # super-linear

    def test_bon_slower_than_safe_at_scale(self):
        """Fig. 6: BON deteriorates by ~15 nodes where SAFE stays linear."""
        vals = _vals(15, 1)
        t_bon = run_bon_round(vals).virtual_time
        t_safe = run_safe_round(vals).virtual_time
        t_insec = run_safe_round(vals, mode="insec").virtual_time
        assert t_bon / t_insec > 5.0
        assert t_safe / t_insec < 5.0


class TestDeepEdge:
    def test_symmetric_only_faster_on_constrained(self):
        """§5.8/§7: pre-negotiated symmetric keys avoid the RSA unwrap
        that dominates on deep-edge hardware."""
        vals = _vals(6, 20)
        hybrid = run_safe_round(vals, cost=DEEP_EDGE, symmetric_only=False)
        prenegotiated = run_safe_round(vals, cost=DEEP_EDGE, symmetric_only=True)
        np.testing.assert_allclose(hybrid.average, prenegotiated.average,
                                   atol=1e-3)
        # pre-negotiation removes one RSA unwrap (~0.35 s on the Archer C7)
        # per hop — 6 hops here
        assert prenegotiated.virtual_time < hybrid.virtual_time - 6 * 0.3
