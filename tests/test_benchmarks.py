"""Benchmark result hygiene (ISSUE 8): one schema'd file per module.

``results/benchmarks/`` used to hold both ``BENCH_<module>.json``
(schema ``safe-bench/v1``) and stale unprefixed twins (``slo.json``,
``paper_scale.json``, …) that drifted out of date the moment a module
evolved. The contract now: :func:`benchmarks.common.save_json` stashes
unprefixed payloads in memory and the next ``save_bench_json`` folds
them into the module's BENCH file under ``payloads`` — only
``BENCH_``-prefixed names ever touch disk. These tests reject any
regression to twin-writing, in code and in the checked-in tree.
"""
import json
import os
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RESULTS = os.path.join(REPO, "results", "benchmarks")

sys.path.insert(0, REPO)  # `import benchmarks` from any pytest rootdir

from benchmarks import common  # noqa: E402


@pytest.fixture(autouse=True)
def _clean_stash():
    """Isolate each test from module-level row/payload accumulators."""
    common._payloads.clear()
    rows_before = list(common._rows)
    yield
    common._payloads.clear()
    common._rows[:] = rows_before


def test_results_dir_holds_only_bench_prefixed_files():
    """The checked-in tree must contain no unprefixed twins."""
    offenders = [f for f in os.listdir(RESULTS)
                 if not f.startswith("BENCH_")]
    assert offenders == [], (
        f"unprefixed benchmark outputs in results/benchmarks/: "
        f"{offenders} — route payloads through save_json + "
        f"save_bench_json (they land under the 'payloads' key)")


def test_unprefixed_save_json_writes_no_file(tmp_path, monkeypatch):
    monkeypatch.setattr(common, "RESULTS_DIR", str(tmp_path))
    path = common.save_json("rogue_module", {"x": 1})
    assert path == ""
    assert os.listdir(tmp_path) == []  # nothing hit disk
    assert common._payloads == {"rogue_module": {"x": 1}}


def test_save_bench_json_folds_payload_stash(tmp_path, monkeypatch):
    monkeypatch.setattr(common, "RESULTS_DIR", str(tmp_path))
    common.save_json("mod", {"detail": [1, 2]})
    common.save_json("mod_extra", {"more": True})
    path = common.save_bench_json("mod", [("mod/row", 1.0, "d")], "ok", 0.5)
    with open(path) as f:
        doc = json.load(f)
    assert doc["schema"] == common.BENCH_SCHEMA
    assert doc["payloads"] == {"mod": {"detail": [1, 2]},
                               "mod_extra": {"more": True}}
    assert doc["rows"] == [{"name": "mod/row", "us_per_call": 1.0,
                            "derived": "d"}]
    # the stash drained: the next module's BENCH file starts clean
    assert common._payloads == {}
    assert sorted(os.listdir(tmp_path)) == ["BENCH_mod.json"]


def test_checked_in_bench_files_parse_with_schema():
    for fname in sorted(os.listdir(RESULTS)):
        with open(os.path.join(RESULTS, fname)) as f:
            doc = json.load(f)
        assert doc.get("schema") == common.BENCH_SCHEMA, fname
        assert "rows" in doc and "status" in doc, fname
