"""Architecture-zoo smoke tests: reduced variant of each assigned arch,
one forward + one decode step on CPU; shape and finiteness asserted.
Decode/prefill cache consistency for representative families."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_arch_ids, get_config, get_smoke_config
from repro.models import Model

ARCHS = all_arch_ids()


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_validates(arch):
    cfg = get_config(arch)
    assert cfg.n_layers == cfg.n_units * len(cfg.pattern)
    assert cfg.param_count() > 0
    if cfg.uses_moe:
        assert cfg.active_param_count() < cfg.param_count()


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_decode(arch):
    cfg = get_smoke_config(arch)
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    B, S = 2, 64
    rng = jax.random.key(1)
    shape = (B, S, cfg.num_codebooks) if cfg.num_codebooks > 1 else (B, S)
    toks = jax.random.randint(rng, shape, 0, cfg.vocab)
    prefix = None
    if cfg.prefix_embeds:
        prefix = jax.random.normal(rng, (B, cfg.prefix_embeds, cfg.d_model),
                                   jnp.bfloat16)
    logits, aux = jax.jit(model.forward)(params, toks, prefix)
    S_out = S + cfg.prefix_embeds
    if cfg.num_codebooks > 1:
        assert logits.shape == (B, S_out, cfg.num_codebooks, cfg.vocab)
    else:
        assert logits.shape == (B, S_out, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), "NaN/inf in forward logits"
    assert bool(jnp.isfinite(aux))

    cache = model.init_cache(B, 32, prefilled=False)
    tok1 = toks[:, 0] if cfg.num_codebooks == 1 else toks[:, 0, :]
    dl, cache2 = jax.jit(model.decode_step)(params, tok1, cache)
    assert bool(jnp.all(jnp.isfinite(dl))), "NaN/inf in decode logits"
    # cache position advanced everywhere
    assert int(cache2[0]["pos"][0, 0]) == 1


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "gemma3-12b",
                                  "rwkv6-1.6b", "zamba2-2.7b",
                                  "qwen3-moe-235b-a22b", "musicgen-large"])
def test_decode_matches_forward(arch):
    """Token-by-token decode must reproduce the teacher-forced forward."""
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    params = model.init(jax.random.key(1))
    B, S = 1, 16
    shape = (B, S, cfg.num_codebooks) if cfg.num_codebooks > 1 else (B, S)
    toks = jax.random.randint(jax.random.key(2), shape, 0, cfg.vocab)
    full, _ = jax.jit(model.forward)(params, toks)
    cache = model.init_cache(B, S, prefilled=False)
    step = jax.jit(model.decode_step)
    scale = float(jnp.max(jnp.abs(full)))
    for t in range(S):
        tok_t = toks[:, t] if cfg.num_codebooks == 1 else toks[:, t, :]
        dl, cache = step(params, tok_t, cache)
        err = float(jnp.max(jnp.abs(dl - full[:, t])))
        assert err / scale < 2e-2, f"pos {t}: rel err {err/scale}"


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "gemma2-27b",
                                  "rwkv6-1.6b", "zamba2-2.7b"])
def test_prefill_then_decode(arch):
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    params = model.init(jax.random.key(3))
    B, S, S0 = 1, 16, 8
    toks = jax.random.randint(jax.random.key(4), (B, S), 0, cfg.vocab)
    full, _ = jax.jit(model.forward)(params, toks)
    scale = float(jnp.max(jnp.abs(full)))
    cache = model.init_cache(B, S, prefilled=False)
    pl, cache = jax.jit(model.prefill)(params, toks[:, :S0], cache=cache)
    assert float(jnp.max(jnp.abs(pl - full[:, S0 - 1]))) / scale < 2e-2
    step = jax.jit(model.decode_step)
    for t in range(S0, S):
        dl, cache = step(params, toks[:, t], cache)
        assert float(jnp.max(jnp.abs(dl - full[:, t]))) / scale < 2e-2


def test_sliding_window_masks_old_tokens():
    """A local layer must not attend beyond its window: far-past token
    perturbations cannot change the output."""
    cfg = get_smoke_config("gemma3-12b")
    cfg = dataclasses.replace(cfg, pattern=("local",), n_layers=1, window=8)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (1, 32), 0, cfg.vocab)
    toks2 = toks.at[0, 0].set((toks[0, 0] + 1) % cfg.vocab)
    a, _ = jax.jit(model.forward)(params, toks)
    b, _ = jax.jit(model.forward)(params, toks2)
    # positions >= window past the change are unaffected
    np.testing.assert_allclose(np.asarray(a[0, 9:]), np.asarray(b[0, 9:]),
                               atol=1e-6)
    # position 0 itself is affected
    assert float(jnp.max(jnp.abs(a[0, 0] - b[0, 0]))) > 1e-4


def test_moe_capacity_drops_are_bounded():
    """With capacity_factor 1.0 and adversarially unbalanced routing some
    tokens drop, but outputs stay finite and aux loss grows."""
    cfg = get_smoke_config("qwen3-moe-235b-a22b")
    moe = dataclasses.replace(cfg.moe, capacity_factor=1.0)
    cfg = dataclasses.replace(cfg, moe=moe)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    toks = jnp.zeros((2, 64), jnp.int32)  # identical tokens -> worst routing
    logits, aux = jax.jit(model.forward)(params, toks)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert float(aux) > 0


def test_zamba2_weight_sharing():
    """shared_attn blocks reuse ONE parameter set."""
    cfg = get_smoke_config("zamba2-2.7b")
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    assert "shared_attn" in params
    # the stacked placeholder for shared positions carries no weights
    for pos, kind in enumerate(cfg.pattern):
        if kind == "shared_attn":
            assert set(params["blocks"][pos].keys()) == {"_shared"}


def test_rwkv6_state_decode_is_constant_memory():
    cfg = get_smoke_config("rwkv6-1.6b")
    model = Model(cfg)
    cache = model.init_cache(2, 10_000, prefilled=True)
    leaves = jax.tree.leaves(cache)
    total = sum(np.prod(np.shape(l)) for l in leaves)
    assert total < 2**22, "rwkv6 cache must be O(1) in sequence length"
