"""Checkpointing: bit-exact save/restore, resume determinism."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.ckpt import latest_step, restore_checkpoint, save_checkpoint


def _tree(seed=0):
    rng = np.random.RandomState(seed)
    return {
        "a": jnp.asarray(rng.randn(4, 8).astype(np.float32)),
        "b": {"c": jnp.asarray(rng.randn(3), dtype=jnp.bfloat16),
              "d": jnp.asarray(rng.randint(0, 100, 5).astype(np.int32))},
        "e": jnp.zeros((), jnp.uint32),
    }


def test_roundtrip_bit_exact(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 7, tree, extra={"step": 7, "note": "x"})
    restored, extra = restore_checkpoint(str(tmp_path), 7, tree)
    assert extra["step"] == 7 and extra["note"] == "x"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_latest_step(tmp_path):
    assert latest_step(str(tmp_path)) is None
    for s in (5, 20, 10):
        save_checkpoint(str(tmp_path), s, _tree())
    assert latest_step(str(tmp_path)) == 20


def test_structure_mismatch_rejected(tmp_path):
    save_checkpoint(str(tmp_path), 1, _tree())
    bad = {"a": jnp.zeros((4, 8))}
    with pytest.raises(AssertionError):
        restore_checkpoint(str(tmp_path), 1, bad)


def test_shape_mismatch_rejected(tmp_path):
    save_checkpoint(str(tmp_path), 1, _tree())
    bad = _tree()
    bad["a"] = jnp.zeros((2, 2), jnp.float32)
    with pytest.raises(AssertionError):
        restore_checkpoint(str(tmp_path), 1, bad)


def test_train_resume_deterministic(tmp_path):
    """Training 4 steps == training 2, checkpointing, restoring, 2 more."""
    from repro.configs import get_smoke_config
    from repro.models import Model
    from repro.optim import AdamW
    from repro.train.loss import next_token_loss

    cfg = get_smoke_config("internlm2-1.8b")
    model = Model(cfg)
    opt = AdamW(lr=1e-3)
    toks = jnp.asarray(np.random.RandomState(0)
                       .randint(0, cfg.vocab, (5, 2, 32)).astype(np.int32))

    @jax.jit
    def step(params, state, batch):
        def loss_fn(p):
            logits, aux = model.forward(p, batch)
            return next_token_loss(logits, batch) + aux
        loss, g = jax.value_and_grad(loss_fn)(params)
        params, state = opt.update(g, state, params)
        return params, state, loss

    p1 = model.init(jax.random.key(0))
    s1 = opt.init(p1)
    for i in range(4):
        p1, s1, _ = step(p1, s1, toks[i])

    p2 = model.init(jax.random.key(0))
    s2 = opt.init(p2)
    for i in range(2):
        p2, s2, _ = step(p2, s2, toks[i])
    save_checkpoint(str(tmp_path), 2, {"p": p2, "s": s2})
    restored, _ = restore_checkpoint(str(tmp_path), 2, {"p": p2, "s": s2})
    p3, s3 = restored["p"], restored["s"]
    for i in range(2, 4):
        p3, s3, _ = step(p3, s3, toks[i])

    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p3)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
