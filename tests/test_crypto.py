"""Crypto substrate: Threefry PRF + fixed-point codec properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.crypto.prf import (
    threefry2x32, keystream, keystream_pair_lanes, derive_key,
    derive_pair_key, RoundCounter)
from repro.crypto.fixedpoint import FixedPointCodec
from repro.crypto.np_impl import (
    threefry2x32_np, keystream_np, keystream_pair_lanes_np, derive_key_np,
    derive_pair_key_np, keystream_slice_np, NpFixedPoint)


class TestThreefry:
    def test_known_vector(self):
        # Threefry-2x32 (20 rounds) reference vector from the Random123
        # distribution: zero key, zero counter.
        y0, y1 = threefry2x32(jnp.zeros(2, jnp.uint32), jnp.uint32(0),
                              jnp.uint32(0))
        assert (int(y0), int(y1)) == (0x6B200159, 0x99BA4EFE)

    def test_matches_numpy_mirror(self):
        rng = np.random.RandomState(0)
        for _ in range(10):
            key = rng.randint(0, 2**32, 2, dtype=np.uint64).astype(np.uint32)
            x = rng.randint(0, 2**32, 64, dtype=np.uint64).astype(np.uint32)
            j0, j1 = threefry2x32(jnp.asarray(key), jnp.asarray(x),
                                  jnp.zeros_like(jnp.asarray(x)))
            n0, n1 = threefry2x32_np(key, x, np.zeros_like(x))
            np.testing.assert_array_equal(np.asarray(j0), n0)
            np.testing.assert_array_equal(np.asarray(j1), n1)

    @given(st.integers(0, 2**32 - 1), st.integers(0, 2**32 - 1),
           st.integers(1, 300), st.integers(0, 2**20))
    @settings(max_examples=25, deadline=None)
    def test_keystream_jnp_np_agree(self, k0, k1, n, base):
        key = np.array([k0, k1], np.uint32)
        np.testing.assert_array_equal(
            np.asarray(keystream(jnp.asarray(key), n, base)),
            keystream_np(key, n, base))
        np.testing.assert_array_equal(
            np.asarray(keystream_pair_lanes(jnp.asarray(key), n, base)),
            keystream_pair_lanes_np(key, n, base))

    def test_keystream_disjoint_counters_differ(self):
        key = jnp.array([1, 2], jnp.uint32)
        a = np.asarray(keystream(key, 128, 0))
        b = np.asarray(keystream(key, 128, 128))
        assert not np.array_equal(a, b)

    def test_derive_key_domain_separation(self):
        m = jnp.array([7, 8], jnp.uint32)
        assert not np.array_equal(np.asarray(derive_key(m, 1)),
                                  np.asarray(derive_key(m, 2)))
        np.testing.assert_array_equal(np.asarray(derive_key(m, 3)),
                                      derive_key_np(np.array([7, 8], np.uint32), 3))

    def test_pair_key_symmetric_derivation(self):
        seed = jnp.array([3, 4], jnp.uint32)
        np.testing.assert_array_equal(
            np.asarray(derive_pair_key(seed, 2, 5)),
            derive_pair_key_np(np.array([3, 4], np.uint32), 2, 5))

    def test_round_counter_no_overlap(self):
        rc = RoundCounter()
        a = rc.reserve(1000)
        b = rc.reserve(500)
        assert b == a + 1000
        with pytest.raises(OverflowError):
            rc.reserve(2**32)

    def test_round_counter_overflow_guard(self):
        """The guard must fire *before* any counter in [base, base+n)
        wraps past 2**32 (a wrap would reuse one-time pads), must not
        poison the allocator, and must allow exactly the full space."""
        rc = RoundCounter()
        base = rc.reserve(2**32 - 4)  # nearly drain the space
        assert base == 0 and rc.remaining == 4
        with pytest.raises(OverflowError):
            rc.reserve(5)  # would wrap — refused pre-mutation
        assert rc.remaining == 4  # refusal left no partial reservation
        tail = rc.reserve(4)  # the exact remainder still fits
        assert tail == 2**32 - 4 and rc.remaining == 0
        with pytest.raises(OverflowError):
            rc.reserve(1)
        assert rc.reserve(0) == 2**32  # degenerate: no words, no wrap
        with pytest.raises(ValueError):
            rc.reserve(-1)

    def test_keystream_uniformity(self):
        """Coarse sanity: keystream bytes should look uniform (mean and
        bit balance), i.e. the pad actually masks."""
        ks = np.asarray(keystream(jnp.array([9, 9], jnp.uint32), 1 << 14))
        bits = np.unpackbits(ks.view(np.uint8))
        assert abs(bits.mean() - 0.5) < 0.01
        assert abs(ks.astype(np.float64).mean() / 2**32 - 0.5) < 0.02


class TestKeystreamSeekability:
    """The streaming chunk-combine rests on one property: slicing the
    keystream at an arbitrary word offset (``keystream_slice_np``)
    yields exactly the words of the single full-length stream — so a
    chunk-by-chunk decrypt/re-encrypt is bit-identical to the
    whole-vector one. counter_base is in two-word blocks, so odd
    offsets land mid-block; both parities must hold."""

    @given(st.integers(0, 2**32 - 1), st.integers(0, 2**32 - 1),
           st.integers(1, 257), st.integers(0, 2**20),
           st.lists(st.integers(0, 256), min_size=0, max_size=8))
    @settings(max_examples=50, deadline=None)
    def test_concatenated_slices_equal_full_stream(self, k0, k1, n, base,
                                                   cuts):
        key = np.array([k0, k1], np.uint32)
        full = keystream_pair_lanes_np(key, n, base)
        bounds = [0] + sorted(min(c, n) for c in cuts) + [n]
        parts = [keystream_slice_np(key, b - a, a, base)
                 for a, b in zip(bounds, bounds[1:])]
        np.testing.assert_array_equal(np.concatenate(parts), full)

    @pytest.mark.parametrize("chunk_words", [1, 2, 3, 16, 64])
    def test_chunk_edges(self, chunk_words):
        """The exact chunking the wire plane performs: V=103 over
        chunk_words-sized slices (ragged tail, odd offsets for odd
        chunk sizes) reassembles the full pad at every counter base."""
        key = np.array([0xDEAD, 0xBEEF], np.uint32)
        V = 103
        for base in (0, 1, 7, 2**31):
            full = keystream_pair_lanes_np(key, V, base)
            for k in range((V + chunk_words - 1) // chunk_words):
                start = k * chunk_words
                stop = min(start + chunk_words, V)
                np.testing.assert_array_equal(
                    keystream_slice_np(key, stop - start, start, base),
                    full[start:stop])

    def test_empty_slices(self):
        key = np.array([1, 2], np.uint32)
        assert keystream_slice_np(key, 0, 0, 0).size == 0
        assert keystream_slice_np(key, 0, 17, 5).size == 0
        # an empty slice between two non-empty ones changes nothing
        full = keystream_pair_lanes_np(key, 9, 3)
        parts = [keystream_slice_np(key, 4, 0, 3),
                 keystream_slice_np(key, 0, 4, 3),
                 keystream_slice_np(key, 5, 4, 3)]
        np.testing.assert_array_equal(np.concatenate(parts), full)

    def test_learner_crypto_pad_slice_matches_pad(self):
        """The machine-level wrapper: pad_slice == pad[start:stop] for
        the hop-pad keys the learners actually derive."""
        from repro.core.machines import LearnerCrypto

        crypto = LearnerCrypto(3, 0xC0FFEE, 0x5EED)
        V, counter = 103, 777
        full = crypto.pad(2, 3, V, counter)
        for start, stop in ((0, 16), (16, 33), (33, 103), (7, 8), (50, 50)):
            np.testing.assert_array_equal(
                crypto.pad_slice(2, 3, start, stop - start, counter),
                full[start:stop])


class TestFixedPoint:
    @given(st.lists(st.floats(-1000, 1000, allow_nan=False, width=32),
                    min_size=1, max_size=64),
           st.integers(8, 24))
    @settings(max_examples=50, deadline=None)
    def test_roundtrip(self, xs, bits):
        from hypothesis import assume
        codec = FixedPointCodec(bits)
        # codec contract: |x| must fit the ring headroom
        assume(max(abs(v) for v in xs) < codec.max_abs_value(1))
        x = jnp.asarray(np.asarray(xs, np.float32))
        dec = np.asarray(codec.decode(codec.encode(x)))
        np.testing.assert_allclose(dec, np.asarray(xs, np.float32),
                                   atol=1.0 / 2**bits + 1e-6)

    @given(st.integers(2, 64), st.integers(0, 2**31 - 1))
    @settings(max_examples=30, deadline=None)
    def test_sum_exactness_in_ring(self, n, seed):
        """Ring sums are exact: encode+add == add+encode to codec
        resolution — the property masking relies on."""
        rng = np.random.RandomState(seed % (2**31 - 1))
        codec = FixedPointCodec(16)
        xs = rng.uniform(-10, 10, (n, 17)).astype(np.float32)
        acc = jnp.zeros(17, jnp.uint32)
        for row in xs:
            acc = acc + codec.encode(jnp.asarray(row))
        dec = np.asarray(codec.decode(acc))
        np.testing.assert_allclose(dec, xs.sum(0), atol=n / 2**16 + 1e-4)

    def test_mask_cancels_exactly(self):
        """cipher - pad == plain, bit-exact (one-time-pad property)."""
        codec = FixedPointCodec(16)
        x = jnp.asarray(np.random.RandomState(0).uniform(-5, 5, 100)
                        .astype(np.float32))
        pad = keystream(jnp.array([1, 2], jnp.uint32), 100)
        cipher = codec.encode(x) + pad
        np.testing.assert_array_equal(np.asarray(cipher - pad),
                                      np.asarray(codec.encode(x)))

    def test_np_mirror(self):
        codec = FixedPointCodec(16)
        ncodec = NpFixedPoint(16)
        x = np.random.RandomState(1).uniform(-100, 100, 256).astype(np.float32)
        np.testing.assert_array_equal(np.asarray(codec.encode(jnp.asarray(x))),
                                      ncodec.encode(x))
