"""SPMD data-plane chain aggregation on an 8-host-device mesh.

Runs in a subprocess (jax device count locks at first init; the main
pytest process stays single-device)."""
import pytest

from helpers import run_multidevice

CHAIN_CODE = """
import jax, jax.numpy as jnp, numpy as np
from repro.core import make_aggregator

mesh = jax.make_mesh((8,), ("data",))
n, V = 8, 37
rng = np.random.RandomState(0)
vals = jnp.asarray(rng.uniform(-2, 2, size=(n, V)).astype(np.float32))
expected = np.mean(np.asarray(vals), axis=0)

def check(name, agg, exp=None, **kw):
    out = np.asarray(agg.aggregate_sharded(mesh, vals, **kw))
    e = expected if exp is None else exp
    err = float(np.max(np.abs(out - e)))
    assert err < 1e-3, f"{name}: err {err}"
    print(name, "ok")

for mode in ["insec", "saf", "safe", "bon"]:
    check(mode, make_aggregator(mode, n))

check("pipelined", make_aggregator("safe", n, pipelined=True))

exp2 = (np.mean(np.asarray(vals)[:4], 0) + np.mean(np.asarray(vals)[4:], 0)) / 2
check("subgroups", make_aggregator("safe", n, subgroups=2), exp=exp2)

alive = jnp.array([1,1,1,0,1,0,1,1], jnp.float32)
mask = np.asarray(alive) > 0
check("failover", make_aggregator("safe", n),
      exp=np.asarray(vals)[mask].mean(0), alive=alive)

alive0 = jnp.array([0,1,1,1,1,1,1,1], jnp.float32)
check("init-failover", make_aggregator("safe", n),
      exp=np.asarray(vals)[1:].mean(0), alive=alive0)

w = jnp.asarray(rng.uniform(1, 10, size=(n,)).astype(np.float32))
check("weighted", make_aggregator("safe", n, weighted=True),
      exp=np.average(np.asarray(vals), 0, weights=np.asarray(w)), weights=w)

alive_b = jnp.array([1,1,0,1,1,1,0,1], jnp.float32)
maskb = np.asarray(alive_b) > 0
check("bon-failover", make_aggregator("bon", n),
      exp=np.asarray(vals)[maskb].mean(0), alive=alive_b)

# pipelined+subgroups compose
check("pipelined-subgroups",
      make_aggregator("safe", n, pipelined=True, subgroups=2), exp=exp2)

# pipelined failover
check("pipelined-failover", make_aggregator("safe", n, pipelined=True),
      exp=np.asarray(vals)[mask].mean(0), alive=alive)

# §8 initiator rotation: correct for every offset, also composed with a
# dead rank landing exactly on the rotated initiator slot
from repro.core import ChainConfig, make_round_keys
from repro.core.chain import chain_aggregate_sequential
from jax.sharding import PartitionSpec as P
cfgr = ChainConfig(num_learners=n, mode="safe")
for rot in (1, 3, 7):
    def pr(v, rot=rot):
        keys = make_round_keys(0xC0FFEE, 0x5EED, 0)
        return chain_aggregate_sequential(v.reshape(-1), keys, cfgr, rotate=rot)
    f = jax.shard_map(pr, mesh=mesh, in_specs=P("data"), out_specs=P(),
                      axis_names=frozenset({"data"}), check_vma=False)
    with jax.set_mesh(mesh):
        out = np.asarray(jax.jit(f)(vals))
    assert np.max(np.abs(out - expected)) < 1e-3, f"rotate={rot}"
def prf_(v):
    keys = make_round_keys(0xC0FFEE, 0x5EED, 0)
    a = jnp.array([1,1,1,0,1,1,1,1], jnp.float32)
    return chain_aggregate_sequential(v.reshape(-1), keys, cfgr, alive=a,
                                      rotate=3)
f = jax.shard_map(prf_, mesh=mesh, in_specs=P("data"), out_specs=P(),
                  axis_names=frozenset({"data"}), check_vma=False)
with jax.set_mesh(mesh):
    out = np.asarray(jax.jit(f)(vals))
m3 = np.ones(n, bool); m3[3] = False
assert np.max(np.abs(out - np.asarray(vals)[m3].mean(0))) < 1e-3
print("rotation ok")
print("ALL_CHAIN_OK")
"""

HIERARCHICAL_CODE = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.core import make_aggregator
devs = np.asarray(jax.devices()[:8]).reshape(2, 4)
mesh = Mesh(devs, ("pod", "data"))
n, V = 4, 19
rng = np.random.RandomState(1)
# one value matrix per pod; hierarchical = mean over pods of pod means
vals = jnp.asarray(rng.uniform(-1, 1, size=(8, V)).astype(np.float32))
agg = make_aggregator("safe", n, axis="data", pod_axis="pod")
from jax.sharding import PartitionSpec as P
def per_rank(v):
    return agg.aggregate(v.reshape(-1), 0)
f = jax.shard_map(per_rank, mesh=mesh, in_specs=P(("pod","data")),
                  out_specs=P(), axis_names=frozenset({"pod","data"}),
                  check_vma=False)
with jax.set_mesh(mesh):
    out = np.asarray(jax.jit(f)(vals))
exp = (np.asarray(vals)[:4].mean(0) + np.asarray(vals)[4:].mean(0)) / 2
err = float(np.max(np.abs(out - exp)))
assert err < 1e-3, err
print("HIERARCHICAL_OK")
"""

PRIVACY_CODE = """
import jax, jax.numpy as jnp, numpy as np
from repro.core import ChainConfig, make_round_keys
from repro.core.chain import chain_aggregate_sequential
from repro.crypto.fixedpoint import FixedPointCodec
from jax.sharding import PartitionSpec as P

# Capture what actually crosses the wire: run the chain but return every
# rank's outgoing value; check none equals an unmasked partial sum.
mesh = jax.make_mesh((4,), ("data",))
n, V = 4, 16
cfg = ChainConfig(num_learners=n, mode="safe")
rng = np.random.RandomState(0)
vals = jnp.asarray(rng.uniform(-1, 1, (n, V)).astype(np.float32))

def per_rank(v):
    v = v.reshape(-1)
    keys = make_round_keys(0xC0FFEE, 0x5EED, 0)
    out = chain_aggregate_sequential(v, keys, cfg)
    return out
f = jax.shard_map(per_rank, mesh=mesh, in_specs=P("data"), out_specs=P(),
                  axis_names=frozenset({"data"}), check_vma=False)
with jax.set_mesh(mesh):
    avg = np.asarray(jax.jit(f)(vals))
np.testing.assert_allclose(avg, np.asarray(vals).mean(0), atol=1e-3)

# determinism: same counter -> same masks -> identical result bits
with jax.set_mesh(mesh):
    avg2 = np.asarray(jax.jit(f)(vals))
np.testing.assert_array_equal(avg, avg2)
print("DEVICE_PRIVACY_OK")
"""


def test_chain_all_modes_multidevice():
    out = run_multidevice(CHAIN_CODE, devices=8)
    assert "ALL_CHAIN_OK" in out


def test_hierarchical_pod_axis():
    out = run_multidevice(HIERARCHICAL_CODE, devices=8)
    assert "HIERARCHICAL_OK" in out


def test_device_chain_determinism():
    out = run_multidevice(PRIVACY_CODE, devices=8)
    assert "DEVICE_PRIVACY_OK" in out
