"""Test helpers: multi-device subprocess runner.

jax locks the host device count at first init, and the main pytest
process must see ONE device (smoke tests). Anything needing a mesh runs
in a child process with XLA_FLAGS set before jax imports.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def partial_manual_supported() -> bool:
    """True when shard_map with *partial* manual axes (manual 'data' +
    auto 'model') works. jax < 0.6's XLA SPMD partitioner cannot lower
    axis_index/collectives inside a partial-manual region ("PartitionId
    instruction is not supported..." / manual-subgroup check failures) —
    tensor-parallel train tests are skipped there. Fully-manual regions
    (the whole aggregation core) work on every supported jax."""
    import jax
    return jax.__version_info__ >= (0, 6, 0)


def run_multidevice(code: str, devices: int = 8, timeout: int = 900) -> str:
    """Run ``code`` in a child python with N host devices; returns stdout.
    Raises on nonzero exit (stderr tail included)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env)
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode}):\n"
            f"--- stdout ---\n{proc.stdout[-3000:]}\n"
            f"--- stderr ---\n{proc.stderr[-3000:]}")
    return proc.stdout
