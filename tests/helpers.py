"""Test helpers: multi-device subprocess runner.

jax locks the host device count at first init, and the main pytest
process must see ONE device (smoke tests). Anything needing a mesh runs
in a child process with XLA_FLAGS set before jax imports.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_multidevice(code: str, devices: int = 8, timeout: int = 900) -> str:
    """Run ``code`` in a child python with N host devices; returns stdout.
    Raises on nonzero exit (stderr tail included)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env)
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode}):\n"
            f"--- stdout ---\n{proc.stdout[-3000:]}\n"
            f"--- stderr ---\n{proc.stderr[-3000:]}")
    return proc.stdout
