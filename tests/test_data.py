"""Data pipeline: determinism, non-IID structure, codebook layout."""
import numpy as np

from repro.data import FederatedTokenStream


def test_deterministic_and_resumable():
    kw = dict(vocab=512, num_learners=4, batch_per_learner=2, seq_len=32,
              seed=7)
    a = FederatedTokenStream(**kw)
    b = FederatedTokenStream(**kw)
    for l in (0, 3):
        for step in (0, 5, 100):
            np.testing.assert_array_equal(
                a.learner_batch(l, step)["tokens"],
                b.learner_batch(l, step)["tokens"])


def test_learners_have_distinct_distributions():
    s = FederatedTokenStream(vocab=512, num_learners=4, batch_per_learner=4,
                             seq_len=128, alpha=0.1, seed=0)
    hists = []
    for l in range(4):
        toks = np.concatenate([s.learner_batch(l, i)["tokens"].ravel()
                               for i in range(3)])
        h, _ = np.histogram(toks % 512, bins=64, density=True)
        hists.append(h)
    # non-IID: at least one pair of learners differs substantially
    dists = [np.abs(hists[i] - hists[j]).sum()
             for i in range(4) for j in range(i + 1, 4)]
    assert max(dists) > 0.05


def test_steps_differ():
    s = FederatedTokenStream(vocab=512, num_learners=2, batch_per_learner=1,
                             seq_len=64, seed=0)
    a = s.learner_batch(0, 0)["tokens"]
    b = s.learner_batch(0, 1)["tokens"]
    assert not np.array_equal(a, b)


def test_codebooks_layout():
    s = FederatedTokenStream(vocab=256, num_learners=2, batch_per_learner=2,
                             seq_len=16, num_codebooks=4, seed=0)
    t = s.learner_batch(0, 0)["tokens"]
    assert t.shape == (2, 16, 4)
    assert t.min() >= 0 and t.max() < 256


def test_global_batch_shape_and_weights():
    s = FederatedTokenStream(vocab=128, num_learners=3, batch_per_learner=2,
                             seq_len=8, seed=1)
    gb = s.global_batch(0)
    assert gb["tokens"].shape == (3, 2, 8)
    assert gb["weights"].shape == (3,)
    assert (gb["weights"] > 0).all()
