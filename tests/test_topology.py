"""Topology layer: cross-plane agreement (sim vs device), failover edge
cases, privacy validation, and the host AliveTracker.

The acceptance property: the discrete-event sim and the device data
plane must agree on successor maps and elected initiators for flat,
subgroup, and failover configurations — both planes now read them from
``repro.topology``, and these tests pin the agreement down:

  * pure-function agreement — the device election formula
    (``elect_initiator_local`` with xp=jax.numpy, exactly what
    core/chain.py traces) against the host/sim formula (xp=numpy, what
    core/protocol.py's runner uses);
  * end-to-end agreement — published averages of the two planes compared
    directly for the same failover configurations (subprocess mesh).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import run_multidevice
from repro.core.protocol import run_safe_round
from repro.core.types import ChainConfig
from repro.topology import (
    AliveTracker,
    HierarchicalTopology,
    RingTopology,
    elect_initiator_local,
    make_topology,
)

TOPOLOGIES = [
    pytest.param(RingTopology(8, 1), id="flat8"),
    pytest.param(RingTopology(12, 3), id="subgroups12x3"),
    pytest.param(RingTopology(9, 3), id="subgroups9x3"),
]


def _alive_patterns(n):
    """All-alive, dead head, dead run, lone survivor per tail group."""
    pats = [np.ones(n, np.float32)]
    a = np.ones(n, np.float32); a[0] = 0
    pats.append(a)
    a = np.ones(n, np.float32); a[2:5] = 0
    pats.append(a)
    a = np.zeros(n, np.float32); a[0] = 1; a[n - 1] = 1
    pats.append(a)
    return pats


class TestCrossPlaneAgreement:
    @pytest.mark.parametrize("topo", TOPOLOGIES)
    def test_successor_maps_agree(self, topo):
        """Device ppermute schedule == sim chain order, rank for rank."""
        perm = dict(topo.ring_permutation())       # device plane schedule
        smap = topo.successor_map()
        for r in range(topo.num_learners):
            assert perm[r] == smap[r]
        for g, chain in topo.group_chains(node_base=1).items():  # sim view
            for i, node in enumerate(chain):
                assert smap[node - 1] + 1 == chain[(i + 1) % len(chain)]

    @pytest.mark.parametrize("topo", TOPOLOGIES)
    def test_elected_initiators_agree(self, topo):
        """jnp (device-traced) and numpy (sim/host) election formulas
        pick the same initiator for every alive pattern × rotation."""
        n, m = topo.num_learners, topo.group_size
        for alive in _alive_patterns(n):
            for rot in range(m):
                host = topo.elect_initiators(alive, rot)
                device = []
                for g in range(topo.subgroups):
                    ga = jnp.asarray(topo.group_alive(alive, g))
                    loc = int(elect_initiator_local(ga, rot, xp=jnp))
                    device.append(g * m + loc)
                # groups with no survivor are degenerate (never run);
                # compare only groups that still have an alive member
                for g in range(topo.subgroups):
                    if topo.group_alive(alive, g).sum() > 0:
                        assert host[g] == device[g], (alive, rot, g)

    def test_hierarchical_delegates_to_pod_rings(self):
        topo = make_topology(4, 1, pods=2)
        assert isinstance(topo, HierarchicalTopology)
        assert topo.num_learners == 8
        # pod-local rings: successor never crosses a pod boundary
        smap = topo.successor_map()
        for r in range(8):
            assert smap[r] // 4 == r // 4
        chains = topo.group_chains(node_base=1)
        assert chains[0][0] == [1, 2, 3, 4]
        assert chains[1][0] == [5, 6, 7, 8]
        alive = np.ones(8, np.float32)
        alive[4] = 0  # pod 1's first rank dead
        inits = topo.elect_initiators(alive)
        assert inits[0] == [0] and inits[1] == [5]

    def test_published_averages_agree_across_planes(self):
        """End-to-end: sim and device publish the same average for flat,
        subgroup, and failover (incl. dead-initiator) configurations."""
        out = run_multidevice("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import make_aggregator
from repro.core.protocol import run_safe_round
mesh = jax.make_mesh((8,), ("data",))
rng = np.random.RandomState(0)
n, V = 8, 33
for subgroups, failed in [(1, []), (1, [4, 6]), (1, [1]),
                          (2, [2]), (2, [1])]:
    vals = rng.uniform(-1, 1, (n, V)).astype(np.float32)
    sim = run_safe_round(vals, subgroups=subgroups, failed_nodes=failed,
                         aggregation_timeout=2.0)
    alive = np.ones(n, np.float32)
    alive[[f - 1 for f in failed]] = 0
    agg = make_aggregator("safe", n, subgroups=subgroups)
    dev = np.asarray(agg.aggregate_sharded(mesh, jnp.asarray(vals),
                                           alive=jnp.asarray(alive)))
    err = float(np.abs(sim.average - dev).max())
    assert err < 2e-3, (subgroups, failed, err)
print("CROSS_PLANE_OK")
""", devices=8)
        assert "CROSS_PLANE_OK" in out


class TestFailoverEdgeCases:
    @pytest.mark.parametrize("subgroups", [1, 2])
    def test_dead_initiator_reelection(self, subgroups):
        """§5.4: the elected initiator is dead before the round — the sim
        times out, re-elects, and still publishes the survivor mean."""
        n, V = 8, 5
        vals = np.random.RandomState(1).uniform(-1, 1, (n, V)).astype(np.float32)
        topo = RingTopology(n, subgroups)
        dead = topo.elect_initiators()[0] + 1  # node id of group-0 initiator
        res = run_safe_round(vals, subgroups=subgroups, failed_nodes=[dead],
                             aggregation_timeout=2.0)
        mask = np.ones(n, bool)
        mask[dead - 1] = False
        if subgroups == 1:
            exp = vals[mask].mean(0)
        else:
            m = n // subgroups
            exp = np.mean([vals[g * m:(g + 1) * m][mask[g * m:(g + 1) * m]].mean(0)
                           for g in range(subgroups)], axis=0)
        np.testing.assert_allclose(res.average, exp, atol=2e-3)
        assert res.initiator_elections >= 1

    def test_all_but_one_dead_subgroup_sim(self):
        """A subgroup reduced to one survivor still completes: the lone
        node self-elects and its value is the group average (§5.3/§5.4)."""
        n, V = 8, 4
        vals = np.random.RandomState(2).uniform(-1, 1, (n, V)).astype(np.float32)
        res = run_safe_round(vals, subgroups=2, failed_nodes=[5, 6, 8],
                             aggregation_timeout=2.0)
        exp = np.mean([vals[0:4].mean(0), vals[6]], axis=0)
        np.testing.assert_allclose(res.average, exp, atol=2e-3)

    def test_all_but_one_dead_subgroup_device(self):
        out = run_multidevice("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import make_aggregator
mesh = jax.make_mesh((8,), ("data",))
n, V = 8, 21
vals = np.random.RandomState(3).uniform(-1, 1, (n, V)).astype(np.float32)
alive = jnp.array([1, 1, 1, 1, 0, 0, 1, 0], jnp.float32)
agg = make_aggregator("safe", n, subgroups=2)
out = np.asarray(agg.aggregate_sharded(mesh, jnp.asarray(vals), alive=alive))
exp = np.mean([vals[0:4].mean(0), vals[6]], axis=0)
assert np.abs(out - exp).max() < 1e-3
print("LONE_SURVIVOR_DEVICE_OK")
""", devices=8)
        assert "LONE_SURVIVOR_DEVICE_OK" in out

    def test_hierarchical_pod_averages(self):
        """§5.10 sim plane: per-pod rounds averaged at the parent equal
        the mean of pod means — and pod initiators come from the shared
        topology objects."""
        topo = make_topology(4, 1, pods=2)
        n, V = 4, 6
        vals = np.random.RandomState(4).uniform(-1, 1, (8, V)).astype(np.float32)
        pod_avgs = []
        for p in range(2):
            res = run_safe_round(vals[p * n:(p + 1) * n])
            pod_avgs.append(res.average)
        parent = np.mean(pod_avgs, axis=0)
        exp = np.mean([vals[:4].mean(0), vals[4:].mean(0)], axis=0)
        np.testing.assert_allclose(parent, exp, atol=2e-3)
        assert topo.elect_initiators()[0] == [0]
        assert topo.elect_initiators()[1] == [4]


class TestPrivacyValidation:
    def test_chainconfig_minimum_three(self):
        with pytest.raises(ValueError):
            ChainConfig(num_learners=2, mode="safe")
        with pytest.raises(ValueError):
            ChainConfig(num_learners=2, mode="saf")
        ChainConfig(num_learners=2, mode="insec")  # baseline: no bound

    @pytest.mark.parametrize("n,subgroups", [(8, 4), (6, 3), (4, 2)])
    def test_chainconfig_subgroup_privacy(self, n, subgroups):
        with pytest.raises(ValueError):
            ChainConfig(num_learners=n, subgroups=subgroups, mode="safe")

    def test_topology_divisibility(self):
        with pytest.raises(ValueError):
            RingTopology(8, 3)

    def test_sim_runner_delegates_validation(self):
        vals = np.zeros((8, 3), np.float32)
        with pytest.raises(ValueError):
            run_safe_round(vals, subgroups=4)  # groups of 2


class TestAliveTracker:
    def test_strikes_and_compaction(self):
        topo = RingTopology(8, 2)
        trk = AliveTracker(topo, max_strikes=2)
        trk.report_failure(3)
        assert trk.alive()[3] == 1.0  # one strike is not dead yet
        trk.report_failure(3)
        assert trk.alive()[3] == 0.0
        chains = trk.compact_chains(node_base=1)
        assert chains[0] == [1, 2, 3]  # node 4 (rank 3) compacted out
        assert chains[1] == [5, 6, 7, 8]
        assert trk.survivors() == 7
        trk.report_recovery(3)
        assert trk.survivors() == 8

    def test_degraded_group_detection(self):
        topo = RingTopology(8, 2)
        trk = AliveTracker(topo)
        for r in (4, 5):
            trk.report_failure(r)
        assert trk.degraded_groups() == [1]  # 2 alive < privacy bound 3

    def test_election_tracks_deaths(self):
        topo = RingTopology(6, 1)
        trk = AliveTracker(topo)
        assert trk.elect_initiators() == [0]
        trk.report_failure(0)
        assert trk.elect_initiators() == [1]
